//! Hermetic in-tree subset of the `anyhow` API.
//!
//! The offline build environment has no registry access, so the crate
//! graph must close over path dependencies only. This shim provides the
//! slice of `anyhow` the workspace actually uses:
//!
//!   * `anyhow::Error` — a context-chain error (no backtraces),
//!   * `anyhow::Result<T>`,
//!   * the `Context` extension trait (`.context`, `.with_context`) on
//!     `Result<_, E: std::error::Error>`, `Result<_, anyhow::Error>`,
//!     and `Option<T>`,
//!   * the `anyhow!`, `bail!`, and `ensure!` macros,
//!   * `Error::msg` and `From<E: std::error::Error + Send + Sync>`.
//!
//! Display prints the outermost message; `{:#}` prints the whole chain
//! separated by `: `, matching anyhow's alternate formatting. Like the
//! real crate, `Error` deliberately does NOT implement
//! `std::error::Error` (the blanket `From` impl would conflict).

use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A chain of error messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    fn from_std<E: std::error::Error + ?Sized>(e: &E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

/// Extension trait adding `.context` / `.with_context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T>
    for std::result::Result<T, E>
{
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from_std(&e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from_std(&e).context(f()))
    }
}

impl<T> Context<T> for Result<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn context_chains() {
        let r: Result<()> = Err(io_err()).context("reading weights");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "reading weights");
        assert_eq!(format!("{e:#}"), "reading weights: disk on fire");
    }

    #[test]
    fn option_context() {
        let r: Result<i32> = None.with_context(|| format!("missing {}", 7));
        assert_eq!(format!("{}", r.unwrap_err()), "missing 7");
        let ok: Result<i32> = Some(3).context("unused");
        assert_eq!(ok.unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "x must be positive, got -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too big");
        let e = anyhow!("standalone {}", 9);
        assert_eq!(format!("{e}"), "standalone 9");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }

    #[test]
    fn nested_anyhow_context() {
        let inner: Result<()> = Err(Error::msg("inner"));
        let e = inner.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(e.chain().count(), 2);
    }
}
