//! Stub of the vendored `xla` (PJRT) crate.
//!
//! The hermetic build environment has neither the third_party XLA fork
//! nor a C++ toolchain, but the `pjrt` cargo feature must keep the PJRT
//! backend *compiling* so the seam stays honest. This crate mirrors the
//! subset of the real crate's API that `dvi::runtime::pjrt` uses:
//!
//!   * `PjRtClient::cpu`, `compile`, `buffer_from_host_buffer`
//!   * `PjRtLoadedExecutable::execute_b`, `client`
//!   * `PjRtBuffer::to_literal_sync`, `Literal::to_vec`
//!   * `HloModuleProto::from_text_file`, `XlaComputation::from_proto`
//!
//! Every constructor returns an error, so the types below are
//! uninhabited past the entry points and the method bodies are
//! unreachable (`match self.void {}`). Deployments with the real fork
//! replace the `[dependencies] xla` path in `rust/Cargo.toml`.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT is unavailable in this build (rust/vendor/xla-stub); \
         point the `xla` path dependency at the real third_party fork"
            .to_string(),
    ))
}

/// Uninhabited: no stub value of these types can ever be constructed.
#[derive(Debug, Clone, Copy)]
pub enum Void {}

#[derive(Debug)]
pub struct PjRtClient {
    void: Void,
}

#[derive(Debug)]
pub struct PjRtBuffer {
    void: Void,
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    void: Void,
}

#[derive(Debug)]
pub struct Literal {
    void: Void,
}

#[derive(Debug)]
pub struct HloModuleProto {
    void: Void,
}

#[derive(Debug)]
pub struct XlaComputation {
    void: Void,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self.void {}
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        match self.void {}
    }
}

impl PjRtLoadedExecutable {
    pub fn client(&self) -> &PjRtClient {
        match self.void {}
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.void {}
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self.void {}
    }
}

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        match self.void {}
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match proto.void {}
    }
}
