//! Engine-level integration tests (need `make artifacts`).
//!
//! The headline property: every speculative engine is LOSSLESS — for any
//! prompt it must emit exactly the greedy AR baseline's token sequence.
//! Plus: DVI tuple-logging invariants and online-learning progress.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use dvi::engine::Engine;
use dvi::harness::{load_prompts, make_engine};
use dvi::learner::{Objective, ReplayBuffer, Schedule, Trainer};
use dvi::runtime::Runtime;

fn artifacts_dir() -> PathBuf {
    std::env::var("DVI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

fn runtime() -> Arc<Runtime> {
    Arc::new(Runtime::load(&artifacts_dir(), None).expect("runtime"))
}

fn prompts(rt: &Runtime, task: &str, n: usize) -> Vec<(Vec<u32>, usize)> {
    load_prompts(rt, task)
        .unwrap()
        .samples
        .iter()
        .take(n)
        .map(|s| (s.prompt.clone(), s.max_new))
        .collect()
}

#[test]
fn all_engines_lossless_vs_ar() {
    if !have_artifacts() {
        eprintln!("SKIP all_engines_lossless_vs_ar: run `make artifacts`");
        return;
    }
    let rt = runtime();
    let cases: Vec<(Vec<u32>, usize)> = ["qa", "translation", "rag"]
        .iter()
        .flat_map(|t| prompts(&rt, t, 3))
        .collect();

    let mut ar = make_engine(rt.clone(), "ar").unwrap();
    let golden: Vec<Vec<u32>> = cases
        .iter()
        .map(|(p, n)| ar.generate(p, *n).unwrap().tokens)
        .collect();

    let needs: &[(&str, &str)] = &[
        ("dvi", "draft_step"),
        ("pld", "target_verify_block"),
        ("sps", "sps_prefill"),
        ("medusa", "medusa_heads"),
        ("hydra", "hydra_chain"),
        ("eagle", "eagle_step"),
    ];
    for (method, required) in needs {
        if !rt.has_artifact(required) {
            eprintln!("SKIP method {method}: artifact '{required}' not exported");
            continue;
        }
        let mut eng = make_engine(rt.clone(), method).unwrap();
        for ((prompt, max_new), want) in cases.iter().zip(&golden) {
            let got = eng.generate(prompt, *max_new).unwrap().tokens;
            assert_eq!(
                &got, want,
                "{method} diverged from AR on prompt {:?}...",
                &prompt[..prompt.len().min(8)]
            );
        }
    }
}

#[test]
fn dvi_tuples_follow_reward_pattern() {
    if !have_artifacts() {
        eprintln!("SKIP dvi_tuples_follow_reward_pattern");
        return;
    }
    let rt = runtime();
    let buffer = Arc::new(Mutex::new(ReplayBuffer::new(4096)));
    let mut eng = dvi::engine::dvi::DviEngine::new(rt.clone())
        .unwrap()
        .with_buffer(buffer.clone());
    let cases = prompts(&rt, "qa", 4);
    let mut total_steps = 0usize;
    for (p, n) in &cases {
        let r = eng.generate(p, *n).unwrap();
        total_steps += r.steps.iter().filter(|s| s.drafted > 0).count();
        // every verification round logs at least 1 and at most k tuples
        for s in &r.steps {
            assert!(s.accepted <= s.drafted);
            assert!(s.committed >= 1);
        }
    }
    let buf = buffer.lock().unwrap();
    assert!(buf.len() > 0, "no tuples logged");
    assert!(buf.len() <= total_steps * 4, "more tuples than k*rounds");
    // rewards are only 0/1 (enforced by type, sanity-check distribution)
    let mr = buf.mean_reward();
    assert!((0.0..=1.0).contains(&mr));
}

#[test]
fn online_kl_training_increases_acceptance() {
    if !have_artifacts() {
        eprintln!("SKIP online_kl_training_increases_acceptance");
        return;
    }
    let rt = runtime();
    let buffer = Arc::new(Mutex::new(ReplayBuffer::new(8192)));
    let mut trainer = Trainer::new(
        rt.clone(), buffer.clone(), Schedule::new(Objective::KlOnly), 42)
        .unwrap();
    trainer.reset().unwrap();
    let mut eng = dvi::engine::dvi::DviEngine::new(rt.clone())
        .unwrap()
        .with_buffer(buffer);

    let stream = load_prompts(&rt, "stream").unwrap();
    let n_prompts = 90;
    for s in stream.samples.iter().take(n_prompts) {
        eng.generate(&s.prompt, s.max_new).unwrap();
        trainer.maybe_train().unwrap();
    }
    assert!(trainer.steps_done > 20, "too few optimizer steps ran");
    // Judge on the trainer's batch-acceptance curve: each point averages a
    // whole minibatch (mixed tasks), so it is far less noisy than
    // per-prompt engine acceptance, which swings with the task mix.
    let curve = trainer.accept_curve();
    let w = 15.min(curve.len() / 2);
    let mean = |v: &[(f64, f64)]| {
        v.iter().map(|(_, a)| a).sum::<f64>() / v.len() as f64
    };
    let a0 = mean(&curve[..w]);
    let a1 = mean(&curve[curve.len() - w..]);
    assert!(
        a1 > a0 - 0.05,
        "batch acceptance degraded under online KD: {a0:.3} -> {a1:.3}"
    );
    // Losslessness must hold even mid-training.
    let mut ar = make_engine(rt.clone(), "ar").unwrap();
    for (p, n) in prompts(&rt, "qa", 2) {
        let want = ar.generate(&p, n).unwrap().tokens;
        let got = eng.generate(&p, n).unwrap().tokens;
        assert_eq!(got, want, "DVI lost losslessness after training");
    }
}

#[test]
fn capacity_guard_stops_cleanly() {
    if !have_artifacts() {
        eprintln!("SKIP capacity_guard_stops_cleanly");
        return;
    }
    let rt = runtime();
    let max_seq = rt.manifest.model_usize("max_seq").unwrap();
    let (p, _) = prompts(&rt, "mt", 1)[0].clone();
    let mut eng = make_engine(rt, "dvi").unwrap();
    // Ask for far more tokens than capacity; must not error or overrun.
    let r = eng.generate(&p, 10_000).unwrap();
    assert!(p.len() + r.tokens.len() <= max_seq + 8);
}
