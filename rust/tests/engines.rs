//! Engine-level integration tests — hermetic, always on.
//!
//! Every test runs against the pure-Rust reference backend
//! (`Runtime::load_reference`): no artifacts directory, no Python, no
//! XLA, zero skips. The headline property: every speculative engine is
//! LOSSLESS — for any prompt it must emit exactly the greedy AR
//! baseline's token sequence. Plus: DVI tuple-logging invariants,
//! online-learning progress, and the KV capacity guard.
//!
//! The PJRT path is exercised separately by `tests/parity.rs` when
//! `DVI_ARTIFACTS` points at a real export.

use std::sync::{Arc, Mutex};

use dvi::engine::Engine;
use dvi::harness::{load_prompts, make_engine, METHODS};
use dvi::learner::{Objective, ReplayBuffer, Schedule, Trainer};
use dvi::runtime::Runtime;

fn runtime() -> Arc<Runtime> {
    Arc::new(Runtime::load_reference(0xD5EED).expect("reference runtime"))
}

fn prompts(rt: &Runtime, task: &str, n: usize) -> Vec<(Vec<u32>, usize)> {
    load_prompts(rt, task)
        .unwrap()
        .samples
        .iter()
        .take(n)
        .map(|s| (s.prompt.clone(), s.max_new))
        .collect()
}

#[test]
fn all_engines_lossless_vs_ar() {
    let rt = runtime();
    let cases: Vec<(Vec<u32>, usize)> = ["qa", "translation", "rag"]
        .iter()
        .flat_map(|t| prompts(&rt, t, 3))
        .collect();
    assert_eq!(cases.len(), 9, "reference workloads must exist");

    let mut ar = make_engine(rt.clone(), "ar").unwrap();
    let golden: Vec<Vec<u32>> = cases
        .iter()
        .map(|(p, n)| ar.generate(p, *n).unwrap().tokens)
        .collect();
    assert!(
        golden.iter().any(|g| !g.is_empty()),
        "AR baseline generated nothing"
    );

    // All seven methods, no skips: the reference backend exports every
    // artifact unconditionally.
    for method in METHODS {
        let mut eng = make_engine(rt.clone(), method).unwrap();
        for ((prompt, max_new), want) in cases.iter().zip(&golden) {
            let got = eng.generate(prompt, *max_new).unwrap().tokens;
            assert_eq!(
                &got, want,
                "{method} diverged from AR on prompt {:?}...",
                &prompt[..prompt.len().min(8)]
            );
        }
    }
}

#[test]
fn dvi_tuples_follow_reward_pattern() {
    let rt = runtime();
    // The tuple bound must come from the engine's configured proposal
    // depth, not a hardcoded k=4.
    let k_spec = rt.manifest.spec_usize("k_spec").unwrap();
    let buffer = Arc::new(Mutex::new(ReplayBuffer::new(4096)));
    let mut eng = dvi::engine::dvi::DviEngine::new(rt.clone())
        .unwrap()
        .with_buffer(buffer.clone());
    assert_eq!(eng.k_spec, k_spec, "engine must read k_spec from the manifest");
    let cases = prompts(&rt, "qa", 4);
    let mut total_steps = 0usize;
    for (p, n) in &cases {
        let r = eng.generate(p, *n).unwrap();
        total_steps += r.steps.iter().filter(|s| s.drafted > 0).count();
        // every verification round drafts exactly k_spec and commits >= 1
        for s in &r.steps {
            assert_eq!(s.drafted, k_spec);
            assert!(s.accepted <= s.drafted);
            assert!(s.committed >= 1 && s.committed <= k_spec + 1);
        }
    }
    let buf = buffer.lock().unwrap();
    assert!(buf.len() > 0, "no tuples logged");
    assert!(
        buf.len() <= total_steps * k_spec,
        "more tuples than k_spec*rounds ({} > {} * {})",
        buf.len(), total_steps, k_spec
    );
    assert_eq!(buf.pushed as usize, buf.len(), "no eviction expected at 4096");
    // rewards are only 0/1 (enforced by type, sanity-check distribution)
    let mr = buf.mean_reward();
    assert!((0.0..=1.0).contains(&mr));
}

#[test]
fn online_kl_training_increases_acceptance() {
    let rt = runtime();
    let buffer = Arc::new(Mutex::new(ReplayBuffer::new(8192)));
    let mut trainer = Trainer::new(
        rt.clone(), buffer.clone(), Schedule::new(Objective::KlOnly), 42)
        .unwrap();
    trainer.reset().unwrap();
    let mut eng = dvi::engine::dvi::DviEngine::new(rt.clone())
        .unwrap()
        .with_buffer(buffer);

    let stream = load_prompts(&rt, "stream").unwrap();
    let n_prompts = 90;
    for s in stream.samples.iter().take(n_prompts) {
        eng.generate(&s.prompt, s.max_new).unwrap();
        trainer.maybe_train().unwrap();
    }
    assert!(trainer.steps_done > 20, "too few optimizer steps ran");
    // Judge on the trainer's batch-acceptance curve: each point averages a
    // whole minibatch (mixed tasks), so it is far less noisy than
    // per-prompt engine acceptance, which swings with the task mix.
    let curve = trainer.accept_curve();
    let w = 15.min(curve.len() / 2);
    let mean = |v: &[(f64, f64)]| {
        v.iter().map(|(_, a)| a).sum::<f64>() / v.len() as f64
    };
    let a0 = mean(&curve[..w]);
    let a1 = mean(&curve[curve.len() - w..]);
    assert!(
        a1 > a0 - 0.05,
        "batch acceptance degraded under online KD: {a0:.3} -> {a1:.3}"
    );
    // Losslessness must hold even mid-training.
    let mut ar = make_engine(rt.clone(), "ar").unwrap();
    for (p, n) in prompts(&rt, "qa", 2) {
        let want = ar.generate(&p, n).unwrap().tokens;
        let got = eng.generate(&p, n).unwrap().tokens;
        assert_eq!(got, want, "DVI lost losslessness after training");
    }
}

#[test]
fn capacity_guard_stops_cleanly() {
    let rt = runtime();
    let max_seq = rt.manifest.model_usize("max_seq").unwrap();
    let (p, _) = prompts(&rt, "mt", 1)[0].clone();
    let mut eng = make_engine(rt, "dvi").unwrap();
    // Ask for far more tokens than capacity; must not error or overrun.
    let r = eng.generate(&p, 10_000).unwrap();
    assert!(p.len() + r.tokens.len() <= max_seq + 8);
}

/// The fused draft_block path and the per-step draft path must agree:
/// both are greedy rollouts of the same shallow stack + LoRA head.
#[test]
fn fused_draft_block_matches_per_step_path() {
    let rt = runtime();
    let cases = prompts(&rt, "qa", 3);

    // Engine A: default (uses draft_block when exported — it is).
    let mut fused = dvi::engine::dvi::DviEngine::new(rt.clone()).unwrap();
    // Engine B: force the per-step path.
    let mut stepwise = dvi::engine::dvi::DviEngine::new(rt.clone())
        .unwrap()
        .without_draft_block();

    for (p, n) in &cases {
        let a = fused.generate(p, *n).unwrap();
        let b = stepwise.generate(p, *n).unwrap();
        assert_eq!(a.tokens, b.tokens, "fused draft diverged from per-step");
        assert_eq!(
            a.steps.iter().map(|s| s.accepted).collect::<Vec<_>>(),
            b.steps.iter().map(|s| s.accepted).collect::<Vec<_>>(),
        );
    }
}

/// Two runtimes built from the same seed must generate identically;
/// a different seed must (overwhelmingly) generate differently.
#[test]
fn reference_runtime_is_seed_deterministic() {
    let a = Arc::new(Runtime::load_reference(7).unwrap());
    let b = Arc::new(Runtime::load_reference(7).unwrap());
    let c = Arc::new(Runtime::load_reference(8).unwrap());
    let (p, n) = prompts(&a, "math", 1)[0].clone();
    let ta = make_engine(a.clone(), "ar").unwrap().generate(&p, n).unwrap();
    let tb = make_engine(b, "ar").unwrap().generate(&p, n).unwrap();
    assert_eq!(ta.tokens, tb.tokens);
    // Different seeds must produce different synthetic weights.
    let a_lora = a.read_global("lora.A").unwrap();
    let c_lora = c.read_global("lora.A").unwrap();
    assert!(
        a_lora.max_abs_diff(&c_lora).unwrap() > 0.0,
        "different seeds produced identical LoRA init"
    );
}
