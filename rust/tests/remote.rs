//! Remote-executor backend integration tests — hermetic, always on.
//!
//! Everything runs over the in-process loopback transport, which
//! exercises the complete remote path (length-prefixed framing, binary
//! codec, server dispatch, shared buffer table, reconnects) with no
//! sockets. One test additionally covers real TCP end-to-end.

use std::sync::Arc;
use std::time::{Duration, Instant};

use dvi::engine::Engine;
use dvi::harness::make_engine;
use dvi::runtime::remote::server::{spawn_loopback_shard, LoopbackShard};
use dvi::runtime::remote::transport::Connector;
use dvi::runtime::{DType, Runtime, Tensor};

const SEED: u64 = 0x2E307E;

fn local() -> Runtime {
    Runtime::load_reference(SEED).expect("reference runtime")
}

fn remote() -> Runtime {
    Runtime::load_remote_loopback(SEED).expect("loopback remote runtime")
}

/// Client runtime over an existing loopback executor (the shard keeps
/// the state/kill handles for assertions).
fn client_of(shard: &LoopbackShard) -> Runtime {
    Runtime::load_remote_with(Box::new(shard.connector.clone()))
        .expect("loopback client runtime")
}

/// Wait (bounded) for the executor's async connection-teardown to leave
/// the buffer table at `want` entries.
fn await_table_len(shard: &LoopbackShard, want: usize) {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let len = shard.state.table.len();
        if len == want {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "buffer table stuck at {len} entries (wanted {want})"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// The handshake must deliver everything a client runtime needs:
/// artifacts, config-derived dimensions, prompt sets, vocabulary.
#[test]
fn handshake_reconstructs_a_full_runtime() {
    let l = local();
    let r = remote();
    assert_eq!(r.backend_name(), "remote");
    for name in [
        "prefill_shallow", "prefill_deep", "draft_step", "draft_block",
        "verify_block", "prefill_full", "target_step", "train_step",
    ] {
        assert!(r.has_artifact(name), "missing artifact {name} after handshake");
    }
    assert_eq!(
        r.manifest.spec_usize("k_spec").unwrap(),
        l.manifest.spec_usize("k_spec").unwrap()
    );
    assert_eq!(
        r.manifest.model_usize("d_model").unwrap(),
        l.manifest.model_usize("d_model").unwrap()
    );
    let lq = l.synthetic_prompts("qa").unwrap();
    let rq = r.synthetic_prompts("qa").unwrap();
    assert_eq!(lq.samples[0].prompt, rq.samples[0].prompt);
    assert_eq!(
        r.tokenizer().unwrap().vocab_size(),
        l.tokenizer().unwrap().vocab_size()
    );
}

/// Single-call parity: one decode step through the wire must be
/// bitwise identical to the same call on a same-seed local backend.
#[test]
fn single_call_is_bitwise_identical_to_local() {
    let l = local();
    let r = remote();
    let inputs = [Tensor::scalar_i32(5), Tensor::scalar_i32(0)];
    let la = l.artifact("target_step").unwrap();
    let ra = r.artifact("target_step").unwrap();
    let lo = la.call(&l.fresh_kv("target_step").unwrap(), &inputs).unwrap();
    let ro = ra.call(&r.fresh_kv("target_step").unwrap(), &inputs).unwrap();
    assert_eq!(lo.outputs[0], ro.outputs[0], "logits diverged across the wire");
    assert_eq!(lo.outputs[1], ro.outputs[1]);
}

/// Full generations through both engines must match bitwise — KV
/// chaining through server-resident buffers included.
#[test]
fn engines_are_bitwise_lossless_over_remote() {
    let l = Arc::new(local());
    let r = Arc::new(remote());
    let prompts = l.synthetic_prompts("qa").unwrap().samples.clone();
    for method in ["dvi", "ar"] {
        let mut le = make_engine(l.clone(), method).unwrap();
        let mut re = make_engine(r.clone(), method).unwrap();
        for s in prompts.iter().take(3) {
            let a = le.generate(&s.prompt, 12).unwrap();
            let b = re.generate(&s.prompt, 12).unwrap();
            assert_eq!(a.tokens, b.tokens, "{method} diverged over remote");
        }
    }
}

/// Upload → download round trip, and the manifest-checked error path
/// for a wrong-shape download.
#[test]
fn upload_download_roundtrip() {
    let r = remote();
    let t = Tensor::f32(vec![2, 3], vec![1.0, -2.5, 0.0, -0.0, 3.25, 1e-30]);
    let buf = r.upload(&t).unwrap();
    let back = r.to_host(&buf, DType::F32, &[2, 3]).unwrap();
    assert_eq!(t, back);
    assert!(r.to_host(&buf, DType::F32, &[3, 2]).is_err());
    assert!(r.to_host(&buf, DType::I32, &[2, 3]).is_err());
}

/// Globals round trip: the learner's set/read/reset path works against
/// a remote executor, and train_step mutates server-side state.
#[test]
fn globals_and_train_step_work_over_remote() {
    let r = remote();
    let a0 = r.read_global("lora.A").unwrap();
    let zero = Tensor::zeros_f32(a0.shape.clone());
    r.set_global("lora.A", &zero).unwrap();
    assert_eq!(r.read_global("lora.A").unwrap(), zero);
    r.reset_global("lora.A").unwrap();
    assert_eq!(r.read_global("lora.A").unwrap(), a0);

    // A train_step over the wire must move lora.B (B starts at zero, so
    // the KL gradient lands there first — same check as the local test).
    let cfg_n = r.manifest.train_f64("batch_size").unwrap() as usize;
    let d = r.manifest.model_usize("d_model").unwrap();
    let v = r.manifest.model_usize("vocab_size").unwrap();
    let b_before = r.read_global("lora.B").unwrap();
    let train = r.artifact("train_step").unwrap();
    let out = train
        .call(
            &[],
            &[
                Tensor::f32(vec![cfg_n, d], vec![0.1; cfg_n * d]),
                Tensor::i32(vec![cfg_n], vec![5; cfg_n]),
                Tensor::f32(vec![cfg_n, v], vec![0.2; cfg_n * v]),
                Tensor::f32(vec![cfg_n], vec![1.0; cfg_n]),
                Tensor::f32(vec![cfg_n], vec![1.0; cfg_n]),
                Tensor::f32(vec![8], vec![0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 3e-3, 1.0]),
            ],
        )
        .unwrap();
    assert!(out.outputs[0].as_f32().unwrap().iter().all(|x| x.is_finite()));
    let b_after = r.read_global("lora.B").unwrap();
    assert!(
        b_after.max_abs_diff(&b_before).unwrap() > 0.0,
        "remote train_step left lora.B unchanged"
    );
}

/// Semantic errors must come back as per-call errors on a healthy
/// connection — the next call on the same connection succeeds.
#[test]
fn semantic_errors_do_not_kill_the_connection() {
    let r = remote();
    assert!(r.read_global("no.such.global").is_err());
    assert!(r.fresh_kv("no_such_artifact").is_err());
    // Connection still healthy:
    assert!(r.read_global("lora.A").is_ok());
}

/// Injected transport failures: at-most-once per call, lazy reconnect,
/// and server-resident KV surviving the reconnect — a sequence driven
/// call-by-call with retries must produce the exact local token stream.
#[test]
fn transport_chaos_reconnects_and_preserves_kv() {
    let l = local();
    let r = Runtime::load_remote_loopback_chaos(SEED, 5, 1_000)
        .expect("chaos runtime");

    // Local golden stream: 20 greedy AR steps.
    let mut l_kv = l.fresh_kv("target_step").unwrap();
    let la = l.artifact("target_step").unwrap();
    let mut golden = Vec::new();
    let mut tok = 5i32;
    for pos in 0..20 {
        let out = la
            .call(&l_kv, &[Tensor::scalar_i32(tok), Tensor::scalar_i32(pos)])
            .unwrap();
        l_kv = out.kv;
        tok = dvi::util::math::argmax(out.outputs[0].as_f32().unwrap()) as i32;
        golden.push(tok);
    }

    // Remote stream under chaos: retry each step until it lands. A
    // failed call must not have advanced the KV (at-most-once), so the
    // retry reproduces the exact same step.
    let mut r_kv = r.fresh_kv("target_step").unwrap();
    let ra = r.artifact("target_step").unwrap();
    let mut got = Vec::new();
    let mut failures = 0usize;
    let mut tok = 5i32;
    for pos in 0..20 {
        loop {
            match ra.call(&r_kv, &[Tensor::scalar_i32(tok), Tensor::scalar_i32(pos)]) {
                Ok(out) => {
                    r_kv = out.kv;
                    tok = dvi::util::math::argmax(out.outputs[0].as_f32().unwrap())
                        as i32;
                    got.push(tok);
                    break;
                }
                Err(_) => {
                    failures += 1;
                    assert!(failures < 100, "chaos retry loop diverged");
                }
            }
        }
    }
    assert!(failures >= 1, "chaos injection never fired");
    assert_eq!(got, golden, "token stream diverged across chaos reconnects");
}

/// Session-leak regression: a client that dies without ever sending its
/// piggybacked frees must not leak executor buffer-table entries — the
/// executor frees everything the session owned when its last connection
/// closes.
#[test]
fn disconnect_frees_session_owned_buffers() {
    let shard = spawn_loopback_shard(Arc::new(local()), None);
    let rt = client_of(&shard);
    let kv_a = rt.fresh_kv("target_step").unwrap();
    let kv_b = rt.fresh_kv("prefill_full").unwrap();
    let staged = rt.upload(&Tensor::scalar_f32(1.5)).unwrap();
    let owned = kv_a.len() + kv_b.len() + 1;
    assert_eq!(shard.state.table.len(), owned);
    // Handles dropped client-side queue frees — but the client dies
    // before any further call could carry them.
    drop((kv_a, kv_b, staged));
    assert_eq!(shard.state.table.len(), owned, "no free was ever sent");
    drop(rt); // last connection of the session closes
    await_table_len(&shard, 0);
}

/// Session teardown is scoped: one client dying frees only its own
/// buffers; a co-resident client keeps its KV and stays serviceable.
#[test]
fn session_teardown_spares_other_clients() {
    let shard = spawn_loopback_shard(Arc::new(local()), None);
    let doomed = client_of(&shard);
    let survivor = client_of(&shard);
    let _doomed_kv = doomed.fresh_kv("target_step").unwrap();
    let kv = survivor.fresh_kv("target_step").unwrap();
    let total = shard.state.table.len();
    assert!(total > kv.len(), "both sessions must have allocations");
    drop(doomed);
    await_table_len(&shard, kv.len());
    // The survivor's KV is still valid server-side.
    let out = survivor
        .artifact("target_step")
        .unwrap()
        .call(&kv, &[Tensor::scalar_i32(5), Tensor::scalar_i32(0)])
        .unwrap();
    assert_eq!(out.kv.len(), kv.len());
}

/// A reply the executor could not deliver must not leak the buffers it
/// minted: the client can never learn those ids, and a session that
/// survives the reconnect would otherwise carry the orphans forever.
/// (v3: the handshake is untagged; the FreshKv request and its
/// undeliverable reply travel as call-id-tagged frames.)
#[test]
fn lost_reply_buffers_are_reclaimed() {
    use dvi::runtime::remote::proto::{self, Msg, Reply, VERSION};
    use dvi::runtime::remote::server::serve_connection;
    use dvi::runtime::remote::transport::{FrameRx, FrameTx, Transport};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;

    /// Feeds scripted request frames and fails every send after the
    /// first `sends_ok` — the deterministic stand-in for a client that
    /// vanished with a reply in flight. Splitting shares the scripted
    /// state so the server's writer/reader worker pair sees it too.
    struct Shared {
        inbox: Mutex<Vec<Vec<u8>>>,
        sends_ok: usize,
        sent: AtomicUsize,
    }
    impl Shared {
        fn send(&self) -> anyhow::Result<()> {
            if self.sent.fetch_add(1, Ordering::SeqCst) >= self.sends_ok {
                anyhow::bail!("client vanished (reply undeliverable)");
            }
            Ok(())
        }
        fn recv(&self) -> anyhow::Result<Vec<u8>> {
            let mut inbox = self.inbox.lock().unwrap();
            if inbox.is_empty() {
                anyhow::bail!("scripted eof");
            }
            Ok(inbox.remove(0))
        }
    }
    struct ScriptedTransport(Arc<Shared>);
    struct ScriptedTx(Arc<Shared>);
    struct ScriptedRx(Arc<Shared>);
    impl Transport for ScriptedTransport {
        fn send(&mut self, _frame: &[u8]) -> anyhow::Result<()> {
            self.0.send()
        }
        fn recv(&mut self) -> anyhow::Result<Vec<u8>> {
            self.0.recv()
        }
        fn split(
            self: Box<Self>,
        ) -> anyhow::Result<(Box<dyn FrameTx>, Box<dyn FrameRx>)> {
            Ok((Box::new(ScriptedTx(self.0.clone())), Box::new(ScriptedRx(self.0))))
        }
    }
    impl FrameTx for ScriptedTx {
        fn send(&mut self, _frame: &[u8]) -> anyhow::Result<()> {
            self.0.send()
        }
    }
    impl FrameRx for ScriptedRx {
        fn recv(&mut self) -> anyhow::Result<Vec<u8>> {
            self.0.recv()
        }
    }

    let server_rt = Arc::new(local());
    let shard = spawn_loopback_shard(server_rt.clone(), None);
    let session = 0x5E55;

    // A second live connection pins the session open, so session-end
    // cleanup cannot mask a leak on the scripted connection.
    let mut hold = shard.connector.clone().connect().unwrap();
    hold.send(
        &Msg::Hello { version: VERSION, want_manifest: false, session }.encode(),
    )
    .unwrap();
    assert!(matches!(
        Reply::decode(&hold.recv().unwrap()).unwrap(),
        Reply::Hello { .. }
    ));

    // Scripted connection, same session: the untagged handshake reply
    // succeeds, the tagged FreshKv executes (minting server-resident
    // buffers), and its tagged reply send fails.
    let shared = Arc::new(Shared {
        inbox: Mutex::new(vec![
            Msg::Hello { version: VERSION, want_manifest: false, session }
                .encode(),
            proto::tag(1, &Msg::FreshKv { artifact: "target_step".into() }.encode()),
        ]),
        sends_ok: 1,
        sent: AtomicUsize::new(0),
    });
    let t = Box::new(ScriptedTransport(shared));
    let err = serve_connection(&server_rt, &shard.state, t).unwrap_err();
    assert!(format!("{err:#}").contains("connection lost"));

    // The minted-but-unreachable buffers were reclaimed even though the
    // session is still alive.
    assert_eq!(shard.state.table.len(), 0, "undeliverable reply leaked KV");
    assert_eq!(shard.state.live_sessions(), 1, "held session must survive");

    // And the surviving connection is still serviceable (tagged now —
    // its handshake completed).
    hold.send(&proto::tag(9, &Msg::Metrics.encode())).unwrap();
    let (id, payload) = {
        let frame = hold.recv().unwrap();
        let (id, payload) = proto::untag(&frame).unwrap();
        (id, payload.to_vec())
    };
    assert_eq!(id, 9, "reply must echo its request's call id");
    match Reply::decode(&payload).unwrap() {
        Reply::Metrics(m) => assert_eq!(m.sessions, 1),
        other => panic!("unexpected reply: {other:?}"),
    }
}

/// A v2 peer dialing a v3 executor must be rejected with a clean
/// in-band error naming both versions — before any session opens and
/// before any tagged frame is exchanged.
#[test]
fn v2_peers_are_rejected_cleanly() {
    use dvi::runtime::remote::proto::{Msg, Reply, VERSION};
    use dvi::runtime::remote::transport::Transport as _;

    let shard = spawn_loopback_shard(Arc::new(local()), None);
    let mut conn = shard.connector.clone().connect().unwrap();
    conn.send(
        &Msg::Hello { version: VERSION - 1, want_manifest: true, session: 7 }
            .encode(),
    )
    .unwrap();
    match Reply::decode(&conn.recv().unwrap()).unwrap() {
        Reply::Err(e) => {
            assert!(
                e.contains("version mismatch"),
                "rejection must name the version problem: {e}"
            );
            assert!(e.contains('2') && e.contains('3'), "both versions: {e}");
        }
        other => panic!("expected a clean rejection, got {other:?}"),
    }
    // No session was opened for the rejected peer.
    assert_eq!(shard.state.live_sessions(), 0);
    // The connection is closed: the next recv observes the hangup.
    assert!(conn.recv().is_err(), "rejected peer's connection must close");
}

/// Two executors with identical manifests (same dims) but different
/// weights (different seeds) must be refused at connect time by the
/// handshake weights fingerprint — divergence is caught before a
/// single lane is routed, not by the first train-step drift check.
#[test]
fn sharded_connect_rejects_divergent_weights() {
    let a = Arc::new(local());
    let b = Arc::new(Runtime::load_reference(SEED + 1).unwrap());
    assert_eq!(
        a.manifest.identity_json().to_string(),
        b.manifest.identity_json().to_string(),
        "precondition: manifests must be identical so only the weights differ"
    );
    let sa = spawn_loopback_shard(a, None);
    let sb = spawn_loopback_shard(b, None);
    let err = Runtime::load_remote_sharded_with(vec![
        Box::new(sa.connector.clone()) as Box<dyn Connector>,
        Box::new(sb.connector.clone()) as Box<dyn Connector>,
    ])
    .unwrap_err();
    assert!(
        format!("{err:#}").contains("different weights"),
        "unexpected error: {err:#}"
    );
}

/// Same-seed executors fingerprint identically, and the fingerprint is
/// surfaced client-side (`Runtime::weights_fingerprint` matches the
/// executor's own).
#[test]
fn weights_fingerprint_roundtrips_through_the_handshake() {
    let server = local();
    let want = server.weights_fingerprint().expect("reference backend hashes");
    let r = remote();
    assert_eq!(r.weights_fingerprint(), Some(want));
}

/// Pipelining overlap, deterministically: a gate holds every reply
/// frame on the client side, N independent calls are submitted through
/// `call_batched_submit` while the gate is closed (so all N are in
/// flight at once), then the gate opens and each handle must resolve
/// to the bitwise-identical result of the same-seed local backend —
/// and the executor metrics must report the realized window depth.
#[test]
fn pipelined_submissions_overlap_and_stay_lossless() {
    use dvi::runtime::remote::server::spawn_loopback;
    use dvi::runtime::remote::transport::{FrameRx, FrameTx, Transport};
    use dvi::runtime::{BatchHandle as _, BatchItem};
    use std::sync::{Condvar, Mutex};

    /// Open/closed latch shared by every gated recv half.
    #[derive(Clone)]
    struct Gate(Arc<(Mutex<bool>, Condvar)>);
    impl Gate {
        fn new() -> Gate {
            Gate(Arc::new((Mutex::new(true), Condvar::new())))
        }
        fn set(&self, open: bool) {
            *self.0 .0.lock().unwrap() = open;
            self.0 .1.notify_all();
        }
        fn wait_open(&self) {
            let mut g = self.0 .0.lock().unwrap();
            while !*g {
                g = self.0 .1.wait(g).unwrap();
            }
        }
    }

    /// Holds each *received* frame until the gate opens — replies reach
    /// the client's reader worker only when the test allows.
    struct HeldTransport {
        inner: Box<dyn Transport>,
        gate: Gate,
    }
    impl Transport for HeldTransport {
        fn send(&mut self, frame: &[u8]) -> anyhow::Result<()> {
            self.inner.send(frame)
        }
        fn recv(&mut self) -> anyhow::Result<Vec<u8>> {
            let f = self.inner.recv()?;
            self.gate.wait_open();
            Ok(f)
        }
        fn split(
            self: Box<Self>,
        ) -> anyhow::Result<(Box<dyn FrameTx>, Box<dyn FrameRx>)> {
            let (tx, rx) = self.inner.split()?;
            Ok((tx, Box::new(HeldRx { inner: rx, gate: self.gate })))
        }
    }
    struct HeldRx {
        inner: Box<dyn FrameRx>,
        gate: Gate,
    }
    impl FrameRx for HeldRx {
        fn recv(&mut self) -> anyhow::Result<Vec<u8>> {
            let f = self.inner.recv()?;
            self.gate.wait_open();
            Ok(f)
        }
    }
    struct HeldConnector<C: dvi::runtime::remote::transport::Connector> {
        inner: C,
        gate: Gate,
    }
    impl<C: dvi::runtime::remote::transport::Connector>
        dvi::runtime::remote::transport::Connector for HeldConnector<C>
    {
        fn connect(&self) -> anyhow::Result<Box<dyn Transport>> {
            Ok(Box::new(HeldTransport {
                inner: self.inner.connect()?,
                gate: self.gate.clone(),
            }))
        }
        fn endpoint(&self) -> String {
            self.inner.endpoint()
        }
    }

    const LANES: usize = 4;
    let gate = Gate::new();
    let connector = HeldConnector {
        inner: spawn_loopback(Arc::new(local())),
        gate: gate.clone(),
    };
    // Window pinned > LANES so submissions never block on a closed
    // gate, regardless of the DVI_MUX_WINDOW the CI lane exports.
    let r =
        Runtime::load_remote_with_window(Box::new(connector), LANES + 1).unwrap();
    let l = local();

    // Independent per-lane KV on both sides (gate open: serial setup).
    let l_art = l.artifact("target_step").unwrap();
    let r_art = r.artifact("target_step").unwrap();
    let l_kvs: Vec<_> =
        (0..LANES).map(|_| l.fresh_kv("target_step").unwrap()).collect();
    let r_kvs: Vec<_> =
        (0..LANES).map(|_| r.fresh_kv("target_step").unwrap()).collect();

    // Golden: serial local calls.
    let golden: Vec<_> = l_kvs
        .iter()
        .enumerate()
        .map(|(i, kv)| {
            let inputs =
                [Tensor::scalar_i32(5 + i as i32), Tensor::scalar_i32(0)];
            l_art.call(kv, &inputs).unwrap()
        })
        .collect();

    // Close the gate, submit all lanes — every call is now in flight on
    // one connection simultaneously (replies exist but cannot resolve).
    gate.set(false);
    let input_sets: Vec<[Tensor; 2]> = (0..LANES)
        .map(|i| [Tensor::scalar_i32(5 + i as i32), Tensor::scalar_i32(0)])
        .collect();
    let handles: Vec<_> = r_kvs
        .iter()
        .zip(&input_sets)
        .map(|(kv, inputs)| {
            r_art.call_batched_submit(&[BatchItem { kv, inputs }])
        })
        .collect();
    gate.set(true);

    for (handle, want) in handles.into_iter().zip(&golden) {
        let mut outs = handle.wait();
        assert_eq!(outs.len(), 1);
        let out = outs.pop().unwrap().expect("pipelined lane failed");
        assert_eq!(
            out.outputs[0], want.outputs[0],
            "pipelined decode diverged from serial local"
        );
    }

    // The realized window depth reached all LANES concurrent calls.
    let status = r.executor_status();
    let m = status[0].metrics.as_ref().expect("executor reachable");
    assert!(
        m.max_inflight >= LANES as u64,
        "window never filled: max_inflight {} < {LANES}",
        m.max_inflight
    );
}

/// A transport-chaos reconnect must NOT count as the session ending:
/// server-resident KV survives because the client parks the dead
/// transport until the replacement connection has handshaken.
#[test]
fn reconnect_does_not_reap_the_session() {
    let shard = spawn_loopback_shard(
        Arc::new(local()),
        Some(dvi::runtime::remote::transport::ChaosPlan::new(4, 2)),
    );
    let rt = client_of(&shard);
    let mut kv = rt.fresh_kv("target_step").unwrap();
    let art = rt.artifact("target_step").unwrap();
    let mut failures = 0;
    for pos in 0..10 {
        loop {
            let inputs = [Tensor::scalar_i32(5), Tensor::scalar_i32(pos)];
            match art.call(&kv, &inputs) {
                Ok(out) => {
                    kv = out.kv;
                    break;
                }
                Err(_) => failures += 1,
            }
            assert!(failures < 50, "retry loop diverged");
        }
    }
    assert!(failures >= 1, "chaos never fired");
    // KV stayed resident through every reconnect (the decode above
    // would have failed with unknown buffer ids otherwise); the session
    // is still the only one and still owns its buffers.
    assert!(shard.state.table.len() >= kv.len());
    assert_eq!(shard.state.live_sessions(), 1);
}

// ----------------------------------------------------------------------------
// Sharded client
// ----------------------------------------------------------------------------

/// Sharded loopback fleet (same seed per shard) + the shard handles.
fn sharded(n: usize) -> (Arc<Runtime>, Vec<LoopbackShard>) {
    let shards: Vec<LoopbackShard> = (0..n)
        .map(|_| spawn_loopback_shard(Arc::new(local()), None))
        .collect();
    let connectors = shards
        .iter()
        .map(|s| Box::new(s.connector.clone()) as Box<dyn Connector>)
        .collect();
    let rt = Runtime::load_remote_sharded_with(connectors)
        .expect("sharded loopback runtime");
    (Arc::new(rt), shards)
}

/// Sharded handshake must reconstruct a full runtime and engines over a
/// 2-shard fleet must stay bitwise identical to the in-process engines.
#[test]
fn sharded_engines_are_bitwise_lossless() {
    let l = Arc::new(local());
    let (r, shards) = sharded(2);
    assert_eq!(r.backend_name(), "remote-sharded");
    let prompts = l.synthetic_prompts("qa").unwrap().samples.clone();
    for method in ["dvi", "ar"] {
        let mut le = make_engine(l.clone(), method).unwrap();
        let mut re = make_engine(r.clone(), method).unwrap();
        for s in prompts.iter().take(3) {
            let a = le.generate(&s.prompt, 12).unwrap();
            let b = re.generate(&s.prompt, 12).unwrap();
            assert_eq!(a.tokens, b.tokens, "{method} diverged over shards");
        }
    }
    // Sequential placement keys round-robined real work onto BOTH
    // executors (engines mint key 0, 1, 2, ... per generation).
    for (i, shard) in shards.iter().enumerate() {
        use std::sync::atomic::Ordering;
        assert!(
            shard.state.stats.calls.load(Ordering::Relaxed) > 0,
            "shard {i} never executed a call"
        );
    }
}

/// Globals stay in lockstep across shards: set/reset broadcast, and a
/// train_step broadcast applies the identical update everywhere (the
/// drift check inside the sharded client verifies outputs bitwise).
#[test]
fn sharded_globals_and_train_step_stay_lockstep() {
    let (r, _shards) = sharded(2);
    let a0 = r.read_global("lora.A").unwrap();
    let zero = Tensor::zeros_f32(a0.shape.clone());
    r.set_global("lora.A", &zero).unwrap();
    assert_eq!(r.read_global("lora.A").unwrap(), zero);
    r.reset_global("lora.A").unwrap();
    assert_eq!(r.read_global("lora.A").unwrap(), a0);

    let cfg_n = r.manifest.train_f64("batch_size").unwrap() as usize;
    let d = r.manifest.model_usize("d_model").unwrap();
    let v = r.manifest.model_usize("vocab_size").unwrap();
    let train = r.artifact("train_step").unwrap();
    let out = train
        .call(
            &[],
            &[
                Tensor::f32(vec![cfg_n, d], vec![0.1; cfg_n * d]),
                Tensor::i32(vec![cfg_n], vec![5; cfg_n]),
                Tensor::f32(vec![cfg_n, v], vec![0.2; cfg_n * v]),
                Tensor::f32(vec![cfg_n], vec![1.0; cfg_n]),
                Tensor::f32(vec![cfg_n], vec![1.0; cfg_n]),
                Tensor::f32(vec![8], vec![0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 3e-3, 1.0]),
            ],
        )
        .unwrap();
    assert!(out.outputs[0].as_f32().unwrap().iter().all(|x| x.is_finite()));
    // Every shard applied the update: lora.B moved identically, so a
    // second broadcast's drift check still passes and read_global
    // (shard 0) equals what any shard would report.
    let b_after = r.read_global("lora.B").unwrap();
    assert!(b_after.as_f32().unwrap().iter().any(|&x| x != 0.0));
}

/// The Metrics message surfaces executor-side counters through
/// `Runtime::executor_status`, one entry per shard.
#[test]
fn executor_metrics_surface_per_shard() {
    let (r, _shards) = sharded(2);
    let mut engine = make_engine(r.clone(), "ar").unwrap();
    let prompt = r.synthetic_prompts("qa").unwrap().samples[0].prompt.clone();
    engine.generate(&prompt, 8).unwrap();
    engine.generate(&prompt, 8).unwrap(); // key 1 → the other shard
    let status = r.executor_status();
    assert_eq!(status.len(), 2, "one status entry per executor");
    for s in &status {
        let m = s.metrics.as_ref().expect("live executor must report metrics");
        assert!(m.calls > 0, "shard {} served no calls", s.shard);
        assert!(m.occupancy() > 0.0);
        assert_eq!(m.sessions, 1, "one sharded client = one session per shard");
    }
    assert_eq!(status[0].shard, 0);
    assert_eq!(status[1].shard, 1);
}

/// Executors fronting different models must be refused at connect time
/// (lanes routed to different shards would silently decode different
/// weights).
#[test]
fn sharded_connect_rejects_mismatched_manifests() {
    use dvi::runtime::ReferenceConfig;
    let a = Arc::new(local());
    let b = Arc::new(
        Runtime::load_reference_with(ReferenceConfig {
            seed: SEED,
            d_model: 24,
            ..Default::default()
        })
        .expect("small-model runtime"),
    );
    let sa = spawn_loopback_shard(a, None);
    let sb = spawn_loopback_shard(b, None);
    let err = Runtime::load_remote_sharded_with(vec![
        Box::new(sa.connector.clone()) as Box<dyn Connector>,
        Box::new(sb.connector.clone()) as Box<dyn Connector>,
    ])
    .unwrap_err();
    assert!(
        format!("{err:#}").contains("different manifest"),
        "unexpected error: {err:#}"
    );
}

/// End-to-end over real TCP: `serve_tcp` in a background thread, a
/// remote runtime dialing 127.0.0.1, one bitwise-checked generation.
#[test]
fn tcp_executor_end_to_end() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server_rt = Arc::new(local());
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    std::thread::spawn(move || {
        let _ = dvi::runtime::remote::server::serve_tcp(listener, server_rt, stop);
    });

    let l = Arc::new(local());
    let r = Arc::new(Runtime::load_remote(&addr).expect("tcp remote runtime"));
    let prompt = l.synthetic_prompts("qa").unwrap().samples[0].prompt.clone();
    let a = make_engine(l, "dvi").unwrap().generate(&prompt, 10).unwrap();
    let b = make_engine(r, "dvi").unwrap().generate(&prompt, 10).unwrap();
    assert_eq!(a.tokens, b.tokens, "TCP remote diverged from local");
}
