//! Remote-executor backend integration tests — hermetic, always on.
//!
//! Everything runs over the in-process loopback transport, which
//! exercises the complete remote path (length-prefixed framing, binary
//! codec, server dispatch, shared buffer table, reconnects) with no
//! sockets. One test additionally covers real TCP end-to-end.

use std::sync::Arc;

use dvi::engine::Engine;
use dvi::harness::make_engine;
use dvi::runtime::{DType, Runtime, Tensor};

const SEED: u64 = 0x2E307E;

fn local() -> Runtime {
    Runtime::load_reference(SEED).expect("reference runtime")
}

fn remote() -> Runtime {
    Runtime::load_remote_loopback(SEED).expect("loopback remote runtime")
}

/// The handshake must deliver everything a client runtime needs:
/// artifacts, config-derived dimensions, prompt sets, vocabulary.
#[test]
fn handshake_reconstructs_a_full_runtime() {
    let l = local();
    let r = remote();
    assert_eq!(r.backend_name(), "remote");
    for name in [
        "prefill_shallow", "prefill_deep", "draft_step", "draft_block",
        "verify_block", "prefill_full", "target_step", "train_step",
    ] {
        assert!(r.has_artifact(name), "missing artifact {name} after handshake");
    }
    assert_eq!(
        r.manifest.spec_usize("k_spec").unwrap(),
        l.manifest.spec_usize("k_spec").unwrap()
    );
    assert_eq!(
        r.manifest.model_usize("d_model").unwrap(),
        l.manifest.model_usize("d_model").unwrap()
    );
    let lq = l.synthetic_prompts("qa").unwrap();
    let rq = r.synthetic_prompts("qa").unwrap();
    assert_eq!(lq.samples[0].prompt, rq.samples[0].prompt);
    assert_eq!(
        r.tokenizer().unwrap().vocab_size(),
        l.tokenizer().unwrap().vocab_size()
    );
}

/// Single-call parity: one decode step through the wire must be
/// bitwise identical to the same call on a same-seed local backend.
#[test]
fn single_call_is_bitwise_identical_to_local() {
    let l = local();
    let r = remote();
    let inputs = [Tensor::scalar_i32(5), Tensor::scalar_i32(0)];
    let la = l.artifact("target_step").unwrap();
    let ra = r.artifact("target_step").unwrap();
    let lo = la.call(&l.fresh_kv("target_step").unwrap(), &inputs).unwrap();
    let ro = ra.call(&r.fresh_kv("target_step").unwrap(), &inputs).unwrap();
    assert_eq!(lo.outputs[0], ro.outputs[0], "logits diverged across the wire");
    assert_eq!(lo.outputs[1], ro.outputs[1]);
}

/// Full generations through both engines must match bitwise — KV
/// chaining through server-resident buffers included.
#[test]
fn engines_are_bitwise_lossless_over_remote() {
    let l = Arc::new(local());
    let r = Arc::new(remote());
    let prompts = l.synthetic_prompts("qa").unwrap().samples.clone();
    for method in ["dvi", "ar"] {
        let mut le = make_engine(l.clone(), method).unwrap();
        let mut re = make_engine(r.clone(), method).unwrap();
        for s in prompts.iter().take(3) {
            let a = le.generate(&s.prompt, 12).unwrap();
            let b = re.generate(&s.prompt, 12).unwrap();
            assert_eq!(a.tokens, b.tokens, "{method} diverged over remote");
        }
    }
}

/// Upload → download round trip, and the manifest-checked error path
/// for a wrong-shape download.
#[test]
fn upload_download_roundtrip() {
    let r = remote();
    let t = Tensor::f32(vec![2, 3], vec![1.0, -2.5, 0.0, -0.0, 3.25, 1e-30]);
    let buf = r.upload(&t).unwrap();
    let back = r.to_host(&buf, DType::F32, &[2, 3]).unwrap();
    assert_eq!(t, back);
    assert!(r.to_host(&buf, DType::F32, &[3, 2]).is_err());
    assert!(r.to_host(&buf, DType::I32, &[2, 3]).is_err());
}

/// Globals round trip: the learner's set/read/reset path works against
/// a remote executor, and train_step mutates server-side state.
#[test]
fn globals_and_train_step_work_over_remote() {
    let r = remote();
    let a0 = r.read_global("lora.A").unwrap();
    let zero = Tensor::zeros_f32(a0.shape.clone());
    r.set_global("lora.A", &zero).unwrap();
    assert_eq!(r.read_global("lora.A").unwrap(), zero);
    r.reset_global("lora.A").unwrap();
    assert_eq!(r.read_global("lora.A").unwrap(), a0);

    // A train_step over the wire must move lora.B (B starts at zero, so
    // the KL gradient lands there first — same check as the local test).
    let cfg_n = r.manifest.train_f64("batch_size").unwrap() as usize;
    let d = r.manifest.model_usize("d_model").unwrap();
    let v = r.manifest.model_usize("vocab_size").unwrap();
    let b_before = r.read_global("lora.B").unwrap();
    let train = r.artifact("train_step").unwrap();
    let out = train
        .call(
            &[],
            &[
                Tensor::f32(vec![cfg_n, d], vec![0.1; cfg_n * d]),
                Tensor::i32(vec![cfg_n], vec![5; cfg_n]),
                Tensor::f32(vec![cfg_n, v], vec![0.2; cfg_n * v]),
                Tensor::f32(vec![cfg_n], vec![1.0; cfg_n]),
                Tensor::f32(vec![cfg_n], vec![1.0; cfg_n]),
                Tensor::f32(vec![8], vec![0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 3e-3, 1.0]),
            ],
        )
        .unwrap();
    assert!(out.outputs[0].as_f32().unwrap().iter().all(|x| x.is_finite()));
    let b_after = r.read_global("lora.B").unwrap();
    assert!(
        b_after.max_abs_diff(&b_before).unwrap() > 0.0,
        "remote train_step left lora.B unchanged"
    );
}

/// Semantic errors must come back as per-call errors on a healthy
/// connection — the next call on the same connection succeeds.
#[test]
fn semantic_errors_do_not_kill_the_connection() {
    let r = remote();
    assert!(r.read_global("no.such.global").is_err());
    assert!(r.fresh_kv("no_such_artifact").is_err());
    // Connection still healthy:
    assert!(r.read_global("lora.A").is_ok());
}

/// Injected transport failures: at-most-once per call, lazy reconnect,
/// and server-resident KV surviving the reconnect — a sequence driven
/// call-by-call with retries must produce the exact local token stream.
#[test]
fn transport_chaos_reconnects_and_preserves_kv() {
    let l = local();
    let r = Runtime::load_remote_loopback_chaos(SEED, 5, 1_000)
        .expect("chaos runtime");

    // Local golden stream: 20 greedy AR steps.
    let mut l_kv = l.fresh_kv("target_step").unwrap();
    let la = l.artifact("target_step").unwrap();
    let mut golden = Vec::new();
    let mut tok = 5i32;
    for pos in 0..20 {
        let out = la
            .call(&l_kv, &[Tensor::scalar_i32(tok), Tensor::scalar_i32(pos)])
            .unwrap();
        l_kv = out.kv;
        tok = dvi::util::math::argmax(out.outputs[0].as_f32().unwrap()) as i32;
        golden.push(tok);
    }

    // Remote stream under chaos: retry each step until it lands. A
    // failed call must not have advanced the KV (at-most-once), so the
    // retry reproduces the exact same step.
    let mut r_kv = r.fresh_kv("target_step").unwrap();
    let ra = r.artifact("target_step").unwrap();
    let mut got = Vec::new();
    let mut failures = 0usize;
    let mut tok = 5i32;
    for pos in 0..20 {
        loop {
            match ra.call(&r_kv, &[Tensor::scalar_i32(tok), Tensor::scalar_i32(pos)]) {
                Ok(out) => {
                    r_kv = out.kv;
                    tok = dvi::util::math::argmax(out.outputs[0].as_f32().unwrap())
                        as i32;
                    got.push(tok);
                    break;
                }
                Err(_) => {
                    failures += 1;
                    assert!(failures < 100, "chaos retry loop diverged");
                }
            }
        }
    }
    assert!(failures >= 1, "chaos injection never fired");
    assert_eq!(got, golden, "token stream diverged across chaos reconnects");
}

/// End-to-end over real TCP: `serve_tcp` in a background thread, a
/// remote runtime dialing 127.0.0.1, one bitwise-checked generation.
#[test]
fn tcp_executor_end_to_end() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server_rt = Arc::new(local());
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    std::thread::spawn(move || {
        let _ = dvi::runtime::remote::server::serve_tcp(listener, server_rt, stop);
    });

    let l = Arc::new(local());
    let r = Arc::new(Runtime::load_remote(&addr).expect("tcp remote runtime"));
    let prompt = l.synthetic_prompts("qa").unwrap().samples[0].prompt.clone();
    let a = make_engine(l, "dvi").unwrap().generate(&prompt, 10).unwrap();
    let b = make_engine(r, "dvi").unwrap().generate(&prompt, 10).unwrap();
    assert_eq!(a.tokens, b.tokens, "TCP remote diverged from local");
}
