//! Continuous-batching scheduler integration tests — hermetic on the
//! reference backend, always on.
//!
//! Headline invariant (losslessness under batching): for a fixed seed
//! and prompt set, the batched scheduler commits **bitwise-identical**
//! token streams to the per-sequence `DviEngine` / `ArEngine` paths,
//! with >= 8 concurrent sequences actually multiplexed (mean batch
//! occupancy > 1) through a recycled KV slot pool. Plus: a property test
//! that interleaved admission never starves a sequence, chaos tests
//! (backend- and transport-level fault injection must fail chunks, not
//! the scheduler, leaving survivors bitwise-identical), and the same
//! losslessness proven through the remote-executor backend.
//!
//! With `DVI_TEST_REMOTE=loopback` (the CI remote step), `runtime()`
//! routes every backend call through the remote executor's loopback
//! transport, so this whole suite additionally proves the wire seam.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use dvi::engine::dvi::DviEngine;
use dvi::engine::Engine;
use dvi::harness::{load_prompts, make_engine};
use dvi::learner::ReplayBuffer;
use dvi::runtime::remote::server::{spawn_loopback_shard, LoopbackShard};
use dvi::runtime::remote::transport::{ChaosPlan, Connector};
use dvi::runtime::{
    chaos::FlakyBackend, shard_for_key, Backend, Buffer, Runtime, Tensor,
};
use dvi::sched::{AdaptiveK, SchedConfig, SchedStats, Scheduler};
use dvi::util::prop::run_prop;

const SEED: u64 = 0xBA7C4;

fn runtime() -> Arc<Runtime> {
    Arc::new(Runtime::load_hermetic(SEED).expect("hermetic runtime"))
}

/// Chaos soak factor: the CI chaos lane (`DVI_TEST_CHAOS=1`) repeats
/// each fault-injection scenario with fresh runtimes/plans for extra
/// coverage; the default suite runs each once. Every repetition keeps
/// the capped, deterministic guarantees.
fn chaos_reps() -> usize {
    match std::env::var("DVI_TEST_CHAOS").as_deref() {
        Ok("") | Err(_) => 1,
        Ok(_) => 3,
    }
}

/// Mixed-task workload via the seeded deterministic shuffle.
fn mixed_prompts(
    rt: &Runtime,
    n: usize,
    max_new: usize,
) -> Vec<(Vec<u32>, usize)> {
    let stream = load_prompts(rt, "stream").unwrap();
    stream
        .shuffled(0x5EED)
        .take(n)
        .samples
        .iter()
        .map(|s| (s.prompt.clone(), s.max_new.min(max_new)))
        .collect()
}

/// Run `cases` through a batched scheduler; return per-case token
/// streams (in submission order) plus the stats handle. Speculation
/// depth follows the environment (`DVI_ADAPTIVE_K`), matching what the
/// per-sequence engines constructed by `make_engine` do — so the
/// adaptive CI lane flips golden and scheduler paths together and every
/// bitwise gate in this file must STILL hold.
fn scheduler_tokens(
    rt: &Arc<Runtime>,
    method: &str,
    cases: &[(Vec<u32>, usize)],
    max_batch: usize,
    max_slots: usize,
) -> (Vec<Vec<u32>>, Arc<SchedStats>) {
    scheduler_tokens_with(rt, method, cases, max_batch, max_slots,
        AdaptiveK::from_env())
}

/// Same, but with the speculation-depth policy pinned explicitly.
fn scheduler_tokens_with(
    rt: &Arc<Runtime>,
    method: &str,
    cases: &[(Vec<u32>, usize)],
    max_batch: usize,
    max_slots: usize,
    adaptive: Option<AdaptiveK>,
) -> (Vec<Vec<u32>>, Arc<SchedStats>) {
    // cache: None pins these gates to the historical cold-prefill path
    // regardless of DVI_PREFIX_CACHE; warm-vs-cold bitwise equivalence
    // has its own dedicated gates in tests/cache.rs.
    let cfg = SchedConfig {
        method: method.into(),
        max_batch,
        max_slots,
        adaptive,
        cache: None,
    };
    let mut sched = Scheduler::new(rt.clone(), cfg, None).unwrap();
    let ids: Vec<u64> = cases
        .iter()
        .map(|(p, n)| sched.submit(p.clone(), *n))
        .collect();
    sched.run_until_idle(100_000).unwrap();
    let stats = sched.stats.clone();
    let mut done = sched.drain_completed();
    assert_eq!(done.len(), cases.len(), "every sequence must complete");
    done.sort_by_key(|r| r.id);
    let tokens = ids
        .iter()
        .zip(done)
        .map(|(&id, r)| {
            assert_eq!(id, r.id);
            r.result.expect("scheduled generation failed").tokens
        })
        .collect();
    (tokens, stats)
}

#[test]
fn batched_dvi_is_bitwise_lossless_vs_engine() {
    let rt = runtime();
    let cases = mixed_prompts(&rt, 10, 24);
    assert!(cases.len() >= 8, "need >= 8 concurrent sequences");
    let mut engine = make_engine(rt.clone(), "dvi").unwrap();
    let golden: Vec<Vec<u32>> = cases
        .iter()
        .map(|(p, n)| engine.generate(p, *n).unwrap().tokens)
        .collect();
    let (got, stats) = scheduler_tokens(&rt, "dvi", &cases, 4, cases.len());
    assert_eq!(got, golden, "batched DVI diverged from per-sequence engine");
    assert!(
        stats.occupancy() > 1.0,
        "scheduler never actually batched (occupancy {})",
        stats.occupancy()
    );
    assert!(
        stats.slot_high_water.load(Ordering::Relaxed) <= cases.len() as u64
    );
    assert!(stats.committed_per_tick() > 0.0);
}

#[test]
fn batched_ar_is_bitwise_lossless_vs_engine() {
    let rt = runtime();
    let cases = mixed_prompts(&rt, 8, 16);
    let mut engine = make_engine(rt.clone(), "ar").unwrap();
    let golden: Vec<Vec<u32>> = cases
        .iter()
        .map(|(p, n)| engine.generate(p, *n).unwrap().tokens)
        .collect();
    let (got, stats) = scheduler_tokens(&rt, "ar", &cases, 8, 8);
    assert_eq!(got, golden, "batched AR diverged from per-sequence engine");
    assert!(stats.occupancy() > 1.0);
}

/// Batch-boundary sweep: the committed streams must not depend on how
/// lanes are chunked into batched calls.
#[test]
fn token_streams_invariant_to_max_batch() {
    let rt = runtime();
    let cases = mixed_prompts(&rt, 8, 12);
    let (a, _) = scheduler_tokens(&rt, "dvi", &cases, 1, 8);
    let (b, _) = scheduler_tokens(&rt, "dvi", &cases, 3, 8);
    let (c, _) = scheduler_tokens(&rt, "dvi", &cases, 8, 4);
    assert_eq!(a, b, "max_batch changed the committed tokens");
    assert_eq!(b, c, "slot pressure changed the committed tokens");
}

// ----------------------------------------------------------------------------
// Adaptive speculation depth
// ----------------------------------------------------------------------------

/// Tentpole gate: with the default adaptive policy actually varying k
/// per round, the committed streams must stay **bitwise identical** to
/// the pinned-k scheduler — greedy longest-prefix acceptance makes the
/// committed stream the verifier's greedy continuation no matter how
/// deep each round drafts. (The pinned streams are in turn pinned to the
/// per-sequence engine by `batched_dvi_is_bitwise_lossless_vs_engine`.)
#[test]
fn adaptive_k_streams_are_bitwise_identical_to_pinned_k() {
    let rt = runtime();
    let cases = mixed_prompts(&rt, 10, 24);
    let (pinned, pinned_stats) =
        scheduler_tokens_with(&rt, "dvi", &cases, 4, cases.len(), None);
    let (adaptive, stats) = scheduler_tokens_with(
        &rt, "dvi", &cases, 4, cases.len(), Some(AdaptiveK::default()));
    assert_eq!(adaptive, pinned, "adaptive-k changed the committed tokens");

    // Observability: every verified round lands in the chosen-k
    // histogram with a sampled acceptance EMA; pinned mode drafts one
    // fixed depth, so its histogram uses exactly one bucket.
    let pinned_hist = pinned_stats.k_hist_snapshot();
    assert_eq!(
        pinned_hist.iter().filter(|&&c| c > 0).count(),
        1,
        "pinned mode must draft a single fixed depth: {pinned_hist:?}"
    );
    let hist = stats.k_hist_snapshot();
    let rounds: u64 = hist.iter().sum();
    assert_eq!(rounds, stats.ema_rounds.load(Ordering::Relaxed));
    assert!(rounds > 0, "no verified rounds recorded");
    let ema = stats.mean_accept_ema();
    assert!(ema > 0.0 && ema <= 1.0, "mean acceptance EMA out of range: {ema}");
    // Unless the hermetic drafter happened to keep its acceptance EMA
    // high the whole run, the policy must have shrunk some round below
    // the pinned depth.
    let k_spec_bucket = pinned_hist.iter().position(|&c| c > 0).unwrap();
    let shallow: u64 = hist[..k_spec_bucket].iter().sum();
    assert!(
        shallow > 0 || ema > 0.8,
        "adaptive-k never shrank below k_spec despite mean EMA {ema}: {hist:?}"
    );
}

/// Satellite regression (truncation accounting): `StepRecord.committed`
/// and the replay tuples pushed for a round must both be bounded by the
/// tokens actually DELIVERED after EOS/max_new truncation. Before the
/// fix, the final truncated round recorded the full pre-truncation
/// commit (skewing MAT upward) and logged tuples for discarded drafted
/// positions (training on supervision the stream never contained).
/// Short budgets make final-round truncation common, so sweep them
/// through BOTH the per-sequence engine and the batched scheduler.
#[test]
fn step_accounting_and_replay_tuples_match_delivered_tokens() {
    let rt = runtime();
    let cases = mixed_prompts(&rt, 6, 24);
    for max_new in 1..=6usize {
        // Per-sequence engine path.
        let buf = Arc::new(Mutex::new(ReplayBuffer::new(4096)));
        let mut engine = DviEngine::new(rt.clone())
            .unwrap()
            .with_adaptive(None)
            .with_buffer(buf.clone());
        for (p, _) in &cases {
            let before = buf.lock().unwrap().pushed;
            let r = engine.generate(p, max_new).unwrap();
            let pushed = (buf.lock().unwrap().pushed - before) as usize;
            let committed: usize = r.steps.iter().map(|s| s.committed).sum();
            assert_eq!(
                1 + committed,
                r.tokens.len(),
                "prefill token + per-round committed must reconstruct the \
                 stream exactly (max_new={max_new})"
            );
            assert!(r.tokens.len() <= max_new, "overshot the token budget");
            // Tuples exist only for delivered drafted positions — never
            // more than the stream minus the prefill-committed token.
            assert!(
                pushed <= r.tokens.len() - 1,
                "replay logged {pushed} tuples for {} delivered tokens \
                 (max_new={max_new})",
                r.tokens.len()
            );
        }
        // Batched scheduler path: same invariants through apply().
        let buf = Arc::new(Mutex::new(ReplayBuffer::new(4096)));
        let cfg = SchedConfig {
            method: "dvi".into(),
            max_batch: 3,
            max_slots: 4,
            adaptive: None,
            cache: None,
        };
        let mut sched =
            Scheduler::new(rt.clone(), cfg, Some(buf.clone())).unwrap();
        for (p, _) in &cases {
            sched.submit(p.clone(), max_new);
        }
        sched.run_until_idle(100_000).unwrap();
        let done = sched.drain_completed();
        assert_eq!(done.len(), cases.len());
        let mut tokens = 0usize;
        let mut committed = 0usize;
        for r in done {
            let g = r.result.expect("scheduled generation failed");
            let c: usize = g.steps.iter().map(|s| s.committed).sum();
            assert_eq!(1 + c, g.tokens.len(), "scheduler path accounting");
            tokens += g.tokens.len();
            committed += c;
        }
        let pushed = buf.lock().unwrap().pushed as usize;
        assert!(
            pushed <= committed,
            "scheduler replay logged {pushed} tuples for {committed} \
             verify-committed tokens (max_new={max_new})"
        );
    }
}

// ----------------------------------------------------------------------------
// Chaos: injected failures must cost chunks, never the scheduler
// ----------------------------------------------------------------------------

/// Drive a chaos scheduler over `cases` (submitting the second half
/// mid-run, so admission races the failures) and check the combined
/// invariant: every sequence reaches a terminal state, at least one
/// fails and at least one survives, survivors are bitwise-identical to
/// the serial engine, and stats stay consistent.
fn chaos_run(rt: Arc<Runtime>, method: &str, cases: &[(Vec<u32>, usize)]) {
    let golden: Vec<Vec<u32>> = {
        let engine_rt = Arc::new(Runtime::load_reference(SEED).unwrap());
        let mut engine = make_engine(engine_rt, method).unwrap();
        cases
            .iter()
            .map(|(p, n)| engine.generate(p, *n).unwrap().tokens)
            .collect()
    };
    // The chaos rate math below counts exact backend calls, so pin the
    // cold-prefill path (the cache would remove prefill work).
    let cfg = SchedConfig {
        method: method.into(),
        max_batch: 2,
        max_slots: 4,
        adaptive: AdaptiveK::from_env(),
        cache: None,
    };
    let mut sched = Scheduler::new(rt, cfg, None).unwrap();
    let half = cases.len() / 2;
    let mut ids: Vec<u64> = cases[..half]
        .iter()
        .map(|(p, n)| sched.submit(p.clone(), *n))
        .collect();
    for _ in 0..3 {
        sched.tick().unwrap();
    }
    // Late arrivals: the queue must keep draining despite failures.
    ids.extend(cases[half..].iter().map(|(p, n)| sched.submit(p.clone(), *n)));
    sched.run_until_idle(100_000).unwrap();
    assert_eq!(sched.queued(), 0, "admission queue starved");

    let mut done = sched.drain_completed();
    assert_eq!(done.len(), cases.len(), "every sequence must terminate");
    done.sort_by_key(|r| r.id);
    let mut oks = 0usize;
    let mut errs = 0usize;
    for (r, (&id, golden)) in done.iter().zip(ids.iter().zip(&golden)) {
        assert_eq!(r.id, id);
        match &r.result {
            Ok(g) => {
                oks += 1;
                assert_eq!(
                    &g.tokens, golden,
                    "surviving lane {id} diverged from serial engine output"
                );
            }
            Err(_) => errs += 1,
        }
    }
    assert!(errs >= 1, "chaos injection never fired");
    assert!(oks >= 1, "chaos killed every lane — nothing survived to check");
    let stats = &sched.stats;
    assert_eq!(stats.served.load(Ordering::Relaxed) as usize, cases.len());
    assert_eq!(stats.failed.load(Ordering::Relaxed) as usize, errs);
    assert_eq!(stats.completed() as usize, oks);
}

/// Backend-level chaos: every Nth `call_batched` chunk errors. The
/// scheduler must absorb each failure via `fail_lane` (that chunk's
/// lanes only) without wedging the tick or starving admission, and
/// surviving lanes must stay bitwise-lossless vs the serial engine.
#[test]
fn chaos_every_nth_chunk_fails_only_its_lanes() {
    // Rate math: even in the degenerate worst case (every sequence
    // EOS-ing right after its two prefill calls), 10 DVI sequences make
    // >= 10 batched calls (2 participations each, at most 2 lanes per
    // chunk), so every=6 guarantees the injection fires; the 3-failure
    // cap kills at most 6 of 10 sequences, so survivors are guaranteed
    // too.
    for _ in 0..chaos_reps() {
        let rt = Runtime::load_reference(SEED).unwrap().map_backend(|inner| {
            Arc::new(FlakyBackend::new(inner, 6, 3)) as Arc<dyn Backend>
        });
        let local = Arc::new(Runtime::load_reference(SEED).unwrap());
        let cases = mixed_prompts(&local, 10, 16);
        chaos_run(Arc::new(rt), "dvi", &cases);
    }
}

// ----------------------------------------------------------------------------
// Remote executor: batched scheduling across the wire seam
// ----------------------------------------------------------------------------

/// Headline remote invariant: batched scheduling through the
/// `RemoteBackend` (loopback transport — full framing/codec/server
/// path, no sockets) commits bitwise-identical token streams to the
/// in-process per-sequence engines, for both DVI and AR.
#[test]
fn remote_batched_is_bitwise_lossless_vs_local_engine() {
    let local = Arc::new(Runtime::load_reference(SEED).unwrap());
    let remote = Arc::new(Runtime::load_remote_loopback(SEED).unwrap());
    assert_eq!(remote.backend_name(), "remote");
    let cases = mixed_prompts(&local, 10, 20);
    for method in ["dvi", "ar"] {
        let mut engine = make_engine(local.clone(), method).unwrap();
        let golden: Vec<Vec<u32>> = cases
            .iter()
            .map(|(p, n)| engine.generate(p, *n).unwrap().tokens)
            .collect();
        let (got, stats) = scheduler_tokens(&remote, method, &cases, 4, cases.len());
        assert_eq!(
            got, golden,
            "remote batched {method} diverged from in-process engine"
        );
        assert!(stats.occupancy() > 1.0, "remote path never actually batched");
        assert_eq!(stats.failed.load(Ordering::Relaxed), 0);
    }
}

/// Transport-level chaos through the full pipelined remote path: every
/// 29th client send errors, at most 2 times (at-most-once execution,
/// lazy bounded reconnect, server-side KV survives the reconnect).
/// Failures must map onto per-lane `fail_lane`, survivors must stay
/// bitwise-lossless. Worst-case damage under pipelining: an injected
/// send failure kills the carried call *plus* everything in flight on
/// that connection — bounded by the active lanes (max_slots = 4), so
/// each failure costs at most 4 of the 10 sequences and the 2-failure
/// cap guarantees >= 2 survivors. (Even in the degenerate worst case a
/// run issues >= 32 sends — handshake, 2 fresh_kv per admission, >= 10
/// batched calls — so 29 guarantees the first failure fires.)
#[test]
fn remote_transport_chaos_fails_chunks_not_the_scheduler() {
    for _ in 0..chaos_reps() {
        let remote =
            Arc::new(Runtime::load_remote_loopback_chaos(SEED, 29, 2).unwrap());
        let local = Arc::new(Runtime::load_reference(SEED).unwrap());
        let cases = mixed_prompts(&local, 10, 16);
        chaos_run(remote, "dvi", &cases);
    }
}

// ----------------------------------------------------------------------------
// Sharded executor fleet: routing, losslessness, and failure domains
// ----------------------------------------------------------------------------

/// Sharded loopback fleet (same seed per shard, so shards are bitwise
/// interchangeable) plus the per-shard kill/state handles.
fn sharded_fleet(n: usize) -> (Arc<Runtime>, Vec<LoopbackShard>) {
    let shards: Vec<LoopbackShard> = (0..n)
        .map(|_| {
            spawn_loopback_shard(
                Arc::new(Runtime::load_reference(SEED).unwrap()),
                None,
            )
        })
        .collect();
    let connectors = shards
        .iter()
        .map(|s| Box::new(s.connector.clone()) as Box<dyn Connector>)
        .collect();
    let rt = Runtime::load_remote_sharded_with(connectors)
        .expect("sharded loopback runtime");
    (Arc::new(rt), shards)
}

/// Headline sharded invariant: batched scheduling across TWO executors
/// commits bitwise-identical token streams to the in-process
/// per-sequence engines, for both DVI and AR, with real multiplexing
/// and zero failures.
#[test]
fn sharded_batched_is_bitwise_lossless_vs_local_engine() {
    let local = Arc::new(Runtime::load_reference(SEED).unwrap());
    let (remote, shards) = sharded_fleet(2);
    assert_eq!(remote.backend_name(), "remote-sharded");
    let cases = mixed_prompts(&local, 10, 20);
    for method in ["dvi", "ar"] {
        let mut engine = make_engine(local.clone(), method).unwrap();
        let golden: Vec<Vec<u32>> = cases
            .iter()
            .map(|(p, n)| engine.generate(p, *n).unwrap().tokens)
            .collect();
        let (got, stats) = scheduler_tokens(&remote, method, &cases, 4, cases.len());
        assert_eq!(
            got, golden,
            "sharded batched {method} diverged from in-process engine"
        );
        assert!(stats.occupancy() > 1.0, "sharded path never actually batched");
        assert_eq!(stats.failed.load(Ordering::Relaxed), 0);
    }
    // Round-robin placement really used both executors.
    for (i, shard) in shards.iter().enumerate() {
        assert!(
            shard.state.stats.calls.load(Ordering::Relaxed) > 0,
            "shard {i} never executed a call"
        );
    }
}

/// Kill one executor of a 2-shard fleet mid-run: every sequence whose
/// KV lives on the dead shard fails (mapped through per-lane
/// `fail_lane`), every sequence on the surviving shard completes with
/// tokens bitwise identical to the in-process engine, and the
/// scheduler neither wedges nor starves its queue.
#[test]
fn killing_one_shard_degrades_only_its_sequences() {
    let local = Arc::new(Runtime::load_reference(SEED).unwrap());
    // Keep only prompts whose generation spans >= 2 committed tokens:
    // those provably out-live the kill point (after the two prefill
    // ticks they still owe draft/verify rounds), which makes the
    // failure accounting below exact instead of probabilistic.
    let mut engine = make_engine(local.clone(), "dvi").unwrap();
    let mut cases: Vec<(Vec<u32>, usize)> = Vec::new();
    let mut golden: Vec<Vec<u32>> = Vec::new();
    for (p, n) in mixed_prompts(&local, 20, 16) {
        let g = engine.generate(&p, n).unwrap().tokens;
        if g.len() >= 2 {
            cases.push((p, n));
            golden.push(g);
        }
        if cases.len() == 10 {
            break;
        }
    }
    assert!(cases.len() >= 6, "not enough multi-round prompts in the stream");

    let (remote, shards) = sharded_fleet(2);
    // The even/odd failure accounting below assumes sequential
    // placement keys, so pin the cache off (placement hints would
    // re-home sequences).
    let cfg = SchedConfig {
        method: "dvi".into(),
        max_batch: 4,
        max_slots: 16,
        adaptive: AdaptiveK::from_env(),
        cache: None,
    };
    let mut sched = Scheduler::new(remote, cfg, None).unwrap();
    let ids: Vec<u64> = cases
        .iter()
        .map(|(p, n)| sched.submit(p.clone(), *n))
        .collect();
    // Two ticks: everything admitted (slots >= cases), shallow + deep
    // prefill issued; every sequence still owes draft/verify rounds.
    sched.tick().unwrap();
    sched.tick().unwrap();
    shards[1].kill.kill();
    sched.run_until_idle(100_000).unwrap();

    let mut done = sched.drain_completed();
    assert_eq!(done.len(), cases.len(), "every sequence must terminate");
    done.sort_by_key(|r| r.id);
    let mut errs = 0usize;
    for (r, (&id, golden)) in done.iter().zip(ids.iter().zip(&golden)) {
        assert_eq!(r.id, id);
        // Admission order is FIFO, so sequence i carries placement key
        // i: even keys live on shard 0 (survives), odd on shard 1
        // (killed).
        let home = shard_for_key(id, 2);
        match &r.result {
            Ok(g) => {
                assert_eq!(
                    home, 0,
                    "sequence {id} lives on the killed shard but completed \
                     after the kill"
                );
                assert_eq!(
                    &g.tokens, golden,
                    "surviving sequence {id} diverged from in-process engine"
                );
            }
            Err(_) => {
                assert_eq!(home, 1, "sequence {id} on the live shard failed");
                errs += 1;
            }
        }
    }
    let odd = (0..cases.len()).filter(|i| i % 2 == 1).count();
    assert_eq!(errs, odd, "exactly the killed shard's sequences must fail");
    let stats = &sched.stats;
    assert_eq!(stats.served.load(Ordering::Relaxed) as usize, cases.len());
    assert_eq!(stats.failed.load(Ordering::Relaxed) as usize, errs);
    assert_eq!(stats.completed() as usize, cases.len() - errs);
}

/// Placement stability: a sequence's KV shard is a pure function of its
/// placement key, descendants of a KV allocation inherit the shard, and
/// transport chaos (with reconnects) never migrates state to another
/// executor mid-generation.
#[test]
fn prop_shard_placement_stable_across_reconnects() {
    let n = 3usize;
    let shards: Vec<LoopbackShard> = (0..n)
        .map(|_| {
            spawn_loopback_shard(
                Arc::new(Runtime::load_reference(SEED).unwrap()),
                Some(ChaosPlan::new(7, 100)),
            )
        })
        .collect();
    let connectors = shards
        .iter()
        .map(|s| Box::new(s.connector.clone()) as Box<dyn Connector>)
        .collect();
    let rt = Runtime::load_remote_sharded_with(connectors)
        .expect("chaotic sharded runtime");

    let shard_of = |b: &Buffer| -> u32 {
        match b {
            Buffer::Remote(h) => h.shard,
            other => panic!("expected a remote buffer, got {other:?}"),
        }
    };
    run_prop("shard-placement-stability", 12, |rng| {
        let key = rng.below(1 << 40);
        let expected = shard_for_key(key, n) as u32;
        let mut retries = 0;
        let mut kv = loop {
            match rt.fresh_kv_keyed("target_step", key) {
                Ok(kv) => break kv,
                Err(_) => retries += 1,
            }
            assert!(retries < 200, "chaos retry loop diverged");
        };
        for b in &kv {
            assert_eq!(shard_of(b), expected, "fresh kv landed off-shard");
        }
        let art = rt.artifact("target_step").unwrap();
        for pos in 0..5 {
            loop {
                let inputs = [Tensor::scalar_i32(7), Tensor::scalar_i32(pos)];
                match art.call(&kv, &inputs) {
                    Ok(out) => {
                        kv = out.kv;
                        break;
                    }
                    Err(_) => retries += 1,
                }
                assert!(retries < 200, "chaos retry loop diverged");
            }
            for b in &kv {
                assert_eq!(
                    shard_of(b),
                    expected,
                    "KV migrated shards mid-generation (key {key})"
                );
            }
        }
    });
}

/// Fairness: under randomly interleaved admission and any (max_batch,
/// max_slots) in range, every admitted sequence completes within a
/// tick budget linear in the offered work — no sequence is starved by
/// co-resident traffic.
#[test]
fn prop_interleaved_admission_never_starves() {
    let rt = runtime();
    let qa = load_prompts(&rt, "qa").unwrap();
    run_prop("sched-no-starvation", 8, |rng| {
        let max_slots = 1 + rng.usize_below(3);
        let cfg = SchedConfig {
            method: "ar".into(),
            max_batch: 1 + rng.usize_below(4),
            max_slots,
            adaptive: None,
            cache: None,
        };
        let mut sched = Scheduler::new(rt.clone(), cfg, None).unwrap();
        let total = 4 + rng.usize_below(5);
        let max_ticks = 64 * total + 64;
        let mut submitted = 0usize;
        let mut ticks = 0usize;
        while submitted < total || !sched.is_idle() {
            // Admission arrives in random bursts, racing the tick loop.
            if submitted < total {
                for _ in 0..rng.usize_below(3) {
                    if submitted < total {
                        let s = &qa.samples[submitted % qa.len()];
                        sched.submit(s.prompt.clone(), s.max_new.min(10));
                        submitted += 1;
                    }
                }
            }
            sched.tick().unwrap();
            ticks += 1;
            assert!(
                ticks <= max_ticks,
                "starvation: {ticks} ticks, {submitted}/{total} submitted, \
                 {} active, {} queued",
                sched.active(),
                sched.queued()
            );
        }
        let done = sched.drain_completed();
        assert_eq!(done.len(), total, "every admitted sequence completes");
        for r in &done {
            assert!(r.result.is_ok());
        }
        assert!(
            sched.stats.slot_high_water.load(Ordering::Relaxed)
                <= max_slots as u64
        );
    });
}
