//! Continuous-batching scheduler integration tests — hermetic on the
//! reference backend, always on.
//!
//! Headline invariant (losslessness under batching): for a fixed seed
//! and prompt set, the batched scheduler commits **bitwise-identical**
//! token streams to the per-sequence `DviEngine` / `ArEngine` paths,
//! with >= 8 concurrent sequences actually multiplexed (mean batch
//! occupancy > 1) through a recycled KV slot pool. Plus: a property test
//! that interleaved admission never starves a sequence.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use dvi::engine::Engine;
use dvi::harness::{load_prompts, make_engine};
use dvi::runtime::Runtime;
use dvi::sched::{SchedConfig, SchedStats, Scheduler};
use dvi::util::prop::run_prop;

fn runtime() -> Arc<Runtime> {
    Arc::new(Runtime::load_reference(0xBA7C4).expect("reference runtime"))
}

/// Mixed-task workload via the seeded deterministic shuffle.
fn mixed_prompts(
    rt: &Runtime,
    n: usize,
    max_new: usize,
) -> Vec<(Vec<u32>, usize)> {
    let stream = load_prompts(rt, "stream").unwrap();
    stream
        .shuffled(0x5EED)
        .take(n)
        .samples
        .iter()
        .map(|s| (s.prompt.clone(), s.max_new.min(max_new)))
        .collect()
}

/// Run `cases` through a batched scheduler; return per-case token
/// streams (in submission order) plus the stats handle.
fn scheduler_tokens(
    rt: &Arc<Runtime>,
    method: &str,
    cases: &[(Vec<u32>, usize)],
    max_batch: usize,
    max_slots: usize,
) -> (Vec<Vec<u32>>, Arc<SchedStats>) {
    let cfg = SchedConfig { method: method.into(), max_batch, max_slots };
    let mut sched = Scheduler::new(rt.clone(), cfg, None).unwrap();
    let ids: Vec<u64> = cases
        .iter()
        .map(|(p, n)| sched.submit(p.clone(), *n))
        .collect();
    sched.run_until_idle(100_000).unwrap();
    let stats = sched.stats.clone();
    let mut done = sched.drain_completed();
    assert_eq!(done.len(), cases.len(), "every sequence must complete");
    done.sort_by_key(|r| r.id);
    let tokens = ids
        .iter()
        .zip(done)
        .map(|(&id, r)| {
            assert_eq!(id, r.id);
            r.result.expect("scheduled generation failed").tokens
        })
        .collect();
    (tokens, stats)
}

#[test]
fn batched_dvi_is_bitwise_lossless_vs_engine() {
    let rt = runtime();
    let cases = mixed_prompts(&rt, 10, 24);
    assert!(cases.len() >= 8, "need >= 8 concurrent sequences");
    let mut engine = make_engine(rt.clone(), "dvi").unwrap();
    let golden: Vec<Vec<u32>> = cases
        .iter()
        .map(|(p, n)| engine.generate(p, *n).unwrap().tokens)
        .collect();
    let (got, stats) = scheduler_tokens(&rt, "dvi", &cases, 4, cases.len());
    assert_eq!(got, golden, "batched DVI diverged from per-sequence engine");
    assert!(
        stats.occupancy() > 1.0,
        "scheduler never actually batched (occupancy {})",
        stats.occupancy()
    );
    assert!(
        stats.slot_high_water.load(Ordering::Relaxed) <= cases.len() as u64
    );
    assert!(stats.committed_per_tick() > 0.0);
}

#[test]
fn batched_ar_is_bitwise_lossless_vs_engine() {
    let rt = runtime();
    let cases = mixed_prompts(&rt, 8, 16);
    let mut engine = make_engine(rt.clone(), "ar").unwrap();
    let golden: Vec<Vec<u32>> = cases
        .iter()
        .map(|(p, n)| engine.generate(p, *n).unwrap().tokens)
        .collect();
    let (got, stats) = scheduler_tokens(&rt, "ar", &cases, 8, 8);
    assert_eq!(got, golden, "batched AR diverged from per-sequence engine");
    assert!(stats.occupancy() > 1.0);
}

/// Batch-boundary sweep: the committed streams must not depend on how
/// lanes are chunked into batched calls.
#[test]
fn token_streams_invariant_to_max_batch() {
    let rt = runtime();
    let cases = mixed_prompts(&rt, 8, 12);
    let (a, _) = scheduler_tokens(&rt, "dvi", &cases, 1, 8);
    let (b, _) = scheduler_tokens(&rt, "dvi", &cases, 3, 8);
    let (c, _) = scheduler_tokens(&rt, "dvi", &cases, 8, 4);
    assert_eq!(a, b, "max_batch changed the committed tokens");
    assert_eq!(b, c, "slot pressure changed the committed tokens");
}

/// Fairness: under randomly interleaved admission and any (max_batch,
/// max_slots) in range, every admitted sequence completes within a
/// tick budget linear in the offered work — no sequence is starved by
/// co-resident traffic.
#[test]
fn prop_interleaved_admission_never_starves() {
    let rt = runtime();
    let qa = load_prompts(&rt, "qa").unwrap();
    run_prop("sched-no-starvation", 8, |rng| {
        let max_slots = 1 + rng.usize_below(3);
        let cfg = SchedConfig {
            method: "ar".into(),
            max_batch: 1 + rng.usize_below(4),
            max_slots,
        };
        let mut sched = Scheduler::new(rt.clone(), cfg, None).unwrap();
        let total = 4 + rng.usize_below(5);
        let max_ticks = 64 * total + 64;
        let mut submitted = 0usize;
        let mut ticks = 0usize;
        while submitted < total || !sched.is_idle() {
            // Admission arrives in random bursts, racing the tick loop.
            if submitted < total {
                for _ in 0..rng.usize_below(3) {
                    if submitted < total {
                        let s = &qa.samples[submitted % qa.len()];
                        sched.submit(s.prompt.clone(), s.max_new.min(10));
                        submitted += 1;
                    }
                }
            }
            sched.tick().unwrap();
            ticks += 1;
            assert!(
                ticks <= max_ticks,
                "starvation: {ticks} ticks, {submitted}/{total} submitted, \
                 {} active, {} queued",
                sched.active(),
                sched.queued()
            );
        }
        let done = sched.drain_completed();
        assert_eq!(done.len(), total, "every admitted sequence completes");
        for r in &done {
            assert!(r.result.is_ok());
        }
        assert!(
            sched.stats.slot_high_water.load(Ordering::Relaxed)
                <= max_slots as u64
        );
    });
}
