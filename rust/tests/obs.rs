//! Observability integration tests — hermetic on the reference backend.
//!
//! The headline gate: with tracing and metrics enabled, every committed
//! token stream is **bitwise identical** to the uninstrumented run, for
//! both the DVI and AR batched schedulers (the `DVI_TRACE=1` CI lane
//! re-runs the whole sched/remote suites under the same gate). Plus:
//! ring overflow increments the drop counter instead of blocking or
//! silently truncating, the Chrome-trace export parses and keeps every
//! track time-monotonic, the required latency histograms (queue wait,
//! draft round, verify, per-shard RPC, train step) actually record, and
//! the router's stats/metrics JSON surfaces stay valid JSON.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use dvi::harness::load_prompts;
use dvi::learner::{Objective, ReplayBuffer, Schedule, Trainer, Tuple};
use dvi::obs::{chrome, metrics, trace, HealthMonitor};
use dvi::runtime::{Runtime, Tensor};
use dvi::sched::{AdaptiveK, SchedConfig, Scheduler};
use dvi::server::{Router, RouterConfig};
use dvi::util::json::Json;

const SEED: u64 = 0x0B5E2;

/// Serializes the tests that toggle process-global tracer state (forced
/// enable, forced ring cap) or drain the shared rings.
fn test_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

fn lock() -> std::sync::MutexGuard<'static, ()> {
    test_lock().lock().unwrap_or_else(|e| e.into_inner())
}

fn runtime() -> Arc<Runtime> {
    Arc::new(Runtime::load_hermetic(SEED).expect("hermetic runtime"))
}

fn mixed_prompts(rt: &Runtime, n: usize, max_new: usize) -> Vec<(Vec<u32>, usize)> {
    let stream = load_prompts(rt, "stream").unwrap();
    stream
        .shuffled(0x5EED)
        .take(n)
        .samples
        .iter()
        .map(|s| (s.prompt.clone(), s.max_new.min(max_new)))
        .collect()
}

fn scheduler_tokens(
    rt: &Arc<Runtime>,
    method: &str,
    cases: &[(Vec<u32>, usize)],
) -> Vec<Vec<u32>> {
    let cfg = SchedConfig {
        method: method.into(),
        max_batch: 4,
        max_slots: cases.len(),
        adaptive: AdaptiveK::from_env(),
        cache: None,
    };
    let mut sched = Scheduler::new(rt.clone(), cfg, None).unwrap();
    let ids: Vec<u64> =
        cases.iter().map(|(p, n)| sched.submit(p.clone(), *n)).collect();
    sched.run_until_idle(100_000).unwrap();
    let mut done = sched.drain_completed();
    assert_eq!(done.len(), cases.len());
    done.sort_by_key(|r| r.id);
    ids.iter()
        .zip(done)
        .map(|(&id, r)| {
            assert_eq!(id, r.id);
            r.result.expect("generation failed").tokens
        })
        .collect()
}

/// The hard gate plus trace-format validity in one serialized pass:
/// identical streams traced vs untraced, then the traced run's events
/// render to a parseable Chrome document with monotonic per-track
/// timestamps, reduce through `summarize`, and back the required
/// quantile histograms.
#[test]
fn traced_scheduler_is_bitwise_identical_and_trace_is_valid() {
    let _g = lock();
    let rt = runtime();
    let cases = mixed_prompts(&rt, 6, 16);

    trace::set_forced(Some(false));
    let golden_dvi = scheduler_tokens(&rt, "dvi", &cases);
    let golden_ar = scheduler_tokens(&rt, "ar", &cases);
    let _ = trace::drain(); // discard anything emitted before forcing on

    trace::set_forced(Some(true));
    let traced_dvi = scheduler_tokens(&rt, "dvi", &cases);
    let traced_ar = scheduler_tokens(&rt, "ar", &cases);
    let events = trace::drain();
    trace::set_forced(None);

    assert_eq!(
        traced_dvi, golden_dvi,
        "tracing changed a DVI committed stream"
    );
    assert_eq!(traced_ar, golden_ar, "tracing changed an AR committed stream");

    for name in
        ["seq.admit", "seq.prefill", "seq.draft_round", "seq.verify",
         "seq.finish", "sched.call", "tick.submit", "tick.drain"]
    {
        assert!(
            events.iter().any(|e| e.name == name),
            "traced run emitted no '{name}' event"
        );
    }

    let doc = chrome::render(&events, trace::drop_count());
    let j = Json::parse(&doc).expect("chrome trace must parse as JSON");
    let arr = j.get("traceEvents").as_arr().expect("traceEvents array");
    assert_eq!(arr.len(), events.len());
    let mut last_ts: BTreeMap<i64, f64> = BTreeMap::new();
    for e in arr {
        let ph = e.get("ph").as_str().expect("event ph");
        assert!(ph == "X" || ph == "i", "unexpected phase {ph}");
        assert!(e.get("name").as_str().is_some(), "event without name");
        let ts = e.get("ts").as_f64().expect("event ts");
        let tid = e.get("tid").as_f64().expect("event tid") as i64;
        if ph == "X" {
            assert!(e.get("dur").as_f64().is_some(), "X event without dur");
        }
        if let Some(prev) = last_ts.insert(tid, ts) {
            assert!(ts >= prev, "track {tid} went backwards in time");
        }
    }

    let (stats, _, _) = chrome::summarize(&doc).expect("trace summarizes");
    assert!(
        stats.iter().any(|s| s.key.starts_with("seq.draft_round")),
        "summary lost the draft-round phase"
    );

    let snap = metrics::global().snapshot();
    for name in [
        "sched.queue_wait_ns",
        "seq.prefill_ns",
        "seq.draft_round_ns",
        "seq.verify_ns",
        "seq.ar_step_ns",
    ] {
        let h = snap
            .hists
            .get(name)
            .unwrap_or_else(|| panic!("histogram '{name}' never registered"));
        assert!(h.count > 0, "histogram '{name}' never observed");
        assert!(h.quantile(0.5) >= h.min && h.quantile(0.99) <= h.max);
    }
}

/// A full trace ring overwrites its oldest events and counts every
/// overwrite in the global drop counter — overflow is never silent and
/// never blocks the emitting thread.
#[test]
fn ring_overflow_increments_drop_counter() {
    let _g = lock();
    let _ = trace::drain();
    trace::set_forced(Some(true));
    trace::set_forced_ring_cap(Some(16));
    let drops_before = trace::drop_count();
    // Fresh thread: the forced cap applies to rings created after it was
    // set, and this thread's ring is created at its first emit.
    std::thread::spawn(|| {
        for _ in 0..50 {
            trace::instant("overflow.test", "test", Vec::new());
        }
    })
    .join()
    .unwrap();
    let dropped = trace::drop_count() - drops_before;
    let kept = trace::drain()
        .iter()
        .filter(|e| e.name == "overflow.test")
        .count();
    trace::set_forced_ring_cap(None);
    trace::set_forced(None);
    assert_eq!(kept, 16, "ring must retain exactly its capacity");
    assert_eq!(dropped, 34, "every overwritten event must be counted");
}

/// With tracing off, emits are discarded (and cost nothing but the
/// enabled() check) — nothing accumulates in any ring.
#[test]
fn disabled_tracer_records_nothing() {
    let _g = lock();
    trace::set_forced(Some(false));
    let _ = trace::drain();
    trace::instant("ghost", "test", Vec::new());
    trace::complete_with_dur("ghost.span", "test", 100, Vec::new());
    let events = trace::drain();
    trace::set_forced(None);
    assert!(
        events.iter().all(|e| !e.name.starts_with("ghost")),
        "disabled tracer must not record events"
    );
}

/// Driving a loopback remote runtime records the per-shard RPC latency
/// family and the executor-side dispatch histogram, and the snapshot
/// shard rollup aggregates the family into `.all`.
#[test]
fn remote_calls_record_per_shard_rpc_histograms() {
    let _g = lock();
    let rt = Runtime::load_remote_loopback(SEED).expect("loopback runtime");
    let art = rt.artifact("target_step").unwrap();
    let kv = rt.fresh_kv("target_step").unwrap();
    let inputs = [Tensor::scalar_i32(7), Tensor::scalar_i32(0)];
    art.call(&kv, &inputs).unwrap();

    let mut snap = metrics::global().snapshot();
    let s0_count = snap
        .hists
        .get("rpc.target_step.s0_ns")
        .expect("per-shard RPC histogram missing")
        .count;
    assert!(s0_count > 0);
    assert!(
        snap.hists.get("exec.call_ns").map(|h| h.count).unwrap_or(0) > 0,
        "executor dispatch histogram missing"
    );
    snap.rollup_shards();
    let all = snap
        .hists
        .get("rpc.target_step.all_ns")
        .expect("shard rollup did not build the .all aggregate");
    assert!(all.count >= s0_count);
}

/// One optimizer step lands in the train-step latency histogram and the
/// trainer's `last_step_ns` mirror.
#[test]
fn train_step_latency_is_recorded() {
    let _g = lock();
    let rt = runtime();
    let buffer = Arc::new(Mutex::new(ReplayBuffer::new(4096)));
    let mut trainer = Trainer::new(
        rt.clone(),
        buffer.clone(),
        Schedule::new(Objective::Dvi),
        0xD1CE,
    )
    .unwrap();
    let d_model = rt.manifest.model_usize("d_model").unwrap();
    let vocab = rt.manifest.model_usize("vocab_size").unwrap();
    let before = metrics::global()
        .snapshot()
        .hists
        .get("learner.train_step_ns")
        .map(|h| h.count)
        .unwrap_or(0);
    {
        let mut buf = buffer.lock().unwrap();
        for i in 0..trainer.batch_size {
            buf.push(Tuple {
                hk: vec![0.01 * i as f32; d_model],
                action: (i % vocab) as u32,
                logits_phi: vec![0.0; vocab],
                reward: if i % 3 == 0 { 0.0 } else { 1.0 },
            });
        }
    }
    let m = trainer.maybe_train().unwrap();
    assert!(m.is_some(), "full buffer must train");
    assert!(trainer.last_step_ns > 0, "last_step_ns not stamped");
    let after = metrics::global()
        .snapshot()
        .hists
        .get("learner.train_step_ns")
        .map(|h| h.count)
        .unwrap_or(0);
    assert_eq!(after, before + 1, "train-step histogram missed the step");
}

/// The router's probe surfaces: `stats_json` (with the learner block)
/// and `metrics_json` both stay valid single-line JSON carrying the
/// documented fields.
#[test]
fn router_stats_and_metrics_json_are_valid() {
    let _g = lock();
    let rt = runtime();
    let router = Router::start(
        rt,
        RouterConfig {
            batched: true,
            max_batch: 4,
            max_slots: 8,
            adaptive: None,
            ..RouterConfig::default()
        },
    )
    .unwrap();
    let cases = {
        let rt2 = runtime();
        mixed_prompts(&rt2, 2, 8)
    };
    for (prompt, max_new) in cases {
        router.generate(prompt, max_new).unwrap();
    }

    let stats = router.stats_json();
    let j = Json::parse(&stats).expect("stats_json must parse");
    assert_eq!(j.get("served").as_usize(), Some(2));
    assert!(
        j.get("learner").get("phase").as_str().is_some(),
        "learner block missing from stats: {stats}"
    );
    assert!(j.get("learner").get("replay_pushed").as_f64().is_some());
    assert!(j.get("learner").get("replay_depth").as_f64().is_some());

    let mj = router.metrics_json();
    let j = Json::parse(&mj).expect("metrics_json must parse");
    let qw = j
        .get("metrics")
        .get("hists")
        .get("sched.queue_wait_ns");
    assert!(
        qw.get("p50").as_f64().is_some()
            && qw.get("p95").as_f64().is_some()
            && qw.get("p99").as_f64().is_some(),
        "queue-wait quantiles missing from metrics: {mj}"
    );
    assert!(j.get("trace").get("enabled").as_bool().is_some());
    router.shutdown();
}

/// Tentpole gate: pull a loopback executor's ring over the wire, merge
/// it with the client track, and check the merged document end to end —
/// parseable, per-(pid, tid)-track time-monotonic, every client
/// `rpc.call` span's call id resolving to exactly one executor `exec`
/// span nested inside it (up to the clock estimator's uncertainty), and
/// the client/server/wire decomposition reducing those pairs per shard.
#[test]
fn merged_fleet_trace_pairs_every_rpc_with_one_exec() {
    let _g = lock();
    trace::set_forced(Some(true));
    let _ = trace::drain();
    let rt = Runtime::load_remote_loopback(SEED).expect("loopback runtime");
    let art = rt.artifact("target_step").unwrap();
    let kv = rt.fresh_kv("target_step").unwrap();
    for step in 0..4 {
        let inputs =
            [Tensor::scalar_i32(5 + step), Tensor::scalar_i32(step)];
        art.call(&kv, &inputs).unwrap();
    }
    let pulls = rt.obs_pull().expect("obs pull");
    let leftover: Vec<_> =
        trace::drain().iter().map(trace::Event::to_owned_event).collect();
    trace::set_forced(None);
    assert_eq!(pulls.len(), 1, "one loopback shard");
    let obs = pulls.into_iter().next().unwrap();
    // Loopback shares the process clock, so the estimator's guarantee
    // |offset − true_offset| <= uncertainty collapses to a checkable
    // absolute bound.
    assert!(
        obs.offset.offset_ns.unsigned_abs() <= obs.offset.uncertainty_ns,
        "loopback clock offset {} ns outside its own uncertainty {} ns",
        obs.offset.offset_ns,
        obs.offset.uncertainty_ns
    );
    // Enclosure slack: clock-alignment error plus a little scheduling
    // jitter between a reply landing and its span being emitted.
    let slack_us = 2.0 * obs.offset.uncertainty_ns as f64 / 1e3 + 500.0;
    let client = chrome::ProcessTrack {
        pid: chrome::CLIENT_PID,
        label: "dvi client".into(),
        // The loopback executor shares the client's rings, so the pull
        // drained (almost) everything into the shard dump — an empty
        // client track is what a merge around an idle client looks like.
        events: leftover,
        dropped: trace::drop_count(),
    };
    let shard = obs.into_track();
    assert_eq!(shard.pid, chrome::shard_pid(0));
    let doc = chrome::render_merged(&[client, shard], 0);

    let j = Json::parse(&doc).expect("merged doc parses");
    let arr = j.get("traceEvents").as_arr().expect("traceEvents array");
    let procs = arr
        .iter()
        .filter(|e| e.get("ph").as_str() == Some("M"))
        .count();
    assert!(procs >= 2, "merged doc must name both process tracks");
    let mut last: BTreeMap<(i64, i64), f64> = BTreeMap::new();
    for e in arr {
        if e.get("ph").as_str() == Some("M") {
            continue;
        }
        let ts = e.get("ts").as_f64().expect("event ts");
        let key = (
            e.get("pid").as_f64().expect("event pid") as i64,
            e.get("tid").as_f64().expect("event tid") as i64,
        );
        if let Some(prev) = last.insert(key, ts) {
            assert!(ts >= prev, "track {key:?} went backwards in time");
        }
    }

    let spans = |name: &str| -> Vec<(i64, f64, f64)> {
        arr.iter()
            .filter(|e| e.get("name").as_str() == Some(name))
            .map(|e| {
                (
                    e.get("args").get("id").as_f64().expect("span id") as i64,
                    e.get("ts").as_f64().unwrap(),
                    e.get("dur").as_f64().unwrap(),
                )
            })
            .collect()
    };
    let rpcs = spans("rpc.call");
    let execs = spans("exec");
    assert!(
        rpcs.len() >= 4,
        "expected an rpc.call span per artifact call, got {}",
        rpcs.len()
    );
    for (id, ts, dur) in &rpcs {
        let partners: Vec<_> =
            execs.iter().filter(|(eid, ..)| eid == id).collect();
        assert_eq!(
            partners.len(),
            1,
            "rpc call id {id} must resolve to exactly one exec span"
        );
        let (_, ets, edur) = partners[0];
        assert!(
            *edur <= dur + 0.01,
            "server exec ({edur} us) cannot outlast its rpc span ({dur} us)"
        );
        assert!(
            *ets + slack_us >= *ts && ets + edur <= ts + dur + slack_us,
            "exec span for call {id} escapes its rpc span beyond the \
             clock uncertainty"
        );
    }

    let rows = chrome::decompose(&doc).expect("decomposition");
    assert_eq!(rows.len(), 1, "one shard row");
    assert_eq!(rows[0].shard, 0);
    assert_eq!(rows[0].matched, rpcs.len());
    assert!(rows[0].server_p50_us <= rows[0].client_p50_us + 0.01);
    assert!(rows[0].wire_p50_us >= 0.0);
}

/// The whole observability stack at once — forced tracing, a wire
/// collection landing mid-run, and an attached health monitor scoring
/// per-tenant deadlines — must leave committed token streams bitwise
/// identical to the all-off in-process run, on a 2-shard loopback
/// fleet.
#[test]
fn full_observability_stack_is_bitwise_inert_on_a_sharded_fleet() {
    let _g = lock();
    let cases = {
        let rt = runtime();
        mixed_prompts(&rt, 6, 12)
    };
    trace::set_forced(Some(false));
    let golden = scheduler_tokens(&runtime(), "dvi", &cases);

    trace::set_forced(Some(true));
    let _ = trace::drain();
    let rt = Arc::new(
        Runtime::load_remote_sharded_loopback(SEED, 2)
            .expect("sharded loopback runtime"),
    );
    let cfg = SchedConfig {
        method: "dvi".into(),
        max_batch: 4,
        max_slots: cases.len(),
        adaptive: AdaptiveK::from_env(),
        cache: None,
    };
    let mut sched = Scheduler::new(rt.clone(), cfg, None).unwrap();
    let health = Arc::new(HealthMonitor::new());
    sched.attach_health(health.clone());
    for (p, n) in &cases {
        // Generous one-hour deadline: the run must be scored (and met),
        // never perturbed.
        sched.submit_with_deadline(
            p.clone(),
            *n,
            Some("chat"),
            Instant::now(),
            Some(3_600_000_000_000),
        );
    }
    let mut pulled = false;
    let mut guard = 0u64;
    while !sched.is_idle() {
        guard += 1;
        assert!(guard < 100_000, "scheduler wedged");
        sched.tick().expect("tick");
        if !pulled {
            // Wire collection racing live traffic on the same mux
            // connections: a control-plane drain must never disturb the
            // data plane.
            pulled = true;
            let pulls = rt.obs_pull().expect("mid-run obs pull");
            assert_eq!(pulls.len(), 2, "one dump per shard");
        }
    }
    let mut done = sched.drain_completed();
    assert_eq!(done.len(), cases.len());
    done.sort_by_key(|r| r.id);
    let streams: Vec<Vec<u32>> = done
        .into_iter()
        .map(|r| r.result.expect("generation failed").tokens)
        .collect();
    let _ = trace::drain();
    trace::set_forced(None);
    assert_eq!(
        streams, golden,
        "observability stack changed a committed stream"
    );

    let snap = health.snapshot();
    let chat = snap.tenants.get("chat").expect("chat tenant ledger");
    assert_eq!(chat.completed, cases.len() as u64);
    assert_eq!(
        chat.in_deadline,
        cases.len() as u64,
        "a one-hour deadline must always be met"
    );
    assert!(chat.goodput_tokens > 0, "goodput must count committed tokens");
    assert!(!snap.alarm, "a healthy run must not trip the drift alarm");
}
