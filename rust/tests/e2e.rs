//! End-to-end coordinator tests: router + worker pool + online learner +
//! TCP API over real artifacts (skipped until `make artifacts`).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use dvi::harness::load_prompts;
use dvi::learner::Objective;
use dvi::runtime::Runtime;
use dvi::server::{api, Router, RouterConfig};
use dvi::tokenizer::Tokenizer;
use dvi::util::json::Json;

fn artifacts_dir() -> PathBuf {
    std::env::var("DVI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

#[test]
fn router_serves_concurrent_requests() {
    if !have_artifacts() {
        eprintln!("SKIP router_serves_concurrent_requests");
        return;
    }
    let rt = Arc::new(Runtime::load(&artifacts_dir(), None).unwrap());
    let stream = load_prompts(&rt, "qa").unwrap();
    let router = Router::start(
        rt,
        RouterConfig {
            workers: 2,
            method: "dvi".into(),
            online: true,
            objective: Objective::Dvi,
            buffer_capacity: 1024,
        },
    )
    .unwrap();

    // Submit a burst of requests, then collect them all.
    let receivers: Vec<_> = stream
        .samples
        .iter()
        .take(6)
        .map(|s| router.submit(s.prompt.clone(), s.max_new.min(24)))
        .collect();
    let mut workers_seen = std::collections::BTreeSet::new();
    for rx in receivers {
        let resp = rx.recv().unwrap();
        assert!(!resp.tokens.is_empty());
        workers_seen.insert(resp.worker);
    }
    assert_eq!(router.stats.served.load(Ordering::Relaxed), 6);
    assert!(router.stats.tokens.load(Ordering::Relaxed) > 0);
    // With 2 workers and 6 queued requests both should have participated
    // (not guaranteed in theory, overwhelmingly likely; tolerate 1).
    assert!(!workers_seen.is_empty());
    router.shutdown();
}

#[test]
fn tcp_api_round_trip() {
    if !have_artifacts() {
        eprintln!("SKIP tcp_api_round_trip");
        return;
    }
    let rt = Arc::new(Runtime::load(&artifacts_dir(), None).unwrap());
    let tok = Arc::new(Tokenizer::load(&rt.manifest.vocab_file).unwrap());
    let router = Arc::new(
        Router::start(
            rt,
            RouterConfig {
                workers: 1,
                method: "dvi".into(),
                online: false,
                objective: Objective::Dvi,
                buffer_capacity: 64,
            },
        )
        .unwrap(),
    );
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let handle = std::thread::spawn(move || {
        let _ = api::serve(listener, router, tok, stop2);
    });

    let mut conn = TcpStream::connect(addr).unwrap();
    writeln!(
        conn,
        r#"{{"prompt": "question : what owns ent01 ? <sep>", "max_new": 16}}"#
    )
    .unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    assert!(j.get("error").is_null(), "API error: {line}");
    assert!(!j.get("tokens").as_arr().unwrap().is_empty());
    assert!(j.get("text").as_str().is_some());

    // malformed request -> error object, connection stays up
    writeln!(conn, "this is not json").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(!Json::parse(&line).unwrap().get("error").is_null());

    stop.store(true, Ordering::Relaxed);
    drop(conn);
    let _ = handle.join();
}
