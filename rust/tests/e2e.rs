//! End-to-end coordinator tests: router + worker pool + online learner +
//! TCP API — hermetic on the reference backend, always on.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use dvi::harness::load_prompts;
use dvi::learner::Objective;
use dvi::runtime::Runtime;
use dvi::server::{api, Router, RouterConfig};
use dvi::util::json::Json;

fn runtime() -> Arc<Runtime> {
    Arc::new(Runtime::load_reference(0xE2E).expect("reference runtime"))
}

/// Start the router with 2 workers, submit a burst of concurrent
/// requests, and check: every response arrives, stats counters are
/// consistent with the responses, and shutdown joins cleanly.
#[test]
fn router_serves_concurrent_requests() {
    let rt = runtime();
    let qa = load_prompts(&rt, "qa").unwrap();
    let stream = load_prompts(&rt, "stream").unwrap();
    let router = Router::start(
        rt,
        RouterConfig {
            workers: 2,
            method: "dvi".into(),
            online: true,
            objective: Objective::Dvi,
            buffer_capacity: 1024,
            ..RouterConfig::default()
        },
    )
    .unwrap();

    // >= 16 in-flight requests across a mixed workload.
    let samples: Vec<_> = qa
        .samples
        .iter()
        .chain(stream.samples.iter())
        .take(18)
        .collect();
    assert!(samples.len() >= 16, "need at least 16 requests");
    let receivers: Vec<_> = samples
        .iter()
        .map(|s| router.submit(s.prompt.clone(), s.max_new.min(24)))
        .collect();

    let mut workers_seen = std::collections::BTreeSet::new();
    let mut ids = std::collections::BTreeSet::new();
    let mut token_total = 0u64;
    for rx in receivers {
        let resp = rx.recv().expect("response must arrive");
        assert!(!resp.tokens.is_empty(), "empty generation");
        assert!(resp.acceptance >= 0.0 && resp.acceptance <= 1.0);
        token_total += resp.tokens.len() as u64;
        workers_seen.insert(resp.worker);
        ids.insert(resp.id);
    }
    assert_eq!(ids.len(), samples.len(), "duplicate or missing request ids");
    assert_eq!(
        router.stats.served.load(Ordering::Relaxed),
        samples.len() as u64
    );
    assert_eq!(
        router.stats.tokens.load(Ordering::Relaxed),
        token_total,
        "stats token counter inconsistent with responses"
    );
    assert!(router.stats.decode_ns.load(Ordering::Relaxed) > 0);
    // With 2 workers and a large queued burst both should have
    // participated (not guaranteed in theory; tolerate 1).
    assert!(!workers_seen.is_empty());
    router.shutdown(); // must join workers + learner without hanging
}

/// Batched mode: the same burst through one continuous-batching
/// scheduler thread — every response arrives, stats agree, occupancy
/// shows real multiplexing, and shutdown drains cleanly.
#[test]
fn batched_router_serves_concurrent_requests() {
    let rt = runtime();
    let qa = load_prompts(&rt, "qa").unwrap();
    let router = Router::start(
        rt,
        RouterConfig {
            method: "dvi".into(),
            online: true,
            objective: Objective::Dvi,
            buffer_capacity: 1024,
            batched: true,
            max_batch: 4,
            max_slots: 8,
            ..RouterConfig::default()
        },
    )
    .unwrap();

    let samples: Vec<_> = qa.samples.iter().take(12).collect();
    let receivers: Vec<_> = samples
        .iter()
        .map(|s| router.submit(s.prompt.clone(), s.max_new.min(16)))
        .collect();
    let mut ids = std::collections::BTreeSet::new();
    let mut token_total = 0u64;
    for rx in receivers {
        let resp = rx.recv().expect("response must arrive");
        assert!(!resp.tokens.is_empty(), "empty generation");
        token_total += resp.tokens.len() as u64;
        ids.insert(resp.id);
    }
    assert_eq!(ids.len(), samples.len(), "duplicate or missing request ids");
    assert_eq!(
        router.stats.served.load(Ordering::Relaxed),
        samples.len() as u64
    );
    assert_eq!(router.stats.tokens.load(Ordering::Relaxed), token_total);
    let sched = router
        .sched_stats
        .clone()
        .expect("batched mode exposes scheduler stats");
    assert!(
        sched.occupancy() > 1.0,
        "batched router never multiplexed (occupancy {})",
        sched.occupancy()
    );
    assert!(sched.slot_high_water.load(Ordering::Relaxed) <= 8);
    router.shutdown();
}

/// Init failures must surface as an Err from Router::start — never a
/// dead worker pool that hangs submitted requests.
#[test]
fn router_init_failure_propagates() {
    let rt = runtime();
    // Unknown engine.
    assert!(Router::start(
        rt.clone(),
        RouterConfig {
            method: "nope".into(),
            online: false,
            ..RouterConfig::default()
        },
    )
    .is_err());
    // Zero workers can never serve.
    assert!(Router::start(
        rt.clone(),
        RouterConfig { workers: 0, online: false, ..RouterConfig::default() },
    )
    .is_err());
    // Batched mode supports only the state-machine methods (dvi | ar).
    assert!(Router::start(
        rt,
        RouterConfig {
            method: "medusa".into(),
            online: false,
            batched: true,
            ..RouterConfig::default()
        },
    )
    .is_err());
}

#[test]
fn tcp_api_round_trip() {
    let rt = runtime();
    let tok = Arc::new(rt.tokenizer().unwrap());
    let router = Arc::new(
        Router::start(
            rt,
            RouterConfig {
                workers: 1,
                method: "dvi".into(),
                online: false,
                objective: Objective::Dvi,
                buffer_capacity: 64,
                ..RouterConfig::default()
            },
        )
        .unwrap(),
    );
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let handle = std::thread::spawn(move || {
        let _ = api::serve(listener, router, tok, stop2);
    });

    let mut conn = TcpStream::connect(addr).unwrap();

    // Token-id request (works on any vocabulary).
    writeln!(conn, r#"{{"prompt_ids": [1, 10, 11, 12, 3], "max_new": 16}}"#)
        .unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    assert!(j.get("error").is_null(), "API error: {line}");
    assert!(!j.get("tokens").as_arr().unwrap().is_empty());
    assert!(j.get("text").as_str().is_some());

    // Text request over the synthetic vocabulary.
    writeln!(conn, r#"{{"prompt": "w004 w010 w020 <sep>", "max_new": 8}}"#)
        .unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    assert!(j.get("error").is_null(), "API error: {line}");
    assert!(!j.get("tokens").as_arr().unwrap().is_empty());

    // malformed request -> error object, connection stays up
    writeln!(conn, "this is not json").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(!Json::parse(&line).unwrap().get("error").is_null());

    stop.store(true, Ordering::Relaxed);
    drop(conn);
    let _ = handle.join();
}
