//! Prefix-cache integration gates — hermetic on the reference backend.
//!
//! Defining constraint (losslessness): a sequence admitted onto a
//! cached prefix (COW-forked KV + suffix-only prefill) must commit a
//! token stream **bitwise identical** to the same prompt cold-prefilled
//! from scratch. KV rows are pure functions of their token prefix, so
//! attaching rows 0..L of a donor that shares L prompt tokens and
//! recomputing only L.. is exact — not approximate. Proven here across
//! all four serving modes: in-process batched, loopback remote,
//! 2-shard fleet, and adaptive-k.
//!
//! Plus the refcount-lifecycle regressions: killing a shard mid-prefill
//! must release every pinned segment (no leaks — the scheduler's
//! post-tick debug audit runs on every tick of every test here), and
//! eviction under capacity pressure must never change a stream.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use dvi::harness::load_prompts;
use dvi::runtime::remote::server::{spawn_loopback_shard, LoopbackShard};
use dvi::runtime::remote::transport::Connector;
use dvi::runtime::Runtime;
use dvi::sched::{AdaptiveK, CacheConfig, SchedConfig, Scheduler};

const SEED: u64 = 0xCAC4E;

fn runtime() -> Arc<Runtime> {
    Arc::new(Runtime::load_hermetic(SEED).expect("hermetic runtime"))
}

/// Chaos soak factor, mirroring tests/sched.rs: the CI chaos lane
/// (`DVI_TEST_CHAOS=1`) repeats eviction-pressure scenarios.
fn chaos_reps() -> usize {
    match std::env::var("DVI_TEST_CHAOS").as_deref() {
        Ok("") | Err(_) => 1,
        Ok(_) => 3,
    }
}

/// A shared-system-prompt workload: every prompt starts with the same
/// `sys_len`-token preamble, then diverges into a per-request tail —
/// the shape the radix tree exists for.
fn shared_prefix_cases(
    rt: &Runtime,
    n: usize,
    sys_len: usize,
    max_new: usize,
) -> Vec<(Vec<u32>, usize)> {
    let prefill_seq = rt.manifest.spec_usize("prefill_seq").unwrap();
    let stream = load_prompts(rt, "stream").unwrap().shuffled(0x5EED);
    let sys: Vec<u32> = stream.samples[0]
        .prompt
        .iter()
        .cycle()
        .take(sys_len)
        .cloned()
        .collect();
    stream
        .samples
        .iter()
        .take(n)
        .map(|s| {
            let mut p = sys.clone();
            p.extend(s.prompt.iter().cloned());
            p.truncate(prefill_seq.min(sys_len + 16));
            (p, s.max_new.min(max_new))
        })
        .collect()
}

fn cfg(
    adaptive: Option<AdaptiveK>,
    cache_cap: Option<usize>,
) -> SchedConfig {
    SchedConfig {
        method: "dvi".into(),
        max_batch: 4,
        max_slots: 16,
        adaptive,
        cache: cache_cap.map(|capacity| CacheConfig { capacity }),
    }
}

/// Push `cases` through `sched` and return their committed streams in
/// submission order. Reusable across passes on one scheduler (the
/// second pass of the same prompts runs fully warm).
fn drive(
    sched: &mut Scheduler,
    cases: &[(Vec<u32>, usize)],
) -> Vec<Vec<u32>> {
    let ids: Vec<u64> = cases
        .iter()
        .map(|(p, n)| sched.submit(p.clone(), *n))
        .collect();
    sched.run_until_idle(100_000).unwrap();
    let mut done = sched.drain_completed();
    assert_eq!(done.len(), cases.len(), "every sequence must complete");
    done.sort_by_key(|r| r.id);
    ids.iter()
        .zip(done)
        .map(|(&id, r)| {
            assert_eq!(id, r.id);
            r.result.expect("scheduled generation failed").tokens
        })
        .collect()
}

/// Core warm-vs-cold gate, parameterized over the runtime. Three runs:
///   1. cache OFF — the historical cold-prefill reference streams;
///   2. cache ON, empty — later admissions already attach to prefixes
///      donated by earlier ones mid-run (partial-prefix hits);
///   3. cache ON, second pass of identical prompts — every admission is
///      a full-prefix hit.
/// All three must be bitwise identical, and the warm runs must show
/// real hits/shared rows and end with zero pinned segments.
fn assert_warm_equals_cold(
    rt: &Arc<Runtime>,
    adaptive: Option<AdaptiveK>,
    cases: &[(Vec<u32>, usize)],
) {
    let cold = {
        let mut sched =
            Scheduler::new(rt.clone(), cfg(adaptive, None), None).unwrap();
        assert!(sched.cache_stats().is_none(), "cache must be off");
        drive(&mut sched, cases)
    };

    let mut sched =
        Scheduler::new(rt.clone(), cfg(adaptive, Some(64)), None).unwrap();
    let first = drive(&mut sched, cases);
    assert_eq!(
        first, cold,
        "cache-on first pass diverged from cold-prefill streams"
    );
    let second = drive(&mut sched, cases);
    assert_eq!(
        second, cold,
        "fully-warm second pass diverged from cold-prefill streams"
    );

    let cs = sched.cache_stats().expect("cache is on");
    assert!(cs.hits > 0, "no cache hit ever happened: {cs:?}");
    assert!(cs.segments > 0, "no snapshot was ever donated");
    assert!(
        sched.stats.cache_shared_rows.load(Ordering::Relaxed) > 0,
        "hits attached zero KV rows"
    );
    assert_eq!(
        sched.cache_total_refs(),
        Some(0),
        "pinned segments leaked past sequence completion"
    );
    // The second pass admits every sequence on a full-prefix hit, so
    // hits must cover at least that pass.
    assert!(
        cs.hits >= cases.len() as u64,
        "second pass should have been fully warm: {cs:?}"
    );
}

#[test]
fn warm_streams_bitwise_equal_cold_in_process() {
    let rt = runtime();
    let cases = shared_prefix_cases(&rt, 10, 12, 16);
    assert_warm_equals_cold(&rt, None, &cases);
}

#[test]
fn warm_streams_bitwise_equal_cold_adaptive_k() {
    let rt = runtime();
    let cases = shared_prefix_cases(&rt, 10, 12, 16);
    assert_warm_equals_cold(&rt, Some(AdaptiveK::default()), &cases);
}

#[test]
fn warm_streams_bitwise_equal_cold_remote_loopback() {
    let remote = Arc::new(Runtime::load_remote_loopback(SEED).unwrap());
    assert_eq!(remote.backend_name(), "remote");
    let cases = shared_prefix_cases(&remote, 8, 12, 14);
    assert_warm_equals_cold(&remote, None, &cases);
}

/// Sharded loopback fleet (same seed per shard, so shards are bitwise
/// interchangeable) plus per-shard kill handles.
fn sharded_fleet(n: usize) -> (Arc<Runtime>, Vec<LoopbackShard>) {
    let shards: Vec<LoopbackShard> = (0..n)
        .map(|_| {
            spawn_loopback_shard(
                Arc::new(Runtime::load_reference(SEED).unwrap()),
                None,
            )
        })
        .collect();
    let connectors = shards
        .iter()
        .map(|s| Box::new(s.connector.clone()) as Box<dyn Connector>)
        .collect();
    let rt = Runtime::load_remote_sharded_with(connectors)
        .expect("sharded loopback runtime");
    (Arc::new(rt), shards)
}

/// Two-executor fleet: warm admission routes by prefix affinity (a hit
/// forks on the donor's shard; a miss takes the least-loaded placement
/// hint) — and none of that may change a committed stream.
#[test]
fn warm_streams_bitwise_equal_cold_sharded() {
    let (remote, _shards) = sharded_fleet(2);
    assert_eq!(remote.backend_name(), "remote-sharded");
    let cases = shared_prefix_cases(&remote, 8, 12, 14);
    assert_warm_equals_cold(&remote, None, &cases);
}

/// Satellite regression (terminal-path refcounts): kill one executor of
/// a 2-shard fleet while warm-admitted sequences are mid-prefill. The
/// failed lanes' pins must be released on the `fail_lane` path exactly
/// like completions — afterwards the tree holds zero references and the
/// scheduler still serves. (The scheduler's post-tick debug audit also
/// cross-checks refs == attached lanes on every tick of the drain.)
#[test]
fn shard_kill_mid_prefill_releases_every_cache_pin() {
    let (remote, shards) = sharded_fleet(2);
    let cases = shared_prefix_cases(&remote, 10, 12, 14);
    let mut sched =
        Scheduler::new(remote.clone(), cfg(None, Some(64)), None).unwrap();

    // Warm-up pass: populate the cache (donations end unpinned).
    drive(&mut sched, &cases);
    assert_eq!(sched.cache_total_refs(), Some(0));
    let warm_segments = sched.cache_stats().unwrap().segments;
    assert!(warm_segments > 0, "warm-up donated nothing");

    // Second pass: every admission pins a segment. One tick admits all
    // of them and issues the shallow prefills — then the kill lands
    // while the deep prefills are still owed.
    for (p, n) in &cases {
        sched.submit(p.clone(), *n);
    }
    sched.tick().unwrap();
    let pinned = sched.cache_total_refs().unwrap();
    assert!(pinned > 0, "no admission pinned a cache segment");
    shards[1].kill.kill();
    sched.run_until_idle(100_000).unwrap();

    let done = sched.drain_completed();
    assert_eq!(done.len(), cases.len(), "every sequence must terminate");
    let errs = done.iter().filter(|r| r.result.is_err()).count();
    assert!(errs >= 1, "the killed shard hosted no in-flight sequence");
    assert!(errs < cases.len(), "the surviving shard served nothing");
    assert_eq!(
        sched.cache_total_refs(),
        Some(0),
        "a failed lane leaked its pinned segment"
    );
    assert_eq!(
        sched.stats.failed.load(Ordering::Relaxed) as usize,
        errs,
        "failure accounting diverged"
    );
}

/// Eviction under capacity pressure (soaked by the CI chaos lane):
/// with room for only 2 segments and 10 distinct prompts, inserts must
/// evict continuously — and neither eviction nor the resulting cold
/// re-prefills may change a single committed token. Live-reader safety
/// (pinned segments never reclaimed) is enforced structurally by the
/// tree and audited per-tick by the scheduler.
#[test]
fn chaos_eviction_under_capacity_pressure_stays_lossless() {
    for _ in 0..chaos_reps() {
        let rt = runtime();
        let cases = shared_prefix_cases(&rt, 10, 12, 14);
        let cold = {
            let mut sched =
                Scheduler::new(rt.clone(), cfg(None, None), None).unwrap();
            drive(&mut sched, &cases)
        };
        let mut sched =
            Scheduler::new(rt.clone(), cfg(None, Some(2)), None).unwrap();
        let first = drive(&mut sched, &cases);
        let second = drive(&mut sched, &cases);
        assert_eq!(first, cold, "evicting cache changed a committed stream");
        assert_eq!(second, cold, "second pass under eviction diverged");
        let cs = sched.cache_stats().unwrap();
        assert!(cs.evictions > 0, "capacity 2 never evicted: {cs:?}");
        assert!(cs.segments <= 2, "capacity overrun: {cs:?}");
        assert_eq!(sched.cache_total_refs(), Some(0));
    }
}

/// Satellite (per-task acceptance priors): tagged submissions fold
/// their final acceptance EMA into a decayed per-task prior, and later
/// sequences of that task seed their adaptive-k EMA from it instead of
/// the optimistic 1.0. Any seed is lossless — the streams must stay
/// bitwise identical to the untagged pinned-k reference.
#[test]
fn task_priors_seed_adaptive_k_without_changing_streams() {
    let rt = runtime();
    let cases = shared_prefix_cases(&rt, 8, 12, 16);
    let golden = {
        let mut sched =
            Scheduler::new(rt.clone(), cfg(None, None), None).unwrap();
        drive(&mut sched, &cases)
    };

    let mut sched = Scheduler::new(
        rt.clone(),
        cfg(Some(AdaptiveK::default()), Some(64)),
        None,
    )
    .unwrap();
    for pass in 0..2 {
        let ids: Vec<u64> = cases
            .iter()
            .map(|(p, n)| sched.submit_tagged(p.clone(), *n, "stream"))
            .collect();
        sched.run_until_idle(100_000).unwrap();
        let mut done = sched.drain_completed();
        assert_eq!(done.len(), cases.len());
        done.sort_by_key(|r| r.id);
        let got: Vec<Vec<u32>> = ids
            .iter()
            .zip(done)
            .map(|(&id, r)| {
                assert_eq!(id, r.id);
                r.result.expect("generation failed").tokens
            })
            .collect();
        assert_eq!(
            got, golden,
            "prior-seeded adaptive-k diverged on pass {pass}"
        );
        // After pass 0 the prior exists; pass 1's sequences seeded from
        // it (and still matched the reference bitwise).
        let priors = sched.stats.task_priors_snapshot();
        let (_, prior) = priors
            .iter()
            .find(|(t, _)| t == "stream")
            .expect("tagged completions must create the task prior");
        assert!(
            *prior > 0.0 && *prior <= 1.0,
            "prior out of range: {prior}"
        );
        assert_eq!(sched.stats.task_prior(Some("stream")), *prior);
        assert_eq!(
            sched.stats.task_prior(None),
            1.0,
            "untagged requests must keep the optimistic seed"
        );
    }
}
