//! Rust <-> Python numerics parity over the AOT bridge.
//!
//! `python/compile/testvec.py` ran every core artifact in JAX on
//! deterministic inputs and dumped inputs + expected outputs into
//! `artifacts/testvecs.bin`. Here we execute the *compiled HLO* through
//! PJRT with the same inputs and assert allclose — covering lowering, the
//! HLO-text round-trip, compilation, manifest ordering, buffer roles, and
//! the Pallas-interpret kernels, end to end.
//!
//! Requires `make artifacts` (skipped, with a loud marker, otherwise).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use dvi::runtime::{load_weights, Role, Runtime, Tensor, WeightMap};

fn artifacts_dir() -> PathBuf {
    std::env::var("DVI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
        && artifacts_dir().join("testvecs.bin").exists()
}

struct Harness {
    rt: Arc<Runtime>,
    vecs: WeightMap,
}

fn harness(names: &[&str]) -> Harness {
    let dir = artifacts_dir();
    let rt = Runtime::load(&dir, Some(names)).expect("runtime load");
    let vecs = load_weights(&dir.join("testvecs.bin")).expect("testvecs");
    Harness { rt: Arc::new(rt), vecs }
}

/// Execute one artifact with its golden inputs; compare every output.
fn check_artifact(h: &Harness, name: &str, atol: f32) {
    let art = h.rt.artifact(name).expect("artifact");
    let spec = art.spec.clone();

    // Globals in the testvec override the store's initial values.
    for port in spec.params_with_role(Role::Global) {
        let key = format!("{name}.in.{}", port.name);
        let t = h.vecs.get(&key).expect(&key);
        let buf = dvi::runtime::artifact::upload(&h.rt.client, t).unwrap();
        h.rt.store.set_global(&port.name, Arc::new(buf));
    }
    let kv: Vec<_> = spec
        .params_with_role(Role::Kv)
        .map(|port| {
            let key = format!("{name}.in.{}", port.name);
            let t = h.vecs.get(&key).expect(&key);
            Arc::new(dvi::runtime::artifact::upload(&h.rt.client, t).unwrap())
        })
        .collect();
    let inputs: Vec<Tensor> = spec
        .params_with_role(Role::In)
        .map(|port| h.vecs.get(&format!("{name}.in.{}", port.name))
             .expect(&port.name).clone())
        .collect();

    let out = art.call(&h.rt.store, &kv, &inputs).expect("call");

    let mut host_iter = out.outputs.iter();
    let mut kv_iter = out.kv.iter();
    let mut checked = 0;
    for port in &spec.outputs {
        let key = format!("{name}.out.{}", port.name);
        let want = h.vecs.get(&key).expect(&key);
        let got: Tensor = match port.role {
            Role::Out => host_iter.next().unwrap().clone(),
            Role::Kv => dvi::runtime::artifact::download(
                kv_iter.next().unwrap(), port.dtype, &port.shape)
                .unwrap(),
            Role::Global => {
                let buf = h.rt.store.global(&port.name).unwrap();
                dvi::runtime::artifact::download(&buf, port.dtype, &port.shape)
                    .unwrap()
            }
            _ => unreachable!(),
        };
        match want.dtype() {
            dvi::runtime::DType::F32 => {
                let diff = got.max_abs_diff(want).unwrap();
                assert!(
                    diff <= atol,
                    "{name}.{}: max|diff| = {diff} > {atol}",
                    port.name
                );
            }
            dvi::runtime::DType::I32 => {
                assert_eq!(got.as_i32().unwrap(), want.as_i32().unwrap(),
                           "{name}.{}", port.name);
            }
        }
        checked += 1;
    }
    assert!(checked > 0);
    // Restore globals for subsequent artifacts.
    for port in spec.params_with_role(Role::Global) {
        h.rt.reset_global(&port.name).unwrap();
    }
}

fn artifact_exported(name: &str) -> bool {
    dvi::runtime::Manifest::load(&artifacts_dir())
        .map(|m| m.artifacts.contains_key(name))
        .unwrap_or(false)
}

macro_rules! parity_test {
    ($fn_name:ident, $artifact:literal, $atol:expr) => {
        #[test]
        fn $fn_name() {
            if !have_artifacts() || !artifact_exported($artifact) {
                eprintln!("SKIP {}: run `make artifacts` first", $artifact);
                return;
            }
            let h = harness(&[$artifact]);
            check_artifact(&h, $artifact, $atol);
        }
    };
}

parity_test!(parity_draft_step, "draft_step", 5e-4);
parity_test!(parity_verify_block, "verify_block", 5e-4);
parity_test!(parity_train_step, "train_step", 5e-4);
parity_test!(parity_prefill_shallow, "prefill_shallow", 5e-4);
parity_test!(parity_prefill_deep, "prefill_deep", 5e-4);
parity_test!(parity_prefill_full, "prefill_full", 5e-4);
parity_test!(parity_target_step, "target_step", 5e-4);
parity_test!(parity_target_verify_block, "target_verify_block", 5e-4);
parity_test!(parity_medusa_heads, "medusa_heads", 5e-4);
parity_test!(parity_hydra_chain, "hydra_chain", 5e-4);
parity_test!(parity_eagle_step, "eagle_step", 5e-4);

/// BufferStore globals must survive a round-trip through train_step: the
/// updated LoRA buffers feed the next draft_step (the online-learning
/// contract). We run train_step twice and check the global *changed*.
#[test]
fn train_step_updates_globals() {
    if !have_artifacts() {
        eprintln!("SKIP train_step_updates_globals");
        return;
    }
    let h = harness(&["train_step"]);
    let art = h.rt.artifact("train_step").unwrap();
    let spec = art.spec.clone();
    let inputs: Vec<Tensor> = spec
        .params_with_role(Role::In)
        .map(|port| h.vecs.get(&format!("train_step.in.{}", port.name))
             .unwrap().clone())
        .collect();

    let before = {
        let buf = h.rt.store.global("lora.A").unwrap();
        let port = spec.params.iter().find(|p| p.name == "lora.A").unwrap();
        dvi::runtime::artifact::download(&buf, port.dtype, &port.shape).unwrap()
    };
    art.call(&h.rt.store, &[], &inputs).unwrap();
    let after = {
        let buf = h.rt.store.global("lora.A").unwrap();
        let port = spec.params.iter().find(|p| p.name == "lora.A").unwrap();
        dvi::runtime::artifact::download(&buf, port.dtype, &port.shape).unwrap()
    };
    let diff = before.max_abs_diff(&after).unwrap();
    assert!(diff > 0.0, "train_step left lora.A unchanged");

    // And reset_global restores the initial value.
    h.rt.reset_global("lora.A").unwrap();
    let reset = {
        let buf = h.rt.store.global("lora.A").unwrap();
        let port = spec.params.iter().find(|p| p.name == "lora.A").unwrap();
        dvi::runtime::artifact::download(&buf, port.dtype, &port.shape).unwrap()
    };
    assert_eq!(reset.max_abs_diff(&before).unwrap(), 0.0);
}

/// Shape mismatches must fail loudly, not corrupt a decode.
#[test]
fn call_rejects_bad_input_shape() {
    if !have_artifacts() {
        eprintln!("SKIP call_rejects_bad_input_shape");
        return;
    }
    let h = harness(&["train_step"]);
    let art = h.rt.artifact("train_step").unwrap();
    let bad = Tensor::zeros_f32(vec![7]); // hk must be [N, d_model]
    let err = art.call(&h.rt.store, &[], &[bad]);
    assert!(err.is_err());
}
