//! Rust <-> Python numerics parity over the AOT bridge (PJRT only).
//!
//! `python/compile/testvec.py` ran every core artifact in JAX on
//! deterministic inputs and dumped inputs + expected outputs into
//! `artifacts/testvecs.bin`. Here we execute the *compiled HLO* through
//! PJRT with the same inputs and assert allclose — covering lowering, the
//! HLO-text round-trip, compilation, manifest ordering, buffer roles, and
//! the Pallas-interpret kernels, end to end.
//!
//! These tests are inherently non-hermetic: they need the `pjrt` cargo
//! feature AND a `make artifacts` export (pointed at by `DVI_ARTIFACTS`).
//! Without either they skip with a loud marker — the hermetic invariant
//! suite in `tests/engines.rs` runs on the reference backend instead.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use dvi::runtime::{load_weights, Role, Runtime, Tensor, WeightMap};

fn artifacts_dir() -> PathBuf {
    std::env::var("DVI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn have_pjrt_artifacts() -> bool {
    cfg!(feature = "pjrt")
        && artifacts_dir().join("manifest.json").exists()
        && artifacts_dir().join("testvecs.bin").exists()
}

struct Harness {
    rt: Arc<Runtime>,
    vecs: WeightMap,
}

fn harness(names: &[&str]) -> Harness {
    let dir = artifacts_dir();
    let rt = Runtime::load(&dir, Some(names)).expect("pjrt runtime load");
    let vecs = load_weights(&dir.join("testvecs.bin")).expect("testvecs");
    Harness { rt: Arc::new(rt), vecs }
}

/// Execute one artifact with its golden inputs; compare every output.
fn check_artifact(h: &Harness, name: &str, atol: f32) {
    let art = h.rt.artifact(name).expect("artifact");
    let spec = art.spec.clone();

    // Globals in the testvec override the store's initial values.
    for port in spec.params_with_role(Role::Global) {
        let key = format!("{name}.in.{}", port.name);
        let t = h.vecs.get(&key).expect(&key);
        h.rt.set_global(&port.name, t).unwrap();
    }
    let kv: Vec<_> = spec
        .params_with_role(Role::Kv)
        .map(|port| {
            let key = format!("{name}.in.{}", port.name);
            let t = h.vecs.get(&key).expect(&key);
            h.rt.upload(t).unwrap()
        })
        .collect();
    let inputs: Vec<Tensor> = spec
        .params_with_role(Role::In)
        .map(|port| h.vecs.get(&format!("{name}.in.{}", port.name))
             .expect(&port.name).clone())
        .collect();

    let out = art.call(&kv, &inputs).expect("call");

    let mut host_iter = out.outputs.iter();
    let mut kv_iter = out.kv.iter();
    let mut checked = 0;
    for port in &spec.outputs {
        let key = format!("{name}.out.{}", port.name);
        let want = h.vecs.get(&key).expect(&key);
        let got: Tensor = match port.role {
            Role::Out => host_iter.next().unwrap().clone(),
            Role::Kv => h
                .rt
                .to_host(kv_iter.next().unwrap(), port.dtype, &port.shape)
                .unwrap(),
            Role::Global => h.rt.read_global(&port.name).unwrap(),
            _ => unreachable!(),
        };
        match want.dtype() {
            dvi::runtime::DType::F32 => {
                let diff = got.max_abs_diff(want).unwrap();
                assert!(
                    diff <= atol,
                    "{name}.{}: max|diff| = {diff} > {atol}",
                    port.name
                );
            }
            dvi::runtime::DType::I32 => {
                assert_eq!(got.as_i32().unwrap(), want.as_i32().unwrap(),
                           "{name}.{}", port.name);
            }
        }
        checked += 1;
    }
    assert!(checked > 0);
    // Restore globals for subsequent artifacts.
    for port in spec.params_with_role(Role::Global) {
        h.rt.reset_global(&port.name).unwrap();
    }
}

fn artifact_exported(name: &str) -> bool {
    dvi::runtime::Manifest::load(&artifacts_dir())
        .map(|m| m.artifacts.contains_key(name))
        .unwrap_or(false)
}

macro_rules! parity_test {
    ($fn_name:ident, $artifact:literal, $atol:expr) => {
        #[test]
        fn $fn_name() {
            if !have_pjrt_artifacts() || !artifact_exported($artifact) {
                eprintln!(
                    "SKIP {}: needs --features pjrt and `make artifacts`",
                    $artifact
                );
                return;
            }
            let h = harness(&[$artifact]);
            check_artifact(&h, $artifact, $atol);
        }
    };
}

parity_test!(parity_draft_step, "draft_step", 5e-4);
parity_test!(parity_verify_block, "verify_block", 5e-4);
parity_test!(parity_train_step, "train_step", 5e-4);
parity_test!(parity_prefill_shallow, "prefill_shallow", 5e-4);
parity_test!(parity_prefill_deep, "prefill_deep", 5e-4);
parity_test!(parity_prefill_full, "prefill_full", 5e-4);
parity_test!(parity_target_step, "target_step", 5e-4);
parity_test!(parity_target_verify_block, "target_verify_block", 5e-4);
parity_test!(parity_medusa_heads, "medusa_heads", 5e-4);
parity_test!(parity_hydra_chain, "hydra_chain", 5e-4);
parity_test!(parity_eagle_step, "eagle_step", 5e-4);

/// Globals must survive a round-trip through train_step: the updated
/// LoRA buffers feed the next draft_step (the online-learning
/// contract). We run train_step and check the global *changed*, then
/// that reset restores the initial value.
#[test]
fn train_step_updates_globals() {
    if !have_pjrt_artifacts() {
        eprintln!("SKIP train_step_updates_globals: needs pjrt artifacts");
        return;
    }
    let h = harness(&["train_step"]);
    let art = h.rt.artifact("train_step").unwrap();
    let spec = art.spec.clone();
    let inputs: Vec<Tensor> = spec
        .params_with_role(Role::In)
        .map(|port| h.vecs.get(&format!("train_step.in.{}", port.name))
             .unwrap().clone())
        .collect();

    let before = h.rt.read_global("lora.A").unwrap();
    art.call(&[], &inputs).unwrap();
    let after = h.rt.read_global("lora.A").unwrap();
    let diff = before.max_abs_diff(&after).unwrap();
    assert!(diff > 0.0, "train_step left lora.A unchanged");

    // And reset_global restores the initial value.
    h.rt.reset_global("lora.A").unwrap();
    let reset = h.rt.read_global("lora.A").unwrap();
    assert_eq!(reset.max_abs_diff(&before).unwrap(), 0.0);
}

/// Shape mismatches must fail loudly, not corrupt a decode. This
/// contract is backend-independent, so check it hermetically on the
/// reference backend (and implicitly on PJRT via the shared
/// `Artifact::call` validation layer).
#[test]
fn call_rejects_bad_input_shape() {
    let rt = Runtime::load_reference(1).unwrap();
    let art = rt.artifact("train_step").unwrap();
    let bad = Tensor::zeros_f32(vec![7]); // hk must be [N, d_model]
    let err = art.call(&[], &[bad]);
    assert!(err.is_err());
}
