//! Cross-request prefix/KV reuse: a radix tree over committed token
//! ids whose nodes own refcounted **KV segments** — immutable snapshots
//! of a sequence's shallow-drafter and deep-verifier caches taken at
//! the prompt boundary.
//!
//! ## Why attaching a cached prefix is lossless
//!
//! Row `j` of every KV cache in this repo is a pure function of tokens
//! `0..=j` (causal attention, deterministic kernels), and KV buffers
//! are **immutable**: each artifact call returns new buffers instead of
//! mutating its inputs. Two consequences the cache is built on:
//!
//!   1. A segment snapshotted from prompt `A` can seed a sequence with
//!      prompt `B` at `attach_len = common_prefix(A, B)`: rows below
//!      the attach point are bitwise identical to what a cold prefill
//!      of `B` would compute, and rows at/above it are stale in *both*
//!      the warm and cold paths (always overwritten before they are
//!      attended). So a segment stored at one node is usable at **any**
//!      prefix length of its path, and the tree's longest-prefix match
//!      is exactly the best attach point.
//!   2. Inserting a segment is a handle clone, not a tensor copy, and
//!      the copy-on-write "fork" at the divergence point
//!      ([`crate::runtime::Backend::fork_kv`]) is handle aliasing too
//!      — the first suffix-prefill call after the attach returns fresh
//!      buffers, which is where the write actually goes.
//!
//! ## Ownership & eviction
//!
//! Segments are refcounted: a lookup pins the segment until the
//! scheduler's terminal path for that sequence releases it (exactly
//! once — `fail_lane`, drain, admission-reject all funnel through one
//! release). Eviction is LRU over **leaf** segments with refcount 0
//! (no pinned reader, no deeper segment extending the path) and is
//! preemption-free: when the capacity is reached and nothing is
//! evictable, the insert is skipped rather than anything reclaimed
//! from under a reader.
//!
//! The tree is single-owner (it lives inside the scheduler, which is
//! single-threaded per serving loop); no interior locking.

use crate::runtime::Buffer;

/// An immutable KV snapshot covering every prefix of the owning node's
/// path. `shallow`/`deep` hold the drafter-layer and verifier-layer
/// cache buffers in manifest port order.
struct Segment {
    shallow: Vec<Buffer>,
    deep: Vec<Buffer>,
    /// Live readers (sequences between lookup and terminal release).
    refs: usize,
    /// Logical LRU clock stamp (updated on insert/hit/release).
    last_use: u64,
}

struct Node {
    /// Token run on the edge from `parent` to this node.
    edge: Vec<u32>,
    /// Total tokens from the root through `edge` (== path length).
    depth: usize,
    parent: usize,
    /// First edge token -> child index; BTreeMap so traversal order is
    /// deterministic.
    children: std::collections::BTreeMap<u32, usize>,
    seg: Option<Segment>,
}

/// Pinned reference to a cache segment, returned by
/// [`PrefixCache::lookup`]. Must be handed back to
/// [`PrefixCache::release`] exactly once; the segment cannot be evicted
/// while any reference is outstanding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegRef(usize);

/// A successful prefix lookup: how many leading tokens of the query the
/// segment covers, plus the pinned segment itself.
pub struct Hit {
    pub attach_len: usize,
    pub seg: SegRef,
}

#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Live segments currently resident.
    pub segments: u64,
}

pub struct PrefixCache {
    nodes: Vec<Node>,
    /// Recycled node slots (freed by pruning after eviction).
    free: Vec<usize>,
    /// Max resident segments; reaching it triggers LRU eviction of an
    /// unpinned leaf segment, or skips the insert if none exists.
    capacity: usize,
    segments: usize,
    clock: u64,
    stats: CacheStats,
}

impl PrefixCache {
    pub fn new(capacity: usize) -> PrefixCache {
        assert!(capacity >= 1, "prefix cache needs capacity >= 1");
        PrefixCache {
            nodes: vec![Node {
                edge: Vec::new(),
                depth: 0,
                parent: 0,
                children: std::collections::BTreeMap::new(),
                seg: None,
            }],
            free: Vec::new(),
            capacity,
            segments: 0,
            clock: 0,
            stats: CacheStats::default(),
        }
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats { segments: self.segments as u64, ..self.stats }
    }

    /// Total outstanding pinned references across every segment — the
    /// scheduler's post-tick invariant compares this against its live
    /// attachments.
    pub fn total_refs(&self) -> usize {
        self.nodes
            .iter()
            .filter_map(|n| n.seg.as_ref())
            .map(|s| s.refs)
            .sum()
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Walk the tree matching `tokens`; returns (node reached, tokens
    /// matched, whether the walk ended part-way down an edge or before
    /// consuming all of `tokens`).
    fn walk(&self, tokens: &[u32]) -> (usize, usize) {
        let mut at = 0usize;
        let mut matched = 0usize;
        while matched < tokens.len() {
            let Some(&child) = self.nodes[at].children.get(&tokens[matched])
            else {
                break;
            };
            let edge = &self.nodes[child].edge;
            let common = edge
                .iter()
                .zip(&tokens[matched..])
                .take_while(|(a, b)| a == b)
                .count();
            matched += common;
            if common < edge.len() {
                // Diverged (or ran out of query) mid-edge: everything
                // under `child` still shares our first `matched` tokens.
                return (child, matched);
            }
            at = child;
        }
        (at, matched)
    }

    /// First segment-bearing node in the subtree rooted at `at`
    /// (deterministic preorder over the BTreeMap child order).
    fn seg_in_subtree(&self, at: usize) -> Option<usize> {
        let mut stack = vec![at];
        while let Some(n) = stack.pop() {
            if self.nodes[n].seg.is_some() {
                return Some(n);
            }
            // Push in reverse so the smallest first-token child pops
            // first.
            for &c in self.nodes[n].children.values().rev() {
                stack.push(c);
            }
        }
        None
    }

    /// Longest cached prefix of `tokens`. On a hit the segment's
    /// refcount is incremented (pinned until [`PrefixCache::release`]).
    /// Queries whose best match is empty count as misses.
    pub fn lookup(&mut self, tokens: &[u32]) -> Option<Hit> {
        let (end, matched) = self.walk(tokens);
        // Best candidate: any segment at/below the divergence point
        // covers all `matched` tokens (its path shares them). Failing
        // that, the deepest segment on the path above covers its own
        // (shorter) depth.
        let mut found: Option<(usize, usize)> = self
            .seg_in_subtree(end)
            .map(|n| (n, matched.min(self.nodes[n].depth)));
        if found.is_none() {
            let mut at = self.nodes[end].parent;
            loop {
                if self.nodes[at].seg.is_some() {
                    found = Some((at, self.nodes[at].depth));
                    break;
                }
                if at == 0 {
                    break;
                }
                at = self.nodes[at].parent;
            }
        }
        match found {
            Some((node, attach_len)) if attach_len > 0 => {
                let stamp = self.tick();
                let seg = self.nodes[node].seg.as_mut().expect("seg present");
                seg.refs += 1;
                seg.last_use = stamp;
                self.stats.hits += 1;
                Some(Hit { attach_len, seg: SegRef(node) })
            }
            _ => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Borrow a pinned segment's KV buffer sets (shallow, deep).
    pub fn segment_kv(&self, r: SegRef) -> (&[Buffer], &[Buffer]) {
        let seg = self.nodes[r.0].seg.as_ref().expect("released segment");
        (&seg.shallow, &seg.deep)
    }

    /// Release one pinned reference. Each [`Hit`] must be released
    /// exactly once.
    pub fn release(&mut self, r: SegRef) {
        let stamp = self.tick();
        let seg = self.nodes[r.0]
            .seg
            .as_mut()
            .expect("release on an evicted segment (refcount underflow?)");
        assert!(seg.refs > 0, "segment refcount underflow");
        seg.refs -= 1;
        seg.last_use = stamp;
    }

    fn alloc_node(&mut self, node: Node) -> usize {
        if let Some(i) = self.free.pop() {
            self.nodes[i] = node;
            i
        } else {
            self.nodes.push(node);
            self.nodes.len() - 1
        }
    }

    /// Locate (creating/splitting as needed) the node whose path is
    /// exactly `tokens`.
    fn node_at(&mut self, tokens: &[u32]) -> usize {
        let mut at = 0usize;
        let mut consumed = 0usize;
        while consumed < tokens.len() {
            let first = tokens[consumed];
            let Some(&child) = self.nodes[at].children.get(&first) else {
                // No branch: the whole remainder becomes one edge.
                let node = Node {
                    edge: tokens[consumed..].to_vec(),
                    depth: tokens.len(),
                    parent: at,
                    children: std::collections::BTreeMap::new(),
                    seg: None,
                };
                let idx = self.alloc_node(node);
                self.nodes[at].children.insert(first, idx);
                return idx;
            };
            let common = self.nodes[child]
                .edge
                .iter()
                .zip(&tokens[consumed..])
                .take_while(|(a, b)| a == b)
                .count();
            if common == self.nodes[child].edge.len() {
                consumed += common;
                at = child;
                continue;
            }
            // Split `child`'s edge at the divergence point: a new
            // interior node takes the shared run, the old child keeps
            // the tail (with its subtree and segment untouched).
            let tail = self.nodes[child].edge.split_off(common);
            let shared = std::mem::take(&mut self.nodes[child].edge);
            let mid_depth = self.nodes[child].depth - tail.len();
            let mid = self.alloc_node(Node {
                edge: shared,
                depth: mid_depth,
                parent: at,
                children: std::collections::BTreeMap::new(),
                seg: None,
            });
            self.nodes[child].edge = tail;
            self.nodes[child].parent = mid;
            let tail_first = self.nodes[child].edge[0];
            self.nodes[mid].children.insert(tail_first, child);
            self.nodes[at].children.insert(first, mid);
            consumed += common;
            at = mid;
        }
        at
    }

    /// True if any descendant of `n` (excluding `n`) owns a segment.
    fn has_deeper_seg(&self, n: usize) -> bool {
        self.nodes[n]
            .children
            .values()
            .any(|&c| self.seg_in_subtree(c).is_some())
    }

    /// Evict the least-recently-used unpinned **leaf** segment. Returns
    /// false when every segment is pinned or extended by a deeper one.
    fn evict_one(&mut self) -> bool {
        let victim = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(i, n)| {
                n.seg.as_ref().is_some_and(|s| s.refs == 0)
                    && !self.has_deeper_seg(*i)
            })
            .min_by_key(|(_, n)| n.seg.as_ref().expect("filtered").last_use)
            .map(|(i, _)| i);
        let Some(victim) = victim else {
            return false;
        };
        self.nodes[victim].seg = None;
        self.segments -= 1;
        self.stats.evictions += 1;
        self.prune_from(victim);
        true
    }

    /// Prune now-useless leaf nodes (no children, no segment) from `at`
    /// upward so dead paths do not accrete.
    fn prune_from(&mut self, at: usize) {
        let mut at = at;
        while at != 0
            && self.nodes[at].children.is_empty()
            && self.nodes[at].seg.is_none()
        {
            let parent = self.nodes[at].parent;
            let first = self.nodes[at].edge[0];
            self.nodes[parent].children.remove(&first);
            self.nodes[at].edge.clear();
            self.free.push(at);
            at = parent;
        }
    }

    /// Insert a snapshot for `tokens`. Skipped (returning false) when
    /// the path already owns a segment (the resident one is refreshed —
    /// snapshots of the same committed prefix are bitwise identical by
    /// construction) or when the cache is full and nothing is
    /// evictable. Empty token runs are never cached.
    pub fn insert(
        &mut self,
        tokens: &[u32],
        shallow: Vec<Buffer>,
        deep: Vec<Buffer>,
    ) -> bool {
        if tokens.is_empty() {
            return false;
        }
        let node = self.node_at(tokens);
        if self.nodes[node].seg.is_some() {
            let stamp = self.tick();
            let seg = self.nodes[node].seg.as_mut().expect("seg present");
            seg.last_use = stamp;
            return false;
        }
        if self.segments >= self.capacity && !self.evict_one() {
            // Preemption-free skip: undo the (seg-less) path the walk
            // may have created so refused inserts don't accrete nodes.
            self.prune_from(node);
            return false;
        }
        let stamp = self.tick();
        self.nodes[node].seg =
            Some(Segment { shallow, deep, refs: 0, last_use: stamp });
        self.segments += 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Tensor;
    use crate::util::prop::run_prop;
    use crate::util::rng::Rng;

    fn buf() -> Vec<Buffer> {
        vec![Buffer::host(Tensor::zeros_f32(vec![1]))]
    }

    fn toks(rng: &mut Rng, len: usize) -> Vec<u32> {
        (0..len).map(|_| rng.below(4) as u32).collect()
    }

    fn common_prefix(a: &[u32], b: &[u32]) -> usize {
        a.iter().zip(b).take_while(|(x, y)| x == y).count()
    }

    #[test]
    fn insert_then_exact_and_partial_lookup() {
        let mut c = PrefixCache::new(8);
        assert!(c.insert(&[1, 2, 3], buf(), buf()));
        let hit = c.lookup(&[1, 2, 3, 9]).expect("prefix hit");
        assert_eq!(hit.attach_len, 3);
        c.release(hit.seg);
        let hit = c.lookup(&[1, 2, 7]).expect("partial hit");
        assert_eq!(hit.attach_len, 2, "mid-edge divergence attaches at 2");
        c.release(hit.seg);
        assert!(c.lookup(&[5, 5]).is_none(), "disjoint prompt must miss");
        assert_eq!(c.stats().hits, 2);
        assert_eq!(c.stats().misses, 1);
    }

    #[test]
    fn split_at_divergence_preserves_both_paths() {
        let mut c = PrefixCache::new(8);
        assert!(c.insert(&[1, 2, 3, 4], buf(), buf()));
        assert!(c.insert(&[1, 2, 9], buf(), buf()));
        for (query, want) in
            [(vec![1, 2, 3, 4], 4), (vec![1, 2, 9], 3), (vec![1, 2, 5], 2)]
        {
            let hit = c.lookup(&query).expect("hit");
            assert_eq!(hit.attach_len, want, "query {query:?}");
            c.release(hit.seg);
        }
    }

    #[test]
    fn prop_longest_prefix_matches_reference_model() {
        run_prop("cache-longest-prefix", 64, |rng| {
            // Unbounded capacity: the tree must agree with the brute
            // force longest-common-prefix over every inserted prompt.
            let mut c = PrefixCache::new(1 << 20);
            let mut model: Vec<Vec<u32>> = Vec::new();
            for _ in 0..rng.usize_below(12) {
                let t = toks(rng, 1 + rng.usize_below(10));
                c.insert(&t, buf(), buf());
                model.push(t);
            }
            for _ in 0..8 {
                let q = toks(rng, 1 + rng.usize_below(10));
                let want = model
                    .iter()
                    .map(|m| common_prefix(m, &q))
                    .max()
                    .unwrap_or(0);
                match c.lookup(&q) {
                    Some(hit) => {
                        assert_eq!(hit.attach_len, want, "query {q:?}");
                        c.release(hit.seg);
                    }
                    None => assert_eq!(want, 0, "missed query {q:?}"),
                }
            }
            assert_eq!(c.total_refs(), 0, "lookup/release must balance");
        });
    }

    #[test]
    fn prop_refcounts_balance_under_random_interleavings() {
        run_prop("cache-refcount-monotone", 64, |rng| {
            let mut c = PrefixCache::new(16);
            let mut held: Vec<SegRef> = Vec::new();
            for _ in 0..40 {
                match rng.usize_below(3) {
                    0 => {
                        let t = toks(rng, 1 + rng.usize_below(8));
                        c.insert(&t, buf(), buf());
                    }
                    1 => {
                        let q = toks(rng, 1 + rng.usize_below(8));
                        if let Some(hit) = c.lookup(&q) {
                            held.push(hit.seg);
                        }
                    }
                    _ => {
                        if !held.is_empty() {
                            let i = rng.usize_below(held.len());
                            c.release(held.swap_remove(i));
                        }
                    }
                }
                assert_eq!(
                    c.total_refs(),
                    held.len(),
                    "total refcounts must equal live attachments"
                );
            }
            for r in held.drain(..) {
                c.release(r);
            }
            assert_eq!(c.total_refs(), 0);
        });
    }

    #[test]
    fn prop_eviction_never_reclaims_a_pinned_segment() {
        run_prop("cache-eviction-respects-pins", 48, |rng| {
            let cap = 2 + rng.usize_below(3);
            let mut c = PrefixCache::new(cap);
            // Pin `cap` distinct single-branch segments.
            let mut pinned: Vec<(Vec<u32>, SegRef)> = Vec::new();
            for i in 0..cap {
                let t = vec![i as u32 + 10, 1, 2];
                assert!(c.insert(&t, buf(), buf()));
                let hit = c.lookup(&t).expect("fresh insert must hit");
                assert_eq!(hit.attach_len, t.len());
                pinned.push((t, hit.seg));
            }
            // Flood with inserts: every one must be skipped (full, all
            // pinned) and every pinned segment must stay resident.
            for _ in 0..10 {
                let t = toks(rng, 1 + rng.usize_below(6));
                let before = c.stats().segments;
                c.insert(&t, buf(), buf());
                assert_eq!(c.stats().evictions, 0, "evicted a pinned segment");
                assert_eq!(c.stats().segments, before);
            }
            for (t, r) in pinned.drain(..) {
                let hit = c.lookup(&t).expect("pinned segment vanished");
                assert_eq!(hit.attach_len, t.len());
                c.release(hit.seg);
                c.release(r);
            }
            // Everything unpinned now: the next insert may evict.
            let before = c.stats().segments;
            assert!(c.insert(&[7, 7, 7, 7], buf(), buf()));
            assert_eq!(c.stats().segments, before, "evict-then-insert at cap");
            assert_eq!(c.stats().evictions, 1);
        });
    }

    #[test]
    fn lru_evicts_the_coldest_unpinned_leaf() {
        let mut c = PrefixCache::new(2);
        assert!(c.insert(&[1, 1], buf(), buf()));
        assert!(c.insert(&[2, 2], buf(), buf()));
        // Touch [1,1] so [2,2] is the LRU victim.
        let hit = c.lookup(&[1, 1]).unwrap();
        c.release(hit.seg);
        assert!(c.insert(&[3, 3], buf(), buf()));
        assert!(c.lookup(&[2, 2, 5]).is_none(), "LRU segment must be gone");
        let hit = c.lookup(&[1, 1]).expect("hot segment survived");
        c.release(hit.seg);
    }

    #[test]
    fn interior_segments_are_not_evicted_while_extended() {
        let mut c = PrefixCache::new(2);
        assert!(c.insert(&[1, 2], buf(), buf()));
        assert!(c.insert(&[1, 2, 3, 4], buf(), buf()));
        // [1,2] is interior (extended by [1,2,3,4]): only the deeper
        // leaf is evictable.
        assert!(c.insert(&[9, 9], buf(), buf()));
        let hit = c.lookup(&[1, 2, 8]).expect("interior segment survived");
        assert_eq!(hit.attach_len, 2);
        c.release(hit.seg);
        assert!(
            c.lookup(&[1, 2, 3, 4]).map(|h| h.attach_len) < Some(4),
            "leaf segment should have been the eviction victim"
        );
    }

    #[test]
    fn duplicate_insert_is_skipped_and_refreshes_lru() {
        let mut c = PrefixCache::new(2);
        assert!(c.insert(&[1, 1], buf(), buf()));
        assert!(!c.insert(&[1, 1], buf(), buf()), "duplicate path");
        assert!(c.insert(&[2, 2], buf(), buf()));
        // Refresh [1,1] via duplicate insert; [2,2] becomes the victim.
        assert!(!c.insert(&[1, 1], buf(), buf()));
        assert!(c.insert(&[3, 3], buf(), buf()));
        assert!(c.lookup(&[2, 2]).is_none());
    }
}
