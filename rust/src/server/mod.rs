//! Serving layer: a request router plus a JSON-lines TCP front end.
//! This is the deployment shape the paper assumes — a single model
//! serving live traffic while the drafter adapts online.
//!
//! Topology: one shared [`Runtime`] (weights + compiled executables +
//! LoRA globals), one shared replay buffer, a dedicated learner thread
//! running optimizer steps whenever a batch of fresh tuples is
//! available, and one of two serving shapes: N worker threads each
//! owning a [`DviEngine`] (per-worker KV state), or — with
//! `RouterConfig::batched` — a single continuous-batching scheduler
//! thread multiplexing every request through batched backend calls
//! ([`crate::sched`]). LoRA buffer swaps are atomic (the store's
//! RwLock), so either serving shape picks up improved adapters on its
//! next draft call without pausing.

pub mod api;
pub mod router;

pub use router::{Router, RouterConfig, RouterStats, Request, Response};
