//! Serving layer: a request router with a worker pool, plus a JSON-lines
//! TCP front end. This is the deployment shape the paper assumes — a
//! single model serving live traffic while the drafter adapts online.
//!
//! Topology: one shared [`Runtime`] (weights + compiled executables +
//! LoRA globals), N worker threads each owning a [`DviEngine`] (per-worker
//! KV state), one shared replay buffer, and a dedicated learner thread
//! running optimizer steps whenever a batch of fresh tuples is available.
//! LoRA buffer swaps are atomic (the store's RwLock), so workers pick up
//! improved adapters on their next draft call without pausing.

pub mod api;
pub mod router;

pub use router::{Router, RouterConfig, RouterStats, Request, Response};
