//! Request router (std threads & channels; no tokio in the offline
//! environment — and the workload is compute-bound backend calls, so
//! threads are the right shape anyway). Two serving modes:
//!
//!   * **per-thread** (default): N worker threads, each owning one
//!     engine; every request monopolizes a worker for its whole
//!     generation and runs batch-size-1 backend calls.
//!   * **batched** (`RouterConfig::batched`): one scheduler thread
//!     multiplexes every request through step-level batched backend
//!     calls ([`crate::sched::Scheduler`]) — many resident sequences,
//!     one call per sequence per tick, `max_batch` lanes per call.
//!
//! Both modes share the replay buffer with the online learner thread, so
//! DVI keeps improving from live traffic either way. Engine, scheduler,
//! and trainer construction all happen *before* any thread spawns:
//! an init failure is an `Err` from [`Router::start`], never a dead pool
//! that silently hangs submitted requests.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::engine::dvi::DviEngine;
use crate::engine::Engine;
use crate::harness::make_engine;
use crate::learner::{Objective, ReplayBuffer, Schedule, Trainer};
use crate::obs::health::HealthMonitor;
use crate::obs::{metrics, trace};
use crate::runtime::{log, ExecutorStatus, Runtime};
use crate::sched::{AdaptiveK, CacheConfig, SchedConfig, SchedStats, Scheduler};

#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Worker threads (per-thread mode; ignored when `batched`).
    pub workers: usize,
    /// Engine used to serve ("dvi", "ar", ...).
    pub method: String,
    /// Run the online learner thread (DVI only).
    pub online: bool,
    pub objective: Objective,
    pub buffer_capacity: usize,
    /// Continuous-batching mode: replace the worker pool with one
    /// scheduler thread driving batched backend calls. Methods: dvi|ar.
    pub batched: bool,
    /// Batched mode: max lanes per batched backend call.
    pub max_batch: usize,
    /// Batched mode: KV slot pool size (max resident sequences).
    pub max_slots: usize,
    /// Adaptive speculation depth for DVI serving (both modes). `None`
    /// (the default unless `DVI_ADAPTIVE_K=1`) pins every round to the
    /// manifest `k_spec`.
    pub adaptive: Option<AdaptiveK>,
    /// Batched mode: radix prefix cache over committed token ids.
    /// `None` (the default unless `DVI_PREFIX_CACHE=1`) disables it.
    pub cache: Option<CacheConfig>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            workers: 2,
            method: "dvi".into(),
            online: true,
            objective: Objective::Dvi,
            buffer_capacity: 8192,
            batched: false,
            max_batch: 8,
            max_slots: 16,
            adaptive: AdaptiveK::from_env(),
            cache: CacheConfig::from_env(),
        }
    }
}

pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub respond: Sender<Response>,
    /// Stamped at [`Router::submit`]; channel residency counts toward
    /// the batched scheduler's queue-wait metric.
    pub submitted: Instant,
    /// Tenant/workload tag for the health monitor's per-tenant SLO
    /// ledger (and, in batched mode, the per-task acceptance priors).
    pub task: Option<String>,
    /// Latency SLO (submit → completion, ns). Observation-only.
    pub deadline_ns: Option<u64>,
}

/// Default request deadline from `DVI_SLO_MS` (unset/0 = no SLO).
/// Parsed once; serves as the fleet-wide SLO when callers don't carry
/// per-request deadlines.
fn env_slo_deadline_ns() -> Option<u64> {
    static SLO: std::sync::OnceLock<Option<u64>> = std::sync::OnceLock::new();
    *SLO.get_or_init(|| {
        std::env::var("DVI_SLO_MS")
            .ok()
            .and_then(|s| s.parse::<u64>().ok())
            .filter(|&ms| ms > 0)
            .map(|ms| ms * 1_000_000)
    })
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub mat: f64,
    pub acceptance: f64,
    pub decode_ns: u64,
    pub prefill_ns: u64,
    /// Serving worker index (always 0 in batched mode).
    pub worker: usize,
}

#[derive(Debug, Default)]
pub struct RouterStats {
    pub served: AtomicU64,
    pub tokens: AtomicU64,
    pub decode_ns: AtomicU64,
    pub train_steps: AtomicU64,
}

/// Learner-thread state mirrored for the stats probe (the trainer lives
/// on its own thread; these are the fields operators watch).
#[derive(Debug, Default)]
pub struct LearnerObs {
    /// Optimizer steps completed.
    pub steps: AtomicU64,
    /// KL→RL schedule phase index (0 warmup, 1 ramp, 2 rl).
    pub phase: AtomicU64,
    /// Wall time of the most recent optimizer step.
    pub last_step_ns: AtomicU64,
}

impl LearnerObs {
    pub fn phase_name(&self) -> &'static str {
        match self.phase.load(Ordering::Relaxed) {
            0 => "warmup",
            1 => "ramp",
            _ => "rl",
        }
    }
}

pub struct Router {
    tx: Sender<Request>,
    pub stats: Arc<RouterStats>,
    /// Scheduler metrics (batch occupancy, queue wait, committed tokens
    /// per tick); `Some` only in batched mode.
    pub sched_stats: Option<Arc<SchedStats>>,
    /// The served runtime, kept so operators can poll remote executor
    /// health ([`Router::executor_status`]) next to the serving stats.
    rt: Arc<Runtime>,
    /// The replay buffer shared with the learner thread, retained so the
    /// stats probe can report its depth/push counters.
    buffer: Arc<Mutex<ReplayBuffer>>,
    /// Mirrored learner-thread state; `Some` when the learner runs.
    pub learner_obs: Option<Arc<LearnerObs>>,
    /// Serving-health monitor: per-tenant SLO attainment and the
    /// acceptance drift detector ([`Router::health_json`] probe).
    pub health: Arc<HealthMonitor>,
    stop: Arc<AtomicBool>,
    workers: Vec<JoinHandle<()>>,
    learner: Option<JoinHandle<()>>,
    next_id: AtomicU64,
}

/// Per-thread worker body: pull requests, generate, respond.
fn worker_loop(
    w: usize,
    mut engine: Box<dyn Engine + Send>,
    rx: Arc<Mutex<Receiver<Request>>>,
    stats: Arc<RouterStats>,
    health: Arc<HealthMonitor>,
) {
    loop {
        let req = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let Ok(req) = req else { break };
        match engine.generate(&req.prompt, req.max_new) {
            Ok(r) => {
                stats.served.fetch_add(1, Ordering::Relaxed);
                stats.tokens.fetch_add(r.tokens.len() as u64, Ordering::Relaxed);
                stats.decode_ns.fetch_add(r.decode_ns, Ordering::Relaxed);
                health.record_completion(
                    req.task.as_deref(),
                    true,
                    req.submitted.elapsed().as_nanos() as u64,
                    req.deadline_ns,
                    r.tokens.len() as u64,
                );
                let resp = Response {
                    id: req.id,
                    mat: r.mat(),
                    acceptance: r.acceptance_rate(),
                    decode_ns: r.decode_ns,
                    prefill_ns: r.prefill_ns,
                    tokens: r.tokens,
                    worker: w,
                };
                let _ = req.respond.send(resp);
            }
            Err(e) => {
                health.record_completion(
                    req.task.as_deref(),
                    false,
                    req.submitted.elapsed().as_nanos() as u64,
                    req.deadline_ns,
                    0,
                );
                log::info(&format!("worker {w} generate failed: {e}"));
            }
        }
    }
}

/// Batched-mode serving thread: one scheduler owns every in-flight
/// sequence; requests enqueue FIFO, ticks advance all of them through
/// batched backend calls, completions are answered as they drain.
fn scheduler_loop(
    mut sched: Scheduler,
    rx: Receiver<Request>,
    stats: Arc<RouterStats>,
) {
    // scheduler-local id -> (request id, response channel)
    let mut waiting: BTreeMap<u64, (u64, Sender<Response>)> = BTreeMap::new();
    fn enqueue(
        sched: &mut Scheduler,
        waiting: &mut BTreeMap<u64, (u64, Sender<Response>)>,
        req: Request,
    ) {
        let sid = sched.submit_with_deadline(
            req.prompt,
            req.max_new,
            req.task.as_deref(),
            req.submitted,
            req.deadline_ns,
        );
        waiting.insert(sid, (req.id, req.respond));
    }
    loop {
        if sched.is_idle() {
            // Nothing in flight: block for work. A closed channel while
            // idle is a clean shutdown (all accepted work is done —
            // completion draining is preemption-free).
            match rx.recv() {
                Ok(req) => enqueue(&mut sched, &mut waiting, req),
                Err(_) => break,
            }
        }
        while let Ok(req) = rx.try_recv() {
            enqueue(&mut sched, &mut waiting, req);
        }
        if let Err(e) = sched.tick() {
            log::info(&format!("scheduler tick failed: {e}"));
            break;
        }
        for done in sched.drain_completed() {
            let Some((req_id, respond)) = waiting.remove(&done.id) else {
                continue;
            };
            match done.result {
                Ok(r) => {
                    stats.served.fetch_add(1, Ordering::Relaxed);
                    stats
                        .tokens
                        .fetch_add(r.tokens.len() as u64, Ordering::Relaxed);
                    stats.decode_ns.fetch_add(r.decode_ns, Ordering::Relaxed);
                    let resp = Response {
                        id: req_id,
                        mat: r.mat(),
                        acceptance: r.acceptance_rate(),
                        decode_ns: r.decode_ns,
                        prefill_ns: r.prefill_ns,
                        tokens: r.tokens,
                        worker: 0,
                    };
                    let _ = respond.send(resp);
                }
                Err(e) => {
                    // Dropping `respond` signals the failure to the
                    // caller (their recv() errors), matching per-thread
                    // mode's behavior.
                    log::info(&format!("request {req_id} failed: {e}"));
                }
            }
        }
    }
}

/// Online learner body: drains fresh tuples into optimizer steps.
/// "Small, frequent updates" (paper §3.3): one optimizer step per fresh
/// quarter-batch of tuples — the learner must not free-run on stale
/// buffer content (it would both overfit the replay and steal decode
/// CPU).
fn learner_loop(
    mut trainer: Trainer,
    stop: Arc<AtomicBool>,
    stats: Arc<RouterStats>,
    obs: Arc<LearnerObs>,
    health: Arc<HealthMonitor>,
) {
    let mut last_pushed = 0u64;
    let fresh_quantum = (trainer.batch_size as u64 / 4).max(1);
    while !stop.load(Ordering::Relaxed) {
        let pushed = trainer.buffer.lock().unwrap().pushed;
        if pushed < last_pushed + fresh_quantum {
            std::thread::sleep(std::time::Duration::from_millis(5));
            continue;
        }
        match trainer.maybe_train() {
            Ok(Some(_)) => {
                last_pushed = pushed;
                stats.train_steps.fetch_add(1, Ordering::Relaxed);
                // Mirror trainer state for the stats probe; announce
                // KL→RL phase transitions on the trace.
                obs.steps.store(trainer.steps_done, Ordering::Relaxed);
                obs.last_step_ns
                    .store(trainer.last_step_ns, Ordering::Relaxed);
                let phase =
                    trainer.schedule.phase_index(trainer.steps_done);
                let prev = obs.phase.swap(phase, Ordering::Relaxed);
                if phase != prev {
                    // Key the drift detector to the schedule: a KL→RL
                    // hand-off legitimately moves acceptance, so the
                    // monitor re-baselines instead of alarming.
                    health.set_phase(phase as u8, obs.phase_name());
                    if trace::enabled() {
                        trace::instant(
                            "learner.phase",
                            "learner",
                            vec![
                                ("phase", trace::Arg::I(phase as i64)),
                                (
                                    "step",
                                    trace::Arg::I(trainer.steps_done as i64),
                                ),
                            ],
                        );
                    }
                }
            }
            Ok(None) => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => {
                log::info(&format!("learner step failed: {e}"));
                break;
            }
        }
    }
}

impl Router {
    pub fn start(rt: Arc<Runtime>, cfg: RouterConfig) -> Result<Router> {
        let (tx, rx) = channel::<Request>();
        let stats = Arc::new(RouterStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let buffer = Arc::new(Mutex::new(ReplayBuffer::new(cfg.buffer_capacity)));
        let online_dvi = cfg.online && cfg.method == "dvi";
        let health = Arc::new(HealthMonitor::new());

        let (workers, sched_stats) = if cfg.batched {
            let mut sched = Scheduler::new(
                rt.clone(),
                SchedConfig {
                    method: cfg.method.clone(),
                    max_batch: cfg.max_batch,
                    max_slots: cfg.max_slots,
                    adaptive: cfg.adaptive,
                    cache: cfg.cache.clone(),
                },
                if online_dvi { Some(buffer.clone()) } else { None },
            )?;
            sched.attach_health(health.clone());
            let sched_stats = sched.stats.clone();
            let stats2 = stats.clone();
            let handle = std::thread::Builder::new()
                .name("dvi-sched".into())
                .spawn(move || scheduler_loop(sched, rx, stats2))?;
            (vec![handle], Some(sched_stats))
        } else {
            ensure!(cfg.workers >= 1, "router needs at least one worker");
            // Construct every engine before spawning anything: a failed
            // init returns Err instead of leaving a dead pool behind.
            let mut engines: Vec<Box<dyn Engine + Send>> = Vec::new();
            for _ in 0..cfg.workers {
                engines.push(if online_dvi {
                    Box::new(
                        DviEngine::new(rt.clone())?
                            .with_adaptive(cfg.adaptive)
                            .with_buffer(buffer.clone()),
                    )
                } else if cfg.method == "dvi" {
                    // Honor the explicit adaptive-k override in offline
                    // per-thread serving too.
                    Box::new(DviEngine::new(rt.clone())?.with_adaptive(cfg.adaptive))
                } else {
                    make_engine(rt.clone(), &cfg.method)?
                });
            }
            let rx = Arc::new(Mutex::new(rx));
            let mut workers = Vec::new();
            for (w, engine) in engines.into_iter().enumerate() {
                let rx = rx.clone();
                let stats = stats.clone();
                let health = health.clone();
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("dvi-worker-{w}"))
                        .spawn(move || {
                            worker_loop(w, engine, rx, stats, health)
                        })?,
                );
            }
            (workers, None)
        };

        // Learner thread: constructed here for the same reason — a bad
        // train_step artifact fails start() instead of dying silently.
        let (learner, learner_obs) = if online_dvi {
            let trainer = Trainer::new(
                rt.clone(),
                buffer.clone(),
                Schedule::new(cfg.objective),
                0x1EA2,
            )?;
            let obs = Arc::new(LearnerObs::default());
            let stop2 = stop.clone();
            let stats2 = stats.clone();
            let obs2 = obs.clone();
            let health2 = health.clone();
            let handle = std::thread::Builder::new()
                .name("dvi-learner".into())
                .spawn(move || {
                    learner_loop(trainer, stop2, stats2, obs2, health2)
                })?;
            (Some(handle), Some(obs))
        } else {
            (None, None)
        };

        Ok(Router {
            tx,
            stats,
            sched_stats,
            rt,
            buffer,
            learner_obs,
            health,
            stop,
            workers,
            learner,
            next_id: AtomicU64::new(0),
        })
    }

    /// Health of the remote executor(s) serving this router's backend
    /// calls: per-shard endpoint plus the executor-side `Metrics`
    /// counters (occupancy, buffer-table size, calls served). Empty for
    /// in-process backends.
    pub fn executor_status(&self) -> Vec<ExecutorStatus> {
        self.rt.executor_status()
    }

    /// One-line JSON snapshot of serving state: router counters plus,
    /// in batched mode, the scheduler metrics — including the adaptive-k
    /// chosen-depth histogram and the mean acceptance EMA — and the
    /// remote executor count. Served by the TCP API for
    /// `{"stats": true}` requests and printed by `dvi serve`.
    pub fn stats_json(&self) -> String {
        let mut out = format!(
            "{{\"served\":{},\"tokens\":{},\"train_steps\":{}",
            self.stats.served.load(Ordering::Relaxed),
            self.stats.tokens.load(Ordering::Relaxed),
            self.stats.train_steps.load(Ordering::Relaxed),
        );
        if let Some(ss) = &self.sched_stats {
            let hist = ss.k_hist_snapshot();
            let hist_s = hist
                .iter()
                .map(u64::to_string)
                .collect::<Vec<_>>()
                .join(",");
            out.push_str(&format!(
                ",\"occupancy\":{:.3},\"committed_per_tick\":{:.3},\
                 \"mean_queue_wait_ms\":{:.3},\"k_hist\":[{hist_s}],\
                 \"mean_accept_ema\":{:.3}",
                ss.occupancy(),
                ss.committed_per_tick(),
                ss.mean_queue_wait_ms(),
                ss.mean_accept_ema(),
            ));
            out.push_str(&format!(
                ",\"cache\":{{\"hits\":{},\"misses\":{},\"evictions\":{},\
                 \"segments\":{},\"shared_rows\":{},\"shared_bytes\":{}}}",
                ss.cache_hits.load(Ordering::Relaxed),
                ss.cache_misses.load(Ordering::Relaxed),
                ss.cache_evictions.load(Ordering::Relaxed),
                ss.cache_segments.load(Ordering::Relaxed),
                ss.cache_shared_rows.load(Ordering::Relaxed),
                ss.cache_shared_bytes.load(Ordering::Relaxed),
            ));
            let priors = ss.task_priors_snapshot();
            if !priors.is_empty() {
                let body = priors
                    .iter()
                    .map(|(t, p)| format!("\"{t}\":{p:.4}"))
                    .collect::<Vec<_>>()
                    .join(",");
                out.push_str(&format!(",\"task_priors\":{{{body}}}"));
            }
        }
        if let Some(obs) = &self.learner_obs {
            let (pushed, depth, mean_reward) = {
                let buf = self.buffer.lock().unwrap();
                (buf.pushed, buf.len(), buf.mean_reward())
            };
            out.push_str(&format!(
                ",\"learner\":{{\"phase\":\"{}\",\"step\":{},\
                 \"last_train_step_ms\":{:.3},\"replay_pushed\":{pushed},\
                 \"replay_depth\":{depth},\"replay_mean_reward\":{:.4}}}",
                obs.phase_name(),
                obs.steps.load(Ordering::Relaxed),
                obs.last_step_ns.load(Ordering::Relaxed) as f64 / 1e6,
                mean_reward,
            ));
        }
        out.push_str(&format!(",\"executors\":{}", self.executor_status().len()));
        out.push('}');
        out
    }

    /// One-line JSON snapshot of the process-wide metrics registry
    /// (counters, gauges, p50/p95/p99 histograms) with per-shard RPC
    /// histogram families rolled up into `.all` aggregates, plus the
    /// tracer's state. Served for `{"metrics": true}` probes and by
    /// `dvi serve --metrics`.
    pub fn metrics_json(&self) -> String {
        let mut snap = metrics::global().snapshot();
        snap.rollup_shards();
        format!(
            "{{\"metrics\":{},\"trace\":{{\"enabled\":{},\
             \"dropped_events\":{}}}}}",
            snap.to_json(),
            trace::enabled(),
            trace::drop_count(),
        )
    }

    /// One-line JSON health snapshot: per-tenant SLO attainment and the
    /// acceptance drift detector's state, keyed by the learner phase.
    /// Served for `{"health": true}` probes and summarized in the
    /// periodic `dvi serve` report.
    pub fn health_json(&self) -> String {
        self.health.to_json()
    }

    /// Submit a prompt; returns a receiver for the response. The
    /// request carries the fleet default SLO (`DVI_SLO_MS`) if one is
    /// configured; [`Router::submit_with_slo`] overrides per request.
    pub fn submit(&self, prompt: Vec<u32>, max_new: usize) -> Receiver<Response> {
        self.submit_with_slo(prompt, max_new, None, None)
    }

    /// [`Router::submit`] with an explicit tenant tag and deadline
    /// (`None` falls back to the `DVI_SLO_MS` fleet default).
    pub fn submit_with_slo(
        &self,
        prompt: Vec<u32>,
        max_new: usize,
        task: Option<&str>,
        deadline_ns: Option<u64>,
    ) -> Receiver<Response> {
        let (tx, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let _ = self.tx.send(Request {
            id,
            prompt,
            max_new,
            respond: tx,
            submitted: Instant::now(),
            task: task.map(str::to_string),
            deadline_ns: deadline_ns.or_else(env_slo_deadline_ns),
        });
        rx
    }

    /// Blocking convenience call.
    pub fn generate(&self, prompt: Vec<u32>, max_new: usize) -> Result<Response> {
        let started = Instant::now();
        let rx = self.submit(prompt, max_new);
        let resp = rx.recv()?;
        log::debug(&format!(
            "request {} served in {:.1}ms by worker {}",
            resp.id,
            started.elapsed().as_secs_f64() * 1e3,
            resp.worker
        ));
        Ok(resp)
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        drop(self.tx);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(l) = self.learner.take() {
            let _ = l.join();
        }
    }
}
