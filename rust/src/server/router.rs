//! Request router + worker pool (std threads & channels; no tokio in the
//! offline environment — and the workload is compute-bound PJRT calls, so
//! a thread pool is the right shape anyway).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::engine::dvi::DviEngine;
use crate::engine::Engine;
use crate::harness::make_engine;
use crate::learner::{Objective, ReplayBuffer, Schedule, Trainer};
use crate::runtime::{log, Runtime};

#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub workers: usize,
    /// Engine used by workers ("dvi", "ar", ...).
    pub method: String,
    /// Run the online learner thread (DVI only).
    pub online: bool,
    pub objective: Objective,
    pub buffer_capacity: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            workers: 2,
            method: "dvi".into(),
            online: true,
            objective: Objective::Dvi,
            buffer_capacity: 8192,
        }
    }
}

pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub respond: Sender<Response>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub mat: f64,
    pub acceptance: f64,
    pub decode_ns: u64,
    pub prefill_ns: u64,
    pub worker: usize,
}

#[derive(Debug, Default)]
pub struct RouterStats {
    pub served: AtomicU64,
    pub tokens: AtomicU64,
    pub decode_ns: AtomicU64,
    pub train_steps: AtomicU64,
}

pub struct Router {
    tx: Sender<Request>,
    pub stats: Arc<RouterStats>,
    stop: Arc<AtomicBool>,
    workers: Vec<JoinHandle<()>>,
    learner: Option<JoinHandle<()>>,
    next_id: AtomicU64,
}

impl Router {
    pub fn start(rt: Arc<Runtime>, cfg: RouterConfig) -> Result<Router> {
        let (tx, rx) = channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));
        let stats = Arc::new(RouterStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let buffer = Arc::new(Mutex::new(ReplayBuffer::new(cfg.buffer_capacity)));

        let mut workers = Vec::new();
        for w in 0..cfg.workers {
            let rx = rx.clone();
            let rt = rt.clone();
            let stats = stats.clone();
            let buffer = buffer.clone();
            let method = cfg.method.clone();
            let online = cfg.online;
            workers.push(std::thread::Builder::new()
                .name(format!("dvi-worker-{w}"))
                .spawn(move || {
                    let mut engine: Box<dyn Engine> = if method == "dvi" && online {
                        match DviEngine::new(rt.clone()) {
                            Ok(e) => Box::new(e.with_buffer(buffer)),
                            Err(e) => {
                                log::info(&format!("worker {w} init failed: {e}"));
                                return;
                            }
                        }
                    } else {
                        match make_engine(rt.clone(), &method) {
                            Ok(e) => e,
                            Err(e) => {
                                log::info(&format!("worker {w} init failed: {e}"));
                                return;
                            }
                        }
                    };
                    loop {
                        let req = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        let Ok(req) = req else { break };
                        match engine.generate(&req.prompt, req.max_new) {
                            Ok(r) => {
                                stats.served.fetch_add(1, Ordering::Relaxed);
                                stats
                                    .tokens
                                    .fetch_add(r.tokens.len() as u64, Ordering::Relaxed);
                                stats.decode_ns.fetch_add(r.decode_ns, Ordering::Relaxed);
                                let resp = Response {
                                    id: req.id,
                                    mat: r.mat(),
                                    acceptance: r.acceptance_rate(),
                                    decode_ns: r.decode_ns,
                                    prefill_ns: r.prefill_ns,
                                    tokens: r.tokens,
                                    worker: w,
                                };
                                let _ = req.respond.send(resp);
                            }
                            Err(e) => {
                                log::info(&format!("worker {w} generate failed: {e}"));
                            }
                        }
                    }
                })?);
        }

        // Learner thread: drains fresh tuples into optimizer steps.
        let learner = if cfg.online && cfg.method == "dvi" {
            let rt = rt.clone();
            let stop2 = stop.clone();
            let stats2 = stats.clone();
            let objective = cfg.objective;
            Some(std::thread::Builder::new()
                .name("dvi-learner".into())
                .spawn(move || {
                    let mut trainer = match Trainer::new(
                        rt, buffer, Schedule::new(objective), 0x1EA2) {
                        Ok(t) => t,
                        Err(e) => {
                            log::info(&format!("learner init failed: {e}"));
                            return;
                        }
                    };
                    // "Small, frequent updates" (paper §3.3): one optimizer
                    // step per fresh quarter-batch of tuples — the learner
                    // must not free-run on stale buffer content (it would
                    // both overfit the replay and steal decode CPU).
                    let mut last_pushed = 0u64;
                    let fresh_quantum =
                        (trainer.batch_size as u64 / 4).max(1);
                    while !stop2.load(Ordering::Relaxed) {
                        let pushed =
                            trainer.buffer.lock().unwrap().pushed;
                        if pushed < last_pushed + fresh_quantum {
                            std::thread::sleep(
                                std::time::Duration::from_millis(5));
                            continue;
                        }
                        match trainer.maybe_train() {
                            Ok(Some(_)) => {
                                last_pushed = pushed;
                                stats2.train_steps.fetch_add(1, Ordering::Relaxed);
                            }
                            Ok(None) => {
                                std::thread::sleep(
                                    std::time::Duration::from_millis(5));
                            }
                            Err(e) => {
                                log::info(&format!("learner step failed: {e}"));
                                break;
                            }
                        }
                    }
                })?)
        } else {
            None
        };

        Ok(Router {
            tx,
            stats,
            stop,
            workers,
            learner,
            next_id: AtomicU64::new(0),
        })
    }

    /// Submit a prompt; returns a receiver for the response.
    pub fn submit(&self, prompt: Vec<u32>, max_new: usize) -> Receiver<Response> {
        let (tx, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let _ = self.tx.send(Request { id, prompt, max_new, respond: tx });
        rx
    }

    /// Blocking convenience call.
    pub fn generate(&self, prompt: Vec<u32>, max_new: usize) -> Result<Response> {
        let started = Instant::now();
        let rx = self.submit(prompt, max_new);
        let resp = rx.recv()?;
        log::debug(&format!(
            "request {} served in {:.1}ms by worker {}",
            resp.id,
            started.elapsed().as_secs_f64() * 1e3,
            resp.worker
        ));
        Ok(resp)
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        drop(self.tx);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(l) = self.learner.take() {
            let _ = l.join();
        }
    }
}
