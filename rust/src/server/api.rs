//! JSON-lines TCP API: one request per line in, one response per line out.
//!
//!   -> {"prompt": "question : what owns ent01 ? <sep>", "max_new": 32}
//!   -> {"prompt_ids": [1, 340, 28], "max_new": 32}
//!   <- {"id": 0, "text": "...", "tokens": [..], "mat": 3.2,
//!       "acceptance": 0.81, "decode_ms": 12.4}
//!   -> {"stats": true}
//!   <- {"served": 12, "tokens": 384, ..., "k_hist": [0,3,1,0,9,0,0,0,0]}
//!   -> {"metrics": true}
//!   <- {"metrics": {"hists": {"sched.queue_wait_ns": {"p50": ..}}}, ...}
//!   -> {"health": true}
//!   <- {"schema": "dvi.health/1", "drift": {...}, "tenants": {...}}
//!
//! Generation requests may carry `"task"` (tenant tag for the health
//! monitor's per-tenant SLO ledger) and `"slo_ms"` (per-request latency
//! deadline; falls back to the `DVI_SLO_MS` fleet default).
//!
//! Designed for the `dvi serve` subcommand and the serving example; the
//! protocol stays trivially scriptable (`nc localhost 7501`).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::runtime::log;
use crate::tokenizer::Tokenizer;
use crate::util::json::{escape, Json};

use super::router::Router;

pub struct ApiServer {
    pub addr: String,
}

/// Parse one request line. Returns (prompt ids, max_new).
pub fn parse_request(line: &str, tok: &Tokenizer) -> Result<(Vec<u32>, usize)> {
    let j = Json::parse(line).context("request is not valid JSON")?;
    let max_new = j.get("max_new").as_usize().unwrap_or(64);
    if let Some(ids) = j.get("prompt_ids").as_arr() {
        let prompt: Vec<u32> = ids
            .iter()
            .map(|v| v.as_usize().map(|x| x as u32).context("prompt id"))
            .collect::<Result<_>>()?;
        return Ok((prompt, max_new));
    }
    let text = j
        .get("prompt")
        .as_str()
        .context("need 'prompt' or 'prompt_ids'")?;
    let mut prompt = vec![crate::tokenizer::BOS];
    prompt.extend(tok.encode(text)?);
    Ok((prompt, max_new))
}

pub fn format_response(
    id: u64,
    tokens: &[u32],
    tok: &Tokenizer,
    mat: f64,
    acceptance: f64,
    decode_ns: u64,
) -> String {
    let ids = tokens
        .iter()
        .map(|t| t.to_string())
        .collect::<Vec<_>>()
        .join(",");
    format!(
        "{{\"id\":{id},\"text\":{},\"tokens\":[{ids}],\"mat\":{mat:.3},\
         \"acceptance\":{acceptance:.3},\"decode_ms\":{:.2}}}",
        escape(&tok.decode(tokens)),
        decode_ns as f64 / 1e6
    )
}

/// Serve until `stop` is set. Each connection handles requests serially;
/// concurrency comes from multiple connections + the router's worker pool.
pub fn serve(
    listener: TcpListener,
    router: Arc<Router>,
    tok: Arc<Tokenizer>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    listener.set_nonblocking(true)?;
    log::info(&format!("listening on {}", listener.local_addr()?));
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                log::debug(&format!("connection from {peer}"));
                let router = router.clone();
                let tok = tok.clone();
                std::thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, &router, &tok) {
                        log::debug(&format!("connection closed: {e}"));
                    }
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            Err(e) => return Err(e.into()),
        }
    }
}

fn handle_conn(stream: TcpStream, router: &Router, tok: &Tokenizer) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        // Stats probe: {"stats": true} returns the serving snapshot
        // (router counters, scheduler metrics, adaptive-k histogram)
        // without consuming a generation.
        let j = Json::parse(&line).ok();
        if let Some(j) = &j {
            if j.get("stats").as_bool() == Some(true) {
                writeln!(writer, "{}", router.stats_json())?;
                continue;
            }
            // Metrics probe: {"metrics": true} returns the quantile
            // registry snapshot (p50/p95/p99 per histogram, per-shard
            // RPC families rolled up) plus tracer state.
            if j.get("metrics").as_bool() == Some(true) {
                writeln!(writer, "{}", router.metrics_json())?;
                continue;
            }
            // Health probe: {"health": true} returns per-tenant SLO
            // attainment and the acceptance drift detector's state.
            if j.get("health").as_bool() == Some(true) {
                writeln!(writer, "{}", router.health_json())?;
                continue;
            }
        }
        match parse_request(&line, tok) {
            Ok((prompt, max_new)) => {
                let task = j
                    .as_ref()
                    .and_then(|j| j.get("task").as_str())
                    .map(str::to_string);
                let deadline_ns = j
                    .as_ref()
                    .and_then(|j| j.get("slo_ms").as_f64())
                    .filter(|&ms| ms > 0.0)
                    .map(|ms| (ms * 1e6) as u64);
                let rx = router.submit_with_slo(
                    prompt,
                    max_new,
                    task.as_deref(),
                    deadline_ns,
                );
                let resp = rx.recv()?;
                let out = format_response(
                    resp.id, &resp.tokens, tok, resp.mat,
                    resp.acceptance, resp.decode_ns,
                );
                writeln!(writer, "{out}")?;
            }
            Err(e) => {
                writeln!(writer, "{{\"error\":{}}}", escape(&e.to_string()))?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn tok() -> Tokenizer {
        let p = std::env::temp_dir().join(format!(
            "dvi_api_vocab_{}_{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        let mut f = std::fs::File::create(&p).unwrap();
        write!(f, r#"["<pad>","<bos>","<eos>","<sep>","what","owns"]"#).unwrap();
        Tokenizer::load(&p).unwrap()
    }

    #[test]
    fn parse_text_request() {
        let t = tok();
        let (p, n) = parse_request(
            r#"{"prompt": "what owns", "max_new": 8}"#, &t).unwrap();
        assert_eq!(p, vec![1, 4, 5]); // BOS + words
        assert_eq!(n, 8);
    }

    #[test]
    fn parse_ids_request() {
        let t = tok();
        let (p, n) = parse_request(r#"{"prompt_ids": [1, 4], "max_new": 3}"#, &t)
            .unwrap();
        assert_eq!(p, vec![1, 4]);
        assert_eq!(n, 3);
    }

    #[test]
    fn parse_rejects_garbage() {
        let t = tok();
        assert!(parse_request("not json", &t).is_err());
        assert!(parse_request(r#"{"max_new": 5}"#, &t).is_err());
    }

    #[test]
    fn response_roundtrips_as_json() {
        let t = tok();
        let s = format_response(3, &[4, 5, 2], &t, 2.5, 0.8, 1_500_000);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("id").as_usize(), Some(3));
        assert_eq!(j.get("text").as_str(), Some("what owns <eos>"));
        assert_eq!(j.get("tokens").as_arr().unwrap().len(), 3);
    }
}
