//! Resumable per-sequence decode state machines.
//!
//! [`DviSeq`] and [`ArSeq`] are the DVI and AR engines' generate loops
//! unrolled into poll-able state machines: `pending_artifact` names the
//! backend call the sequence needs next, `next_call` materialises it,
//! `apply` consumes the result and advances the phase
//! (Prefilling → Drafting → Verifying → Done). A single sequence driven
//! call-by-call reproduces the old engine loops exactly — the engines
//! themselves now run on these machines — and the continuous-batching
//! scheduler ([`crate::sched::Scheduler`]) drives many of them through
//! batched backend calls. Because both paths execute the identical
//! per-sequence op sequence, batched serving is bitwise-lossless against
//! per-sequence decoding (asserted by `tests/sched.rs`).

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::engine::{truncate_at_eos, GenResult, StepRecord};
use crate::learner::{ReplayBuffer, Tuple};
use crate::obs::{metrics, trace};
use crate::runtime::{Artifact, Buffer, CallOut, Role, Runtime, Tensor};
use crate::spec::{longest_prefix, SeqPos};
use crate::util::math::argmax;

/// Adaptive speculation-depth policy (paper-adjacent: the dynamic draft
/// length surveyed in PAPERS.md 2401.07851 §4 / 2411.13157). Each DVI
/// sequence tracks an acceptance-rate EMA from its own verify outcomes
/// and picks the next round's draft length k as the deepest speculation
/// whose expected full-acceptance probability still clears `target`
/// (`ema^k >= target`), clamped to `[floor, min(ceiling, k_spec)]`.
///
/// Disabled (`None`) is the default everywhere: every sequence then
/// drafts exactly `k_spec` tokens per round and all call shapes are
/// bitwise identical to the historical fixed-k pipeline, which is what
/// the lossless test gates pin.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptiveK {
    /// Lower bound on the chosen k (>= 1).
    pub floor: usize,
    /// Upper bound on the chosen k (clamped to the manifest k_spec).
    pub ceiling: usize,
    /// EMA smoothing factor in (0, 1]; higher adapts faster.
    pub alpha: f64,
    /// Full-acceptance probability target in (0, 1): draft k tokens only
    /// while `ema^k >= target`.
    pub target: f64,
}

impl Default for AdaptiveK {
    fn default() -> AdaptiveK {
        AdaptiveK { floor: 1, ceiling: usize::MAX, alpha: 0.25, target: 0.5 }
    }
}

impl AdaptiveK {
    /// Read the policy from the environment: `DVI_ADAPTIVE_K=1` enables
    /// it, `DVI_K_FLOOR` / `DVI_K_CEIL` / `DVI_K_ALPHA` / `DVI_K_TARGET`
    /// override the defaults. Returns `None` (pinned-k) when unset.
    pub fn from_env() -> Option<AdaptiveK> {
        let on = std::env::var("DVI_ADAPTIVE_K").ok()?;
        if on != "1" && !on.eq_ignore_ascii_case("true") {
            return None;
        }
        let mut ad = AdaptiveK::default();
        if let Some(v) = env_parse::<usize>("DVI_K_FLOOR") {
            ad.floor = v;
        }
        if let Some(v) = env_parse::<usize>("DVI_K_CEIL") {
            ad.ceiling = v;
        }
        if let Some(v) = env_parse::<f64>("DVI_K_ALPHA") {
            ad.alpha = v;
        }
        if let Some(v) = env_parse::<f64>("DVI_K_TARGET") {
            ad.target = v;
        }
        Some(ad)
    }

    /// Pick the next round's draft length from the sequence's acceptance
    /// EMA. Total, and monotone in `ema`: a drafter that is being
    /// accepted more gets to speculate deeper.
    pub fn choose(&self, ema: f64, k_spec: usize) -> usize {
        let ceil = self.ceiling.min(k_spec).max(1);
        let floor = self.floor.clamp(1, ceil);
        let p = ema.clamp(0.01, 0.999);
        let target = self.target.clamp(1e-3, 0.999);
        let raw = (target.ln() / p.ln()).floor();
        let k = if raw.is_finite() && raw >= 1.0 { raw as usize } else { 1 };
        k.clamp(floor, ceil)
    }
}

fn env_parse<T: std::str::FromStr>(name: &str) -> Option<T> {
    std::env::var(name).ok()?.parse().ok()
}

/// Cached global-registry handles for the per-sequence lifecycle
/// histograms, resolved once per context so the per-round hot path
/// records with lock-free atomics only. Observation-only: values are
/// read from timing fields the machines already maintain, so decode
/// streams are bitwise independent of whether anyone looks.
#[derive(Clone)]
pub struct SeqObs {
    pub prefill: metrics::HistHandle,
    pub draft_round: metrics::HistHandle,
    pub verify: metrics::HistHandle,
    pub ar_step: metrics::HistHandle,
}

impl SeqObs {
    pub fn new() -> SeqObs {
        SeqObs {
            prefill: metrics::hist("seq.prefill_ns"),
            draft_round: metrics::hist("seq.draft_round_ns"),
            verify: metrics::hist("seq.verify_ns"),
            ar_step: metrics::hist("seq.ar_step_ns"),
        }
    }
}

impl Default for SeqObs {
    fn default() -> SeqObs {
        SeqObs::new()
    }
}

/// Coarse phase of a sequence, shared by both machines. AR sequences
/// have no draft stage; their decode steps count as Verifying (each is
/// one target-model call).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqPhase {
    Prefilling,
    Drafting,
    Verifying,
    Done,
}

/// One materialised backend call: the artifact plus this sequence's KV
/// handles (cheap `Arc` clones) and host inputs. Owned, so the scheduler
/// can collect a batch of these without borrow entanglement.
pub struct CallSpec {
    pub artifact: Arc<Artifact>,
    pub kv: Vec<Buffer>,
    pub inputs: Vec<Tensor>,
}

/// Shared immutable context for DVI sequences: artifact handles and
/// model dimensions, resolved once per engine/scheduler.
#[derive(Clone)]
pub struct DviCtx {
    pub rt: Arc<Runtime>,
    pub prefill_sh: Arc<Artifact>,
    pub prefill_dp: Arc<Artifact>,
    pub draft: Arc<Artifact>,
    /// Fused k_spec-step draft loop; `None` forces the per-step path.
    pub draft_block: Option<Arc<Artifact>>,
    pub verify: Arc<Artifact>,
    pub k_spec: usize,
    pub d_model: usize,
    pub prefill_seq: usize,
    pub max_seq: usize,
    /// Per-sequence adaptive draft length; `None` pins every round to
    /// `k_spec` (the bitwise-reference mode).
    pub adaptive: Option<AdaptiveK>,
    /// Whether the backend's block artifacts declare the scalar `len`
    /// In port. Manifests exported before it existed don't; those run
    /// the historical 2-input calls and adaptive-k degrades to pinned.
    pub var_len: bool,
    /// Whether both prefill artifacts declare the scalar `start` In
    /// port (suffix-only prefill). Without it the prefix cache cannot
    /// attach and degrades to cold prefill for every sequence.
    pub var_start: bool,
    /// Cached lifecycle histogram handles (shared registry).
    pub obs: SeqObs,
}

impl DviCtx {
    pub fn new(rt: Arc<Runtime>) -> Result<DviCtx> {
        let k_spec = rt.manifest.spec_usize("k_spec")?;
        let d_model = rt.manifest.model_usize("d_model")?;
        let prefill_seq = rt.manifest.spec_usize("prefill_seq")?;
        let max_seq = rt.manifest.model_usize("max_seq")?;
        let has_len = |a: &Artifact| {
            a.spec
                .params
                .iter()
                .any(|p| p.role == Role::In && p.name == "len")
        };
        let verify = rt.artifact("verify_block")?;
        let draft_block = rt.artifact("draft_block").ok();
        let var_len = has_len(&verify)
            && draft_block.as_deref().map_or(true, has_len);
        let has_start = |a: &Artifact| {
            a.spec
                .params
                .iter()
                .any(|p| p.role == Role::In && p.name == "start")
        };
        let prefill_sh = rt.artifact("prefill_shallow")?;
        let prefill_dp = rt.artifact("prefill_deep")?;
        let var_start = has_start(&prefill_sh) && has_start(&prefill_dp);
        Ok(DviCtx {
            prefill_sh,
            prefill_dp,
            draft: rt.artifact("draft_step")?,
            draft_block,
            verify,
            rt,
            k_spec,
            d_model,
            prefill_seq,
            max_seq,
            adaptive: AdaptiveK::from_env(),
            var_len,
            var_start,
            obs: SeqObs::new(),
        })
    }

    /// Override the adaptive-k policy (explicit config beats env).
    pub fn with_adaptive(mut self, adaptive: Option<AdaptiveK>) -> DviCtx {
        self.adaptive = adaptive;
        self
    }

    /// True when rounds may actually vary in length (policy present and
    /// the backend accepts a round-length input).
    pub fn adaptive_active(&self) -> bool {
        self.adaptive.is_some() && self.var_len
    }
}

/// Shared immutable context for AR sequences.
#[derive(Clone)]
pub struct ArCtx {
    pub rt: Arc<Runtime>,
    pub prefill: Arc<Artifact>,
    pub step: Arc<Artifact>,
    pub prefill_seq: usize,
    pub max_seq: usize,
    /// Cached lifecycle histogram handles (shared registry).
    pub obs: SeqObs,
}

impl ArCtx {
    pub fn new(rt: Arc<Runtime>) -> Result<ArCtx> {
        let prefill_seq = rt.manifest.spec_usize("prefill_seq")?;
        let max_seq = rt.manifest.model_usize("max_seq")?;
        Ok(ArCtx {
            prefill: rt.artifact("prefill_full")?,
            step: rt.artifact("target_step")?,
            rt,
            prefill_seq,
            max_seq,
            obs: SeqObs::new(),
        })
    }
}

// ----------------------------------------------------------------------------
// DVI sequence
// ----------------------------------------------------------------------------

enum DviStep {
    PrefillShallow,
    PrefillDeep,
    /// Draft sub-step index: always 0 on the fused draft_block path,
    /// 0..k_spec on the per-step path.
    Draft(usize),
    Verify,
    Done,
}

/// A warm start handed to a new sequence by the scheduler's prefix
/// cache: already-forked KV buffer sets (COW aliases of a cached
/// segment — see [`crate::cache::PrefixCache`]) plus the attach length.
/// Rows `0..attach_len` of both KV sets are valid for this sequence's
/// prompt; the prefill calls compute only `attach_len..` and overwrite
/// everything above the attach point, so the resulting streams are
/// bitwise identical to a cold prefill.
pub struct PrefixAttach {
    pub kv_sh: Vec<Buffer>,
    pub kv_dp: Vec<Buffer>,
    pub attach_len: usize,
}

/// Post-prefill KV snapshot the scheduler inserts into the prefix
/// cache: the prompt tokens (the radix-tree path) plus cheap handle
/// clones of both prefill-output KV sets. Buffers are immutable once
/// written, so holding these costs nothing and can never observe later
/// decode steps (which mint fresh buffers).
pub struct PrefixSnapshot {
    pub tokens: Vec<u32>,
    pub kv_sh: Vec<Buffer>,
    pub kv_dp: Vec<Buffer>,
}

/// Construction options beyond the prompt itself; `Default` reproduces
/// the historical cold-start behavior exactly.
pub struct DviSeqOpts {
    /// Warm start from the prefix cache (`None` = cold prefill).
    pub attach: Option<PrefixAttach>,
    /// Initial acceptance EMA. 1.0 (optimistic full-depth first round,
    /// the pinned-k-compatible default) unless a per-task prior says
    /// otherwise. Any seed is lossless: greedy longest-prefix
    /// acceptance commits the same stream for every round length.
    pub ema0: f64,
    /// Capture a [`PrefixSnapshot`] after the deep prefill so the
    /// scheduler can populate the cache. Off by default (no cost when
    /// the cache is disabled).
    pub capture_prefix: bool,
}

impl Default for DviSeqOpts {
    fn default() -> DviSeqOpts {
        DviSeqOpts { attach: None, ema0: 1.0, capture_prefix: false }
    }
}

/// One in-flight DVI sequence (paper §3.2–3.3 round structure, unrolled).
pub struct DviSeq {
    ctx: Arc<DviCtx>,
    /// Tuple sink; accept/reject supervision is logged when present.
    buffer: Option<Arc<Mutex<ReplayBuffer>>>,
    step: DviStep,
    seq: SeqPos,
    prompt_len: usize,
    max_new: usize,
    kv_sh: Vec<Buffer>,
    kv_dp: Vec<Buffer>,
    /// Shallow prefill rows awaiting the deep prefill call.
    hk_seq: Option<Tensor>,
    /// Feed point at the start of the current round.
    round_feed: (u32, usize),
    drafted: Vec<u32>,
    hk_rows: Vec<f32>,
    /// Draft length chosen for the current round (== k_spec when the
    /// adaptive policy is off).
    round_k: usize,
    /// Draft length of the last *verified* round, for stats surfacing.
    last_round_k: Option<usize>,
    /// Acceptance-rate EMA over this sequence's verify outcomes
    /// (accepted / drafted per round). Starts optimistic at 1.0 so the
    /// first round speculates at full depth, matching pinned-k — unless
    /// a per-task prior seeded it (see [`DviSeqOpts::ema0`]).
    accept_ema: f64,
    /// Cached-prefix attach point (0 = cold prefill).
    attach_len: usize,
    /// Whether to capture a prefix snapshot at deep-prefill completion.
    capture_prefix: bool,
    /// Snapshot parked for [`DviSeq::take_prefix_snapshot`].
    snapshot: Option<PrefixSnapshot>,
    result: GenResult,
    started: Instant,
    round_t0: Instant,
    call_t0: Instant,
    decode_t0: Instant,
    draft_ns: u64,
}

impl DviSeq {
    /// `key` is the sequence's placement key: both KV sets are allocated
    /// with it, so on a sharded remote backend the sequence's entire
    /// server-resident state lives on one executor (see
    /// [`crate::runtime::shard_for_key`]). In-process backends ignore it.
    pub fn new(
        ctx: Arc<DviCtx>,
        buffer: Option<Arc<Mutex<ReplayBuffer>>>,
        prompt: &[u32],
        max_new: usize,
        key: u64,
    ) -> Result<DviSeq> {
        Self::new_with(ctx, buffer, prompt, max_new, key, DviSeqOpts::default())
    }

    /// [`DviSeq::new`] with prefix-cache / prior options. With a warm
    /// [`DviSeqOpts::attach`], the provided (already-forked) KV sets are
    /// used instead of fresh allocations and the prefill calls start at
    /// `attach_len`; the attach requires the manifest's `start` ports.
    pub fn new_with(
        ctx: Arc<DviCtx>,
        buffer: Option<Arc<Mutex<ReplayBuffer>>>,
        prompt: &[u32],
        max_new: usize,
        key: u64,
        opts: DviSeqOpts,
    ) -> Result<DviSeq> {
        ensure!(
            prompt.len() <= ctx.prefill_seq,
            "prompt length {} exceeds prefill capacity {}",
            prompt.len(),
            ctx.prefill_seq
        );
        let (kv_sh, kv_dp, attach_len) = match opts.attach {
            Some(a) => {
                ensure!(
                    ctx.var_start,
                    "prefix attach requires prefill artifacts with a \
                     'start' port"
                );
                // Strictly below the prompt length: the last prompt
                // position's deep-prefill logits are always computed
                // live (the kernels enforce start < len too).
                ensure!(
                    a.attach_len < prompt.len(),
                    "attach length {} must be < prompt length {}",
                    a.attach_len,
                    prompt.len()
                );
                (a.kv_sh, a.kv_dp, a.attach_len)
            }
            None => (
                ctx.rt.fresh_kv_keyed("prefill_shallow", key)?,
                ctx.rt.fresh_kv_keyed("prefill_deep", key)?,
                0,
            ),
        };
        let now = Instant::now();
        Ok(DviSeq {
            buffer,
            step: DviStep::PrefillShallow,
            seq: SeqPos::after_prefill(prompt),
            prompt_len: prompt.len(),
            max_new,
            kv_sh,
            kv_dp,
            hk_seq: None,
            round_feed: (0, 0),
            drafted: Vec::with_capacity(ctx.k_spec),
            hk_rows: Vec::with_capacity(ctx.k_spec * ctx.d_model),
            round_k: ctx.k_spec,
            last_round_k: None,
            accept_ema: opts.ema0,
            attach_len,
            capture_prefix: opts.capture_prefix,
            snapshot: None,
            result: GenResult::default(),
            started: now,
            round_t0: now,
            call_t0: now,
            decode_t0: now,
            draft_ns: 0,
            ctx,
        })
    }

    pub fn pending_artifact(&self) -> Option<&'static str> {
        match self.step {
            DviStep::PrefillShallow => Some("prefill_shallow"),
            DviStep::PrefillDeep => Some("prefill_deep"),
            DviStep::Draft(_) => Some(if self.ctx.draft_block.is_some() {
                "draft_block"
            } else {
                "draft_step"
            }),
            DviStep::Verify => Some("verify_block"),
            DviStep::Done => None,
        }
    }

    pub fn phase(&self) -> SeqPhase {
        match self.step {
            DviStep::PrefillShallow | DviStep::PrefillDeep => SeqPhase::Prefilling,
            DviStep::Draft(_) => SeqPhase::Drafting,
            DviStep::Verify => SeqPhase::Verifying,
            DviStep::Done => SeqPhase::Done,
        }
    }

    pub fn is_done(&self) -> bool {
        matches!(self.step, DviStep::Done)
    }

    pub fn into_result(self) -> GenResult {
        self.result
    }

    /// Acceptance-rate EMA over this sequence's verified rounds.
    pub fn accept_ema(&self) -> f64 {
        self.accept_ema
    }

    /// Cached-prefix attach point this sequence started from (0 = cold).
    pub fn attach_len(&self) -> usize {
        self.attach_len
    }

    /// Take the post-prefill snapshot (present once per sequence, after
    /// the deep prefill completes, when construction asked for capture).
    pub fn take_prefix_snapshot(&mut self) -> Option<PrefixSnapshot> {
        self.snapshot.take()
    }

    /// Draft length of the most recently verified round.
    pub fn last_round_k(&self) -> Option<usize> {
        self.last_round_k
    }

    /// Live row count of the pending verify call (the current round's
    /// chosen k), when the sequence is waiting on a verify.
    pub fn verify_rows(&self) -> Option<usize> {
        if matches!(self.step, DviStep::Verify) {
            Some(self.round_k)
        } else {
            None
        }
    }

    /// Materialise the next backend call for this sequence.
    pub fn next_call(&mut self) -> Result<CallSpec> {
        let now = Instant::now();
        match self.step {
            DviStep::PrefillShallow => {
                let mut padded: Vec<i32> = self.seq.tokens[..self.prompt_len]
                    .iter()
                    .map(|&t| t as i32)
                    .collect();
                padded.resize(self.ctx.prefill_seq, 0);
                let mut inputs =
                    vec![Tensor::i32(vec![self.ctx.prefill_seq], padded)];
                if self.ctx.var_start {
                    // 0 for cold prefill — bitwise identical to the
                    // historical no-start call by kernel construction.
                    inputs.push(Tensor::scalar_i32(self.attach_len as i32));
                }
                Ok(CallSpec {
                    artifact: self.ctx.prefill_sh.clone(),
                    kv: self.kv_sh.clone(),
                    inputs,
                })
            }
            DviStep::PrefillDeep => {
                let hk = match &self.hk_seq {
                    Some(t) => t.clone(),
                    None => bail!("deep prefill without shallow prefill rows"),
                };
                let mut inputs =
                    vec![hk, Tensor::scalar_i32(self.prompt_len as i32)];
                if self.ctx.var_start {
                    inputs.push(Tensor::scalar_i32(self.attach_len as i32));
                }
                Ok(CallSpec {
                    artifact: self.ctx.prefill_dp.clone(),
                    kv: self.kv_dp.clone(),
                    inputs,
                })
            }
            DviStep::Draft(i) => {
                if i == 0 {
                    self.round_t0 = now;
                    self.round_feed = self.seq.feed();
                    self.drafted.clear();
                    self.hk_rows.clear();
                    self.round_k = match &self.ctx.adaptive {
                        Some(ad) if self.ctx.var_len => {
                            ad.choose(self.accept_ema, self.ctx.k_spec)
                        }
                        _ => self.ctx.k_spec,
                    };
                }
                if let Some(block) = &self.ctx.draft_block {
                    let mut inputs = vec![
                        Tensor::scalar_i32(self.round_feed.0 as i32),
                        Tensor::scalar_i32(self.round_feed.1 as i32),
                    ];
                    if self.ctx.var_len {
                        inputs.push(Tensor::scalar_i32(self.round_k as i32));
                    }
                    Ok(CallSpec {
                        artifact: block.clone(),
                        kv: self.kv_sh.clone(),
                        inputs,
                    })
                } else {
                    let tok = if i == 0 {
                        self.round_feed.0
                    } else {
                        *self.drafted.last().expect("draft sub-step without prior")
                    };
                    Ok(CallSpec {
                        artifact: self.ctx.draft.clone(),
                        kv: self.kv_sh.clone(),
                        inputs: vec![
                            Tensor::scalar_i32(tok as i32),
                            Tensor::scalar_i32((self.round_feed.1 + i) as i32),
                        ],
                    })
                }
            }
            DviStep::Verify => {
                self.call_t0 = now;
                self.draft_ns = self.round_t0.elapsed().as_nanos() as u64;
                self.ctx.obs.draft_round.observe(self.draft_ns);
                if trace::enabled() {
                    trace::complete_with_dur(
                        "seq.draft_round",
                        "seq",
                        self.draft_ns,
                        vec![("k", trace::Arg::I(self.round_k as i64))],
                    );
                }
                // The hk block always travels at the manifest's uniform
                // [k_spec, d] shape; short adaptive rounds zero-pad and
                // tell the backend the live row count via `len`.
                let mut hk = self.hk_rows.clone();
                hk.resize(self.ctx.k_spec * self.ctx.d_model, 0.0);
                let mut inputs = vec![
                    Tensor::f32(vec![self.ctx.k_spec, self.ctx.d_model], hk),
                    Tensor::scalar_i32(self.round_feed.1 as i32),
                ];
                if self.ctx.var_len {
                    inputs.push(Tensor::scalar_i32(self.round_k as i32));
                }
                Ok(CallSpec {
                    artifact: self.ctx.verify.clone(),
                    kv: self.kv_dp.clone(),
                    inputs,
                })
            }
            DviStep::Done => bail!("sequence already complete"),
        }
    }

    /// Consume the result of the call [`Self::next_call`] described.
    /// Returns the number of tokens committed by this call.
    pub fn apply(&mut self, out: CallOut) -> Result<usize> {
        match self.step {
            DviStep::PrefillShallow => {
                self.kv_sh = out.kv;
                self.hk_seq = Some(out.outputs[0].clone());
                self.step = DviStep::PrefillDeep;
                Ok(0)
            }
            DviStep::PrefillDeep => {
                self.kv_dp = out.kv;
                self.hk_seq = None; // consumed; don't pin [P, d] per slot
                if self.capture_prefix {
                    // Post-prefill KV is a complete snapshot of the
                    // prompt (the kernels clone *all* input rows before
                    // computing the suffix), so even a warm-attached
                    // sequence can donate its full prompt to the cache.
                    self.snapshot = Some(PrefixSnapshot {
                        tokens: self.seq.tokens[..self.prompt_len].to_vec(),
                        kv_sh: self.kv_sh.clone(),
                        kv_dp: self.kv_dp.clone(),
                    });
                }
                let first = argmax(out.outputs[0].as_f32()?) as u32;
                self.seq.push_committed(first);
                self.result.tokens.push(first);
                self.result.prefill_ns = self.started.elapsed().as_nanos() as u64;
                self.ctx.obs.prefill.observe(self.result.prefill_ns);
                if trace::enabled() {
                    trace::complete_with_dur(
                        "seq.prefill",
                        "seq",
                        self.result.prefill_ns,
                        vec![("prompt", trace::Arg::I(self.prompt_len as i64))],
                    );
                }
                self.decode_t0 = Instant::now();
                self.roll_or_finish();
                // Delivered delta (post-truncation), so scheduler token
                // accounting matches what the caller receives.
                Ok(self.result.tokens.len())
            }
            DviStep::Draft(i) => {
                self.kv_sh = out.kv;
                if self.ctx.draft_block.is_some() {
                    self.drafted = out.outputs[0]
                        .as_i32()?
                        .iter()
                        .map(|&t| t as u32)
                        .collect();
                    self.hk_rows = out.outputs[1].as_f32()?.to_vec();
                    self.step = DviStep::Verify;
                } else {
                    let d = argmax(out.outputs[0].as_f32()?) as u32;
                    self.hk_rows.extend_from_slice(out.outputs[1].as_f32()?);
                    self.drafted.push(d);
                    self.step = if i + 1 < self.round_k {
                        DviStep::Draft(i + 1)
                    } else {
                        DviStep::Verify
                    };
                }
                Ok(0)
            }
            DviStep::Verify => {
                self.kv_dp = out.kv;
                let k = self.round_k;
                let logits_phi = &out.outputs[0];
                let verifier: Vec<u32> = (0..k)
                    .map(|i| Ok(argmax(logits_phi.row_f32(i)?) as u32))
                    .collect::<Result<_>>()?;
                let outcome = longest_prefix(&self.drafted, &verifier);
                let verify_ns = self.call_t0.elapsed().as_nanos() as u64;
                self.ctx.obs.verify.observe(verify_ns);
                if trace::enabled() {
                    trace::complete_with_dur(
                        "seq.verify",
                        "seq",
                        verify_ns,
                        vec![
                            ("k", trace::Arg::I(k as i64)),
                            ("accepted", trace::Arg::I(outcome.accepted as i64)),
                        ],
                    );
                }

                let before = self.result.tokens.len();
                self.seq.advance(k, outcome.accepted, &outcome.committed);
                self.result.tokens.extend_from_slice(&outcome.committed);
                self.roll_or_finish();
                // Delivered delta: EOS/max_new truncation in
                // roll_or_finish never cuts below `before` (earlier
                // rounds already survived it), so this is what the
                // caller actually gains from the round — and what the
                // round's accounting and supervision must be clamped
                // to, or the final round overcounts.
                let delivered = self.result.tokens.len().saturating_sub(before);
                self.result.steps.push(StepRecord {
                    drafted: k,
                    accepted: outcome.accepted,
                    committed: delivered,
                    draft_ns: self.draft_ns,
                    verify_ns,
                });

                // IMPROVE: one tuple per drafted position up to and
                // including the first reject (counterfactual positions
                // beyond it are never logged), clamped to the delivered
                // point — a token cut by EOS/max_new truncation was
                // never served, so the learner must not train on it.
                // The reward-masked reject position survives exactly
                // when its bonus token was delivered.
                if let Some(buf) = &self.buffer {
                    let mut buf = buf.lock().unwrap();
                    let logged = (outcome.accepted + 1).min(k).min(delivered);
                    let d = self.ctx.d_model;
                    for i in 0..logged {
                        buf.push(Tuple {
                            hk: self.hk_rows[i * d..(i + 1) * d].to_vec(),
                            action: self.drafted[i],
                            logits_phi: logits_phi.row_f32(i)?.to_vec(),
                            reward: if i < outcome.accepted { 1.0 } else { 0.0 },
                        });
                    }
                }

                // Acceptance EMA feeds the adaptive-k policy (and stats)
                // regardless of mode; truncation does not touch it — it
                // measures drafter quality, not delivery budget.
                let alpha = self
                    .ctx
                    .adaptive
                    .map_or(AdaptiveK::default().alpha, |ad| ad.alpha);
                self.accept_ema = alpha * (outcome.accepted as f64 / k as f64)
                    + (1.0 - alpha) * self.accept_ema;
                self.last_round_k = Some(k);
                Ok(delivered)
            }
            DviStep::Done => bail!("sequence already complete"),
        }
    }

    /// The engine loop's continuation condition, verbatim: under max_new,
    /// no EOS emitted (with its truncation side effect), and KV headroom
    /// for one more round. Anything else finalises the result.
    fn roll_or_finish(&mut self) {
        let k = self.ctx.k_spec;
        if self.result.tokens.len() < self.max_new
            && !truncate_at_eos(&mut self.result.tokens)
            && self.seq.kv_len + k + 1 < self.ctx.max_seq
        {
            self.step = DviStep::Draft(0);
        } else {
            truncate_at_eos(&mut self.result.tokens);
            self.result.tokens.truncate(self.max_new);
            self.result.decode_ns = self.decode_t0.elapsed().as_nanos() as u64;
            self.step = DviStep::Done;
            if trace::enabled() {
                trace::instant(
                    "seq.finish",
                    "seq",
                    vec![(
                        "tokens",
                        trace::Arg::I(self.result.tokens.len() as i64),
                    )],
                );
            }
        }
    }
}

// ----------------------------------------------------------------------------
// AR sequence
// ----------------------------------------------------------------------------

enum ArStep {
    Prefill,
    Step,
    Done,
}

/// One in-flight greedy-AR sequence over the full-model artifacts.
pub struct ArSeq {
    ctx: Arc<ArCtx>,
    step: ArStep,
    seq: SeqPos,
    prompt_len: usize,
    max_new: usize,
    kv: Vec<Buffer>,
    result: GenResult,
    started: Instant,
    call_t0: Instant,
    decode_t0: Instant,
}

impl ArSeq {
    /// `key`: placement key for the KV allocation (see [`DviSeq::new`]).
    pub fn new(
        ctx: Arc<ArCtx>,
        prompt: &[u32],
        max_new: usize,
        key: u64,
    ) -> Result<ArSeq> {
        ensure!(
            prompt.len() <= ctx.prefill_seq,
            "prompt length {} exceeds prefill capacity {}",
            prompt.len(),
            ctx.prefill_seq
        );
        let kv = ctx.rt.fresh_kv_keyed("prefill_full", key)?;
        let now = Instant::now();
        Ok(ArSeq {
            step: ArStep::Prefill,
            seq: SeqPos::after_prefill(prompt),
            prompt_len: prompt.len(),
            max_new,
            kv,
            result: GenResult::default(),
            started: now,
            call_t0: now,
            decode_t0: now,
            ctx,
        })
    }

    pub fn pending_artifact(&self) -> Option<&'static str> {
        match self.step {
            ArStep::Prefill => Some("prefill_full"),
            ArStep::Step => Some("target_step"),
            ArStep::Done => None,
        }
    }

    pub fn phase(&self) -> SeqPhase {
        match self.step {
            ArStep::Prefill => SeqPhase::Prefilling,
            ArStep::Step => SeqPhase::Verifying,
            ArStep::Done => SeqPhase::Done,
        }
    }

    pub fn is_done(&self) -> bool {
        matches!(self.step, ArStep::Done)
    }

    pub fn into_result(self) -> GenResult {
        self.result
    }

    pub fn next_call(&mut self) -> Result<CallSpec> {
        let now = Instant::now();
        match self.step {
            ArStep::Prefill => {
                let mut padded: Vec<i32> = self.seq.tokens[..self.prompt_len]
                    .iter()
                    .map(|&t| t as i32)
                    .collect();
                padded.resize(self.ctx.prefill_seq, 0);
                Ok(CallSpec {
                    artifact: self.ctx.prefill.clone(),
                    kv: self.kv.clone(),
                    inputs: vec![
                        Tensor::i32(vec![self.ctx.prefill_seq], padded),
                        Tensor::scalar_i32(self.prompt_len as i32),
                    ],
                })
            }
            ArStep::Step => {
                self.call_t0 = now;
                let (tok, pos) = self.seq.feed();
                Ok(CallSpec {
                    artifact: self.ctx.step.clone(),
                    kv: self.kv.clone(),
                    inputs: vec![
                        Tensor::scalar_i32(tok as i32),
                        Tensor::scalar_i32(pos as i32),
                    ],
                })
            }
            ArStep::Done => bail!("sequence already complete"),
        }
    }

    pub fn apply(&mut self, out: CallOut) -> Result<usize> {
        match self.step {
            ArStep::Prefill => {
                self.kv = out.kv;
                let first = argmax(out.outputs[0].as_f32()?) as u32;
                self.seq.push_committed(first);
                self.result.tokens.push(first);
                self.result.prefill_ns = self.started.elapsed().as_nanos() as u64;
                self.ctx.obs.prefill.observe(self.result.prefill_ns);
                if trace::enabled() {
                    trace::complete_with_dur(
                        "seq.prefill",
                        "seq",
                        self.result.prefill_ns,
                        vec![("prompt", trace::Arg::I(self.prompt_len as i64))],
                    );
                }
                self.decode_t0 = Instant::now();
                self.roll_or_finish();
                Ok(1)
            }
            ArStep::Step => {
                self.kv = out.kv;
                let tok = argmax(out.outputs[0].as_f32()?) as u32;
                self.seq.advance_ar(tok);
                self.result.tokens.push(tok);
                let step_ns = self.call_t0.elapsed().as_nanos() as u64;
                self.ctx.obs.ar_step.observe(step_ns);
                self.result.steps.push(StepRecord {
                    drafted: 0,
                    accepted: 0,
                    committed: 1,
                    draft_ns: 0,
                    verify_ns: step_ns,
                });
                self.roll_or_finish();
                Ok(1)
            }
            ArStep::Done => bail!("sequence already complete"),
        }
    }

    fn roll_or_finish(&mut self) {
        if self.result.tokens.len() < self.max_new
            && !truncate_at_eos(&mut self.result.tokens)
            && self.seq.kv_len + 1 < self.ctx.max_seq
        {
            self.step = ArStep::Step;
        } else {
            truncate_at_eos(&mut self.result.tokens);
            self.result.decode_ns = self.decode_t0.elapsed().as_nanos() as u64;
            self.step = ArStep::Done;
            if trace::enabled() {
                trace::instant(
                    "seq.finish",
                    "seq",
                    vec![(
                        "tokens",
                        trace::Arg::I(self.result.tokens.len() as i64),
                    )],
                );
            }
        }
    }
}

// ----------------------------------------------------------------------------
// Method-indexed wrappers
// ----------------------------------------------------------------------------

/// A sequence of either method, behind one poll/apply interface.
pub enum SeqState {
    Dvi(Box<DviSeq>),
    Ar(Box<ArSeq>),
}

impl SeqState {
    pub fn pending_artifact(&self) -> Option<&'static str> {
        match self {
            SeqState::Dvi(s) => s.pending_artifact(),
            SeqState::Ar(s) => s.pending_artifact(),
        }
    }

    pub fn next_call(&mut self) -> Result<CallSpec> {
        match self {
            SeqState::Dvi(s) => s.next_call(),
            SeqState::Ar(s) => s.next_call(),
        }
    }

    pub fn apply(&mut self, out: CallOut) -> Result<usize> {
        match self {
            SeqState::Dvi(s) => s.apply(out),
            SeqState::Ar(s) => s.apply(out),
        }
    }

    pub fn is_done(&self) -> bool {
        match self {
            SeqState::Dvi(s) => s.is_done(),
            SeqState::Ar(s) => s.is_done(),
        }
    }

    pub fn phase(&self) -> SeqPhase {
        match self {
            SeqState::Dvi(s) => s.phase(),
            SeqState::Ar(s) => s.phase(),
        }
    }

    pub fn into_result(self) -> GenResult {
        match self {
            SeqState::Dvi(s) => s.into_result(),
            SeqState::Ar(s) => s.into_result(),
        }
    }

    /// Acceptance EMA (DVI sequences only).
    pub fn accept_ema(&self) -> Option<f64> {
        match self {
            SeqState::Dvi(s) => Some(s.accept_ema()),
            SeqState::Ar(_) => None,
        }
    }

    /// Draft length of the last verified round (DVI sequences only).
    pub fn last_round_k(&self) -> Option<usize> {
        match self {
            SeqState::Dvi(s) => s.last_round_k(),
            SeqState::Ar(_) => None,
        }
    }

    /// Rows the pending verify call will carry (DVI sequences only).
    pub fn verify_rows(&self) -> Option<usize> {
        match self {
            SeqState::Dvi(s) => s.verify_rows(),
            SeqState::Ar(_) => None,
        }
    }

    /// Cached-prefix attach point (DVI only; AR bypasses the cache).
    pub fn attach_len(&self) -> usize {
        match self {
            SeqState::Dvi(s) => s.attach_len(),
            SeqState::Ar(_) => 0,
        }
    }

    /// Take the post-prefill cache snapshot, if one was captured.
    pub fn take_prefix_snapshot(&mut self) -> Option<PrefixSnapshot> {
        match self {
            SeqState::Dvi(s) => s.take_prefix_snapshot(),
            SeqState::Ar(_) => None,
        }
    }
}

/// What the scheduler needs to mint fresh sequences of one method.
enum MethodKind {
    Dvi {
        ctx: Arc<DviCtx>,
        buffer: Option<Arc<Mutex<ReplayBuffer>>>,
    },
    Ar {
        ctx: Arc<ArCtx>,
    },
}

/// Sequence factory: resolves the method's artifacts once and mints
/// sequences with **sequential placement keys** (0, 1, 2, ...) so a
/// sharded backend round-robins sequences across executors while each
/// sequence's KV stays on exactly one (key i ↔ the i-th created
/// sequence — deterministic, which the shard kill tests rely on).
pub struct MethodCtx {
    kind: MethodKind,
    next_key: std::sync::atomic::AtomicU64,
}

impl MethodCtx {
    /// `adaptive` sets the DVI draft-length policy explicitly; `None`
    /// pins k (AR sequences ignore it either way).
    pub fn new(
        rt: Arc<Runtime>,
        method: &str,
        buffer: Option<Arc<Mutex<ReplayBuffer>>>,
        adaptive: Option<AdaptiveK>,
    ) -> Result<MethodCtx> {
        let kind = match method {
            "dvi" => MethodKind::Dvi {
                ctx: Arc::new(DviCtx::new(rt)?.with_adaptive(adaptive)),
                buffer,
            },
            "ar" => MethodKind::Ar {
                ctx: Arc::new(ArCtx::new(rt)?),
            },
            other => bail!("scheduler supports methods dvi|ar, got '{other}'"),
        };
        Ok(MethodCtx { kind, next_key: std::sync::atomic::AtomicU64::new(0) })
    }

    /// True when sequences minted here may vary their round length.
    pub fn adaptive_active(&self) -> bool {
        match &self.kind {
            MethodKind::Dvi { ctx, .. } => ctx.adaptive_active(),
            MethodKind::Ar { .. } => false,
        }
    }

    /// The manifest draft depth bound (DVI only).
    pub fn k_spec(&self) -> Option<usize> {
        match &self.kind {
            MethodKind::Dvi { ctx, .. } => Some(ctx.k_spec),
            MethodKind::Ar { .. } => None,
        }
    }

    /// True when sequences minted here can start from a cached prefix
    /// (DVI with `start`-capable prefill artifacts; AR never attaches).
    pub fn supports_prefix_attach(&self) -> bool {
        match &self.kind {
            MethodKind::Dvi { ctx, .. } => ctx.var_start,
            MethodKind::Ar { .. } => false,
        }
    }

    /// The runtime behind this method's artifacts (the scheduler's
    /// prefix cache forks KV through it).
    pub fn runtime(&self) -> &Arc<Runtime> {
        match &self.kind {
            MethodKind::Dvi { ctx, .. } => &ctx.rt,
            MethodKind::Ar { ctx } => &ctx.rt,
        }
    }

    pub fn new_seq(&self, prompt: &[u32], max_new: usize) -> Result<SeqState> {
        self.new_seq_with(prompt, max_new, None, DviSeqOpts::default())
    }

    /// [`MethodCtx::new_seq`] with scheduler-supplied options.
    /// `placement` overrides the sequential key for cold allocations
    /// (the backend's least-loaded hint); when `None`, or always on the
    /// default path, keys stay sequential (0, 1, 2, ...) so cache-off
    /// placement is byte-for-byte the historical round-robin. AR
    /// sequences ignore `opts` (no draft EMA, no prefix attach).
    pub fn new_seq_with(
        &self,
        prompt: &[u32],
        max_new: usize,
        placement: Option<u64>,
        opts: DviSeqOpts,
    ) -> Result<SeqState> {
        let key = placement.unwrap_or_else(|| {
            self.next_key
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        });
        match &self.kind {
            MethodKind::Dvi { ctx, buffer } => {
                Ok(SeqState::Dvi(Box::new(DviSeq::new_with(
                    ctx.clone(),
                    buffer.clone(),
                    prompt,
                    max_new,
                    key,
                    opts,
                )?)))
            }
            MethodKind::Ar { ctx } => Ok(SeqState::Ar(Box::new(ArSeq::new(
                ctx.clone(),
                prompt,
                max_new,
                key,
            )?))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Arc<Runtime> {
        Arc::new(Runtime::load_reference(0x5E9).expect("reference runtime"))
    }

    /// Drive a DviSeq call-by-call: phases must progress Prefilling →
    /// Drafting → Verifying rounds → Done, and the result must be a
    /// plausible generation.
    #[test]
    fn dvi_seq_phases_progress() {
        let rt = runtime();
        let ctx = Arc::new(DviCtx::new(rt.clone()).unwrap());
        let prompt: Vec<u32> = vec![1, 10, 11, 3];
        let mut s = DviSeq::new(ctx, None, &prompt, 12, 0).unwrap();
        assert_eq!(s.phase(), SeqPhase::Prefilling);
        let mut seen_draft = false;
        let mut seen_verify = false;
        let mut calls = 0;
        while !s.is_done() {
            calls += 1;
            assert!(calls < 500, "sequence did not terminate");
            let call = s.next_call().unwrap();
            let out = call.artifact.call(&call.kv, &call.inputs).unwrap();
            s.apply(out).unwrap();
            match s.phase() {
                SeqPhase::Drafting => seen_draft = true,
                SeqPhase::Verifying => seen_verify = true,
                _ => {}
            }
        }
        assert!(seen_draft && seen_verify, "phases skipped");
        assert!(s.pending_artifact().is_none());
        let r = s.into_result();
        assert!(!r.tokens.is_empty() && r.tokens.len() <= 12);
        assert!(r.steps.iter().all(|st| st.drafted > 0));
    }

    /// Regression (truncation-skewed accounting/supervision): when the
    /// final round's committed tokens are cut by `max_new`, the step
    /// record must carry the delivered delta — not the pre-truncation
    /// commit count — and the replay buffer must not receive tuples for
    /// tokens that were never served. Before the fix this recorded
    /// `committed = k` and logged `min(accepted+1, k)` tuples.
    #[test]
    fn truncated_final_round_records_delivered_not_committed() {
        let rt = runtime();
        let ctx = Arc::new(DviCtx::new(rt.clone()).unwrap().with_adaptive(None));
        let k = ctx.k_spec;
        let vocab = rt.manifest.model_usize("vocab_size").unwrap();
        let buffer = Arc::new(Mutex::new(ReplayBuffer::new(64)));
        let prompt: Vec<u32> = vec![1, 10, 11, 3];
        // max_new = 2: prefill delivers token 1, so the single verify
        // round has a delivery budget of exactly 1.
        let mut s = DviSeq::new(ctx, Some(buffer.clone()), &prompt, 2, 0).unwrap();
        while !matches!(s.step, DviStep::Verify) {
            assert!(!s.is_done(), "finished before the first verify");
            let call = s.next_call().unwrap();
            let out = call.artifact.call(&call.kv, &call.inputs).unwrap();
            s.apply(out).unwrap();
        }
        let call = s.next_call().unwrap();
        // Craft verifier logits that accept every drafted token: the
        // round wants to commit k tokens into a budget of 1.
        let mut logits = vec![0.0f32; k * vocab];
        for (i, &d) in s.drafted.iter().enumerate() {
            logits[i * vocab + d as usize] = 1.0;
        }
        let out = CallOut {
            outputs: vec![Tensor::f32(vec![k, vocab], logits)],
            kv: call.kv,
        };
        let delivered = s.apply(out).unwrap();
        assert!(s.is_done());
        let r = s.into_result();
        assert!(r.tokens.len() <= 2);
        let st = r.steps.last().unwrap();
        assert_eq!(st.accepted, k, "crafted verify must accept all drafted");
        assert_eq!(
            st.committed, delivered,
            "step accounting must record the delivered delta"
        );
        assert!(
            st.committed < k,
            "truncation must cut the recorded commit below k"
        );
        let buf = buffer.lock().unwrap();
        assert_eq!(
            buf.pushed as usize, delivered,
            "replay tuples must stop at the delivered point"
        );
    }

    /// The adaptive-k policy is total, bounded, and monotone in the
    /// acceptance EMA; an optimistic (fresh) sequence speculates at
    /// full depth so the first round matches pinned-k.
    #[test]
    fn adaptive_k_policy_bounds_and_monotonicity() {
        let ad = AdaptiveK::default();
        assert_eq!(ad.choose(1.0, 4), 4);
        assert_eq!(ad.choose(0.0, 4), 1);
        let mut last = usize::MAX;
        for ema in [0.95, 0.8, 0.6, 0.4, 0.2] {
            let k = ad.choose(ema, 8);
            assert!((1..=8).contains(&k));
            assert!(k <= last, "k must not grow as acceptance falls");
            last = k;
        }
        let tight = AdaptiveK { floor: 2, ceiling: 3, ..AdaptiveK::default() };
        for ema in [0.0, 0.5, 1.0] {
            let k = tight.choose(ema, 8);
            assert!((2..=3).contains(&k));
        }
    }

    /// Prompts longer than the prefill window must be rejected at
    /// construction, not mid-flight.
    #[test]
    fn oversized_prompt_rejected_at_admission() {
        let rt = runtime();
        let ctx = Arc::new(ArCtx::new(rt.clone()).unwrap());
        let long = vec![1u32; ctx.prefill_seq + 1];
        assert!(ArSeq::new(ctx, &long, 8, 0).is_err());
        let dctx = Arc::new(DviCtx::new(rt).unwrap());
        let long = vec![1u32; dctx.prefill_seq + 1];
        assert!(DviSeq::new(dctx, None, &long, 8, 0).is_err());
    }
}
