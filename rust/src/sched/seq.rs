//! Resumable per-sequence decode state machines.
//!
//! [`DviSeq`] and [`ArSeq`] are the DVI and AR engines' generate loops
//! unrolled into poll-able state machines: `pending_artifact` names the
//! backend call the sequence needs next, `next_call` materialises it,
//! `apply` consumes the result and advances the phase
//! (Prefilling → Drafting → Verifying → Done). A single sequence driven
//! call-by-call reproduces the old engine loops exactly — the engines
//! themselves now run on these machines — and the continuous-batching
//! scheduler ([`crate::sched::Scheduler`]) drives many of them through
//! batched backend calls. Because both paths execute the identical
//! per-sequence op sequence, batched serving is bitwise-lossless against
//! per-sequence decoding (asserted by `tests/sched.rs`).

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::engine::{truncate_at_eos, GenResult, StepRecord};
use crate::learner::{ReplayBuffer, Tuple};
use crate::runtime::{Artifact, Buffer, CallOut, Runtime, Tensor};
use crate::spec::{longest_prefix, SeqPos};
use crate::util::math::argmax;

/// Coarse phase of a sequence, shared by both machines. AR sequences
/// have no draft stage; their decode steps count as Verifying (each is
/// one target-model call).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqPhase {
    Prefilling,
    Drafting,
    Verifying,
    Done,
}

/// One materialised backend call: the artifact plus this sequence's KV
/// handles (cheap `Arc` clones) and host inputs. Owned, so the scheduler
/// can collect a batch of these without borrow entanglement.
pub struct CallSpec {
    pub artifact: Arc<Artifact>,
    pub kv: Vec<Buffer>,
    pub inputs: Vec<Tensor>,
}

/// Shared immutable context for DVI sequences: artifact handles and
/// model dimensions, resolved once per engine/scheduler.
#[derive(Clone)]
pub struct DviCtx {
    pub rt: Arc<Runtime>,
    pub prefill_sh: Arc<Artifact>,
    pub prefill_dp: Arc<Artifact>,
    pub draft: Arc<Artifact>,
    /// Fused k_spec-step draft loop; `None` forces the per-step path.
    pub draft_block: Option<Arc<Artifact>>,
    pub verify: Arc<Artifact>,
    pub k_spec: usize,
    pub d_model: usize,
    pub prefill_seq: usize,
    pub max_seq: usize,
}

impl DviCtx {
    pub fn new(rt: Arc<Runtime>) -> Result<DviCtx> {
        let k_spec = rt.manifest.spec_usize("k_spec")?;
        let d_model = rt.manifest.model_usize("d_model")?;
        let prefill_seq = rt.manifest.spec_usize("prefill_seq")?;
        let max_seq = rt.manifest.model_usize("max_seq")?;
        Ok(DviCtx {
            prefill_sh: rt.artifact("prefill_shallow")?,
            prefill_dp: rt.artifact("prefill_deep")?,
            draft: rt.artifact("draft_step")?,
            draft_block: rt.artifact("draft_block").ok(),
            verify: rt.artifact("verify_block")?,
            rt,
            k_spec,
            d_model,
            prefill_seq,
            max_seq,
        })
    }
}

/// Shared immutable context for AR sequences.
#[derive(Clone)]
pub struct ArCtx {
    pub rt: Arc<Runtime>,
    pub prefill: Arc<Artifact>,
    pub step: Arc<Artifact>,
    pub prefill_seq: usize,
    pub max_seq: usize,
}

impl ArCtx {
    pub fn new(rt: Arc<Runtime>) -> Result<ArCtx> {
        let prefill_seq = rt.manifest.spec_usize("prefill_seq")?;
        let max_seq = rt.manifest.model_usize("max_seq")?;
        Ok(ArCtx {
            prefill: rt.artifact("prefill_full")?,
            step: rt.artifact("target_step")?,
            rt,
            prefill_seq,
            max_seq,
        })
    }
}

// ----------------------------------------------------------------------------
// DVI sequence
// ----------------------------------------------------------------------------

enum DviStep {
    PrefillShallow,
    PrefillDeep,
    /// Draft sub-step index: always 0 on the fused draft_block path,
    /// 0..k_spec on the per-step path.
    Draft(usize),
    Verify,
    Done,
}

/// One in-flight DVI sequence (paper §3.2–3.3 round structure, unrolled).
pub struct DviSeq {
    ctx: Arc<DviCtx>,
    /// Tuple sink; accept/reject supervision is logged when present.
    buffer: Option<Arc<Mutex<ReplayBuffer>>>,
    step: DviStep,
    seq: SeqPos,
    prompt_len: usize,
    max_new: usize,
    kv_sh: Vec<Buffer>,
    kv_dp: Vec<Buffer>,
    /// Shallow prefill rows awaiting the deep prefill call.
    hk_seq: Option<Tensor>,
    /// Feed point at the start of the current round.
    round_feed: (u32, usize),
    drafted: Vec<u32>,
    hk_rows: Vec<f32>,
    result: GenResult,
    started: Instant,
    round_t0: Instant,
    call_t0: Instant,
    decode_t0: Instant,
    draft_ns: u64,
}

impl DviSeq {
    /// `key` is the sequence's placement key: both KV sets are allocated
    /// with it, so on a sharded remote backend the sequence's entire
    /// server-resident state lives on one executor (see
    /// [`crate::runtime::shard_for_key`]). In-process backends ignore it.
    pub fn new(
        ctx: Arc<DviCtx>,
        buffer: Option<Arc<Mutex<ReplayBuffer>>>,
        prompt: &[u32],
        max_new: usize,
        key: u64,
    ) -> Result<DviSeq> {
        ensure!(
            prompt.len() <= ctx.prefill_seq,
            "prompt length {} exceeds prefill capacity {}",
            prompt.len(),
            ctx.prefill_seq
        );
        let kv_sh = ctx.rt.fresh_kv_keyed("prefill_shallow", key)?;
        let kv_dp = ctx.rt.fresh_kv_keyed("prefill_deep", key)?;
        let now = Instant::now();
        Ok(DviSeq {
            buffer,
            step: DviStep::PrefillShallow,
            seq: SeqPos::after_prefill(prompt),
            prompt_len: prompt.len(),
            max_new,
            kv_sh,
            kv_dp,
            hk_seq: None,
            round_feed: (0, 0),
            drafted: Vec::with_capacity(ctx.k_spec),
            hk_rows: Vec::with_capacity(ctx.k_spec * ctx.d_model),
            result: GenResult::default(),
            started: now,
            round_t0: now,
            call_t0: now,
            decode_t0: now,
            draft_ns: 0,
            ctx,
        })
    }

    pub fn pending_artifact(&self) -> Option<&'static str> {
        match self.step {
            DviStep::PrefillShallow => Some("prefill_shallow"),
            DviStep::PrefillDeep => Some("prefill_deep"),
            DviStep::Draft(_) => Some(if self.ctx.draft_block.is_some() {
                "draft_block"
            } else {
                "draft_step"
            }),
            DviStep::Verify => Some("verify_block"),
            DviStep::Done => None,
        }
    }

    pub fn phase(&self) -> SeqPhase {
        match self.step {
            DviStep::PrefillShallow | DviStep::PrefillDeep => SeqPhase::Prefilling,
            DviStep::Draft(_) => SeqPhase::Drafting,
            DviStep::Verify => SeqPhase::Verifying,
            DviStep::Done => SeqPhase::Done,
        }
    }

    pub fn is_done(&self) -> bool {
        matches!(self.step, DviStep::Done)
    }

    pub fn into_result(self) -> GenResult {
        self.result
    }

    /// Materialise the next backend call for this sequence.
    pub fn next_call(&mut self) -> Result<CallSpec> {
        let now = Instant::now();
        match self.step {
            DviStep::PrefillShallow => {
                let mut padded: Vec<i32> = self.seq.tokens[..self.prompt_len]
                    .iter()
                    .map(|&t| t as i32)
                    .collect();
                padded.resize(self.ctx.prefill_seq, 0);
                Ok(CallSpec {
                    artifact: self.ctx.prefill_sh.clone(),
                    kv: self.kv_sh.clone(),
                    inputs: vec![Tensor::i32(vec![self.ctx.prefill_seq], padded)],
                })
            }
            DviStep::PrefillDeep => {
                let hk = match &self.hk_seq {
                    Some(t) => t.clone(),
                    None => bail!("deep prefill without shallow prefill rows"),
                };
                Ok(CallSpec {
                    artifact: self.ctx.prefill_dp.clone(),
                    kv: self.kv_dp.clone(),
                    inputs: vec![hk, Tensor::scalar_i32(self.prompt_len as i32)],
                })
            }
            DviStep::Draft(i) => {
                if i == 0 {
                    self.round_t0 = now;
                    self.round_feed = self.seq.feed();
                    self.drafted.clear();
                    self.hk_rows.clear();
                }
                if let Some(block) = &self.ctx.draft_block {
                    Ok(CallSpec {
                        artifact: block.clone(),
                        kv: self.kv_sh.clone(),
                        inputs: vec![
                            Tensor::scalar_i32(self.round_feed.0 as i32),
                            Tensor::scalar_i32(self.round_feed.1 as i32),
                        ],
                    })
                } else {
                    let tok = if i == 0 {
                        self.round_feed.0
                    } else {
                        *self.drafted.last().expect("draft sub-step without prior")
                    };
                    Ok(CallSpec {
                        artifact: self.ctx.draft.clone(),
                        kv: self.kv_sh.clone(),
                        inputs: vec![
                            Tensor::scalar_i32(tok as i32),
                            Tensor::scalar_i32((self.round_feed.1 + i) as i32),
                        ],
                    })
                }
            }
            DviStep::Verify => {
                self.call_t0 = now;
                self.draft_ns = self.round_t0.elapsed().as_nanos() as u64;
                Ok(CallSpec {
                    artifact: self.ctx.verify.clone(),
                    kv: self.kv_dp.clone(),
                    inputs: vec![
                        Tensor::f32(
                            vec![self.ctx.k_spec, self.ctx.d_model],
                            self.hk_rows.clone(),
                        ),
                        Tensor::scalar_i32(self.round_feed.1 as i32),
                    ],
                })
            }
            DviStep::Done => bail!("sequence already complete"),
        }
    }

    /// Consume the result of the call [`Self::next_call`] described.
    /// Returns the number of tokens committed by this call.
    pub fn apply(&mut self, out: CallOut) -> Result<usize> {
        match self.step {
            DviStep::PrefillShallow => {
                self.kv_sh = out.kv;
                self.hk_seq = Some(out.outputs[0].clone());
                self.step = DviStep::PrefillDeep;
                Ok(0)
            }
            DviStep::PrefillDeep => {
                self.kv_dp = out.kv;
                self.hk_seq = None; // consumed; don't pin [P, d] per slot
                let first = argmax(out.outputs[0].as_f32()?) as u32;
                self.seq.push_committed(first);
                self.result.tokens.push(first);
                self.result.prefill_ns = self.started.elapsed().as_nanos() as u64;
                self.decode_t0 = Instant::now();
                self.roll_or_finish();
                // Delivered delta (post-truncation), so scheduler token
                // accounting matches what the caller receives.
                Ok(self.result.tokens.len())
            }
            DviStep::Draft(i) => {
                self.kv_sh = out.kv;
                if self.ctx.draft_block.is_some() {
                    self.drafted = out.outputs[0]
                        .as_i32()?
                        .iter()
                        .map(|&t| t as u32)
                        .collect();
                    self.hk_rows = out.outputs[1].as_f32()?.to_vec();
                    self.step = DviStep::Verify;
                } else {
                    let d = argmax(out.outputs[0].as_f32()?) as u32;
                    self.hk_rows.extend_from_slice(out.outputs[1].as_f32()?);
                    self.drafted.push(d);
                    self.step = if i + 1 < self.ctx.k_spec {
                        DviStep::Draft(i + 1)
                    } else {
                        DviStep::Verify
                    };
                }
                Ok(0)
            }
            DviStep::Verify => {
                self.kv_dp = out.kv;
                let k = self.ctx.k_spec;
                let logits_phi = &out.outputs[0];
                let verifier: Vec<u32> = (0..k)
                    .map(|i| Ok(argmax(logits_phi.row_f32(i)?) as u32))
                    .collect::<Result<_>>()?;
                let outcome = longest_prefix(&self.drafted, &verifier);
                let verify_ns = self.call_t0.elapsed().as_nanos() as u64;

                // IMPROVE: one tuple per drafted position up to and
                // including the first reject (counterfactual positions
                // beyond it are never logged).
                if let Some(buf) = &self.buffer {
                    let mut buf = buf.lock().unwrap();
                    let logged = (outcome.accepted + 1).min(k);
                    let d = self.ctx.d_model;
                    for i in 0..logged {
                        buf.push(Tuple {
                            hk: self.hk_rows[i * d..(i + 1) * d].to_vec(),
                            action: self.drafted[i],
                            logits_phi: logits_phi.row_f32(i)?.to_vec(),
                            reward: if i < outcome.accepted { 1.0 } else { 0.0 },
                        });
                    }
                }

                let before = self.result.tokens.len();
                self.seq.advance(k, outcome.accepted, &outcome.committed);
                self.result.tokens.extend_from_slice(&outcome.committed);
                self.result.steps.push(StepRecord {
                    drafted: k,
                    accepted: outcome.accepted,
                    committed: outcome.total_committed(),
                    draft_ns: self.draft_ns,
                    verify_ns,
                });
                self.roll_or_finish();
                // Delivered delta: EOS/max_new truncation in
                // roll_or_finish never cuts below `before` (earlier
                // rounds already survived it), so this is what the
                // caller actually gains from the round.
                Ok(self.result.tokens.len().saturating_sub(before))
            }
            DviStep::Done => bail!("sequence already complete"),
        }
    }

    /// The engine loop's continuation condition, verbatim: under max_new,
    /// no EOS emitted (with its truncation side effect), and KV headroom
    /// for one more round. Anything else finalises the result.
    fn roll_or_finish(&mut self) {
        let k = self.ctx.k_spec;
        if self.result.tokens.len() < self.max_new
            && !truncate_at_eos(&mut self.result.tokens)
            && self.seq.kv_len + k + 1 < self.ctx.max_seq
        {
            self.step = DviStep::Draft(0);
        } else {
            truncate_at_eos(&mut self.result.tokens);
            self.result.tokens.truncate(self.max_new);
            self.result.decode_ns = self.decode_t0.elapsed().as_nanos() as u64;
            self.step = DviStep::Done;
        }
    }
}

// ----------------------------------------------------------------------------
// AR sequence
// ----------------------------------------------------------------------------

enum ArStep {
    Prefill,
    Step,
    Done,
}

/// One in-flight greedy-AR sequence over the full-model artifacts.
pub struct ArSeq {
    ctx: Arc<ArCtx>,
    step: ArStep,
    seq: SeqPos,
    prompt_len: usize,
    max_new: usize,
    kv: Vec<Buffer>,
    result: GenResult,
    started: Instant,
    call_t0: Instant,
    decode_t0: Instant,
}

impl ArSeq {
    /// `key`: placement key for the KV allocation (see [`DviSeq::new`]).
    pub fn new(
        ctx: Arc<ArCtx>,
        prompt: &[u32],
        max_new: usize,
        key: u64,
    ) -> Result<ArSeq> {
        ensure!(
            prompt.len() <= ctx.prefill_seq,
            "prompt length {} exceeds prefill capacity {}",
            prompt.len(),
            ctx.prefill_seq
        );
        let kv = ctx.rt.fresh_kv_keyed("prefill_full", key)?;
        let now = Instant::now();
        Ok(ArSeq {
            step: ArStep::Prefill,
            seq: SeqPos::after_prefill(prompt),
            prompt_len: prompt.len(),
            max_new,
            kv,
            result: GenResult::default(),
            started: now,
            call_t0: now,
            decode_t0: now,
            ctx,
        })
    }

    pub fn pending_artifact(&self) -> Option<&'static str> {
        match self.step {
            ArStep::Prefill => Some("prefill_full"),
            ArStep::Step => Some("target_step"),
            ArStep::Done => None,
        }
    }

    pub fn phase(&self) -> SeqPhase {
        match self.step {
            ArStep::Prefill => SeqPhase::Prefilling,
            ArStep::Step => SeqPhase::Verifying,
            ArStep::Done => SeqPhase::Done,
        }
    }

    pub fn is_done(&self) -> bool {
        matches!(self.step, ArStep::Done)
    }

    pub fn into_result(self) -> GenResult {
        self.result
    }

    pub fn next_call(&mut self) -> Result<CallSpec> {
        let now = Instant::now();
        match self.step {
            ArStep::Prefill => {
                let mut padded: Vec<i32> = self.seq.tokens[..self.prompt_len]
                    .iter()
                    .map(|&t| t as i32)
                    .collect();
                padded.resize(self.ctx.prefill_seq, 0);
                Ok(CallSpec {
                    artifact: self.ctx.prefill.clone(),
                    kv: self.kv.clone(),
                    inputs: vec![
                        Tensor::i32(vec![self.ctx.prefill_seq], padded),
                        Tensor::scalar_i32(self.prompt_len as i32),
                    ],
                })
            }
            ArStep::Step => {
                self.call_t0 = now;
                let (tok, pos) = self.seq.feed();
                Ok(CallSpec {
                    artifact: self.ctx.step.clone(),
                    kv: self.kv.clone(),
                    inputs: vec![
                        Tensor::scalar_i32(tok as i32),
                        Tensor::scalar_i32(pos as i32),
                    ],
                })
            }
            ArStep::Done => bail!("sequence already complete"),
        }
    }

    pub fn apply(&mut self, out: CallOut) -> Result<usize> {
        match self.step {
            ArStep::Prefill => {
                self.kv = out.kv;
                let first = argmax(out.outputs[0].as_f32()?) as u32;
                self.seq.push_committed(first);
                self.result.tokens.push(first);
                self.result.prefill_ns = self.started.elapsed().as_nanos() as u64;
                self.decode_t0 = Instant::now();
                self.roll_or_finish();
                Ok(1)
            }
            ArStep::Step => {
                self.kv = out.kv;
                let tok = argmax(out.outputs[0].as_f32()?) as u32;
                self.seq.advance_ar(tok);
                self.result.tokens.push(tok);
                self.result.steps.push(StepRecord {
                    drafted: 0,
                    accepted: 0,
                    committed: 1,
                    draft_ns: 0,
                    verify_ns: self.call_t0.elapsed().as_nanos() as u64,
                });
                self.roll_or_finish();
                Ok(1)
            }
            ArStep::Done => bail!("sequence already complete"),
        }
    }

    fn roll_or_finish(&mut self) {
        if self.result.tokens.len() < self.max_new
            && !truncate_at_eos(&mut self.result.tokens)
            && self.seq.kv_len + 1 < self.ctx.max_seq
        {
            self.step = ArStep::Step;
        } else {
            truncate_at_eos(&mut self.result.tokens);
            self.result.decode_ns = self.decode_t0.elapsed().as_nanos() as u64;
            self.step = ArStep::Done;
        }
    }
}

// ----------------------------------------------------------------------------
// Method-indexed wrappers
// ----------------------------------------------------------------------------

/// A sequence of either method, behind one poll/apply interface.
pub enum SeqState {
    Dvi(Box<DviSeq>),
    Ar(Box<ArSeq>),
}

impl SeqState {
    pub fn pending_artifact(&self) -> Option<&'static str> {
        match self {
            SeqState::Dvi(s) => s.pending_artifact(),
            SeqState::Ar(s) => s.pending_artifact(),
        }
    }

    pub fn next_call(&mut self) -> Result<CallSpec> {
        match self {
            SeqState::Dvi(s) => s.next_call(),
            SeqState::Ar(s) => s.next_call(),
        }
    }

    pub fn apply(&mut self, out: CallOut) -> Result<usize> {
        match self {
            SeqState::Dvi(s) => s.apply(out),
            SeqState::Ar(s) => s.apply(out),
        }
    }

    pub fn is_done(&self) -> bool {
        match self {
            SeqState::Dvi(s) => s.is_done(),
            SeqState::Ar(s) => s.is_done(),
        }
    }

    pub fn phase(&self) -> SeqPhase {
        match self {
            SeqState::Dvi(s) => s.phase(),
            SeqState::Ar(s) => s.phase(),
        }
    }

    pub fn into_result(self) -> GenResult {
        match self {
            SeqState::Dvi(s) => s.into_result(),
            SeqState::Ar(s) => s.into_result(),
        }
    }
}

/// What the scheduler needs to mint fresh sequences of one method.
enum MethodKind {
    Dvi {
        ctx: Arc<DviCtx>,
        buffer: Option<Arc<Mutex<ReplayBuffer>>>,
    },
    Ar {
        ctx: Arc<ArCtx>,
    },
}

/// Sequence factory: resolves the method's artifacts once and mints
/// sequences with **sequential placement keys** (0, 1, 2, ...) so a
/// sharded backend round-robins sequences across executors while each
/// sequence's KV stays on exactly one (key i ↔ the i-th created
/// sequence — deterministic, which the shard kill tests rely on).
pub struct MethodCtx {
    kind: MethodKind,
    next_key: std::sync::atomic::AtomicU64,
}

impl MethodCtx {
    pub fn new(
        rt: Arc<Runtime>,
        method: &str,
        buffer: Option<Arc<Mutex<ReplayBuffer>>>,
    ) -> Result<MethodCtx> {
        let kind = match method {
            "dvi" => MethodKind::Dvi {
                ctx: Arc::new(DviCtx::new(rt)?),
                buffer,
            },
            "ar" => MethodKind::Ar {
                ctx: Arc::new(ArCtx::new(rt)?),
            },
            other => bail!("scheduler supports methods dvi|ar, got '{other}'"),
        };
        Ok(MethodCtx { kind, next_key: std::sync::atomic::AtomicU64::new(0) })
    }

    pub fn new_seq(&self, prompt: &[u32], max_new: usize) -> Result<SeqState> {
        let key = self
            .next_key
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        match &self.kind {
            MethodKind::Dvi { ctx, buffer } => Ok(SeqState::Dvi(Box::new(
                DviSeq::new(ctx.clone(), buffer.clone(), prompt, max_new, key)?,
            ))),
            MethodKind::Ar { ctx } => Ok(SeqState::Ar(Box::new(ArSeq::new(
                ctx.clone(),
                prompt,
                max_new,
                key,
            )?))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Arc<Runtime> {
        Arc::new(Runtime::load_reference(0x5E9).expect("reference runtime"))
    }

    /// Drive a DviSeq call-by-call: phases must progress Prefilling →
    /// Drafting → Verifying rounds → Done, and the result must be a
    /// plausible generation.
    #[test]
    fn dvi_seq_phases_progress() {
        let rt = runtime();
        let ctx = Arc::new(DviCtx::new(rt.clone()).unwrap());
        let prompt: Vec<u32> = vec![1, 10, 11, 3];
        let mut s = DviSeq::new(ctx, None, &prompt, 12, 0).unwrap();
        assert_eq!(s.phase(), SeqPhase::Prefilling);
        let mut seen_draft = false;
        let mut seen_verify = false;
        let mut calls = 0;
        while !s.is_done() {
            calls += 1;
            assert!(calls < 500, "sequence did not terminate");
            let call = s.next_call().unwrap();
            let out = call.artifact.call(&call.kv, &call.inputs).unwrap();
            s.apply(out).unwrap();
            match s.phase() {
                SeqPhase::Drafting => seen_draft = true,
                SeqPhase::Verifying => seen_verify = true,
                _ => {}
            }
        }
        assert!(seen_draft && seen_verify, "phases skipped");
        assert!(s.pending_artifact().is_none());
        let r = s.into_result();
        assert!(!r.tokens.is_empty() && r.tokens.len() <= 12);
        assert!(r.steps.iter().all(|st| st.drafted > 0));
    }

    /// Prompts longer than the prefill window must be rejected at
    /// construction, not mid-flight.
    #[test]
    fn oversized_prompt_rejected_at_admission() {
        let rt = runtime();
        let ctx = Arc::new(ArCtx::new(rt.clone()).unwrap());
        let long = vec![1u32; ctx.prefill_seq + 1];
        assert!(ArSeq::new(ctx, &long, 8, 0).is_err());
        let dctx = Arc::new(DviCtx::new(rt).unwrap());
        let long = vec![1u32; dctx.prefill_seq + 1];
        assert!(DviSeq::new(dctx, None, &long, 8, 0).is_err());
    }
}
