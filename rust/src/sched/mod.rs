//! Continuous-batching scheduler: step-level multiplexing of many
//! in-flight sequences through **batched** backend calls.
//!
//! The per-thread router dedicates one worker thread (and one
//! batch-size-1 backend call stream) to each request. This scheduler
//! instead keeps up to `max_slots` sequences resident as
//! [`seq::SeqState`] machines and, each [`Scheduler::tick`]:
//!
//!   1. admits queued requests FIFO into free KV slots,
//!   2. groups every active sequence by the artifact it needs next
//!      (prefill / draft / verify) and advances each by exactly one call
//!      via [`crate::runtime::Artifact::call_batched`], at most
//!      `max_batch` lanes per call,
//!   3. drains completed sequences (preemption-free: an admitted
//!      sequence always runs to completion).
//!
//! Fairness falls out of the tick structure: admission is strictly FIFO
//! and every active lane advances once per tick, so no sequence can be
//! starved by co-resident traffic. Losslessness falls out of the batched
//! backend contract: lane results are bitwise identical to per-sequence
//! calls, so the committed token streams equal the per-sequence engines'
//! (asserted by `tests/sched.rs`).
//!
//! DVI sequences log accept/reject tuples into the shared
//! [`ReplayBuffer`] exactly like the per-thread engines do, so the
//! online learner thread needs no changes to ride on batched serving.
//!
//! With `DVI_PREFIX_CACHE=1` the scheduler additionally keeps a radix
//! [`PrefixCache`] over committed token ids: admission attaches new
//! sequences to the longest cached prefix (COW-forked KV, suffix-only
//! prefill) and every completed deep prefill donates its snapshot back.
//! Warm streams are bitwise identical to cold ones — KV rows are pure
//! functions of their token prefix — which `tests/cache.rs` gates
//! across in-process, loopback-remote, sharded, and adaptive-k serving.

pub mod seq;

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Result};

use crate::cache::{CacheStats, PrefixCache, SegRef};
use crate::engine::GenResult;
use crate::learner::ReplayBuffer;
use crate::obs::health::HealthMonitor;
use crate::obs::{metrics, trace};
use crate::runtime::{log, BatchHandle, BatchItem, Role, Runtime};

use self::seq::{CallSpec, DviSeqOpts, MethodCtx, PrefixAttach, SeqState};

pub use self::seq::AdaptiveK;

/// Decay applied when folding a completed sequence's final acceptance
/// EMA into its task's prior: `prior = (1-a)*prior + a*ema`. Observation
/// only — priors seed new sequences' starting EMA, and greedy
/// longest-prefix acceptance commits the same stream for any seed.
const TASK_PRIOR_ALPHA: f64 = 0.25;

/// Prefix-cache sizing. `None` in [`SchedConfig::cache`] disables the
/// cache entirely — the historical byte-identical admission path.
#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// Max resident KV segments; at capacity the least-recently-used
    /// unpinned leaf segment is evicted (preemption-free: pinned
    /// segments are never reclaimed, full caches skip the insert).
    pub capacity: usize,
}

impl CacheConfig {
    /// `DVI_PREFIX_CACHE=1` opts in; `DVI_PREFIX_CACHE_CAP` overrides
    /// the default capacity (64 segments). Default OFF: warm admission
    /// changes KV placement keys and call shapes (never committed
    /// streams — see `tests/cache.rs`), and opt-in keeps the default
    /// serving path byte-for-byte the historical one.
    pub fn from_env() -> Option<CacheConfig> {
        if std::env::var("DVI_PREFIX_CACHE").ok().as_deref() != Some("1") {
            return None;
        }
        let capacity = std::env::var("DVI_PREFIX_CACHE_CAP")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(64)
            .max(1);
        Some(CacheConfig { capacity })
    }
}

#[derive(Debug, Clone)]
pub struct SchedConfig {
    /// Sequence engine: "dvi" or "ar".
    pub method: String,
    /// Max lanes per batched backend call.
    pub max_batch: usize,
    /// KV slot pool size = max concurrently resident sequences.
    pub max_slots: usize,
    /// Adaptive speculation depth for DVI sequences. `None` (the
    /// default unless `DVI_ADAPTIVE_K=1` is set) pins every round to
    /// the manifest `k_spec` — the bitwise-reference mode that the
    /// lossless test gates compare against.
    pub adaptive: Option<AdaptiveK>,
    /// Radix prefix cache over committed token ids. `None` (the default
    /// unless `DVI_PREFIX_CACHE=1`) disables caching; DVI sequences
    /// then always cold-prefill. Ignored for methods that cannot attach
    /// a cached prefix (AR, or manifests without suffix-only prefill).
    pub cache: Option<CacheConfig>,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            method: "dvi".into(),
            max_batch: 8,
            max_slots: 16,
            adaptive: AdaptiveK::from_env(),
            cache: CacheConfig::from_env(),
        }
    }
}

/// Serving metrics, updated inside the tick loop and readable from any
/// thread (the router exposes them alongside its own counters).
#[derive(Debug, Default)]
pub struct SchedStats {
    pub ticks: AtomicU64,
    /// Batched backend calls issued.
    pub calls: AtomicU64,
    /// Lanes carried by those calls (occupancy numerator).
    pub lanes: AtomicU64,
    /// Tokens committed across all sequences.
    pub committed_tokens: AtomicU64,
    /// Sequences that reached a terminal state: completed **plus**
    /// failed. Every terminal path (drain, mid-flight `fail_lane`,
    /// admission rejection) increments this *and* adds the sequence's
    /// queue wait to `queue_wait_ns`, so `mean_queue_wait_ms` is a true
    /// mean over everything served — failures included. Invariant
    /// (regression-tested): `served == completed + failed` and
    /// `queue_wait_ns == Σ queue_wait` over all drained results.
    pub served: AtomicU64,
    /// Subset of `served` that ended in an error (admission rejection,
    /// backend/transport failure, apply failure).
    pub failed: AtomicU64,
    /// Total submit→admission wait, over completed AND failed lanes.
    pub queue_wait_ns: AtomicU64,
    /// Most slots ever occupied at once (must stay <= max_slots).
    pub slot_high_water: AtomicU64,
    /// Histogram of verified DVI round lengths: bucket k counts rounds
    /// drafted at depth k (bucket 8 collects k >= 8). Populated in
    /// pinned mode too — every bucket lands on k_spec there.
    pub k_hist: [AtomicU64; 9],
    /// Σ (acceptance EMA × 1000) sampled once per verified round, with
    /// `ema_rounds` the sample count — [`Self::mean_accept_ema`] is
    /// their ratio.
    pub ema_milli_sum: AtomicU64,
    pub ema_rounds: AtomicU64,
    /// Prefix-cache counters, mirrored from [`crate::cache::CacheStats`]
    /// at the end of every tick (all zero with the cache disabled).
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    pub cache_evictions: AtomicU64,
    /// Segments currently resident in the tree.
    pub cache_segments: AtomicU64,
    /// KV rows (token positions) admitted sequences attached from the
    /// cache instead of recomputing — Σ attach_len over warm admissions.
    pub cache_shared_rows: AtomicU64,
    /// Same, in KV bytes (rows × per-row KV footprint of both stages).
    pub cache_shared_bytes: AtomicU64,
    /// Per-task acceptance-EMA priors: a completed DVI sequence tagged
    /// via [`Scheduler::submit_tagged`] folds its final EMA in (decay
    /// [`TASK_PRIOR_ALPHA`]); new sequences of the same task seed their
    /// adaptive-k EMA from the prior instead of the optimistic 1.0.
    pub task_priors: Mutex<BTreeMap<String, f64>>,
}

impl SchedStats {
    /// Mean lanes per batched backend call. > 1 means batching is real.
    pub fn occupancy(&self) -> f64 {
        let calls = self.calls.load(Ordering::Relaxed);
        if calls == 0 {
            0.0
        } else {
            self.lanes.load(Ordering::Relaxed) as f64 / calls as f64
        }
    }

    /// Sequences that completed successfully. Loads `failed` first and
    /// subtracts saturating: a concurrent `fail_lane` bumps `served`
    /// before `failed`, so the opposite order could transiently read
    /// failed > served and wrap.
    pub fn completed(&self) -> u64 {
        let failed = self.failed.load(Ordering::Relaxed);
        self.served.load(Ordering::Relaxed).saturating_sub(failed)
    }

    /// Mean submit→admission wait across every terminal sequence —
    /// failed lanes keep their wait in the numerator AND denominator,
    /// so failures can't bias the mean low.
    pub fn mean_queue_wait_ms(&self) -> f64 {
        let served = self.served.load(Ordering::Relaxed);
        if served == 0 {
            0.0
        } else {
            self.queue_wait_ns.load(Ordering::Relaxed) as f64
                / served as f64
                / 1e6
        }
    }

    pub fn committed_per_tick(&self) -> f64 {
        let ticks = self.ticks.load(Ordering::Relaxed);
        if ticks == 0 {
            0.0
        } else {
            self.committed_tokens.load(Ordering::Relaxed) as f64 / ticks as f64
        }
    }

    /// Snapshot of the chosen-k histogram (bucket index = round length,
    /// bucket 8 = anything deeper).
    pub fn k_hist_snapshot(&self) -> [u64; 9] {
        std::array::from_fn(|i| self.k_hist[i].load(Ordering::Relaxed))
    }

    /// Mean per-round acceptance EMA across all verified DVI rounds.
    pub fn mean_accept_ema(&self) -> f64 {
        let rounds = self.ema_rounds.load(Ordering::Relaxed);
        if rounds == 0 {
            0.0
        } else {
            self.ema_milli_sum.load(Ordering::Relaxed) as f64
                / rounds as f64
                / 1000.0
        }
    }

    /// Starting acceptance EMA for a new sequence: the task's decayed
    /// prior when one exists, the optimistic 1.0 otherwise (untagged
    /// requests always get 1.0 — the historical seed).
    pub fn task_prior(&self, task: Option<&str>) -> f64 {
        let Some(task) = task else { return 1.0 };
        let priors = self.task_priors.lock().expect("task priors poisoned");
        priors.get(task).copied().unwrap_or(1.0)
    }

    /// Fold a completed sequence's final acceptance EMA into its task's
    /// prior (first completion seeds the prior directly).
    pub fn fold_task_prior(&self, task: &str, ema: f64) {
        let mut priors = self.task_priors.lock().expect("task priors poisoned");
        match priors.get_mut(task) {
            Some(p) => {
                *p = (1.0 - TASK_PRIOR_ALPHA) * *p + TASK_PRIOR_ALPHA * ema;
            }
            None => {
                priors.insert(task.to_string(), ema);
            }
        }
    }

    /// Snapshot of every task's prior, for `stats_json` and tests.
    pub fn task_priors_snapshot(&self) -> Vec<(String, f64)> {
        let priors = self.task_priors.lock().expect("task priors poisoned");
        priors.iter().map(|(k, &v)| (k.clone(), v)).collect()
    }
}

struct Pending {
    id: u64,
    prompt: Vec<u32>,
    max_new: usize,
    submitted: Instant,
    /// Workload label for per-task acceptance priors (None = untagged).
    task: Option<String>,
    /// Latency SLO for this request (submit → completion budget, ns);
    /// observation-only — admission and scheduling never look at it.
    deadline_ns: Option<u64>,
}

struct Lane {
    id: u64,
    state: SeqState,
    queue_wait_ns: u64,
    /// Original submit stamp (possibly backdated via `submit_at` /
    /// `submit_tagged_at`), the origin for TTFT.
    submitted: Instant,
    /// Submit → first committed token(s), set on the first apply() that
    /// commits. None until then (and forever, for lanes that fail before
    /// committing anything).
    first_commit_ns: Option<u64>,
    /// Pin on the cache segment this sequence attached from. Released
    /// exactly once, on whichever terminal path the lane takes (drain,
    /// mid-flight [`Scheduler::fail_lane`]); the post-tick leak audit
    /// cross-checks pins against the tree's refcounts.
    cache_ref: Option<SegRef>,
    task: Option<String>,
    deadline_ns: Option<u64>,
}

/// A completed sequence, in completion order.
pub struct SchedResult {
    pub id: u64,
    pub queue_wait_ns: u64,
    /// Time-to-first-token: submit stamp → the tick that committed this
    /// sequence's first token(s). None for sequences that never
    /// committed (admission rejects, failures before the first commit).
    pub ttft_ns: Option<u64>,
    pub result: Result<GenResult>,
}

pub struct Scheduler {
    ctx: MethodCtx,
    cfg: SchedConfig,
    queue: VecDeque<Pending>,
    slots: Vec<Option<Lane>>,
    done: Vec<SchedResult>,
    pub stats: Arc<SchedStats>,
    next_id: u64,
    /// Radix prefix cache (None when disabled by config or when the
    /// method cannot attach cached prefixes — AR, old manifests).
    cache: Option<PrefixCache>,
    /// Per-position KV footprint (bytes) across both prefill stages,
    /// for the `cache_shared_bytes` counter.
    kv_row_bytes: u64,
    /// Cached `sched.queue_wait_ns` histogram handle (observation-only;
    /// recording never influences admission or call construction).
    m_queue_wait: metrics::HistHandle,
    /// Serving-health monitor (SLO attainment + acceptance drift).
    /// Observation-only: recording never influences admission, chunk
    /// planning, or call construction, so attaching it keeps committed
    /// streams bitwise identical (gated in `tests/obs.rs`).
    health: Option<Arc<HealthMonitor>>,
}

impl Scheduler {
    /// Construction resolves the method's artifacts up front, so a bad
    /// method or missing artifact fails here — not inside a serving
    /// thread with requests already queued.
    pub fn new(
        rt: Arc<Runtime>,
        cfg: SchedConfig,
        buffer: Option<Arc<Mutex<ReplayBuffer>>>,
    ) -> Result<Scheduler> {
        ensure!(cfg.max_batch >= 1, "max_batch must be >= 1");
        ensure!(cfg.max_slots >= 1, "max_slots must be >= 1");
        let ctx = MethodCtx::new(rt, &cfg.method, buffer, cfg.adaptive)?;
        let slots = (0..cfg.max_slots).map(|_| None).collect();
        let cache = match &cfg.cache {
            Some(c) if ctx.supports_prefix_attach() => {
                Some(PrefixCache::new(c.capacity))
            }
            _ => None,
        };
        // Per-row KV bytes: each KV port is [layers, positions, d], so
        // one position costs Π(shape minus the position axis) elements
        // × 4 bytes (f32), summed over both prefill stages' KV sets.
        let kv_row_bytes = if cache.is_some() {
            let per_row = |name: &str| -> u64 {
                ctx.runtime()
                    .artifact(name)
                    .map(|a| {
                        a.spec
                            .params_with_role(Role::Kv)
                            .map(|p| {
                                p.shape
                                    .iter()
                                    .enumerate()
                                    .filter(|&(ax, _)| ax != 1)
                                    .map(|(_, &d)| d as u64)
                                    .product::<u64>()
                                    * 4
                            })
                            .sum()
                    })
                    .unwrap_or(0)
            };
            per_row("prefill_shallow") + per_row("prefill_deep")
        } else {
            0
        };
        Ok(Scheduler {
            ctx,
            cfg,
            queue: VecDeque::new(),
            slots,
            done: Vec::new(),
            stats: Arc::new(SchedStats::default()),
            next_id: 0,
            cache,
            kv_row_bytes,
            m_queue_wait: metrics::hist("sched.queue_wait_ns"),
            health: None,
        })
    }

    /// Attach the shared serving-health monitor: every completion from
    /// here on is scored against its deadline, and each verified
    /// round's acceptance EMA feeds the drift detector.
    pub fn attach_health(&mut self, health: Arc<HealthMonitor>) {
        self.health = Some(health);
    }

    /// Enqueue a request; returns its scheduler-local id (also carried
    /// by the matching [`SchedResult`]).
    pub fn submit(&mut self, prompt: Vec<u32>, max_new: usize) -> u64 {
        self.submit_at(prompt, max_new, Instant::now())
    }

    /// Enqueue with an externally stamped submit time, so callers that
    /// relay requests through a channel (the batched router) can count
    /// channel residency toward the queue-wait metric.
    pub fn submit_at(
        &mut self,
        prompt: Vec<u32>,
        max_new: usize,
        submitted: Instant,
    ) -> u64 {
        self.push_pending(prompt, max_new, None, submitted, None)
    }

    /// [`Scheduler::submit`] with a workload label. The sequence seeds
    /// its adaptive-k acceptance EMA from the task's decayed prior (see
    /// [`SchedStats::task_priors`]) and folds its final EMA back in on
    /// completion. Lossless for any prior: greedy longest-prefix
    /// acceptance commits the same stream at every round length.
    pub fn submit_tagged(
        &mut self,
        prompt: Vec<u32>,
        max_new: usize,
        task: &str,
    ) -> u64 {
        self.submit_tagged_at(prompt, max_new, task, Instant::now())
    }

    /// [`Scheduler::submit_tagged`] with an externally stamped submit
    /// time. Open-loop drivers (benches/serving_load.rs) stamp each
    /// request with its scheduled arrival, so queue-wait and TTFT both
    /// include time spent in the admission queue before a slot freed —
    /// previously tagged submissions could only stamp `Instant::now()`,
    /// which under-reported wait under load.
    pub fn submit_tagged_at(
        &mut self,
        prompt: Vec<u32>,
        max_new: usize,
        task: &str,
        submitted: Instant,
    ) -> u64 {
        self.submit_with_deadline(
            prompt,
            max_new,
            Some(task),
            submitted,
            None,
        )
    }

    /// The fully general submit: optional task tag plus an optional
    /// latency SLO (`deadline_ns`, measured submit → completion). The
    /// deadline rides along untouched until the request finishes, where
    /// the attached [`HealthMonitor`] scores it — per-tenant attainment
    /// and SLO goodput. Scheduling itself never reads it: deadlines
    /// observe, they do not prioritize (admission stays strictly FIFO).
    pub fn submit_with_deadline(
        &mut self,
        prompt: Vec<u32>,
        max_new: usize,
        task: Option<&str>,
        submitted: Instant,
        deadline_ns: Option<u64>,
    ) -> u64 {
        self.push_pending(
            prompt,
            max_new,
            task.map(str::to_string),
            submitted,
            deadline_ns,
        )
    }

    fn push_pending(
        &mut self,
        prompt: Vec<u32>,
        max_new: usize,
        task: Option<String>,
        submitted: Instant,
        deadline_ns: Option<u64>,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Pending {
            id,
            prompt,
            max_new,
            submitted,
            task,
            deadline_ns,
        });
        id
    }

    /// Prefix-cache counters (None when the cache is disabled).
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }

    /// Live pinned-reference total across the tree (leak audits).
    pub fn cache_total_refs(&self) -> Option<usize> {
        self.cache.as_ref().map(|c| c.total_refs())
    }

    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active() == 0
    }

    /// Take all results completed since the last drain.
    pub fn drain_completed(&mut self) -> Vec<SchedResult> {
        std::mem::take(&mut self.done)
    }

    /// Complete a lane with an error, freeing its slot. Accounting must
    /// mirror the success path exactly: served + queue-wait both move,
    /// plus the failure counter (see [`SchedStats::served`]).
    fn fail_lane(&mut self, slot: usize, err: anyhow::Error) {
        if let Some(mut lane) = self.slots[slot].take() {
            Self::release_pin(&mut self.cache, &mut lane.cache_ref);
            log::info(&format!("scheduled sequence {} failed: {err}", lane.id));
            if let Some(h) = &self.health {
                h.record_completion(
                    lane.task.as_deref(),
                    false,
                    lane.submitted.elapsed().as_nanos() as u64,
                    lane.deadline_ns,
                    0,
                );
            }
            self.stats.served.fetch_add(1, Ordering::Relaxed);
            self.stats.failed.fetch_add(1, Ordering::Relaxed);
            self.stats
                .queue_wait_ns
                .fetch_add(lane.queue_wait_ns, Ordering::Relaxed);
            self.done.push(SchedResult {
                id: lane.id,
                queue_wait_ns: lane.queue_wait_ns,
                ttft_ns: lane.first_commit_ns,
                result: Err(err),
            });
        }
    }

    /// Drop a lane's prefix-cache pin. Every attached sequence funnels
    /// through here exactly once — from [`Scheduler::fail_lane`], the
    /// completed-lane drain, or the admission-reject path — so the
    /// tree's refcounts always equal the live attachments (asserted
    /// after every tick in debug builds). Associated fn (not `&mut
    /// self`) so callers can hold the lane disjointly.
    fn release_pin(cache: &mut Option<PrefixCache>, pin: &mut Option<SegRef>) {
        if let Some(seg) = pin.take() {
            if let Some(cache) = cache.as_mut() {
                cache.release(seg);
            }
        }
    }

    /// Donate a lane's post-prefill KV snapshot to the cache (cheap
    /// handle clones; duplicates of an already-resident path are
    /// skipped). No-op unless the sequence just finished its deep
    /// prefill with capture requested.
    fn try_cache_insert(&mut self, slot: usize) {
        let Some(cache) = self.cache.as_mut() else { return };
        let Some(lane) = self.slots[slot].as_mut() else { return };
        if let Some(snap) = lane.state.take_prefix_snapshot() {
            cache.insert(&snap.tokens, snap.kv_sh, snap.kv_dp);
        }
    }

    /// Record a just-verified DVI round into the chosen-k histogram and
    /// acceptance-EMA aggregates. Observability only: runs in pinned
    /// mode too (where every round lands in the k_spec bucket) and
    /// never influences call construction.
    fn record_round_stats(&self, slot: usize) {
        let Some(lane) = self.slots[slot].as_ref() else { return };
        if let Some(k) = lane.state.last_round_k() {
            self.stats.k_hist[k.min(8)].fetch_add(1, Ordering::Relaxed);
        }
        if let Some(ema) = lane.state.accept_ema() {
            self.stats
                .ema_milli_sum
                .fetch_add((ema * 1000.0).round() as u64, Ordering::Relaxed);
            self.stats.ema_rounds.fetch_add(1, Ordering::Relaxed);
            if let Some(h) = &self.health {
                h.record_accept((ema * 1000.0).round() as u64);
            }
        }
    }

    /// Split one artifact group's lanes into batched-call chunks.
    ///
    /// Pinned-k (and every non-verify artifact): fixed-size slices in
    /// slot order, exactly the historical discipline — byte-for-byte the
    /// same call stream, which the bitwise lossless gates rely on.
    ///
    /// Adaptive-k verify chunks are acceptance-aware instead: lanes are
    /// ordered by descending acceptance EMA (deep, high-confidence
    /// rounds first, ties broken by slot index for determinism) and
    /// packed greedily by *expected verify rows* against a budget of
    /// `max_batch x k_spec` rows per call — short rounds from
    /// low-acceptance sequences share a call instead of each wasting a
    /// full-width lane.
    fn plan_chunks(&self, name: &str, idxs: Vec<usize>) -> Vec<Vec<usize>> {
        if !(name == "verify_block" && self.ctx.adaptive_active()) {
            return idxs
                .chunks(self.cfg.max_batch)
                .map(|c| c.to_vec())
                .collect();
        }
        let k_spec = self.ctx.k_spec().unwrap_or(1).max(1);
        let budget = self.cfg.max_batch * k_spec;
        let lane_ema = |i: usize| {
            self.slots[i]
                .as_ref()
                .and_then(|l| l.state.accept_ema())
                .unwrap_or(0.0)
        };
        let lane_rows = |i: usize| {
            self.slots[i]
                .as_ref()
                .and_then(|l| l.state.verify_rows())
                .unwrap_or(k_spec)
        };
        let mut order = idxs;
        order.sort_by(|&a, &b| {
            lane_ema(b)
                .partial_cmp(&lane_ema(a))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut chunks: Vec<Vec<usize>> = Vec::new();
        let mut cur: Vec<usize> = Vec::new();
        let mut rows = 0usize;
        for i in order {
            let r = lane_rows(i);
            if !cur.is_empty() && rows + r > budget {
                chunks.push(std::mem::take(&mut cur));
                rows = 0;
            }
            rows += r;
            cur.push(i);
        }
        if !cur.is_empty() {
            chunks.push(cur);
        }
        chunks
    }

    /// One scheduling step: admit, advance every active lane by exactly
    /// one batched backend call, drain completions. Returns the number
    /// of lanes advanced (0 with an empty queue means idle).
    pub fn tick(&mut self) -> Result<usize> {
        self.stats.ticks.fetch_add(1, Ordering::Relaxed);

        // ---- admission: FIFO into free slots ---------------------------
        while !self.queue.is_empty() {
            let Some(free) = self.slots.iter().position(|s| s.is_none()) else {
                break;
            };
            let p = self.queue.pop_front().expect("queue checked non-empty");
            let queue_wait_ns = p.submitted.elapsed().as_nanos() as u64;
            self.m_queue_wait.observe(queue_wait_ns);
            if trace::enabled() {
                trace::instant(
                    "seq.admit",
                    "sched",
                    vec![
                        ("seq", trace::Arg::I(p.id as i64)),
                        ("queue_wait_ns", trace::Arg::I(queue_wait_ns as i64)),
                    ],
                );
            }
            // Cache-aware admission. A hit pins the segment, forks its
            // KV (COW aliases — cheap, shard-affine) and starts warm at
            // the cached prefix; a miss cold-prefills toward the
            // least-loaded shard. With the cache disabled this entire
            // block reduces to the historical defaults (cold prefill,
            // sequential placement keys, EMA seed from the task prior).
            let mut opts = DviSeqOpts {
                ema0: self.stats.task_prior(p.task.as_deref()),
                ..DviSeqOpts::default()
            };
            let mut placement: Option<u64> = None;
            let mut pin: Option<SegRef> = None;
            if let Some(cache) = self.cache.as_mut() {
                opts.capture_prefix = true;
                if let Some(hit) = cache.lookup(&p.prompt) {
                    // Clamp: at least one prompt token must run through
                    // prefill so it emits the first committed logits.
                    let attach_len =
                        hit.attach_len.min(p.prompt.len().saturating_sub(1));
                    let forked = if attach_len == 0 {
                        None
                    } else {
                        let (sh, dp) = cache.segment_kv(hit.seg);
                        let rt = self.ctx.runtime();
                        rt.fork_kv("prefill_shallow", sh)
                            .and_then(|kv_sh| {
                                rt.fork_kv("prefill_deep", dp)
                                    .map(|kv_dp| (kv_sh, kv_dp))
                            })
                            .ok()
                    };
                    match forked {
                        Some((kv_sh, kv_dp)) => {
                            self.stats.cache_shared_rows.fetch_add(
                                attach_len as u64,
                                Ordering::Relaxed,
                            );
                            self.stats.cache_shared_bytes.fetch_add(
                                attach_len as u64 * self.kv_row_bytes,
                                Ordering::Relaxed,
                            );
                            opts.attach = Some(PrefixAttach {
                                kv_sh,
                                kv_dp,
                                attach_len,
                            });
                            pin = Some(hit.seg);
                        }
                        // Unusable hit (whole-prompt clamp, fork error,
                        // dead shard): unpin and run cold instead.
                        None => cache.release(hit.seg),
                    }
                }
                if opts.attach.is_none() {
                    placement = self.ctx.runtime().kv_placement_hint();
                }
            }
            match self.ctx.new_seq_with(&p.prompt, p.max_new, placement, opts)
            {
                Ok(state) => {
                    self.slots[free] = Some(Lane {
                        id: p.id,
                        state,
                        queue_wait_ns,
                        submitted: p.submitted,
                        first_commit_ns: None,
                        cache_ref: pin,
                        task: p.task,
                        deadline_ns: p.deadline_ns,
                    });
                }
                Err(e) => {
                    // Bad request (e.g. oversized prompt): fail fast, keep
                    // the slot for the next queued request. An attached
                    // sequence that never made it to a lane still owned a
                    // pin — drop it here or the segment leaks.
                    Self::release_pin(&mut self.cache, &mut pin);
                    if let Some(h) = &self.health {
                        h.record_completion(
                            p.task.as_deref(),
                            false,
                            queue_wait_ns,
                            p.deadline_ns,
                            0,
                        );
                    }
                    self.stats.served.fetch_add(1, Ordering::Relaxed);
                    self.stats.failed.fetch_add(1, Ordering::Relaxed);
                    self.stats
                        .queue_wait_ns
                        .fetch_add(queue_wait_ns, Ordering::Relaxed);
                    self.done.push(SchedResult {
                        id: p.id,
                        queue_wait_ns,
                        ttft_ns: None,
                        result: Err(e),
                    });
                }
            }
        }
        self.stats
            .slot_high_water
            .fetch_max(self.active() as u64, Ordering::Relaxed);

        // ---- group active lanes by the artifact they need next ---------
        let mut groups: BTreeMap<&'static str, Vec<usize>> = BTreeMap::new();
        for (i, slot) in self.slots.iter().enumerate() {
            if let Some(lane) = slot {
                if let Some(name) = lane.state.pending_artifact() {
                    groups.entry(name).or_default().push(i);
                }
            }
        }

        // ---- submit one batched backend call per (artifact, chunk) -----
        // Submission is split from draining so independent chunks are in
        // flight *together*: on the pipelined remote backends (protocol
        // v3 mux) every shard's in-flight window fills before the first
        // reply is awaited — a tick's wall time tracks the slowest
        // shard's work, not the sum of chunk round trips. In-process
        // backends execute at submit time and their handles resolve
        // instantly, so their semantics (and bitwise streams) are
        // unchanged.
        struct PendingChunk {
            idxs: Vec<usize>,
            name: String,
            handle: Box<dyn BatchHandle>,
            /// Submit timestamp ([`trace::now_ns`]) for the per-chunk
            /// call-latency histogram and trace span.
            t0_ns: u64,
            /// Owns the lanes' kv/inputs until the handle resolves (the
            /// buffers must not hit the free-list while in flight).
            _specs: Vec<CallSpec>,
        }
        let submit_t0 = trace::now_ns();
        let mut in_flight: Vec<PendingChunk> = Vec::new();
        for (name, idxs) in groups {
            let chunks = self.plan_chunks(name, idxs);
            for chunk in &chunks {
                let chunk = chunk.as_slice();
                let mut specs = Vec::with_capacity(chunk.len());
                let mut chunk_ok = true;
                for &i in chunk {
                    let call = self.slots[i]
                        .as_mut()
                        .expect("grouped lane is live")
                        .state
                        .next_call();
                    match call {
                        Ok(s) => specs.push(s),
                        Err(e) => {
                            // next_call is re-invocable, so the chunk's
                            // other lanes simply retry next tick.
                            self.fail_lane(i, e);
                            chunk_ok = false;
                            break;
                        }
                    }
                }
                if !chunk_ok {
                    continue;
                }
                let items: Vec<BatchItem<'_>> = specs
                    .iter()
                    .map(|s| BatchItem { kv: &s.kv, inputs: &s.inputs })
                    .collect();
                let t0_ns = trace::now_ns();
                let handle = specs[0].artifact.call_batched_submit(&items);
                drop(items);
                in_flight.push(PendingChunk {
                    idxs: chunk.to_vec(),
                    name: specs[0].artifact.spec.name.clone(),
                    handle,
                    t0_ns,
                    _specs: specs,
                });
            }
        }
        if trace::enabled() && !in_flight.is_empty() {
            trace::complete(
                "tick.submit",
                "sched",
                submit_t0,
                vec![("chunks", trace::Arg::I(in_flight.len() as i64))],
            );
        }

        // ---- drain completion handles in submission order --------------
        // Per-lane failure granularity: on a sharded remote backend a
        // dead executor fails only the lanes whose KV it owns; every
        // other lane in the chunk commits normally. Single-executor
        // backends degenerate to whole-chunk fate sharing. Draining in
        // submission order keeps apply()/replay-buffer order — and thus
        // the committed streams — identical to the serial discipline.
        let drain_t0 = trace::now_ns();
        let mut drained = 0usize;
        let mut advanced = 0usize;
        for chunk in in_flight {
            let PendingChunk { idxs, name, handle, t0_ns, _specs } = chunk;
            let outs = handle.wait();
            let call_ns = trace::now_ns().saturating_sub(t0_ns);
            metrics::hist(&format!("sched.call.{name}_ns")).observe(call_ns);
            if trace::enabled() {
                trace::complete_with_dur(
                    "sched.call",
                    "sched",
                    call_ns,
                    vec![
                        ("artifact", trace::Arg::S(name.clone())),
                        ("lanes", trace::Arg::I(idxs.len() as i64)),
                    ],
                );
            }
            drained += 1;
            let mut ok_lanes = 0u64;
            for (&i, out) in idxs.iter().zip(outs) {
                match out {
                    Ok(out) => {
                        ok_lanes += 1;
                        let applied = self.slots[i]
                            .as_mut()
                            .expect("grouped lane is live")
                            .state
                            .apply(out);
                        match applied {
                            Ok(committed) => {
                                self.stats.committed_tokens.fetch_add(
                                    committed as u64,
                                    Ordering::Relaxed,
                                );
                                if committed > 0 {
                                    if let Some(lane) =
                                        self.slots[i].as_mut()
                                    {
                                        if lane.first_commit_ns.is_none() {
                                            lane.first_commit_ns = Some(
                                                lane.submitted
                                                    .elapsed()
                                                    .as_nanos()
                                                    as u64,
                                            );
                                        }
                                    }
                                }
                                if name == "verify_block" {
                                    self.record_round_stats(i);
                                }
                                if name == "prefill_deep" {
                                    self.try_cache_insert(i);
                                }
                            }
                            Err(e) => self.fail_lane(i, e),
                        }
                    }
                    Err(e) => self.fail_lane(
                        i,
                        anyhow!("batched {name} call failed: {e:#}"),
                    ),
                }
            }
            // Only lanes that actually executed count toward progress
            // and occupancy — a failing backend must not report healthy
            // batching.
            advanced += ok_lanes as usize;
            if ok_lanes > 0 {
                self.stats.calls.fetch_add(1, Ordering::Relaxed);
                self.stats.lanes.fetch_add(ok_lanes, Ordering::Relaxed);
            }
        }
        if trace::enabled() && drained > 0 {
            trace::complete(
                "tick.drain",
                "sched",
                drain_t0,
                vec![("chunks", trace::Arg::I(drained as i64))],
            );
        }

        // ---- drain completed sequences ---------------------------------
        for i in 0..self.slots.len() {
            let finished =
                matches!(&self.slots[i], Some(l) if l.state.is_done());
            if finished {
                let mut lane = self.slots[i].take().expect("finished lane");
                Self::release_pin(&mut self.cache, &mut lane.cache_ref);
                if let (Some(task), Some(ema)) =
                    (lane.task.as_deref(), lane.state.accept_ema())
                {
                    self.stats.fold_task_prior(task, ema);
                }
                self.stats.served.fetch_add(1, Ordering::Relaxed);
                self.stats
                    .queue_wait_ns
                    .fetch_add(lane.queue_wait_ns, Ordering::Relaxed);
                let result = lane.state.into_result();
                if let Some(h) = &self.health {
                    h.record_completion(
                        lane.task.as_deref(),
                        true,
                        lane.submitted.elapsed().as_nanos() as u64,
                        lane.deadline_ns,
                        result.tokens.len() as u64,
                    );
                }
                self.done.push(SchedResult {
                    id: lane.id,
                    queue_wait_ns: lane.queue_wait_ns,
                    ttft_ns: lane.first_commit_ns,
                    result: Ok(result),
                });
            }
        }

        // ---- cache accounting + refcount leak audit --------------------
        if let Some(cache) = &self.cache {
            let cs = cache.stats();
            self.stats.cache_hits.store(cs.hits, Ordering::Relaxed);
            self.stats.cache_misses.store(cs.misses, Ordering::Relaxed);
            self.stats.cache_evictions.store(cs.evictions, Ordering::Relaxed);
            self.stats.cache_segments.store(cs.segments, Ordering::Relaxed);
            // Mirror into the process-wide registry so `metrics_json`
            // probes see the cache next to the RPC/tick histograms.
            metrics::counter("sched.cache.hits").store(cs.hits, Ordering::Relaxed);
            metrics::counter("sched.cache.misses")
                .store(cs.misses, Ordering::Relaxed);
            metrics::counter("sched.cache.evictions")
                .store(cs.evictions, Ordering::Relaxed);
            metrics::gauge("sched.cache.segments")
                .store(cs.segments as i64, Ordering::Relaxed);
            metrics::counter("sched.cache.shared_bytes").store(
                self.stats.cache_shared_bytes.load(Ordering::Relaxed),
                Ordering::Relaxed,
            );
            let pinned = self
                .slots
                .iter()
                .flatten()
                .filter(|l| l.cache_ref.is_some())
                .count();
            debug_assert_eq!(
                cache.total_refs(),
                pinned,
                "prefix-cache refcount leak: {} tree refs vs {} attached \
                 lanes after tick",
                cache.total_refs(),
                pinned,
            );
        }
        Ok(advanced)
    }

    /// Drive until every queued and resident sequence completes.
    /// `max_ticks` bounds runaway loops; a healthy run needs roughly
    /// ceil(sequences / max_slots) x calls-per-sequence ticks.
    pub fn run_until_idle(&mut self, max_ticks: usize) -> Result<()> {
        for _ in 0..max_ticks {
            if self.is_idle() {
                return Ok(());
            }
            self.tick()?;
        }
        if self.is_idle() {
            Ok(())
        } else {
            bail!(
                "scheduler not idle after {max_ticks} ticks \
                 ({} active, {} queued)",
                self.active(),
                self.queued()
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use std::time::Duration;

    use crate::runtime::chaos::FlakyBackend;
    use crate::runtime::Backend;

    fn runtime() -> Arc<Runtime> {
        Arc::new(Runtime::load_reference(0x5C4ED).expect("reference runtime"))
    }

    /// Regression (accounting audit): a lane failed MID-FLIGHT must
    /// contribute its queue wait to `queue_wait_ns` and count in both
    /// `served` and `failed`, exactly like a completed lane — otherwise
    /// `mean_queue_wait_ms` is biased low under failures. Submissions
    /// are backdated 50ms so the bias would be unmissable: dropping the
    /// failed lanes' waits would pull the mean to ~half of 50ms.
    ///
    /// FlakyBackend(every=2, cap=1) fails exactly the SECOND batched
    /// call: with 4 admitted lanes and max_batch=2 that is
    /// deterministically the second prefill chunk — two resident lanes
    /// fail mid-flight while the first chunk's two lanes complete.
    #[test]
    fn failed_lanes_keep_queue_wait_accounting_consistent() {
        let rt = Runtime::load_reference(0x5C4ED)
            .unwrap()
            .map_backend(|inner| {
                Arc::new(FlakyBackend::new(inner, 2, 1)) as Arc<dyn Backend>
            });
        let rt = Arc::new(rt);
        let cfg = SchedConfig {
            method: "ar".into(),
            max_batch: 2,
            max_slots: 4,
            adaptive: None,
            cache: None,
        };
        let mut sched = Scheduler::new(rt.clone(), cfg, None).unwrap();
        let backdated = Instant::now()
            .checked_sub(Duration::from_millis(50))
            .expect("monotonic clock supports a 50ms backdate");
        for p in prompts(&rt, 4) {
            sched.submit_at(p, 6, backdated);
        }
        sched.run_until_idle(10_000).unwrap();
        let done = sched.drain_completed();
        assert_eq!(done.len(), 4, "every lane must reach a terminal state");
        let errs = done.iter().filter(|r| r.result.is_err()).count();
        // Exactly the second prefill chunk's two lanes fail; the first
        // chunk's two lanes complete. Both outcomes coexist, so the
        // mean check below actually exercises the failed-lane path.
        assert_eq!(errs, 2, "expected exactly the failed chunk's lanes to err");

        let stats = &sched.stats;
        assert_eq!(stats.served.load(Ordering::Relaxed), 4);
        assert_eq!(stats.failed.load(Ordering::Relaxed) as usize, errs);
        assert_eq!(stats.completed() as usize, 4 - errs);
        // The stats' total equals the per-result sum exactly: no
        // terminal path may drop (or double-count) a lane's wait.
        let sum: u64 = done.iter().map(|r| r.queue_wait_ns).sum();
        assert_eq!(stats.queue_wait_ns.load(Ordering::Relaxed), sum);
        // Every wait was >= 50ms, so a mean over served must be too;
        // failed lanes missing from the numerator would show up here.
        assert!(
            stats.mean_queue_wait_ms() >= 50.0,
            "mean queue wait {}ms < 50ms: a failed lane's wait was dropped",
            stats.mean_queue_wait_ms()
        );
    }

    fn prompts(rt: &Runtime, n: usize) -> Vec<Vec<u32>> {
        let set = rt.synthetic_prompts("qa").expect("qa prompts");
        set.samples.iter().take(n).map(|s| s.prompt.clone()).collect()
    }

    /// Deadlines ride `submit_with_deadline` untouched and the attached
    /// [`HealthMonitor`] scores each completion per tenant: a backdated
    /// request whose deadline already passed is a miss (tokens counted,
    /// zero goodput), a generous deadline is pure goodput.
    #[test]
    fn deadlines_feed_the_health_monitor_per_tenant() {
        let rt = runtime();
        let cfg = SchedConfig {
            method: "dvi".into(),
            max_batch: 2,
            max_slots: 2,
            adaptive: None,
            cache: None,
        };
        let mut sched = Scheduler::new(rt.clone(), cfg, None).unwrap();
        let health = Arc::new(HealthMonitor::with_config(
            crate::obs::health::DriftConfig {
                window: 4,
                drop_milli: 100,
                sustain: 2,
            },
        ));
        sched.attach_health(health.clone());
        let backdated = Instant::now()
            .checked_sub(Duration::from_millis(50))
            .expect("monotonic clock supports a 50ms backdate");
        let ps = prompts(&rt, 2);
        // 1ms budget, submitted 50ms ago: missed before it was admitted.
        sched.submit_with_deadline(
            ps[0].clone(),
            4,
            Some("strict"),
            backdated,
            Some(1_000_000),
        );
        // One-hour budget: cannot miss.
        sched.submit_with_deadline(
            ps[1].clone(),
            4,
            Some("lax"),
            backdated,
            Some(3_600_000_000_000),
        );
        sched.run_until_idle(10_000).unwrap();
        let done = sched.drain_completed();
        assert_eq!(done.len(), 2);
        let tokens_of = |id: u64| -> u64 {
            let r = done.iter().find(|r| r.id == id).expect("result by id");
            r.result.as_ref().expect("sequence completed").tokens.len() as u64
        };
        let s = health.snapshot();
        let strict = &s.tenants["strict"];
        assert_eq!((strict.completed, strict.in_deadline), (1, 0));
        assert_eq!(strict.tokens, tokens_of(0));
        assert_eq!(strict.goodput_tokens, 0, "missed deadline is not goodput");
        assert_eq!(strict.attainment_milli(), 0);
        let lax = &s.tenants["lax"];
        assert_eq!((lax.completed, lax.in_deadline), (1, 1));
        assert_eq!(lax.goodput_tokens, tokens_of(1));
        assert_eq!(lax.attainment_milli(), 1000);
    }

    /// Regression (open-loop bugfix): `submit_tagged_at` must honor the
    /// caller's stamp so queue-wait under load includes admission-queue
    /// time. Backdated tagged submissions through a 1-slot scheduler
    /// must all report >= the backdate, and TTFT (measured from the
    /// same origin) must be at least the queue wait.
    #[test]
    fn backdated_tagged_submissions_count_admission_queue_time() {
        let rt = runtime();
        let cfg = SchedConfig {
            method: "dvi".into(),
            max_batch: 2,
            max_slots: 1,
            adaptive: None,
            cache: None,
        };
        let mut sched = Scheduler::new(rt.clone(), cfg, None).unwrap();
        let backdated = Instant::now()
            .checked_sub(Duration::from_millis(50))
            .expect("monotonic clock supports a 50ms backdate");
        for p in prompts(&rt, 3) {
            sched.submit_tagged_at(p, 4, "qa", backdated);
        }
        sched.run_until_idle(10_000).unwrap();
        let done = sched.drain_completed();
        assert_eq!(done.len(), 3);
        let floor = Duration::from_millis(50).as_nanos() as u64;
        for r in &done {
            assert!(r.result.is_ok(), "sequence {} failed", r.id);
            assert!(
                r.queue_wait_ns >= floor,
                "queue wait {}ns dropped the 50ms backdate",
                r.queue_wait_ns
            );
            let ttft = r.ttft_ns.expect("committed sequence has a TTFT");
            assert!(
                ttft >= r.queue_wait_ns,
                "TTFT {}ns < queue wait {}ns",
                ttft,
                r.queue_wait_ns
            );
        }
        // With one slot, later arrivals also absorb earlier sequences'
        // service time, so the max wait strictly exceeds the backdate.
        let max = done.iter().map(|r| r.queue_wait_ns).max().unwrap();
        assert!(max > floor, "no request waited for the busy slot");
        let sum: u64 = done.iter().map(|r| r.queue_wait_ns).sum();
        assert_eq!(sched.stats.queue_wait_ns.load(Ordering::Relaxed), sum);
        // Tagged path still feeds the per-task prior.
        assert!(sched
            .stats
            .task_priors_snapshot()
            .iter()
            .any(|(t, _)| t == "qa"));
    }

    /// Regression (closed-loop accounting unchanged): `submit_tagged`
    /// now routes through `submit_tagged_at(.., Instant::now())`; the
    /// committed streams and serving counters must be identical to the
    /// pre-refactor behavior (compared against an explicitly now-stamped
    /// scheduler), and TTFT never exceeds the run's wall time.
    #[test]
    fn closed_loop_tagged_accounting_is_unchanged() {
        let rt = runtime();
        let cfg = SchedConfig {
            method: "dvi".into(),
            max_batch: 4,
            max_slots: 4,
            adaptive: None,
            cache: None,
        };
        let run = |explicit: bool| -> Vec<(u64, Vec<u32>)> {
            let mut sched =
                Scheduler::new(rt.clone(), cfg.clone(), None).unwrap();
            let t0 = Instant::now();
            for p in prompts(&rt, 4) {
                if explicit {
                    sched.submit_tagged_at(p, 6, "qa", Instant::now());
                } else {
                    sched.submit_tagged(p, 6, "qa");
                }
            }
            sched.run_until_idle(10_000).unwrap();
            let wall_ns = t0.elapsed().as_nanos() as u64;
            let mut done = sched.drain_completed();
            done.sort_by_key(|r| r.id);
            assert_eq!(sched.stats.served.load(Ordering::Relaxed), 4);
            assert_eq!(sched.stats.failed.load(Ordering::Relaxed), 0);
            done.iter()
                .map(|r| {
                    let ttft =
                        r.ttft_ns.expect("committed sequence has a TTFT");
                    assert!(
                        ttft <= wall_ns,
                        "TTFT {ttft}ns exceeds the run's wall time"
                    );
                    assert!(ttft >= r.queue_wait_ns);
                    (r.id, r.result.as_ref().unwrap().tokens.clone())
                })
                .collect()
        };
        assert_eq!(
            run(false),
            run(true),
            "tagged closed-loop streams diverged from now-stamped streams"
        );
    }

    /// 9 sequences through 3 slots: slots must be recycled (high-water
    /// stays at the configured max), everything completes, and batched
    /// occupancy is real (> 1 lane per call).
    #[test]
    fn slots_are_recycled_and_all_complete() {
        let rt = runtime();
        let cfg = SchedConfig {
            method: "ar".into(),
            max_batch: 4,
            max_slots: 3,
            adaptive: None,
            cache: None,
        };
        let mut sched = Scheduler::new(rt.clone(), cfg, None).unwrap();
        let mut ids = Vec::new();
        for p in prompts(&rt, 9) {
            ids.push(sched.submit(p, 6));
        }
        sched.run_until_idle(10_000).unwrap();
        let done = sched.drain_completed();
        assert_eq!(done.len(), 9);
        let mut seen: Vec<u64> = done.iter().map(|r| r.id).collect();
        seen.sort_unstable();
        assert_eq!(seen, ids, "every submitted id completes exactly once");
        let mut tokens = 0u64;
        for r in done {
            tokens += r.result.expect("generation succeeds").tokens.len() as u64;
        }
        let stats = &sched.stats;
        assert_eq!(stats.committed_tokens.load(Ordering::Relaxed), tokens);
        assert!(
            stats.slot_high_water.load(Ordering::Relaxed) <= 3,
            "slot pool exceeded its configured max"
        );
        assert!(stats.occupancy() > 1.0, "batching never exceeded one lane");
        assert_eq!(stats.served.load(Ordering::Relaxed), 9);
        assert_eq!(stats.failed.load(Ordering::Relaxed), 0);
        assert_eq!(stats.completed(), 9);
    }

    /// Oversized prompts are rejected at admission with an Err result;
    /// the remaining traffic is unaffected.
    #[test]
    fn bad_request_fails_fast_without_wedging() {
        let rt = runtime();
        let prefill_seq = rt.manifest.spec_usize("prefill_seq").unwrap();
        let cfg = SchedConfig {
            method: "dvi".into(),
            max_batch: 4,
            max_slots: 2,
            adaptive: None,
            cache: None,
        };
        let mut sched = Scheduler::new(rt.clone(), cfg, None).unwrap();
        let bad = sched.submit(vec![1u32; prefill_seq + 5], 8);
        let good = sched.submit(prompts(&rt, 1).remove(0), 8);
        sched.run_until_idle(10_000).unwrap();
        let done = sched.drain_completed();
        assert_eq!(done.len(), 2);
        for r in done {
            if r.id == bad {
                assert!(r.result.is_err());
            } else {
                assert_eq!(r.id, good);
                assert!(!r.result.unwrap().tokens.is_empty());
            }
        }
        // Admission rejections are served + failed, like any terminal.
        assert_eq!(sched.stats.served.load(Ordering::Relaxed), 2);
        assert_eq!(sched.stats.failed.load(Ordering::Relaxed), 1);
    }

    /// Unknown methods fail at construction, before any thread spawns.
    #[test]
    fn unknown_method_fails_at_construction() {
        let rt = runtime();
        let cfg = SchedConfig { method: "banana".into(), ..Default::default() };
        assert!(Scheduler::new(rt, cfg, None).is_err());
    }
}
