//! Deterministic open-loop workload generation.
//!
//! Serving benchmarks need *open-loop* load — requests arrive on a
//! wall-clock schedule regardless of whether the system has kept up —
//! because closed-loop drivers (submit, wait, submit) hide queueing
//! collapse entirely. This module turns a seeded [`WorkloadSpec`] into a
//! concrete admission schedule: every request carries an arrival
//! timestamp, a tenant, a task tag, a prompt drawn from the existing
//! [`PromptSet`] corpora (optionally truncated to a sampled length), a
//! sampled output budget, and the tenant's latency deadline (`slo_ms`)
//! when one is configured.
//!
//! The same seed always yields the bitwise-identical schedule
//! ([`encode_schedule`] / [`fingerprint`] make that checkable), so a
//! benchmark run is replayable and two builds can be compared under the
//! exact same traffic.

use anyhow::{bail, Result};

use crate::runtime::weights::Fnv64;
use crate::util::rng::Rng;
use crate::workload::{PromptSet, TASK_NAMES};

const NS_PER_S: f64 = 1e9;

/// Arrival process for the open-loop schedule. Timestamps are
/// nanoseconds relative to the start of the run.
#[derive(Debug, Clone)]
pub enum Arrival {
    /// Homogeneous Poisson arrivals at `rate_per_s` requests/second.
    Poisson { rate_per_s: f64 },
    /// On/off-modulated Poisson: alternating phases of `on_s` seconds
    /// at `rate_on` req/s and `off_s` seconds at `rate_off` req/s,
    /// starting in the on phase. Sampled exactly via the time-change
    /// construction: a unit-rate exponential "exposure" is consumed at
    /// the phase-dependent rate, carrying correctly across phase
    /// boundaries.
    Bursty { rate_on: f64, rate_off: f64, on_s: f64, off_s: f64 },
}

impl Arrival {
    fn validate(&self) -> Result<()> {
        match *self {
            Arrival::Poisson { rate_per_s } => {
                if !rate_per_s.is_finite() || rate_per_s <= 0.0 {
                    bail!("poisson rate must be finite and > 0");
                }
            }
            Arrival::Bursty { rate_on, rate_off, on_s, off_s } => {
                if !rate_on.is_finite() || rate_on <= 0.0 {
                    bail!("bursty rate_on must be finite and > 0");
                }
                if !rate_off.is_finite() || rate_off < 0.0 {
                    bail!("bursty rate_off must be finite and >= 0");
                }
                if on_s <= 0.0 || off_s <= 0.0 {
                    bail!("bursty phase durations must be > 0");
                }
            }
        }
        Ok(())
    }

    /// Time (seconds) of the next arrival strictly after `t` seconds.
    fn next_after_s(&self, t: f64, rng: &mut Rng) -> f64 {
        // Unit-mean exponential exposure; (1 - u) is in (0, 1] so the
        // log is finite and the sample strictly positive.
        let exposure = -(1.0 - rng.f64()).ln();
        match *self {
            Arrival::Poisson { rate_per_s } => t + exposure / rate_per_s,
            Arrival::Bursty { rate_on, rate_off, on_s, off_s } => {
                let period = on_s + off_s;
                let mut t = t;
                let mut left = exposure;
                loop {
                    let pos = t.rem_euclid(period);
                    let (rate, phase_end) = if pos < on_s {
                        (rate_on, on_s)
                    } else {
                        (rate_off, period)
                    };
                    let span = phase_end - pos;
                    // Exposure this phase can still absorb.
                    let cap = rate * span;
                    if rate > 0.0 && left <= cap {
                        return t + left / rate;
                    }
                    left -= cap;
                    t += span;
                }
            }
        }
    }
}

/// Sampled length distribution (prompt truncation, output budgets).
#[derive(Debug, Clone)]
pub enum LenDist {
    Fixed(usize),
    /// Uniform over `lo..=hi` (inclusive).
    Uniform { lo: usize, hi: usize },
}

impl LenDist {
    fn validate(&self, what: &str) -> Result<()> {
        match *self {
            LenDist::Fixed(n) => {
                if n == 0 {
                    bail!("{what}: fixed length must be >= 1");
                }
            }
            LenDist::Uniform { lo, hi } => {
                if lo == 0 || lo > hi {
                    bail!("{what}: uniform bounds need 1 <= lo <= hi");
                }
            }
        }
        Ok(())
    }

    fn sample(&self, rng: &mut Rng) -> usize {
        match *self {
            LenDist::Fixed(n) => n,
            LenDist::Uniform { lo, hi } => lo + rng.usize_below(hi - lo + 1),
        }
    }
}

/// One tenant's traffic profile: a share of overall arrivals, a task
/// mix over [`TASK_NAMES`], and length distributions.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    /// Relative share of arrivals (normalized across tenants).
    pub weight: f64,
    /// `(task_name, weight)` pairs; normalized within the tenant.
    pub task_mix: Vec<(String, f64)>,
    /// Prompt truncation length (clamped to the source sample's length,
    /// floor 2 so BOS + content survive).
    pub prompt_len: LenDist,
    /// Output token budget per request.
    pub max_new: LenDist,
    /// Per-tenant latency SLO: every request this tenant admits carries
    /// this deadline (milliseconds, submit → completion), feeding the
    /// health monitor's attainment ledger and the bench's SLO-goodput
    /// metric. `None` = best-effort tenant (always in-deadline).
    pub slo_ms: Option<u64>,
}

/// Full description of a workload; `generate` is a pure function of
/// this spec plus the source prompt corpus.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub seed: u64,
    pub requests: usize,
    pub arrival: Arrival,
    pub tenants: Vec<TenantSpec>,
}

/// One scheduled request. `tenant` indexes `WorkloadSpec::tenants`;
/// `task` indexes [`TASK_NAMES`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Admission {
    pub at_ns: u64,
    pub tenant: u32,
    pub task: u32,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    /// Latency deadline (nanoseconds, submit → completion) inherited
    /// from the tenant's `slo_ms`; `None` = best-effort.
    pub deadline_ns: Option<u64>,
}

fn task_id(name: &str) -> Result<u32> {
    match TASK_NAMES.iter().position(|t| *t == name) {
        Some(i) => Ok(i as u32),
        None => bail!("unknown task {name:?} (expected one of {TASK_NAMES:?})"),
    }
}

/// Weighted index draw over `cum` (inclusive prefix sums of weights).
fn pick_weighted(rng: &mut Rng, cum: &[f64]) -> usize {
    let total = *cum.last().expect("non-empty weights");
    let u = rng.f64() * total;
    cum.iter().position(|c| u < *c).unwrap_or(cum.len() - 1)
}

fn prefix_sums(weights: &[f64], what: &str) -> Result<Vec<f64>> {
    if weights.is_empty() {
        bail!("{what}: empty weight list");
    }
    let mut acc = 0.0;
    let mut cum = Vec::with_capacity(weights.len());
    for &w in weights {
        if !w.is_finite() || w <= 0.0 {
            bail!("{what}: weights must be finite and > 0");
        }
        acc += w;
        cum.push(acc);
    }
    Ok(cum)
}

/// Expand a seeded [`WorkloadSpec`] into a concrete admission schedule
/// over `source` (typically the mixed-task "stream" prompt set).
/// Deterministic: the same `(spec, source)` pair always returns the
/// bitwise-identical schedule.
pub fn generate(spec: &WorkloadSpec, source: &PromptSet) -> Result<Vec<Admission>> {
    if spec.requests == 0 {
        bail!("workload spec needs requests > 0");
    }
    spec.arrival.validate()?;
    if spec.tenants.is_empty() {
        bail!("workload spec needs at least one tenant");
    }
    let tenant_cum = prefix_sums(
        &spec.tenants.iter().map(|t| t.weight).collect::<Vec<_>>(),
        "tenants",
    )?;
    // Per-tenant: resolved task ids + cumulative mix weights.
    let mut mixes: Vec<(Vec<u32>, Vec<f64>)> = Vec::new();
    for t in &spec.tenants {
        t.prompt_len.validate(&format!("tenant {}: prompt_len", t.name))?;
        t.max_new.validate(&format!("tenant {}: max_new", t.name))?;
        if t.slo_ms == Some(0) {
            bail!("tenant {}: slo_ms must be >= 1 (use None for no SLO)", t.name);
        }
        let mut ids = Vec::with_capacity(t.task_mix.len());
        for (name, _) in &t.task_mix {
            ids.push(task_id(name)?);
        }
        let cum = prefix_sums(
            &t.task_mix.iter().map(|m| m.1).collect::<Vec<_>>(),
            &format!("tenant {}: task_mix", t.name),
        )?;
        mixes.push((ids, cum));
    }
    // Index the source corpus by task once; every task named by any
    // tenant must have at least one sample to draw from.
    let mut by_task: Vec<Vec<usize>> = vec![Vec::new(); TASK_NAMES.len()];
    for (i, s) in source.samples.iter().enumerate() {
        if (s.task as usize) < by_task.len() && !s.prompt.is_empty() {
            by_task[s.task as usize].push(i);
        }
    }
    for (ids, _) in &mixes {
        for id in ids {
            if by_task[*id as usize].is_empty() {
                bail!(
                    "source prompt set has no samples for task {:?}",
                    TASK_NAMES[*id as usize]
                );
            }
        }
    }

    let mut rng = Rng::new(spec.seed);
    let mut t_s = 0.0f64;
    let mut out = Vec::with_capacity(spec.requests);
    for _ in 0..spec.requests {
        t_s = spec.arrival.next_after_s(t_s, &mut rng);
        let tenant = pick_weighted(&mut rng, &tenant_cum);
        let (ids, cum) = &mixes[tenant];
        let task = ids[pick_weighted(&mut rng, cum)];
        let pool = &by_task[task as usize];
        let sample = &source.samples[pool[rng.usize_below(pool.len())]];
        let want = spec.tenants[tenant].prompt_len.sample(&mut rng);
        let keep = want.clamp(2.min(sample.prompt.len()), sample.prompt.len());
        let prompt = sample.prompt[..keep].to_vec();
        let max_new = spec.tenants[tenant].max_new.sample(&mut rng).max(1);
        out.push(Admission {
            at_ns: (t_s * NS_PER_S).round() as u64,
            tenant: tenant as u32,
            task,
            prompt,
            max_new,
            deadline_ns: spec.tenants[tenant].slo_ms.map(|ms| ms * 1_000_000),
        });
    }
    Ok(out)
}

/// Canonical byte encoding of a schedule (little-endian, versioned).
/// Two schedules are identical iff their encodings are byte-equal —
/// benches assert this for replay determinism.
pub fn encode_schedule(schedule: &[Admission]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(b"DVIW");
    // v2: per-admission deadline_ns (0 = none; generate rejects
    // slo_ms=0 so the sentinel is unambiguous).
    out.extend_from_slice(&2u32.to_le_bytes());
    out.extend_from_slice(&(schedule.len() as u32).to_le_bytes());
    for a in schedule {
        out.extend_from_slice(&a.at_ns.to_le_bytes());
        out.extend_from_slice(&a.tenant.to_le_bytes());
        out.extend_from_slice(&a.task.to_le_bytes());
        out.extend_from_slice(&(a.max_new as u32).to_le_bytes());
        out.extend_from_slice(&a.deadline_ns.unwrap_or(0).to_le_bytes());
        out.extend_from_slice(&(a.prompt.len() as u32).to_le_bytes());
        for t in &a.prompt {
            out.extend_from_slice(&t.to_le_bytes());
        }
    }
    out
}

/// FNV-1a fingerprint of [`encode_schedule`] — a compact replay stamp
/// persisted into `BENCH_serving_load.json`.
pub fn fingerprint(schedule: &[Admission]) -> u64 {
    let mut h = Fnv64::new();
    h.bytes(&encode_schedule(schedule));
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::PromptSample;

    /// Synthetic corpus: 8 samples per task, prompts long enough to
    /// exercise truncation, first token tagged with the task id.
    fn corpus() -> PromptSet {
        let mut samples = Vec::new();
        for task in 0..TASK_NAMES.len() as u32 {
            for j in 0..8u32 {
                samples.push(PromptSample {
                    task,
                    max_new: 32,
                    prompt: (0..24).map(|k| task * 1000 + j * 32 + k).collect(),
                    answer: Vec::new(),
                });
            }
        }
        PromptSet { samples }
    }

    fn one_tenant(mix: &[(&str, f64)]) -> TenantSpec {
        TenantSpec {
            name: "t0".into(),
            weight: 1.0,
            task_mix: mix.iter().map(|(n, w)| (n.to_string(), *w)).collect(),
            prompt_len: LenDist::Uniform { lo: 4, hi: 12 },
            max_new: LenDist::Uniform { lo: 2, hi: 6 },
            slo_ms: None,
        }
    }

    #[test]
    fn poisson_interarrival_mean_within_tolerance() {
        let rate = 500.0;
        let spec = WorkloadSpec {
            seed: 11,
            requests: 4000,
            arrival: Arrival::Poisson { rate_per_s: rate },
            tenants: vec![one_tenant(&[("qa", 1.0)])],
        };
        let sched = generate(&spec, &corpus()).unwrap();
        let span_s = sched.last().unwrap().at_ns as f64 / NS_PER_S;
        let mean = span_s / (sched.len() - 1) as f64;
        let expect = 1.0 / rate;
        assert!(
            (mean - expect).abs() / expect < 0.05,
            "mean inter-arrival {mean:.6}s vs expected {expect:.6}s"
        );
        // Strictly increasing timestamps (arrivals never collide).
        for w in sched.windows(2) {
            assert!(w[0].at_ns < w[1].at_ns);
        }
    }

    #[test]
    fn bursty_duty_cycle_matches_rates() {
        let (rate_on, rate_off, on_s, off_s) = (1000.0, 50.0, 0.1, 0.1);
        let spec = WorkloadSpec {
            seed: 12,
            requests: 4000,
            arrival: Arrival::Bursty { rate_on, rate_off, on_s, off_s },
            tenants: vec![one_tenant(&[("mt", 1.0)])],
        };
        let sched = generate(&spec, &corpus()).unwrap();
        let period = on_s + off_s;
        let in_on = sched
            .iter()
            .filter(|a| {
                (a.at_ns as f64 / NS_PER_S).rem_euclid(period) < on_s
            })
            .count();
        let frac = in_on as f64 / sched.len() as f64;
        let expect =
            (rate_on * on_s) / (rate_on * on_s + rate_off * off_s);
        assert!(
            (frac - expect).abs() < 0.03,
            "on-phase fraction {frac:.3} vs expected {expect:.3}"
        );
    }

    #[test]
    fn bursty_off_rate_zero_skips_off_phases() {
        let spec = WorkloadSpec {
            seed: 13,
            requests: 500,
            arrival: Arrival::Bursty {
                rate_on: 800.0,
                rate_off: 0.0,
                on_s: 0.05,
                off_s: 0.05,
            },
            tenants: vec![one_tenant(&[("rag", 1.0)])],
        };
        let sched = generate(&spec, &corpus()).unwrap();
        for a in &sched {
            let pos = (a.at_ns as f64 / NS_PER_S).rem_euclid(0.1);
            assert!(
                pos <= 0.05 + 1e-6,
                "arrival at phase offset {pos:.4}s despite rate_off=0"
            );
        }
    }

    #[test]
    fn tenant_and_task_mix_proportions() {
        let mut chat = one_tenant(&[("qa", 1.0)]);
        chat.name = "chat".into();
        chat.weight = 3.0;
        let mut batch = one_tenant(&[("mt", 0.5), ("math", 0.5)]);
        batch.name = "batch".into();
        batch.weight = 1.0;
        let spec = WorkloadSpec {
            seed: 14,
            requests: 4000,
            arrival: Arrival::Poisson { rate_per_s: 100.0 },
            tenants: vec![chat, batch],
        };
        let sched = generate(&spec, &corpus()).unwrap();
        let n = sched.len() as f64;
        let chat_frac =
            sched.iter().filter(|a| a.tenant == 0).count() as f64 / n;
        assert!(
            (chat_frac - 0.75).abs() < 0.03,
            "chat share {chat_frac:.3} vs expected 0.75"
        );
        let qa = task_id("qa").unwrap();
        let mt = task_id("mt").unwrap();
        let math = task_id("math").unwrap();
        let batch_reqs: Vec<_> =
            sched.iter().filter(|a| a.tenant == 1).collect();
        let mt_frac = batch_reqs.iter().filter(|a| a.task == mt).count()
            as f64
            / batch_reqs.len() as f64;
        assert!(
            (mt_frac - 0.5).abs() < 0.05,
            "mt share within batch tenant {mt_frac:.3}"
        );
        for a in &sched {
            let ok = if a.tenant == 0 {
                a.task == qa
            } else {
                a.task == mt || a.task == math
            };
            assert!(ok, "task {} outside tenant {}'s mix", a.task, a.tenant);
            // Prompt is a prefix of a real corpus sample of that task.
            assert_eq!(a.prompt[0] / 1000, a.task);
        }
    }

    #[test]
    fn length_bounds_respected() {
        let mut t = one_tenant(&[("summarization", 1.0)]);
        t.prompt_len = LenDist::Uniform { lo: 5, hi: 9 };
        t.max_new = LenDist::Fixed(7);
        let spec = WorkloadSpec {
            seed: 15,
            requests: 300,
            arrival: Arrival::Poisson { rate_per_s: 50.0 },
            tenants: vec![t],
        };
        for a in generate(&spec, &corpus()).unwrap() {
            assert!((5..=9).contains(&a.prompt.len()), "{}", a.prompt.len());
            assert_eq!(a.max_new, 7);
        }
    }

    #[test]
    fn replay_is_bitwise_deterministic() {
        let spec = WorkloadSpec {
            seed: 16,
            requests: 256,
            arrival: Arrival::Bursty {
                rate_on: 400.0,
                rate_off: 40.0,
                on_s: 0.2,
                off_s: 0.1,
            },
            tenants: vec![
                one_tenant(&[("qa", 0.6), ("mt", 0.4)]),
                one_tenant(&[("rag", 1.0)]),
            ],
        };
        let c = corpus();
        let a = generate(&spec, &c).unwrap();
        let b = generate(&spec, &c).unwrap();
        assert_eq!(encode_schedule(&a), encode_schedule(&b));
        assert_eq!(fingerprint(&a), fingerprint(&b));
        let mut other = spec.clone();
        other.seed = 17;
        let d = generate(&other, &c).unwrap();
        assert_ne!(encode_schedule(&a), encode_schedule(&d));
        assert_ne!(fingerprint(&a), fingerprint(&d));
    }

    #[test]
    fn encode_distinguishes_every_field() {
        let base = Admission {
            at_ns: 10,
            tenant: 0,
            task: 1,
            prompt: vec![1, 2, 3],
            max_new: 4,
            deadline_ns: Some(250_000_000),
        };
        let enc = |a: &Admission| encode_schedule(std::slice::from_ref(a));
        let mut m = base.clone();
        m.at_ns = 11;
        assert_ne!(enc(&base), enc(&m));
        let mut m = base.clone();
        m.prompt = vec![1, 2, 9];
        assert_ne!(enc(&base), enc(&m));
        let mut m = base.clone();
        m.max_new = 5;
        assert_ne!(enc(&base), enc(&m));
        let mut m = base.clone();
        m.deadline_ns = Some(300_000_000);
        assert_ne!(enc(&base), enc(&m));
        let mut m = base.clone();
        m.deadline_ns = None;
        assert_ne!(enc(&base), enc(&m));
    }

    /// Every admission inherits exactly its tenant's deadline, scaled
    /// to nanoseconds; best-effort tenants stay `None`.
    #[test]
    fn deadlines_follow_the_tenant() {
        let mut chat = one_tenant(&[("qa", 1.0)]);
        chat.name = "chat".into();
        chat.slo_ms = Some(250);
        let mut batch = one_tenant(&[("mt", 1.0)]);
        batch.name = "batch".into();
        let spec = WorkloadSpec {
            seed: 21,
            requests: 400,
            arrival: Arrival::Poisson { rate_per_s: 200.0 },
            tenants: vec![chat, batch],
        };
        let sched = generate(&spec, &corpus()).unwrap();
        assert!(sched.iter().any(|a| a.tenant == 0));
        assert!(sched.iter().any(|a| a.tenant == 1));
        for a in &sched {
            match a.tenant {
                0 => assert_eq!(a.deadline_ns, Some(250_000_000)),
                _ => assert_eq!(a.deadline_ns, None),
            }
        }
    }

    #[test]
    fn rejects_invalid_specs() {
        let c = corpus();
        let good = WorkloadSpec {
            seed: 1,
            requests: 4,
            arrival: Arrival::Poisson { rate_per_s: 10.0 },
            tenants: vec![one_tenant(&[("qa", 1.0)])],
        };
        assert!(generate(&good, &c).is_ok());
        let mut bad = good.clone();
        bad.requests = 0;
        assert!(generate(&bad, &c).is_err());
        let mut bad = good.clone();
        bad.tenants.clear();
        assert!(generate(&bad, &c).is_err());
        let mut bad = good.clone();
        bad.tenants[0].task_mix = vec![("nosuch".into(), 1.0)];
        assert!(generate(&bad, &c).is_err());
        let mut bad = good.clone();
        bad.tenants[0].weight = 0.0;
        assert!(generate(&bad, &c).is_err());
        let mut bad = good.clone();
        bad.arrival = Arrival::Poisson { rate_per_s: 0.0 };
        assert!(generate(&bad, &c).is_err());
        let mut bad = good.clone();
        bad.tenants[0].task_mix = vec![("qa".into(), -1.0)];
        assert!(generate(&bad, &c).is_err());
        let mut bad = good.clone();
        bad.tenants[0].slo_ms = Some(0);
        assert!(generate(&bad, &c).is_err());
        // Empty corpus for a requested task.
        let empty = PromptSet { samples: Vec::new() };
        assert!(generate(&good, &empty).is_err());
    }
}
