//! Workloads: the Spec-Bench-analogue evaluation prompt sets plus the
//! ShareGPT-analogue online training stream, both generated at build time
//! by `python/compile/corpus.py` and shipped as token-id binaries.
//!
//! Binary format (little-endian), written by `aot.py::write_prompts_bin`:
//!   magic b"DVIP", u32 version (1), u32 count, then per record:
//!   u32 task_id, u32 max_new, u32 prompt_len, u32 answer_len,
//!   prompt_len x u32 ids, answer_len x u32 ids.

pub mod gen;

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::rng::Rng;

/// Task ids match `corpus.TASK_IDS` ordering.
pub const TASK_NAMES: [&str; 6] =
    ["mt", "translation", "summarization", "qa", "math", "rag"];

#[derive(Debug, Clone)]
pub struct PromptSample {
    pub task: u32,
    pub max_new: usize,
    pub prompt: Vec<u32>,
    /// Reference continuation (for optional output-quality checks).
    pub answer: Vec<u32>,
}

#[derive(Debug, Clone)]
pub struct PromptSet {
    pub samples: Vec<PromptSample>,
}

impl PromptSet {
    pub fn load(path: &Path) -> Result<PromptSet> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&bytes).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn parse(bytes: &[u8]) -> Result<PromptSet> {
        let take_u32 = |i: &mut usize| -> Result<u32> {
            if *i + 4 > bytes.len() {
                bail!("truncated prompt file at byte {}", *i);
            }
            let v = u32::from_le_bytes(bytes[*i..*i + 4].try_into().unwrap());
            *i += 4;
            Ok(v)
        };
        if bytes.len() < 4 || &bytes[..4] != b"DVIP" {
            bail!("bad prompt-file magic");
        }
        let mut i = 4usize;
        let version = take_u32(&mut i)?;
        if version != 1 {
            bail!("unsupported prompt-file version {version}");
        }
        let count = take_u32(&mut i)? as usize;
        let mut samples = Vec::with_capacity(count);
        for _ in 0..count {
            let task = take_u32(&mut i)?;
            let max_new = take_u32(&mut i)? as usize;
            let plen = take_u32(&mut i)? as usize;
            let alen = take_u32(&mut i)? as usize;
            if plen + alen > 1 << 20 {
                bail!("implausible record lengths");
            }
            let mut prompt = Vec::with_capacity(plen);
            for _ in 0..plen {
                prompt.push(take_u32(&mut i)?);
            }
            let mut answer = Vec::with_capacity(alen);
            for _ in 0..alen {
                answer.push(take_u32(&mut i)?);
            }
            samples.push(PromptSample { task, max_new, prompt, answer });
        }
        if i != bytes.len() {
            bail!("trailing bytes after {count} records");
        }
        Ok(PromptSet { samples })
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// First `n` samples (benchmarks use deterministic prefixes).
    pub fn take(&self, n: usize) -> PromptSet {
        PromptSet { samples: self.samples.iter().take(n).cloned().collect() }
    }

    /// Seeded deterministic permutation (Fisher–Yates over
    /// [`crate::util::rng::Rng`]): the same seed always yields the same
    /// order, so benches and the serving workload can mix task types
    /// without giving up reproducibility.
    pub fn shuffled(&self, seed: u64) -> PromptSet {
        let mut samples = self.samples.clone();
        Rng::new(seed).shuffle(&mut samples);
        PromptSet { samples }
    }

    /// Only the samples of one task (id per [`TASK_NAMES`] ordering),
    /// original order preserved.
    pub fn filter_task(&self, task: u32) -> PromptSet {
        PromptSet {
            samples: self
                .samples
                .iter()
                .filter(|s| s.task == task)
                .cloned()
                .collect(),
        }
    }
}

/// Serialize (round-trip tests + synthetic workload construction in Rust).
pub fn serialize_prompts(set: &PromptSet) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(b"DVIP");
    out.extend_from_slice(&1u32.to_le_bytes());
    out.extend_from_slice(&(set.samples.len() as u32).to_le_bytes());
    for s in &set.samples {
        out.extend_from_slice(&s.task.to_le_bytes());
        out.extend_from_slice(&(s.max_new as u32).to_le_bytes());
        out.extend_from_slice(&(s.prompt.len() as u32).to_le_bytes());
        out.extend_from_slice(&(s.answer.len() as u32).to_le_bytes());
        for t in &s.prompt {
            out.extend_from_slice(&t.to_le_bytes());
        }
        for t in &s.answer {
            out.extend_from_slice(&t.to_le_bytes());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_set() -> PromptSet {
        PromptSet {
            samples: vec![
                PromptSample { task: 1, max_new: 32,
                               prompt: vec![1, 5, 9], answer: vec![7, 2] },
                PromptSample { task: 0, max_new: 96,
                               prompt: vec![1], answer: vec![] },
            ],
        }
    }

    #[test]
    fn roundtrip() {
        let set = sample_set();
        let bytes = serialize_prompts(&set);
        let back = PromptSet::parse(&bytes).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.samples[0].prompt, vec![1, 5, 9]);
        assert_eq!(back.samples[0].answer, vec![7, 2]);
        assert_eq!(back.samples[1].max_new, 96);
    }

    #[test]
    fn rejects_truncated() {
        let bytes = serialize_prompts(&sample_set());
        assert!(PromptSet::parse(&bytes[..bytes.len() - 1]).is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = serialize_prompts(&sample_set());
        bytes[1] = b'X';
        assert!(PromptSet::parse(&bytes).is_err());
    }

    #[test]
    fn take_prefix() {
        assert_eq!(sample_set().take(1).len(), 1);
        assert_eq!(sample_set().take(99).len(), 2);
    }

    fn numbered_set(n: usize) -> PromptSet {
        PromptSet {
            samples: (0..n as u32)
                .map(|i| PromptSample {
                    task: i % 3,
                    max_new: 8,
                    prompt: vec![i],
                    answer: Vec::new(),
                })
                .collect(),
        }
    }

    #[test]
    fn shuffle_is_deterministic_per_seed() {
        let set = numbered_set(40);
        let a = set.shuffled(7);
        let b = set.shuffled(7);
        let ids = |s: &PromptSet| -> Vec<u32> {
            s.samples.iter().map(|x| x.prompt[0]).collect()
        };
        assert_eq!(ids(&a), ids(&b), "same seed must give the same order");
        // A permutation, not a filter.
        let mut sorted = ids(&a);
        sorted.sort_unstable();
        assert_eq!(sorted, (0..40).collect::<Vec<_>>());
        // Different seeds disagree (overwhelmingly) and the source set
        // is untouched.
        assert_ne!(ids(&a), ids(&set.shuffled(8)));
        assert_eq!(ids(&set), (0..40).collect::<Vec<_>>());
    }

    #[test]
    fn filter_task_keeps_order_and_task() {
        let set = numbered_set(10);
        let t1 = set.filter_task(1);
        assert!(!t1.is_empty());
        assert!(t1.samples.iter().all(|s| s.task == 1));
        let ids: Vec<u32> = t1.samples.iter().map(|s| s.prompt[0]).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted, "filter must preserve source order");
        assert!(set.filter_task(99).is_empty());
    }
}
