//! Word-level tokenizer over the synthetic vocabulary
//! (`artifacts/vocab.json`, emitted by `python/compile/corpus.py`).
//!
//! The language is whitespace-tokenized with a closed 512-word vocabulary,
//! so encode/decode are exact inverses; benchmarks ship token ids directly
//! (`prompts/*.bin`) and this type mostly serves examples/debug output.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
pub const SEP: u32 = 3;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    id_to_word: Vec<String>,
    word_to_id: HashMap<String, u32>,
}

impl Tokenizer {
    /// Build directly from an id-ordered word list (the reference
    /// backend's synthetic vocabulary lives in memory, not on disk).
    pub fn from_words(id_to_word: Vec<String>) -> Tokenizer {
        let word_to_id = id_to_word
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as u32))
            .collect();
        Tokenizer { id_to_word, word_to_id }
    }

    pub fn load(path: &Path) -> Result<Tokenizer> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("vocab.json")?;
        let arr = j.as_arr().context("vocab.json must be an array")?;
        let id_to_word: Vec<String> = arr
            .iter()
            .map(|v| v.as_str().map(|s| s.to_string()).context("vocab entry"))
            .collect::<Result<_>>()?;
        let word_to_id = id_to_word
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as u32))
            .collect();
        Ok(Tokenizer { id_to_word, word_to_id })
    }

    pub fn vocab_size(&self) -> usize {
        self.id_to_word.len()
    }

    pub fn encode(&self, text: &str) -> Result<Vec<u32>> {
        text.split_whitespace()
            .map(|w| {
                self.word_to_id
                    .get(w)
                    .copied()
                    .with_context(|| format!("unknown word '{w}'"))
            })
            .collect()
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .map(|&i| {
                self.id_to_word
                    .get(i as usize)
                    .map(|s| s.as_str())
                    .unwrap_or("<bad>")
            })
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn id(&self, word: &str) -> Result<u32> {
        match self.word_to_id.get(word) {
            Some(&i) => Ok(i),
            None => bail!("unknown word '{word}'"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tiny() -> Tokenizer {
        let mut f = tempfile();
        write!(f.1, r#"["<pad>","<bos>","<eos>","<sep>","hello","world"]"#)
            .unwrap();
        Tokenizer::load(&f.0).unwrap()
    }

    fn tempfile() -> (std::path::PathBuf, std::fs::File) {
        let p = std::env::temp_dir().join(format!(
            "dvi_vocab_{}_{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        let f = std::fs::File::create(&p).unwrap();
        (p, f)
    }

    #[test]
    fn roundtrip() {
        let t = tiny();
        let ids = t.encode("hello world hello").unwrap();
        assert_eq!(ids, vec![4, 5, 4]);
        assert_eq!(t.decode(&ids), "hello world hello");
    }

    #[test]
    fn unknown_word_errors() {
        assert!(tiny().encode("nope").is_err());
    }

    #[test]
    fn specials() {
        let t = tiny();
        assert_eq!(t.id("<eos>").unwrap(), EOS);
        assert_eq!(t.vocab_size(), 6);
    }
}
