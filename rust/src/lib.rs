//! # DVI — Draft, Verify, & Improve
//!
//! Production-shaped reproduction of *"Draft, Verify, & Improve: Toward
//! Training-Aware Speculative Decoding"* (Bhansali & Heck, 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — serving coordinator: decode engines (DVI
//!   self-speculation + AR/PLD/SpS/Medusa/Hydra/EAGLE baselines), the
//!   online learner (replay buffer + KL→RL schedule), a request
//!   router with per-thread workers or a continuous-batching scheduler
//!   ([`sched`]), workloads, metrics, and the Spec-Bench-style
//!   benchmark harness.
//! * **L2/L1 (python/compile, build-time only)** — JAX model + Pallas
//!   kernels, AOT-lowered to HLO text executed through PJRT
//!   (`runtime` module, cargo feature `pjrt`). Python never runs on
//!   the request path.
//!
//! The runtime is multi-backend behind [`runtime::Backend`]: the
//! hermetic pure-Rust reference interpreter
//! ([`runtime::Runtime::load_reference`] — no artifacts, no Python, no
//! XLA; the invariant test suite runs on it unconditionally), the
//! PJRT path ([`runtime::Runtime::load`]), and the remote executor
//! ([`runtime::Runtime::load_remote`] / `dvi serve-backend` —
//! batched calls shipped to another process/host over a
//! dependency-free wire protocol, [`runtime::remote`]). Start with
//! [`runtime::Runtime::load_auto`], then construct engines from
//! [`engine`], or drive everything through the `dvi` binary.

pub mod cache;
pub mod engine;
pub mod harness;
pub mod learner;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod sched;
pub mod server;
pub mod spec;
pub mod tokenizer;
pub mod util;
pub mod workload;
