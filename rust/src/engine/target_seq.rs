//! `TargetSeq`: a live full-model sequence (prefill + AR step + chain
//! verification) over the `prefill_full` / `target_step` /
//! `target_verify_block` artifacts. This is the verifier substrate shared
//! by the AR baseline and by every *external-drafter* method (PLD, SpS,
//! Medusa, Hydra, EAGLE). DVI has its own split-path plumbing.
//!
//! The same struct also drives the SpS *drafter* model (same artifact
//! shapes under the `sps_*` names), so it is generic over artifact names.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::runtime::{Artifact, Buffer, Runtime, Tensor};
use crate::spec::{longest_prefix, SeqPos, VerifyOutcome};
use crate::util::math::argmax;

pub struct TargetSeq {
    rt: Arc<Runtime>,
    prefill: Arc<Artifact>,
    step: Arc<Artifact>,
    verify: Option<Arc<Artifact>>,
    kv: Vec<Buffer>,
    pub seq: SeqPos,
    prompt_len: usize,
    max_seq: usize,
    vocab: usize,
}

impl TargetSeq {
    /// Prefill a prompt. Returns the engine plus the first generated token
    /// and the h_L feature row that produced it (used by Medusa/EAGLE).
    pub fn start(
        rt: Arc<Runtime>,
        prefill_name: &str,
        step_name: &str,
        verify_name: Option<&str>,
        prompt: &[u32],
    ) -> Result<(TargetSeq, u32, Vec<f32>)> {
        let prefill = rt.artifact(prefill_name)?;
        let step = rt.artifact(step_name)?;
        let verify = verify_name.map(|n| rt.artifact(n)).transpose()?;
        let prefill_seq = rt.manifest.spec_usize("prefill_seq")?;
        let max_seq = rt.manifest.model_usize("max_seq")?;
        let vocab = rt.manifest.model_usize("vocab_size")?;
        anyhow::ensure!(
            prompt.len() <= prefill_seq,
            "prompt length {} exceeds prefill capacity {}",
            prompt.len(),
            prefill_seq
        );

        let kv = rt.fresh_kv(prefill_name)?;
        let mut padded: Vec<i32> = prompt.iter().map(|&t| t as i32).collect();
        padded.resize(prefill_seq, 0);
        let out = prefill.call(
            &kv,
            &[
                Tensor::i32(vec![prefill_seq], padded),
                Tensor::scalar_i32(prompt.len() as i32),
            ],
        )?;
        let logits = out.outputs[0].as_f32()?;
        let hl = out.outputs[1].as_f32()?.to_vec();
        let first = argmax(logits) as u32;
        let mut seq = SeqPos::after_prefill(prompt);
        seq.push_committed(first);
        Ok((
            TargetSeq {
                rt, prefill, step, verify,
                kv: out.kv,
                seq,
                prompt_len: prompt.len(),
                max_seq, vocab,
            },
            first,
            hl,
        ))
    }

    pub fn generated(&self) -> usize {
        self.seq.generated(self.prompt_len)
    }

    /// Remaining KV capacity guard: can we run a round writing `k` slots?
    pub fn has_capacity(&self, k: usize) -> bool {
        self.seq.kv_len + k < self.max_seq
    }

    /// Plain AR step: feed the pending token, commit the argmax. Returns
    /// (new token, h_L feature row of the fed position).
    pub fn ar_step(&mut self) -> Result<(u32, Vec<f32>)> {
        let (tok, pos) = self.seq.feed();
        let out = self.step.call(
            &self.kv,
            &[Tensor::scalar_i32(tok as i32), Tensor::scalar_i32(pos as i32)],
        )?;
        self.kv = out.kv;
        let logits = out.outputs[0].as_f32()?;
        let hl = out.outputs[1].as_f32()?.to_vec();
        let next = argmax(logits) as u32;
        self.seq.advance_ar(next);
        Ok((next, hl))
    }

    /// Verify `proposals` (exactly the artifact's block size k_spec).
    /// Feeds [pending, proposals[..k-1]] and applies the acceptance rule.
    /// Returns the outcome plus the h_L row at the last *valid* fed
    /// position (the re-root feature for Medusa/Hydra/EAGLE).
    pub fn verify_chain(
        &mut self,
        proposals: &[u32],
    ) -> Result<(VerifyOutcome, Vec<f32>)> {
        let verify = self.verify.as_ref().context("no verify artifact")?;
        let k = proposals.len();
        let (tok, pos) = self.seq.feed();
        let mut feed: Vec<i32> = Vec::with_capacity(k);
        feed.push(tok as i32);
        feed.extend(proposals[..k - 1].iter().map(|&t| t as i32));
        let out = verify.call(
            &self.kv,
            &[
                Tensor::i32(vec![k], feed),
                Tensor::scalar_i32(pos as i32),
            ],
        )?;
        self.kv = out.kv;
        let logits = &out.outputs[0];
        let verifier: Vec<u32> = (0..k)
            .map(|i| Ok(argmax(logits.row_f32(i)?) as u32))
            .collect::<Result<_>>()?;
        let outcome = longest_prefix(proposals, &verifier);
        self.seq.advance(k, outcome.accepted, &outcome.committed);
        // h_L row at the last valid fed slot: index min(m, k-1).
        let root = outcome.accepted.min(k - 1);
        let hl = out.outputs[1].row_f32(root)?.to_vec();
        Ok((outcome, hl))
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// All committed tokens (prompt + generated).
    pub fn tokens(&self) -> &[u32] {
        &self.seq.tokens
    }

    /// Re-prefill for a new prompt, reusing the engine's artifacts.
    pub fn restart(&mut self, prompt: &[u32]) -> Result<(u32, Vec<f32>)> {
        let (ts, first, hl) = TargetSeq::start(
            self.rt.clone(),
            &self.prefill.spec.name,
            &self.step.spec.name,
            self.verify.as_ref().map(|v| v.spec.name.as_str()),
            prompt,
        )?;
        *self = ts;
        Ok((first, hl))
    }
}
