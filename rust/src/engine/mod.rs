//! Decoding engines: greedy AR baseline, DVI self-speculation, and the
//! five reimplemented comparison methods (PLD, SpS, Medusa, Hydra, EAGLE).
//!
//! Every engine implements `Engine::generate` and reports per-round
//! `StepRecord`s, from which the Spec-Bench metrics (MAT, acceptance
//! rate, wall-time speedup) are derived by `crate::metrics`.

pub mod ar;
pub mod dvi;
pub mod eagle;
pub mod medusa;
pub mod pld;
pub mod sps;
pub mod target_seq;

use anyhow::Result;

pub use target_seq::TargetSeq;

use crate::tokenizer::EOS;

/// One verification round (or one AR step).
#[derive(Debug, Clone, Default)]
pub struct StepRecord {
    /// Drafted tokens this round (0 for plain AR steps).
    pub drafted: usize,
    /// Drafted tokens accepted by the verifier (m).
    pub accepted: usize,
    /// Tokens committed (accepted + bonus, or 1 for AR).
    pub committed: usize,
    /// Nanoseconds spent producing proposals.
    pub draft_ns: u64,
    /// Nanoseconds spent in the verifier pass.
    pub verify_ns: u64,
}

#[derive(Debug, Clone, Default)]
pub struct GenResult {
    /// Generated tokens (prompt excluded), truncated at EOS if emitted.
    pub tokens: Vec<u32>,
    pub steps: Vec<StepRecord>,
    pub prefill_ns: u64,
    /// Total decode wall time (draft + verify + coordinator overhead).
    pub decode_ns: u64,
}

impl GenResult {
    /// Mean accepted tokens per *verification step* (Spec-Bench MAT).
    /// AR steps (drafted == 0) do not count as verification steps.
    pub fn mat(&self) -> f64 {
        let vsteps: Vec<_> = self.steps.iter().filter(|s| s.drafted > 0).collect();
        if vsteps.is_empty() {
            return 0.0;
        }
        vsteps.iter().map(|s| s.accepted as f64).sum::<f64>() / vsteps.len() as f64
    }

    /// Fraction of drafted tokens accepted.
    pub fn acceptance_rate(&self) -> f64 {
        let drafted: usize = self.steps.iter().map(|s| s.drafted).sum();
        if drafted == 0 {
            return 0.0;
        }
        let accepted: usize = self.steps.iter().map(|s| s.accepted).sum();
        accepted as f64 / drafted as f64
    }

    /// Tokens committed per verifier call (throughput proxy).
    pub fn tokens_per_step(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        self.tokens.len() as f64 / self.steps.len() as f64
    }
}

pub trait Engine {
    fn name(&self) -> &'static str;

    /// Greedy generation. Lossless engines must produce *exactly* the
    /// AR baseline's token sequence (asserted by integration tests).
    fn generate(&mut self, prompt: &[u32], max_new: usize) -> Result<GenResult>;
}

/// Truncate `tokens` at the first EOS (inclusive). Returns true if found.
pub fn truncate_at_eos(tokens: &mut Vec<u32>) -> bool {
    if let Some(idx) = tokens.iter().position(|&t| t == EOS) {
        tokens.truncate(idx + 1);
        true
    } else {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_ignores_ar_steps() {
        let r = GenResult {
            tokens: vec![1, 2, 3],
            steps: vec![
                StepRecord { drafted: 4, accepted: 2, committed: 3, ..Default::default() },
                StepRecord { drafted: 0, accepted: 0, committed: 1, ..Default::default() },
                StepRecord { drafted: 4, accepted: 4, committed: 4, ..Default::default() },
            ],
            ..Default::default()
        };
        assert_eq!(r.mat(), 3.0);
        assert!((r.acceptance_rate() - 6.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn truncation() {
        let mut t = vec![5, 6, EOS, 9];
        assert!(truncate_at_eos(&mut t));
        assert_eq!(t, vec![5, 6, EOS]);
        let mut u = vec![5, 6];
        assert!(!truncate_at_eos(&mut u));
        assert_eq!(u.len(), 2);
    }

    #[test]
    fn empty_result_metrics() {
        let r = GenResult::default();
        assert_eq!(r.mat(), 0.0);
        assert_eq!(r.acceptance_rate(), 0.0);
        assert_eq!(r.tokens_per_step(), 0.0);
    }
}
