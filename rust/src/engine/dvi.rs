//! The DVI engine: self-speculative decode over a split backbone with
//! online tuple logging (paper §3.2–3.3).
//!
//! Per round (committed prefix ..x_P at feed point (f, P)):
//!   1. DRAFT — k_spec calls to `draft_step` (shallow layers + LoRA head),
//!      feeding f, d_1, .., d_{k-1} at positions P..P+k-1; collects the
//!      raw h_k rows and greedy drafted tokens d_1..d_k.
//!   2. VERIFY — one `verify_block` call runs the deep layers over the
//!      h_k rows (this is where self-speculation amortizes: the deep pass
//!      re-uses the shallow computation instead of re-embedding tokens).
//!   3. IMPROVE — the longest-agreeing prefix commits (greedy => lossless;
//!      `spec::accept` rule); one tuple per drafted position up to and
//!      including the first reject goes to the replay buffer; positions
//!      beyond the first reject are counterfactual and are NOT logged.
//!
//! When `online` is set, the engine triggers the trainer after each
//! prompt, so LoRA updates land between requests exactly like the paper's
//! serving-time adaptation loop.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::learner::{ReplayBuffer, Tuple};
use crate::runtime::{Artifact, Buffer, Runtime, Tensor};
use crate::spec::{longest_prefix, SeqPos};
use crate::util::math::argmax;

use super::{truncate_at_eos, Engine, GenResult, StepRecord};

pub struct DviEngine {
    rt: Arc<Runtime>,
    prefill_sh: Arc<Artifact>,
    prefill_dp: Arc<Artifact>,
    draft: Arc<Artifact>,
    /// Fused k_spec-step draft loop (one PJRT call instead of k_spec;
    /// see EXPERIMENTS.md §Perf). Falls back to `draft` when absent.
    draft_block: Option<Arc<Artifact>>,
    verify: Arc<Artifact>,
    pub k_spec: usize,
    d_model: usize,
    prefill_seq: usize,
    max_seq: usize,
    /// Tuple sink; engine logs accept/reject supervision when present.
    pub buffer: Option<Arc<Mutex<ReplayBuffer>>>,
}

impl DviEngine {
    pub fn new(rt: Arc<Runtime>) -> Result<DviEngine> {
        let k_spec = rt.manifest.spec_usize("k_spec")?;
        let d_model = rt.manifest.model_usize("d_model")?;
        let prefill_seq = rt.manifest.spec_usize("prefill_seq")?;
        let max_seq = rt.manifest.model_usize("max_seq")?;
        Ok(DviEngine {
            prefill_sh: rt.artifact("prefill_shallow")?,
            prefill_dp: rt.artifact("prefill_deep")?,
            draft: rt.artifact("draft_step")?,
            draft_block: rt.artifact("draft_block").ok(),
            verify: rt.artifact("verify_block")?,
            rt,
            k_spec,
            d_model,
            prefill_seq,
            max_seq,
            buffer: None,
        })
    }

    pub fn with_buffer(mut self, buffer: Arc<Mutex<ReplayBuffer>>) -> Self {
        self.buffer = Some(buffer);
        self
    }

    /// Force the k_spec per-step draft path even when the fused
    /// `draft_block` artifact is exported (parity testing / ablation).
    pub fn without_draft_block(mut self) -> Self {
        self.draft_block = None;
        self
    }

    fn prefill(
        &self,
        prompt: &[u32],
    ) -> Result<(Vec<Buffer>, Vec<Buffer>, u32)> {
        anyhow::ensure!(
            prompt.len() <= self.prefill_seq,
            "prompt length {} exceeds prefill capacity {}",
            prompt.len(),
            self.prefill_seq
        );
        let kv_sh = self.rt.fresh_kv("prefill_shallow")?;
        let mut padded: Vec<i32> = prompt.iter().map(|&t| t as i32).collect();
        padded.resize(self.prefill_seq, 0);
        let sh = self.prefill_sh.call(
            &kv_sh,
            &[Tensor::i32(vec![self.prefill_seq], padded)],
        )?;
        // sh.outputs[0] = h_k rows [P, d]; feed them into the deep prefill.
        let kv_dp = self.rt.fresh_kv("prefill_deep")?;
        let dp = self.prefill_dp.call(
            &kv_dp,
            &[
                sh.outputs[0].clone(),
                Tensor::scalar_i32(prompt.len() as i32),
            ],
        )?;
        let first = argmax(dp.outputs[0].as_f32()?) as u32;
        Ok((sh.kv, dp.kv, first))
    }
}

impl Engine for DviEngine {
    fn name(&self) -> &'static str {
        "dvi"
    }

    fn generate(&mut self, prompt: &[u32], max_new: usize) -> Result<GenResult> {
        let t0 = Instant::now();
        let (mut kv_sh, mut kv_dp, first) = self.prefill(prompt)?;
        let prefill_ns = t0.elapsed().as_nanos() as u64;

        let mut seq = SeqPos::after_prefill(prompt);
        seq.push_committed(first);
        let mut result = GenResult {
            tokens: vec![first],
            prefill_ns,
            ..Default::default()
        };

        let k = self.k_spec;
        let td = Instant::now();
        while result.tokens.len() < max_new
            && !truncate_at_eos(&mut result.tokens)
            && seq.kv_len + k + 1 < self.max_seq
        {
            // ---- DRAFT: k shallow steps ----------------------------------
            // One fused PJRT call when the draft_block artifact exists
            // (greedy argmax between steps happens in-graph); otherwise
            // k_spec per-step calls.
            let tdraft = Instant::now();
            let (feed_tok, feed_pos) = seq.feed();
            let mut drafted: Vec<u32> = Vec::with_capacity(k);
            let mut hk_rows: Vec<f32> = Vec::with_capacity(k * self.d_model);
            if let Some(block) = &self.draft_block {
                let out = block.call(
                    &kv_sh,
                    &[
                        Tensor::scalar_i32(feed_tok as i32),
                        Tensor::scalar_i32(feed_pos as i32),
                    ],
                )?;
                kv_sh = out.kv;
                drafted.extend(out.outputs[0].as_i32()?.iter().map(|&t| t as u32));
                hk_rows.extend_from_slice(out.outputs[1].as_f32()?);
            } else {
                let mut tok = feed_tok;
                for i in 0..k {
                    let out = self.draft.call(
                        &kv_sh,
                        &[
                            Tensor::scalar_i32(tok as i32),
                            Tensor::scalar_i32((feed_pos + i) as i32),
                        ],
                    )?;
                    kv_sh = out.kv;
                    let logits_theta = out.outputs[0].as_f32()?;
                    hk_rows.extend_from_slice(out.outputs[1].as_f32()?);
                    let d = argmax(logits_theta) as u32;
                    drafted.push(d);
                    tok = d;
                }
            }
            let draft_ns = tdraft.elapsed().as_nanos() as u64;

            // ---- VERIFY: one deep block ----------------------------------
            let tver = Instant::now();
            let out = self.verify.call(
                &kv_dp,
                &[
                    Tensor::f32(vec![k, self.d_model], hk_rows.clone()),
                    Tensor::scalar_i32(feed_pos as i32),
                ],
            )?;
            kv_dp = out.kv;
            let logits_phi = &out.outputs[0];
            let verifier: Vec<u32> = (0..k)
                .map(|i| Ok(argmax(logits_phi.row_f32(i)?) as u32))
                .collect::<Result<_>>()?;
            let outcome = longest_prefix(&drafted, &verifier);
            let verify_ns = tver.elapsed().as_nanos() as u64;

            // ---- IMPROVE: log supervision tuples --------------------------
            if let Some(buf) = &self.buffer {
                let mut buf = buf.lock().unwrap();
                let logged = (outcome.accepted + 1).min(k); // incl. first reject
                for i in 0..logged {
                    buf.push(Tuple {
                        hk: hk_rows[i * self.d_model..(i + 1) * self.d_model]
                            .to_vec(),
                        action: drafted[i],
                        logits_phi: logits_phi.row_f32(i)?.to_vec(),
                        reward: if i < outcome.accepted { 1.0 } else { 0.0 },
                    });
                }
            }

            seq.advance(k, outcome.accepted, &outcome.committed);
            result.tokens.extend_from_slice(&outcome.committed);
            result.steps.push(StepRecord {
                drafted: k,
                accepted: outcome.accepted,
                committed: outcome.total_committed(),
                draft_ns,
                verify_ns,
            });
        }
        truncate_at_eos(&mut result.tokens);
        result.tokens.truncate(max_new);
        result.decode_ns = td.elapsed().as_nanos() as u64;
        Ok(result)
    }
}
