//! The DVI engine: self-speculative decode over a split backbone with
//! online tuple logging (paper §3.2–3.3).
//!
//! Per round (committed prefix ..x_P at feed point (f, P)):
//!   1. DRAFT — k_spec calls to `draft_step` (shallow layers + LoRA head),
//!      feeding f, d_1, .., d_{k-1} at positions P..P+k-1; collects the
//!      raw h_k rows and greedy drafted tokens d_1..d_k.
//!   2. VERIFY — one `verify_block` call runs the deep layers over the
//!      h_k rows (this is where self-speculation amortizes: the deep pass
//!      re-uses the shallow computation instead of re-embedding tokens).
//!   3. IMPROVE — the longest-agreeing prefix commits (greedy => lossless;
//!      `spec::accept` rule); one tuple per drafted position up to and
//!      including the first reject goes to the replay buffer; positions
//!      beyond the first reject are counterfactual and are NOT logged.
//!
//! The round structure lives in [`crate::sched::seq::DviSeq`], a
//! resumable state machine this engine drives one call at a time; the
//! continuous-batching scheduler drives the same machine through batched
//! backend calls, which is why batched serving stays bitwise-lossless
//! against this engine.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::learner::ReplayBuffer;
use crate::runtime::Runtime;
use crate::sched::seq::{AdaptiveK, DviCtx, DviSeq};

use super::{Engine, GenResult};

pub struct DviEngine {
    ctx: Arc<DviCtx>,
    pub k_spec: usize,
    /// Tuple sink; engine logs accept/reject supervision when present.
    pub buffer: Option<Arc<Mutex<ReplayBuffer>>>,
    /// Sequential placement key per generation (sharded backends pin
    /// each sequence's KV to one executor by it).
    next_key: u64,
}

impl DviEngine {
    pub fn new(rt: Arc<Runtime>) -> Result<DviEngine> {
        let ctx = DviCtx::new(rt)?;
        Ok(DviEngine {
            k_spec: ctx.k_spec,
            ctx: Arc::new(ctx),
            buffer: None,
            next_key: 0,
        })
    }

    pub fn with_buffer(mut self, buffer: Arc<Mutex<ReplayBuffer>>) -> Self {
        self.buffer = Some(buffer);
        self
    }

    /// Force the k_spec per-step draft path even when the fused
    /// `draft_block` artifact is exported (parity testing / ablation).
    pub fn without_draft_block(mut self) -> Self {
        let mut ctx = (*self.ctx).clone();
        ctx.draft_block = None;
        self.ctx = Arc::new(ctx);
        self
    }

    /// Override the adaptive speculation-depth policy explicitly
    /// (construction defaults to the `DVI_ADAPTIVE_K` environment;
    /// `None` pins every round to `k_spec`).
    pub fn with_adaptive(mut self, adaptive: Option<AdaptiveK>) -> Self {
        let ctx = (*self.ctx).clone().with_adaptive(adaptive);
        self.ctx = Arc::new(ctx);
        self
    }
}

impl Engine for DviEngine {
    fn name(&self) -> &'static str {
        "dvi"
    }

    fn generate(&mut self, prompt: &[u32], max_new: usize) -> Result<GenResult> {
        let key = self.next_key;
        self.next_key += 1;
        let mut seq =
            DviSeq::new(self.ctx.clone(), self.buffer.clone(), prompt, max_new, key)?;
        while !seq.is_done() {
            let call = seq.next_call()?;
            let out = call.artifact.call(&call.kv, &call.inputs)?;
            seq.apply(out)?;
        }
        Ok(seq.into_result())
    }
}
