//! SpS: classic two-model speculative sampling (Leviathan et al. /
//! Chen et al.) — an independent small drafter LM proposes, the full
//! target model verifies. The drafter here is the 2-layer mini-LM
//! distilled offline by `python/compile/distill.py` (weights `sps.*`).
//!
//! This engine demonstrates the costs DVI's self-speculation removes: a
//! second KV cache, drafter catch-up feeds, and a second model's weights.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::{Artifact, Buffer, Runtime, Tensor};
use crate::spec::SeqPos;
use crate::util::math::argmax;

use super::{truncate_at_eos, Engine, GenResult, StepRecord, TargetSeq};

pub struct SpsEngine {
    rt: Arc<Runtime>,
    draft_prefill: Arc<Artifact>,
    draft_step: Arc<Artifact>,
    pub k_spec: usize,
    prefill_seq: usize,
}

impl SpsEngine {
    pub fn new(rt: Arc<Runtime>) -> Result<SpsEngine> {
        Ok(SpsEngine {
            draft_prefill: rt.artifact("sps_prefill")?,
            draft_step: rt.artifact("sps_draft_step")?,
            k_spec: rt.manifest.spec_usize("k_spec")?,
            prefill_seq: rt.manifest.spec_usize("prefill_seq")?,
            rt,
        })
    }
}

struct DrafterState {
    kv: Vec<Buffer>,
    seq: SeqPos,
}

impl Engine for SpsEngine {
    fn name(&self) -> &'static str {
        "sps"
    }

    fn generate(&mut self, prompt: &[u32], max_new: usize) -> Result<GenResult> {
        let t0 = Instant::now();
        let (mut target, first, _hl) = TargetSeq::start(
            self.rt.clone(),
            "prefill_full",
            "target_step",
            Some("target_verify_block"),
            prompt,
        )?;
        // Drafter prefills the same prompt on its own weights/cache.
        let kv = self.rt.fresh_kv("sps_prefill")?;
        let mut padded: Vec<i32> = prompt.iter().map(|&t| t as i32).collect();
        padded.resize(self.prefill_seq, 0);
        let dout = self.draft_prefill.call(
            &kv,
            &[
                Tensor::i32(vec![self.prefill_seq], padded),
                Tensor::scalar_i32(prompt.len() as i32),
            ],
        )?;
        let mut drafter = DrafterState {
            kv: dout.kv,
            seq: SeqPos::after_prefill(prompt),
        };
        drafter.seq.push_committed(first); // target's first token
        let prefill_ns = t0.elapsed().as_nanos() as u64;

        let mut result = GenResult {
            tokens: vec![first],
            prefill_ns,
            ..Default::default()
        };

        let k = self.k_spec;
        let td = Instant::now();
        while result.tokens.len() < max_new
            && !truncate_at_eos(&mut result.tokens)
            && target.has_capacity(k + 1)
        {
            // ---- DRAFT: catch-up + k greedy steps on the small model ----
            let tdraft = Instant::now();
            // Catch-up: feed any committed tokens the drafter's KV has not
            // ingested yet, except the newest (which seeds drafting).
            while drafter.seq.kv_len + 1 < drafter.seq.tokens.len() {
                let (tok, pos) = drafter.seq.feed();
                let out = self.draft_step.call(
                    &drafter.kv,
                    &[Tensor::scalar_i32(tok as i32),
                      Tensor::scalar_i32(pos as i32)],
                )?;
                drafter.kv = out.kv;
                drafter.seq.kv_len += 1;
            }
            let kv_snapshot = drafter.seq.kv_len;
            let mut drafted: Vec<u32> = Vec::with_capacity(k);
            let (mut tok, mut pos) = drafter.seq.feed();
            for _ in 0..k {
                let out = self.draft_step.call(
                    &drafter.kv,
                    &[Tensor::scalar_i32(tok as i32),
                      Tensor::scalar_i32(pos as i32)],
                )?;
                drafter.kv = out.kv;
                let d = argmax(out.outputs[0].as_f32()?) as u32;
                drafted.push(d);
                tok = d;
                pos += 1;
            }
            let draft_ns = tdraft.elapsed().as_nanos() as u64;

            // ---- VERIFY on the target model ------------------------------
            let tver = Instant::now();
            let (outcome, _hl) = target.verify_chain(&drafted)?;
            let verify_ns = tver.elapsed().as_nanos() as u64;

            // Reconcile the drafter with ground truth: tokens come from
            // the target; drafter KV validity follows the same rule as
            // any chain (feed + accepted drafted-that-were-fed).
            drafter.seq.tokens = target.seq.tokens.clone();
            drafter.seq.kv_len = kv_snapshot + 1 + outcome.accepted.min(k - 1);

            result.tokens.extend_from_slice(&outcome.committed);
            result.steps.push(StepRecord {
                drafted: k,
                accepted: outcome.accepted,
                committed: outcome.total_committed(),
                draft_ns,
                verify_ns,
            });
        }
        truncate_at_eos(&mut result.tokens);
        result.tokens.truncate(max_new);
        result.decode_ns = td.elapsed().as_nanos() as u64;
        Ok(result)
    }
}
