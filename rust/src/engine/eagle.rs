//! EAGLE-style feature-level drafting (Li et al. 2024a).
//!
//! The drafter autoregresses in *feature space*: from (h_L at position
//! t, embedding of token t+1) it predicts h_L at t+1, and the frozen
//! verifier LM head turns predicted features into draft tokens. After
//! verification the feature state re-roots on the *true* h_L row returned
//! by the verify block, so drift never compounds past one round.
//!
//! The feature predictor is the residual MLP trained offline in
//! `distill.py` (the original uses a one-layer transformer over features;
//! see DESIGN.md §Substitutions).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::{Artifact, Runtime, Tensor};
use crate::util::math::argmax;

use super::{truncate_at_eos, Engine, GenResult, StepRecord, TargetSeq};

pub struct EagleEngine {
    rt: Arc<Runtime>,
    step: Arc<Artifact>,
    pub k_spec: usize,
}

impl EagleEngine {
    pub fn new(rt: Arc<Runtime>) -> Result<EagleEngine> {
        Ok(EagleEngine {
            step: rt.artifact("eagle_step")?,
            k_spec: rt.manifest.spec_usize("k_spec")?,
            rt,
        })
    }
}

impl Engine for EagleEngine {
    fn name(&self) -> &'static str {
        "eagle"
    }

    fn generate(&mut self, prompt: &[u32], max_new: usize) -> Result<GenResult> {
        let t0 = Instant::now();
        let (mut ts, first, mut feat) = TargetSeq::start(
            self.rt.clone(),
            "prefill_full",
            "target_step",
            Some("target_verify_block"),
            prompt,
        )?;
        let prefill_ns = t0.elapsed().as_nanos() as u64;
        let mut result = GenResult {
            tokens: vec![first],
            prefill_ns,
            ..Default::default()
        };

        let k = self.k_spec;
        let d = feat.len();
        let td = Instant::now();
        while result.tokens.len() < max_new
            && !truncate_at_eos(&mut result.tokens)
            && ts.has_capacity(k + 1)
        {
            // ---- DRAFT: autoregressive feature rollout -------------------
            let tdraft = Instant::now();
            let (mut tok, _pos) = ts.seq.feed();
            let mut f = feat.clone();
            let mut proposals: Vec<u32> = Vec::with_capacity(k);
            for _ in 0..k {
                let out = self.step.call(
                    &[],
                    &[
                        Tensor::f32(vec![d], f),
                        Tensor::scalar_i32(tok as i32),
                    ],
                )?;
                let t = argmax(out.outputs[0].as_f32()?) as u32;
                f = out.outputs[1].as_f32()?.to_vec();
                proposals.push(t);
                tok = t;
            }
            let draft_ns = tdraft.elapsed().as_nanos() as u64;

            // ---- VERIFY + re-root on true features -----------------------
            let tver = Instant::now();
            let (outcome, new_feat) = ts.verify_chain(&proposals)?;
            feat = new_feat;
            result.tokens.extend_from_slice(&outcome.committed);
            result.steps.push(StepRecord {
                drafted: k,
                accepted: outcome.accepted,
                committed: outcome.total_committed(),
                draft_ns,
                verify_ns: tver.elapsed().as_nanos() as u64,
            });
        }
        truncate_at_eos(&mut result.tokens);
        result.tokens.truncate(max_new);
        result.decode_ns = td.elapsed().as_nanos() as u64;
        Ok(result)
    }
}
