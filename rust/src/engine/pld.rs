//! PLD (Prompt Lookup Decoding): training-free drafting by n-gram match.
//!
//! Proposals come from the sequence's own history: find the most recent
//! earlier occurrence of the current suffix n-gram (n = 3 falling back to
//! 2) and propose the k tokens that followed it. Strong on copy-heavy
//! workloads (summarization/RAG), useless on novel text — exactly the
//! per-task profile Table 2 shows for PLD.
//!
//! When no match exists the engine takes a plain AR step (no wasted
//! verifier block on garbage proposals).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::Runtime;

use super::{truncate_at_eos, Engine, GenResult, StepRecord, TargetSeq};

pub struct PldEngine {
    rt: Arc<Runtime>,
    pub k_spec: usize,
}

impl PldEngine {
    pub fn new(rt: Arc<Runtime>) -> Result<PldEngine> {
        let k_spec = rt.manifest.spec_usize("k_spec")?;
        Ok(PldEngine { rt, k_spec })
    }
}

/// Find a continuation of the token history by suffix n-gram lookup.
/// Returns exactly `k` proposed tokens, or None if no n-gram matches.
pub fn lookup_proposal(history: &[u32], k: usize) -> Option<Vec<u32>> {
    for n in (2..=3.min(history.len())).rev() {
        let suffix = &history[history.len() - n..];
        // most recent earlier occurrence
        let mut best: Option<usize> = None;
        if history.len() < n + 1 {
            continue;
        }
        for start in 0..history.len() - n {
            if &history[start..start + n] == suffix {
                best = Some(start);
            }
        }
        if let Some(start) = best {
            let cont = start + n;
            let avail = history.len() - n - start; // tokens after the match
            if avail == 0 {
                continue;
            }
            let mut prop: Vec<u32> = Vec::with_capacity(k);
            for i in 0..k {
                // wrap by repeating the last available token if the match
                // runs into the suffix itself
                let idx = cont + i;
                if idx < history.len() - n {
                    prop.push(history[idx]);
                } else {
                    prop.push(*history.get(idx).unwrap_or(history.last().unwrap()));
                }
            }
            return Some(prop);
        }
    }
    None
}

impl Engine for PldEngine {
    fn name(&self) -> &'static str {
        "pld"
    }

    fn generate(&mut self, prompt: &[u32], max_new: usize) -> Result<GenResult> {
        let t0 = Instant::now();
        let (mut ts, first, _hl) = TargetSeq::start(
            self.rt.clone(),
            "prefill_full",
            "target_step",
            Some("target_verify_block"),
            prompt,
        )?;
        let prefill_ns = t0.elapsed().as_nanos() as u64;
        let mut result = GenResult {
            tokens: vec![first],
            prefill_ns,
            ..Default::default()
        };

        let k = self.k_spec;
        let td = Instant::now();
        while result.tokens.len() < max_new
            && !truncate_at_eos(&mut result.tokens)
            && ts.has_capacity(k + 1)
        {
            let tdraft = Instant::now();
            // Lookup over the *full* committed history except the pending
            // feed token (which is the anchor of the suffix).
            let proposal = lookup_proposal(ts.tokens(), k);
            let draft_ns = tdraft.elapsed().as_nanos() as u64;

            match proposal {
                Some(props) => {
                    let tver = Instant::now();
                    let (outcome, _hl) = ts.verify_chain(&props)?;
                    result.tokens.extend_from_slice(&outcome.committed);
                    result.steps.push(StepRecord {
                        drafted: k,
                        accepted: outcome.accepted,
                        committed: outcome.total_committed(),
                        draft_ns,
                        verify_ns: tver.elapsed().as_nanos() as u64,
                    });
                }
                None => {
                    let tver = Instant::now();
                    let (tok, _) = ts.ar_step()?;
                    result.tokens.push(tok);
                    result.steps.push(StepRecord {
                        drafted: 0,
                        accepted: 0,
                        committed: 1,
                        draft_ns,
                        verify_ns: tver.elapsed().as_nanos() as u64,
                    });
                }
            }
        }
        truncate_at_eos(&mut result.tokens);
        result.tokens.truncate(max_new);
        result.decode_ns = td.elapsed().as_nanos() as u64;
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::lookup_proposal;

    #[test]
    fn finds_repeat() {
        // history: a b c d a b -> suffix [a b] matched at 0, proposes c d ..
        let h = [10, 11, 12, 13, 10, 11];
        let p = lookup_proposal(&h, 2).unwrap();
        assert_eq!(p, vec![12, 13]);
    }

    #[test]
    fn prefers_trigram() {
        // trigram suffix [b c d] matches earlier; bigram would match elsewhere
        let h = [11, 12, 13, 99, 12, 13, 50, 11, 12, 13];
        let p = lookup_proposal(&h, 1).unwrap();
        // trigram [11 12 13] matched at 0 -> next token 99
        assert_eq!(p, vec![99]);
    }

    #[test]
    fn no_match() {
        assert!(lookup_proposal(&[1, 2, 3, 4, 5], 2).is_none());
        assert!(lookup_proposal(&[1], 2).is_none());
        assert!(lookup_proposal(&[], 2).is_none());
    }

    #[test]
    fn most_recent_match_wins() {
        let h = [7, 8, 100, 7, 8, 200, 7, 8];
        let p = lookup_proposal(&h, 1).unwrap();
        assert_eq!(p, vec![200]); // later occurrence preferred
    }
}
