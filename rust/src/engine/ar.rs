//! Greedy autoregressive baseline — the reference point every speedup in
//! Table 2 is measured against, and the losslessness oracle for the
//! speculative engines (they must emit byte-identical token streams).
//!
//! The prefill/step loop lives in [`crate::sched::seq::ArSeq`], the same
//! resumable state machine the continuous-batching scheduler multiplexes;
//! this engine just drives one sequence serially.

use std::sync::Arc;

use anyhow::Result;

use crate::runtime::Runtime;
use crate::sched::seq::{ArCtx, ArSeq};

use super::{Engine, GenResult};

pub struct ArEngine {
    ctx: Arc<ArCtx>,
    /// Sequential placement key per generation (sharded backends pin
    /// each sequence's KV to one executor by it).
    next_key: u64,
}

impl ArEngine {
    pub fn new(rt: Arc<Runtime>) -> Result<ArEngine> {
        Ok(ArEngine { ctx: Arc::new(ArCtx::new(rt)?), next_key: 0 })
    }
}

impl Engine for ArEngine {
    fn name(&self) -> &'static str {
        "ar"
    }

    fn generate(&mut self, prompt: &[u32], max_new: usize) -> Result<GenResult> {
        let key = self.next_key;
        self.next_key += 1;
        let mut seq = ArSeq::new(self.ctx.clone(), prompt, max_new, key)?;
        while !seq.is_done() {
            let call = seq.next_call()?;
            let out = call.artifact.call(&call.kv, &call.inputs)?;
            seq.apply(out)?;
        }
        Ok(seq.into_result())
    }
}
