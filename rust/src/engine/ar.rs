//! Greedy autoregressive baseline — the reference point every speedup in
//! Table 2 is measured against, and the losslessness oracle for the
//! speculative engines (they must emit byte-identical token streams).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::Runtime;

use super::{truncate_at_eos, Engine, GenResult, StepRecord, TargetSeq};

pub struct ArEngine {
    rt: Arc<Runtime>,
}

impl ArEngine {
    pub fn new(rt: Arc<Runtime>) -> ArEngine {
        ArEngine { rt }
    }
}

impl Engine for ArEngine {
    fn name(&self) -> &'static str {
        "ar"
    }

    fn generate(&mut self, prompt: &[u32], max_new: usize) -> Result<GenResult> {
        let t0 = Instant::now();
        let (mut ts, first, _hl) = TargetSeq::start(
            self.rt.clone(), "prefill_full", "target_step", None, prompt)?;
        let prefill_ns = t0.elapsed().as_nanos() as u64;

        let mut result = GenResult {
            tokens: vec![first],
            prefill_ns,
            ..Default::default()
        };
        let td = Instant::now();
        while result.tokens.len() < max_new
            && !truncate_at_eos(&mut result.tokens)
            && ts.has_capacity(1)
        {
            let ts0 = Instant::now();
            let (tok, _hl) = ts.ar_step()?;
            result.tokens.push(tok);
            result.steps.push(StepRecord {
                drafted: 0,
                accepted: 0,
                committed: 1,
                draft_ns: 0,
                verify_ns: ts0.elapsed().as_nanos() as u64,
            });
        }
        truncate_at_eos(&mut result.tokens);
        result.decode_ns = td.elapsed().as_nanos() as u64;
        Ok(result)
    }
}
