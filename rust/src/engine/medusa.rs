//! Medusa & Hydra: multi-head drafting over the target's h_L features.
//!
//! Medusa (Cai et al.): 4 time-independent MLP heads over h_L propose the
//! next 4 positions; the chain is verified by the target in one block.
//! Hydra (Ankner et al.): sequentially-dependent heads — head k consumes
//! the embedding of the token proposed by head k-1, improving chain
//! coherence (higher MAT than Medusa at equal budget, as in Table 2).
//!
//! Both use *sequence* (chain) verification here — the paper evaluates
//! DVI under single-sequence verification, and Spec-Bench normalizes
//! methods into one harness; tree attention is out of scope (DESIGN.md).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::{Artifact, Runtime, Tensor};
use crate::util::math::argmax;

use super::{truncate_at_eos, Engine, GenResult, StepRecord, TargetSeq};

pub struct MedusaEngine {
    rt: Arc<Runtime>,
    heads: Arc<Artifact>,
    pub k_spec: usize,
}

impl MedusaEngine {
    pub fn new(rt: Arc<Runtime>) -> Result<MedusaEngine> {
        Ok(MedusaEngine {
            heads: rt.artifact("medusa_heads")?,
            k_spec: rt.manifest.spec_usize("k_spec")?,
            rt,
        })
    }
}

impl Engine for MedusaEngine {
    fn name(&self) -> &'static str {
        "medusa"
    }

    fn generate(&mut self, prompt: &[u32], max_new: usize) -> Result<GenResult> {
        let t0 = Instant::now();
        let (mut ts, first, mut hl) = TargetSeq::start(
            self.rt.clone(),
            "prefill_full",
            "target_step",
            Some("target_verify_block"),
            prompt,
        )?;
        let prefill_ns = t0.elapsed().as_nanos() as u64;
        let mut result = GenResult {
            tokens: vec![first],
            prefill_ns,
            ..Default::default()
        };

        let k = self.k_spec;
        let d = hl.len();
        let td = Instant::now();
        while result.tokens.len() < max_new
            && !truncate_at_eos(&mut result.tokens)
            && ts.has_capacity(k + 1)
        {
            let tdraft = Instant::now();
            let out = self.heads.call(
                &[],
                &[Tensor::f32(vec![d], hl.clone())],
            )?;
            // head i proposes the token i+1 positions after the pending feed
            let logits = &out.outputs[0];
            let proposals: Vec<u32> = (0..k)
                .map(|i| Ok(argmax(logits.row_f32(i)?) as u32))
                .collect::<Result<_>>()?;
            let draft_ns = tdraft.elapsed().as_nanos() as u64;

            let tver = Instant::now();
            let (outcome, new_hl) = ts.verify_chain(&proposals)?;
            hl = new_hl;
            result.tokens.extend_from_slice(&outcome.committed);
            result.steps.push(StepRecord {
                drafted: k,
                accepted: outcome.accepted,
                committed: outcome.total_committed(),
                draft_ns,
                verify_ns: tver.elapsed().as_nanos() as u64,
            });
        }
        truncate_at_eos(&mut result.tokens);
        result.tokens.truncate(max_new);
        result.decode_ns = td.elapsed().as_nanos() as u64;
        Ok(result)
    }
}

pub struct HydraEngine {
    rt: Arc<Runtime>,
    chain: Arc<Artifact>,
    pub k_spec: usize,
}

impl HydraEngine {
    pub fn new(rt: Arc<Runtime>) -> Result<HydraEngine> {
        Ok(HydraEngine {
            chain: rt.artifact("hydra_chain")?,
            k_spec: rt.manifest.spec_usize("k_spec")?,
            rt,
        })
    }
}

impl Engine for HydraEngine {
    fn name(&self) -> &'static str {
        "hydra"
    }

    fn generate(&mut self, prompt: &[u32], max_new: usize) -> Result<GenResult> {
        let t0 = Instant::now();
        let (mut ts, first, mut hl) = TargetSeq::start(
            self.rt.clone(),
            "prefill_full",
            "target_step",
            Some("target_verify_block"),
            prompt,
        )?;
        let prefill_ns = t0.elapsed().as_nanos() as u64;
        let mut result = GenResult {
            tokens: vec![first],
            prefill_ns,
            ..Default::default()
        };

        let k = self.k_spec;
        let d = hl.len();
        let td = Instant::now();
        while result.tokens.len() < max_new
            && !truncate_at_eos(&mut result.tokens)
            && ts.has_capacity(k + 1)
        {
            let tdraft = Instant::now();
            // Sequentially-dependent chain: the artifact consumes the
            // pending feed token and rolls the head state inside HLO.
            let (feed_tok, _pos) = ts.seq.feed();
            let out = self.chain.call(
                &[],
                &[
                    Tensor::f32(vec![d], hl.clone()),
                    Tensor::scalar_i32(feed_tok as i32),
                ],
            )?;
            let proposals: Vec<u32> = out.outputs[0]
                .as_i32()?
                .iter()
                .map(|&t| t as u32)
                .collect();
            let draft_ns = tdraft.elapsed().as_nanos() as u64;

            let tver = Instant::now();
            let (outcome, new_hl) = ts.verify_chain(&proposals[..k])?;
            hl = new_hl;
            result.tokens.extend_from_slice(&outcome.committed);
            result.steps.push(StepRecord {
                drafted: k,
                accepted: outcome.accepted,
                committed: outcome.total_committed(),
                draft_ns,
                verify_ns: tver.elapsed().as_nanos() as u64,
            });
        }
        truncate_at_eos(&mut result.tokens);
        result.tokens.truncate(max_new);
        result.decode_ns = td.elapsed().as_nanos() as u64;
        Ok(result)
    }
}
