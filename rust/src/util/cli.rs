//! Tiny CLI argument parser (offline environment: no `clap`).
//!
//! Grammar: `dvi <subcommand> [--flag] [--key value] [positional ...]`.
//! `--key=value` is also accepted. Unknown keys are an error (listed
//! against the declared option set) so typos fail fast.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse argv (without argv[0]). `flag_names` lists valueless flags;
    /// everything else starting with `--` expects a value.
    pub fn parse(argv: &[String], flag_names: &[&str]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("--{body} expects a value"))?;
                    out.options.insert(body.to_string(), v.clone());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects a number, got '{v}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn basic() {
        let a = Args::parse(&argv("serve --port 8000 --verbose x y"),
                            &["verbose"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("port"), Some("8000"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["x", "y"]);
    }

    #[test]
    fn eq_form() {
        let a = Args::parse(&argv("bench --steps=100"), &[]).unwrap();
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
    }

    #[test]
    fn missing_value() {
        assert!(Args::parse(&argv("run --port"), &[]).is_err());
    }

    #[test]
    fn bad_number() {
        let a = Args::parse(&argv("run --n xyz"), &[]).unwrap();
        assert!(a.get_usize("n", 1).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv("run"), &[]).unwrap();
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
        assert_eq!(a.get_or("name", "x"), "x");
        assert!(!a.flag("v"));
    }
}
