//! Deterministic PRNG (xoshiro256**, SplitMix64 seeding) — offline
//! environment has no `rand` crate. Used by workload generators, the
//! replay buffer's minibatch sampler, and the property-test harness.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.usize_below(i + 1));
        }
    }

    /// Fork an independent child stream (for per-worker determinism).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        let mut acc = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            acc += x;
        }
        let mean = acc / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.usize_below(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_independent() {
        let mut r = Rng::new(6);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
