//! Mini property-based testing harness (offline environment: no proptest).
//!
//! `run_prop(name, cases, |rng| { ... })` executes the closure `cases`
//! times with independent deterministic RNG streams. On failure the seed
//! is printed so the case can be replayed with `replay_prop`.
//!
//! This intentionally skips shrinking — generators below are built to
//! produce small cases with reasonable probability instead (the standard
//! trade-off for a shrinking-free harness).

use super::rng::Rng;

pub const DEFAULT_CASES: usize = 256;

/// Run a property. Panics (with the failing seed) on the first failure.
pub fn run_prop<F: FnMut(&mut Rng)>(name: &str, cases: usize, mut f: F) {
    for case in 0..cases {
        let seed = 0xD5_1000 + case as u64;
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || f(&mut rng),
        ));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {case} (seed {seed})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay_prop<F: FnMut(&mut Rng)>(seed: u64, mut f: F) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

// ----------------------------------------------------------------------------
// Generators
// ----------------------------------------------------------------------------

/// Small usize, biased toward tiny values (p(0) ~ 1/4).
pub fn small_usize(rng: &mut Rng, max: usize) -> usize {
    let shaped = rng.f64().powi(2); // bias low
    (shaped * (max as f64 + 1.0)) as usize
}

pub fn vec_f32(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| (rng.normal() as f32) * scale).collect()
}

pub fn vec_u32_below(rng: &mut Rng, len: usize, bound: u32) -> Vec<u32> {
    (0..len).map(|_| rng.below(bound as u64) as u32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn props_run_all_cases() {
        let mut count = 0;
        run_prop("counter", 100, |_| count += 1);
        assert_eq!(count, 100);
    }

    #[test]
    #[should_panic]
    fn props_report_failure() {
        run_prop("fails", 50, |rng| {
            let x = rng.usize_below(100);
            assert!(x < 95, "found {x}");
        });
    }

    #[test]
    fn generators_in_bounds() {
        run_prop("gen-bounds", 100, |rng| {
            assert!(small_usize(rng, 10) <= 10);
            let v = vec_u32_below(rng, 8, 5);
            assert!(v.iter().all(|&x| x < 5));
        });
    }
}
