//! ASCII line plots for terminal reports (Figure 2 learning curves).

/// Render series of (x, y) points as a fixed-size ASCII chart.
pub fn ascii_plot(title: &str, series: &[(&str, &[(f64, f64)])],
                  width: usize, height: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!("  {title}\n"));
    let pts: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|(_, s)| s.iter().copied())
        .collect();
    if pts.is_empty() {
        out.push_str("  (no data)\n");
        return out;
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    let marks = ['*', '+', 'o', 'x', '#', '@'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, s)) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        for &(x, y) in s.iter() {
            let cx = ((x - x0) / (x1 - x0) * (width - 1) as f64).round() as usize;
            let cy = ((y - y0) / (y1 - y0) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx.min(width - 1)] = mark;
        }
    }
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            format!("{y1:8.3}")
        } else if r == height - 1 {
            format!("{y0:8.3}")
        } else {
            " ".repeat(8)
        };
        out.push_str(&format!("{label} |{}|\n", row.iter().collect::<String>()));
    }
    out.push_str(&format!(
        "{:>8}  {x0:<12.1}{:>w$.1}\n",
        "", x1, w = width.saturating_sub(12)
    ));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("    {} = {name}\n", marks[si % marks.len()]));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_without_panic() {
        let s1: Vec<(f64, f64)> = (0..50).map(|i| (i as f64, (i as f64).sin())).collect();
        let s2: Vec<(f64, f64)> = (0..50).map(|i| (i as f64, 0.5)).collect();
        let out = ascii_plot("test", &[("sin", &s1), ("flat", &s2)], 60, 12);
        assert!(out.contains("test"));
        assert!(out.lines().count() > 12);
    }

    #[test]
    fn empty_series() {
        let out = ascii_plot("empty", &[("none", &[])], 40, 8);
        assert!(out.contains("no data"));
    }

    #[test]
    fn constant_series_no_div_by_zero() {
        let s: Vec<(f64, f64)> = (0..5).map(|i| (i as f64, 1.0)).collect();
        let _ = ascii_plot("const", &[("c", &s)], 40, 8);
    }
}
