//! Minimal JSON parser/writer (offline environment: no serde).
//!
//! Supports the full JSON grammar minus exotic number forms; used for
//! `artifacts/manifest.json`, `vocab.json`, config files, and metric
//! reports. Numbers parse into f64 (the manifest only carries shapes and
//! names, well within f64-exactness).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj.get("a").get("b")` style access; returns Null on any miss.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.into(), offset: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // surrogate pairs
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            self.i -= 1; // compensated below
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain utf-8 bytes
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ----------------------------------------------------------------------------
// Writer
// ----------------------------------------------------------------------------

pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write!(f, "{}", escape(s)),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", escape(k), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Convenience builder for writer-side code.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e3 ").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b").as_str(), Some("x"));
        assert!(v.get("c").is_null());
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""Aé""#).unwrap(),
                   Json::Str("Aé".into()));
        // surrogate pair (emoji)
        assert_eq!(Json::parse(r#""😀""#).unwrap(),
                   Json::Str("😀".into()));
    }

    #[test]
    fn reject_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn escape_control_chars() {
        let v = Json::Str("a\u{1}b".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
