//! In-tree utility layer. The offline build environment carries no
//! third-party crates beyond `xla`/`anyhow`, so JSON, PRNG, CLI parsing,
//! property testing, plotting, and math helpers live here.

pub mod cli;
pub mod json;
pub mod math;
pub mod plot;
pub mod prop;
pub mod rng;
