//! Small f32 vector helpers used on the coordinator hot path
//! (argmax/softmax over the 512-entry vocabulary, reward baselines).

/// Index of the maximum element; first occurrence wins on ties (matches
/// XLA/jnp argmax semantics so Rust-side greedy == artifact-side greedy).
pub fn argmax(xs: &[f32]) -> usize {
    debug_assert!(!xs.is_empty());
    let mut best = 0;
    let mut best_v = xs[0];
    for (i, &v) in xs.iter().enumerate().skip(1) {
        if v > best_v {
            best = i;
            best_v = v;
        }
    }
    best
}

/// Numerically-stable in-place softmax.
pub fn softmax_inplace(xs: &mut [f32]) {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    for x in xs.iter_mut() {
        *x /= sum;
    }
}

/// log-sum-exp of a slice.
pub fn logsumexp(xs: &[f32]) -> f32 {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if m == f32::NEG_INFINITY {
        return m;
    }
    m + xs.iter().map(|&x| (x - m).exp()).sum::<f32>().ln()
}

/// Exponential moving average tracker (the PG baseline `b` in §3.4).
#[derive(Debug, Clone)]
pub struct Ema {
    pub value: f64,
    pub alpha: f64,
    initialized: bool,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Ema { value: 0.0, alpha, initialized: false }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        if self.initialized {
            self.value = self.alpha * self.value + (1.0 - self.alpha) * x;
        } else {
            self.value = x;
            self.initialized = true;
        }
        self.value
    }
}

/// Online mean/min/max/count accumulator for metrics.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn add(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        self.sum += x;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;

    #[test]
    fn argmax_first_tie() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[-2.0, -1.0, -3.0]), 1);
    }

    #[test]
    fn prop_argmax_is_maximal_and_first() {
        // argmax returns an index holding the maximum, and on ties the
        // FIRST such index — the XLA/jnp convention the engines rely on
        // for coordinator-side greedy == in-graph greedy.
        run_prop("argmax-first-max", 512, |rng| {
            let n = 1 + rng.usize_below(12);
            // Tiny value set forces frequent ties.
            let xs: Vec<f32> = (0..n)
                .map(|_| rng.usize_below(3) as f32)
                .collect();
            let i = argmax(&xs);
            assert!(xs.iter().all(|&x| x <= xs[i]), "not maximal: {xs:?}");
            assert!(
                xs[..i].iter().all(|&x| x < xs[i]),
                "tie not broken toward first index: {xs:?} -> {i}"
            );
        });
    }

    #[test]
    fn prop_argmax_invariant_under_positive_shift() {
        // Shifting all logits by a constant never changes the winner
        // (softmax/greedy equivalence used throughout the engines).
        run_prop("argmax-shift", 256, |rng| {
            let n = 1 + rng.usize_below(10);
            let xs: Vec<f32> = (0..n)
                .map(|_| (rng.normal() as f32 * 2.0 * 8.0).round() / 8.0)
                .collect();
            let shift = rng.normal() as f32;
            let shifted: Vec<f32> = xs.iter().map(|x| x + shift).collect();
            assert_eq!(argmax(&xs), argmax(&shifted));
        });
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut v = vec![1.0f32, 2.0, 3.0, 4.0];
        softmax_inplace(&mut v);
        let s: f32 = v.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(v[3] > v[2] && v[2] > v[1]);
    }

    #[test]
    fn softmax_handles_large_values() {
        let mut v = vec![1000.0f32, 1000.0, 999.0];
        softmax_inplace(&mut v);
        assert!(v.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn lse_matches_naive() {
        let v = [0.1f32, 0.2, 0.3];
        let naive = v.iter().map(|x| x.exp()).sum::<f32>().ln();
        assert!((logsumexp(&v) - naive).abs() < 1e-6);
    }

    #[test]
    fn ema_tracks() {
        let mut e = Ema::new(0.9);
        assert_eq!(e.update(1.0), 1.0); // first sample initializes
        let v = e.update(0.0);
        assert!((v - 0.9).abs() < 1e-12);
    }

    #[test]
    fn stats_basic() {
        let mut s = Stats::default();
        for x in [1.0, 2.0, 3.0] {
            s.add(x);
        }
        assert_eq!(s.n, 3);
        assert_eq!(s.mean(), 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }
}
