//! Experiment harness: everything needed to regenerate the paper's
//! tables and figures (DESIGN.md §Experiment-index).
//!
//! * [`make_engine`] — engine factory by method name.
//! * [`run_task`] — run one engine over one task's prompt set.
//! * [`online_train`] — the DVI online-learning loop (one optimizer step
//!   per streamed prompt, mirroring the paper's 2,000 prompts / 2,000
//!   steps budget).
//! * [`table1`] / [`table2`] / [`table3`] / [`fig2`] — the paper's
//!   Table 1/2/3 and Figure 2 regenerators.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::engine::{
    ar::ArEngine, dvi::DviEngine, eagle::EagleEngine, medusa::MedusaEngine,
    medusa::HydraEngine, pld::PldEngine, sps::SpsEngine, Engine,
};
use crate::learner::{Objective, ReplayBuffer, Schedule, Trainer};
use crate::metrics::report::{csv_table2, render_table2, render_table3};
use crate::metrics::RunMetrics;
use crate::runtime::{log, Runtime};
use crate::workload::{PromptSet, TASK_NAMES};

pub const METHODS: [&str; 7] =
    ["eagle", "hydra", "medusa", "pld", "sps", "dvi", "ar"];

pub fn make_engine(rt: Arc<Runtime>, name: &str) -> Result<Box<dyn Engine + Send>> {
    Ok(match name {
        "ar" => Box::new(ArEngine::new(rt)?),
        "dvi" => Box::new(DviEngine::new(rt)?),
        "pld" => Box::new(PldEngine::new(rt)?),
        "sps" => Box::new(SpsEngine::new(rt)?),
        "medusa" => Box::new(MedusaEngine::new(rt)?),
        "hydra" => Box::new(HydraEngine::new(rt)?),
        "eagle" => Box::new(EagleEngine::new(rt)?),
        other => bail!("unknown method '{other}'"),
    })
}

/// Run `engine` over the first `n` prompts of `set`.
pub fn run_task(
    engine: &mut dyn Engine,
    set: &PromptSet,
    n: usize,
) -> Result<RunMetrics> {
    let mut m = RunMetrics::default();
    for s in set.samples.iter().take(n) {
        let r = engine.generate(&s.prompt, s.max_new)?;
        m.add(&r);
    }
    Ok(m)
}

/// Load the prompt set for a task name: synthesized in-memory sets on
/// the reference backend, `prompts/*.bin` files on PJRT artifact dirs.
pub fn load_prompts(rt: &Runtime, task: &str) -> Result<PromptSet> {
    if let Some(set) = rt.synthetic_prompts(task) {
        return Ok(set.clone());
    }
    let path = rt
        .manifest
        .prompts
        .get(task)
        .ok_or_else(|| anyhow::anyhow!("no prompt set '{task}'"))?;
    PromptSet::load(path)
}

// ----------------------------------------------------------------------------
// Online training (the "Improve" loop)
// ----------------------------------------------------------------------------

pub struct OnlineRunReport {
    pub trainer_steps: u64,
    pub prompts_seen: usize,
    /// (step, batch acceptance) learning curve (Fig. 2).
    pub curve: Vec<(f64, f64)>,
    /// Rolling engine-side acceptance (per prompt).
    pub engine_accept: Vec<(f64, f64)>,
}

/// Stream `n_prompts` prompts through a DVI engine with online updates:
/// after each prompt, run exactly one optimizer step once the buffer has
/// a full batch (paper: 2,000 prompts -> 2,000 steps, each prompt seen
/// once). Resets LoRA/Adam first so runs are independent.
pub fn online_train(
    rt: Arc<Runtime>,
    objective: Objective,
    n_prompts: usize,
    quiet: bool,
) -> Result<OnlineRunReport> {
    let stream = load_prompts(&rt, "stream")?;
    anyhow::ensure!(
        stream.len() >= n_prompts,
        "stream has {} prompts, wanted {n_prompts}",
        stream.len()
    );
    let buffer = Arc::new(Mutex::new(ReplayBuffer::new(8192)));
    let mut trainer = Trainer::new(
        rt.clone(), buffer.clone(), Schedule::new(objective), 0xD5EED)?;
    trainer.reset()?;
    let mut engine = DviEngine::new(rt)?.with_buffer(buffer);

    let mut engine_accept = Vec::new();
    for (i, s) in stream.samples.iter().take(n_prompts).enumerate() {
        let r = engine.generate(&s.prompt, s.max_new)?;
        engine_accept.push((i as f64, r.acceptance_rate()));
        trainer.maybe_train()?;
        if !quiet && (i + 1) % 100 == 0 {
            let recent: f64 = engine_accept
                [engine_accept.len().saturating_sub(100)..]
                .iter()
                .map(|(_, a)| a)
                .sum::<f64>()
                / 100.0;
            log::info(&format!(
                "online[{}] prompt {}/{} accept(last100)={:.3} steps={}",
                objective.name(), i + 1, n_prompts, recent,
                trainer.steps_done
            ));
        }
    }
    Ok(OnlineRunReport {
        trainer_steps: trainer.steps_done,
        prompts_seen: n_prompts,
        curve: trainer.accept_curve(),
        engine_accept,
    })
}

// ----------------------------------------------------------------------------
// Table 2 — Spec-Bench grid
// ----------------------------------------------------------------------------

pub struct Table2Result {
    pub results: BTreeMap<(String, String), RunMetrics>,
    pub markdown: String,
    pub csv: String,
}

/// Run `methods` x all six tasks, `n` prompts each. Assumes any online
/// training for DVI already happened (call [`online_train`] first).
pub fn table2(
    rt: Arc<Runtime>,
    methods: &[&str],
    n: usize,
) -> Result<Table2Result> {
    let mut results = BTreeMap::new();
    for m in methods {
        let mut engine = make_engine(rt.clone(), m)?;
        for task in TASK_NAMES {
            let set = load_prompts(&rt, task)?;
            let metrics = run_task(engine.as_mut(), &set, n)?;
            log::info(&format!(
                "table2 {m}/{task}: mat={:.2} tok/s={:.1}",
                metrics.mat.mean(),
                metrics.tokens_per_sec()
            ));
            results.insert((m.to_string(), task.to_string()), metrics);
        }
    }
    let tasks: Vec<&str> = TASK_NAMES.to_vec();
    let markdown = render_table2(&tasks, methods, &results, "ar");
    let csv = csv_table2(&tasks, methods, &results, "ar");
    Ok(Table2Result { results, markdown, csv })
}

// ----------------------------------------------------------------------------
// Table 3 + Figure 2 — objective ablations
// ----------------------------------------------------------------------------

pub struct AblationResult {
    pub objective: Objective,
    pub curve: Vec<(f64, f64)>,
    pub mat: f64,
    pub speedup: f64,
}

/// For each objective: fresh LoRA -> online train on the stream -> eval
/// MAT + speedup on the Spec-Bench grid (averaged over tasks).
pub fn ablations(
    rt: Arc<Runtime>,
    objectives: &[Objective],
    train_prompts: usize,
    eval_n: usize,
) -> Result<Vec<AblationResult>> {
    // AR baseline once (shared denominator).
    let mut ar = make_engine(rt.clone(), "ar")?;
    let mut ar_by_task = BTreeMap::new();
    for task in TASK_NAMES {
        let set = load_prompts(&rt, task)?;
        ar_by_task.insert(task, run_task(ar.as_mut(), &set, eval_n)?);
    }

    let mut out = Vec::new();
    for &obj in objectives {
        let report = online_train(rt.clone(), obj, train_prompts, false)?;
        let mut engine = DviEngine::new(rt.clone())?;
        let mut mats = Vec::new();
        let mut speedups = Vec::new();
        for task in TASK_NAMES {
            let set = load_prompts(&rt, task)?;
            let m = run_task(&mut engine, &set, eval_n)?;
            mats.push(m.mat.mean());
            speedups.push(m.speedup_vs(&ar_by_task[task]));
        }
        let mat = mats.iter().sum::<f64>() / mats.len() as f64;
        let speedup = speedups.iter().sum::<f64>() / speedups.len() as f64;
        log::info(&format!(
            "ablation {}: MAT={mat:.3} speedup={speedup:.3}x",
            obj.name()
        ));
        out.push(AblationResult { objective: obj, curve: report.curve, mat, speedup });
    }
    Ok(out)
}

pub fn table3_markdown(results: &[AblationResult]) -> String {
    let rows: Vec<(String, f64, f64)> = results
        .iter()
        .map(|r| (r.objective.name().to_string(), r.mat, r.speedup))
        .collect();
    render_table3(&rows)
}

// ----------------------------------------------------------------------------
// Table 1 — training budgets
// ----------------------------------------------------------------------------

/// Budget table: our measured numbers next to the paper's reported ones.
pub fn table1(rt: &Runtime, dvi_prompts: usize) -> String {
    let mut out = String::from(
        "| Method | Prompt exposures (ours) | Optimiser steps (ours) | \
         Paper exposures | Paper relative budget |\n|---|---|---|---|---|\n",
    );
    out.push_str(&format!(
        "| DVI (online) | {dvi_prompts} | {dvi_prompts} | 2,000 | 1x |\n"
    ));
    let paper: &[(&str, &str, &str, &str)] = &[
        ("med", "Medusa", "120,000", "~60x more"),
        ("sps", "SpS drafter (KD)", "n/a (external drafter)", "-"),
        ("hy", "Hydra", "120,000", "~60x more"),
        ("ea", "EAGLE", "2,400,000", "~1,200x more"),
    ];
    for (key, label, pexp, prel) in paper {
        let exp = rt.manifest.exposures.get(key);
        let (ours_e, ours_s) = if exp.is_null() {
            ("-".to_string(), "-".to_string())
        } else {
            (
                format!("{}", exp.get("prompt_exposures").as_usize().unwrap_or(0)),
                format!("{}", exp.get("optimiser_steps").as_usize().unwrap_or(0)),
            )
        };
        out.push_str(&format!(
            "| {label} (offline) | {ours_e} | {ours_s} | {pexp} | {prel} |\n"
        ));
    }
    out
}
