//! Trainer: assembles minibatches from the replay buffer and invokes the
//! AOT `train_step` artifact. The artifact updates the LoRA/Adam `global`
//! buffers in the shared store, so the DVI engine's next `draft_step`
//! immediately decodes with the improved drafter — inference and training
//! interleave exactly as at serve time (minimal train/serve skew, §3.3).

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::obs::{metrics, trace};
use crate::runtime::{Artifact, Runtime, Tensor};
use crate::util::math::Ema;
use crate::util::rng::Rng;

use super::buffer::ReplayBuffer;
use super::schedule::Schedule;

/// Metrics vector layout mirrors python/compile/train.py.
#[derive(Debug, Clone, Copy, Default)]
pub struct TrainMetrics {
    pub step: u64,
    pub total: f32,
    pub l_pg: f32,
    pub l_kl: f32,
    pub l_ce: f32,
    pub l_ent: f32,
    pub l_rl: f32,
    /// Fraction of the minibatch's tuples that were accepted (the paper's
    /// "batch acceptance rate", Fig. 2 y-axis).
    pub batch_accept: f32,
    pub grad_norm: f32,
}

pub struct Trainer {
    rt: Arc<Runtime>,
    train_step: Arc<Artifact>,
    pub buffer: Arc<Mutex<ReplayBuffer>>,
    pub schedule: Schedule,
    baseline: Ema,
    rng: Rng,
    pub steps_done: u64,
    pub batch_size: usize,
    d_model: usize,
    vocab: usize,
    /// Learning-curve log: one entry per optimizer step.
    pub curve: Vec<TrainMetrics>,
    /// Wall time of the most recent optimizer step (observation-only).
    pub last_step_ns: u64,
    m_step: metrics::HistHandle,
}

impl Trainer {
    pub fn new(
        rt: Arc<Runtime>,
        buffer: Arc<Mutex<ReplayBuffer>>,
        schedule: Schedule,
        seed: u64,
    ) -> Result<Trainer> {
        let train_step = rt.artifact("train_step")?;
        let batch_size = rt.manifest.train_f64("batch_size")? as usize;
        let d_model = rt.manifest.model_usize("d_model")?;
        let vocab = rt.manifest.model_usize("vocab_size")?;
        Ok(Trainer {
            rt,
            train_step,
            buffer,
            schedule,
            baseline: Ema::new(0.9),
            rng: Rng::new(seed),
            steps_done: 0,
            batch_size,
            d_model,
            vocab,
            curve: Vec::new(),
            last_step_ns: 0,
            m_step: metrics::hist("learner.train_step_ns"),
        })
    }

    /// Reset LoRA + Adam global buffers to their initial values (fresh
    /// drafter) and clear progress. Used between ablation runs.
    pub fn reset(&mut self) -> Result<()> {
        for name in ["lora.A", "lora.B", "adam.mA", "adam.vA", "adam.mB", "adam.vB"] {
            self.rt.reset_global(name)?;
        }
        self.steps_done = 0;
        self.curve.clear();
        self.baseline = Ema::new(0.9);
        self.buffer.lock().unwrap().clear();
        Ok(())
    }

    pub fn can_train(&self) -> bool {
        self.buffer.lock().unwrap().len() >= self.batch_size
    }

    /// One optimizer step if the buffer holds a full batch.
    pub fn maybe_train(&mut self) -> Result<Option<TrainMetrics>> {
        if !self.can_train() {
            return Ok(None);
        }
        let n = self.batch_size;
        let (mut hk, mut actions, mut logits_phi, mut rewards, mask);
        let batch_reward_mean;
        {
            let buf = self.buffer.lock().unwrap();
            let batch = buf.sample(n, &mut self.rng);
            hk = Vec::with_capacity(n * self.d_model);
            actions = Vec::with_capacity(n);
            logits_phi = Vec::with_capacity(n * self.vocab);
            rewards = Vec::with_capacity(n);
            mask = vec![1.0f32; n];
            for t in &batch {
                debug_assert_eq!(t.hk.len(), self.d_model);
                debug_assert_eq!(t.logits_phi.len(), self.vocab);
                hk.extend_from_slice(&t.hk);
                actions.push(t.action as i32);
                logits_phi.extend_from_slice(&t.logits_phi);
                rewards.push(t.reward);
            }
            batch_reward_mean =
                rewards.iter().map(|&r| r as f64).sum::<f64>() / n as f64;
        }

        // EMA baseline uses rewards *before* this step (paper: EMA of
        // recent rewards as the variance-reduction baseline b).
        let b = self.baseline.value as f32;
        self.baseline.update(batch_reward_mean);

        let hyper = self.schedule.hyper(self.steps_done, b);
        let t0_ns = trace::now_ns();
        let out = self.train_step.call(
            &[],
            &[
                Tensor::f32(vec![n, self.d_model], hk),
                Tensor::i32(vec![n], actions),
                Tensor::f32(vec![n, self.vocab], logits_phi),
                Tensor::f32(vec![n], rewards),
                Tensor::f32(vec![n], mask),
                Tensor::f32(vec![8], hyper.to_vec()),
            ],
        )?;
        let step_ns = trace::now_ns().saturating_sub(t0_ns);
        self.last_step_ns = step_ns;
        self.m_step.observe(step_ns);
        if trace::enabled() {
            trace::complete_with_dur(
                "learner.train_step",
                "learner",
                step_ns,
                vec![("step", trace::Arg::I(self.steps_done as i64))],
            );
        }
        let m = out.outputs[0].as_f32()?;
        let metrics = TrainMetrics {
            step: self.steps_done,
            total: m[0],
            l_pg: m[1],
            l_kl: m[2],
            l_ce: m[3],
            l_ent: m[4],
            l_rl: m[5],
            batch_accept: m[6],
            grad_norm: m[7],
        };
        self.steps_done += 1;
        self.curve.push(metrics);
        Ok(Some(metrics))
    }

    /// Learning curve as (step, batch_accept) points for Fig. 2.
    pub fn accept_curve(&self) -> Vec<(f64, f64)> {
        self.curve
            .iter()
            .map(|m| (m.step as f64, m.batch_accept as f64))
            .collect()
    }
}
