//! Online learner: converts verifier accept/reject feedback into LoRA
//! draft-head updates (the "Improve" of Draft, Verify, & Improve).
//!
//! * `buffer` — the online replay buffer of per-position tuples
//!   (h_k, action, verifier logits, reward) logged by the DVI engine.
//! * `schedule` — the KL->RL annealing schedule (paper §3.4) plus the
//!   single-term ablation variants (KL-only / PG-only / CE-only).
//! * `trainer` — samples minibatches, assembles the hyper vector, and
//!   invokes the AOT `train_step` artifact (loss + grads + Adam fused);
//!   the LoRA/Adam `global` buffers update in place, so the very next
//!   `draft_step` call decodes with the improved drafter.

pub mod buffer;
pub mod schedule;
pub mod trainer;

pub use buffer::{ReplayBuffer, Tuple};
pub use schedule::{Objective, Schedule};
pub use trainer::{TrainMetrics, Trainer};
