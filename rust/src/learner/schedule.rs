//! The KL->RL annealing schedule (paper §3.4) and the single-term
//! ablation objectives of §4.3.
//!
//! Paper's piecewise weights over optimizer steps t:
//!
//!   (lam_pg, lam_kl)(t) =
//!     (0, lam0)                                   t <  T_warmup
//!     (ramp * lam_pg_max,
//!      lam0 - ramp * (lam0 - lam_kl_min))         during the ramp,
//!                      ramp = (t - T_warmup) / T_ramp
//!     (lam_pg_max, lam_kl_min)                    after
//!
//! The on-policy REINFORCE weight w_rl follows the same gate as lam_pg
//! (zero through warmup, ramped in), and its KL companion beta(t) is the
//! annealed lam_kl itself — the schedule "gently decays to retain
//! calibration" exactly as §3.4 prescribes.

/// Which objective variant drives training (§4.3 ablations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Full DVI: KL warmup -> reward-masked CE + on-policy PG.
    Dvi,
    /// Online distillation only.
    KlOnly,
    /// On-policy REINFORCE only.
    PgOnly,
    /// Reward-masked cross-entropy only.
    CeOnly,
}

impl Objective {
    pub fn parse(s: &str) -> Option<Objective> {
        Some(match s {
            "dvi" | "full" => Objective::Dvi,
            "kl" | "kl-only" => Objective::KlOnly,
            "pg" | "pg-only" => Objective::PgOnly,
            "ce" | "ce-only" => Objective::CeOnly,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Objective::Dvi => "dvi",
            Objective::KlOnly => "kl-only",
            Objective::PgOnly => "pg-only",
            Objective::CeOnly => "ce-only",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Schedule {
    pub objective: Objective,
    pub t_warmup: u64,
    pub t_ramp: u64,
    pub lam0: f32,
    pub lam_kl_min: f32,
    pub lam_pg_max: f32,
    pub w_ce: f32,
    pub w_ent: f32,
    pub w_rl: f32,
    pub lr: f32,
}

/// The 8-slot hyper vector consumed by the `train_step` artifact
/// (layout documented in python/compile/train.py).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hyper {
    pub lam_pg: f32,
    pub lam_kl: f32,
    pub w_ce: f32,
    pub w_ent: f32,
    pub w_rl: f32,
    pub baseline: f32,
    pub lr: f32,
    pub step: f32,
}

impl Hyper {
    pub fn to_vec(self) -> Vec<f32> {
        vec![
            self.lam_pg, self.lam_kl, self.w_ce, self.w_ent,
            self.w_rl, self.baseline, self.lr, self.step,
        ]
    }
}

impl Schedule {
    pub fn new(objective: Objective) -> Schedule {
        Schedule {
            objective,
            t_warmup: 300,
            t_ramp: 600,
            lam0: 1.0,
            lam_kl_min: 0.2,
            lam_pg_max: 1.0,
            w_ce: 0.5,
            w_ent: 0.01,
            w_rl: 0.5,
            // Calibrated against the offline KD ceiling experiment
            // (EXPERIMENTS.md §Calibration): 3e-3 reaches the rank-64
            // agreement ceiling within the paper's 2k-step budget.
            lr: 3e-3,
        }
    }

    /// Schedule phase at step `t`: 0 = KL warmup, 1 = ramp, 2 = RL.
    pub fn phase_index(&self, t: u64) -> u64 {
        if t < self.t_warmup {
            0
        } else if t < self.t_warmup + self.t_ramp {
            1
        } else {
            2
        }
    }

    /// Human-readable name of [`Schedule::phase_index`].
    pub fn phase_name(&self, t: u64) -> &'static str {
        match self.phase_index(t) {
            0 => "warmup",
            1 => "ramp",
            _ => "rl",
        }
    }

    /// Ramp fraction in [0, 1].
    fn ramp(&self, t: u64) -> f32 {
        if t < self.t_warmup {
            0.0
        } else {
            (((t - self.t_warmup) as f32) / self.t_ramp.max(1) as f32).min(1.0)
        }
    }

    /// Hyper vector for optimizer step `t` (0-based) with EMA baseline `b`.
    /// The artifact's Adam bias correction uses step+1.
    pub fn hyper(&self, t: u64, baseline: f32) -> Hyper {
        let r = self.ramp(t);
        let (lam_pg, lam_kl, w_ce, w_ent, w_rl) = match self.objective {
            Objective::Dvi => (
                r * self.lam_pg_max,
                self.lam0 - r * (self.lam0 - self.lam_kl_min),
                r * self.w_ce,
                self.w_ent,
                r * self.w_rl,
            ),
            Objective::KlOnly => (0.0, self.lam0, 0.0, 0.0, 0.0),
            Objective::PgOnly => (0.0, 0.0, 0.0, 0.0, self.w_rl + self.lam_pg_max),
            Objective::CeOnly => (self.lam_pg_max, 0.0, 0.0, 0.0, 0.0),
        };
        Hyper {
            lam_pg, lam_kl, w_ce, w_ent, w_rl,
            baseline,
            lr: self.lr,
            step: (t + 1) as f32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_is_kl_only() {
        let s = Schedule::new(Objective::Dvi);
        let h = s.hyper(0, 0.0);
        assert_eq!(h.lam_pg, 0.0);
        assert_eq!(h.lam_kl, s.lam0);
        assert_eq!(h.w_rl, 0.0);
    }

    #[test]
    fn ramp_interpolates() {
        let s = Schedule::new(Objective::Dvi);
        let h = s.hyper(s.t_warmup + s.t_ramp / 2, 0.0);
        assert!((h.lam_pg - 0.5 * s.lam_pg_max).abs() < 1e-6);
        let expect_kl = s.lam0 - 0.5 * (s.lam0 - s.lam_kl_min);
        assert!((h.lam_kl - expect_kl).abs() < 1e-6);
    }

    #[test]
    fn after_ramp_saturates() {
        let s = Schedule::new(Objective::Dvi);
        let h = s.hyper(10_000, 0.0);
        assert_eq!(h.lam_pg, s.lam_pg_max);
        assert!((h.lam_kl - s.lam_kl_min).abs() < 1e-6);
    }

    #[test]
    fn monotone_schedule() {
        let s = Schedule::new(Objective::Dvi);
        let mut prev = s.hyper(0, 0.0);
        for t in 1..2000 {
            let h = s.hyper(t, 0.0);
            assert!(h.lam_pg >= prev.lam_pg);
            assert!(h.lam_kl <= prev.lam_kl);
            prev = h;
        }
    }

    #[test]
    fn ablations_single_term() {
        let kl = Schedule::new(Objective::KlOnly).hyper(5000, 0.0);
        assert!(kl.lam_pg == 0.0 && kl.w_rl == 0.0 && kl.w_ce == 0.0);
        assert!(kl.lam_kl > 0.0);

        let pg = Schedule::new(Objective::PgOnly).hyper(0, 0.0);
        assert!(pg.lam_kl == 0.0 && pg.lam_pg == 0.0 && pg.w_ce == 0.0);
        assert!(pg.w_rl > 0.0);

        let ce = Schedule::new(Objective::CeOnly).hyper(0, 0.0);
        assert!(ce.lam_kl == 0.0 && ce.w_rl == 0.0);
        assert!(ce.lam_pg > 0.0);
    }

    #[test]
    fn step_is_one_based() {
        let s = Schedule::new(Objective::Dvi);
        assert_eq!(s.hyper(0, 0.0).step, 1.0);
    }

    #[test]
    fn transition_fires_at_configured_step() {
        // The KL->RL phase transition must track the *configured*
        // t_warmup/t_ramp, not the defaults.
        let mut s = Schedule::new(Objective::Dvi);
        s.t_warmup = 10;
        s.t_ramp = 20;

        // Through the whole warmup (t < t_warmup) AND at exactly
        // t_warmup (ramp fraction 0): pure KL, no PG/RL/CE.
        for t in 0..=s.t_warmup {
            let h = s.hyper(t, 0.0);
            assert_eq!(h.lam_pg, 0.0, "PG leaked into warmup at t={t}");
            assert_eq!(h.w_rl, 0.0, "RL leaked into warmup at t={t}");
            assert_eq!(h.w_ce, 0.0, "CE leaked into warmup at t={t}");
            assert_eq!(h.lam_kl, s.lam0, "KL decayed during warmup at t={t}");
        }
        // The very next step the ramp engages: PG/RL become positive
        // and KL starts decaying.
        let h = s.hyper(s.t_warmup + 1, 0.0);
        assert!(h.lam_pg > 0.0, "PG did not fire after warmup");
        assert!(h.w_rl > 0.0, "RL did not fire after warmup");
        assert!(h.lam_kl < s.lam0, "KL did not start decaying");
        // And saturation happens exactly at t_warmup + t_ramp.
        let end = s.hyper(s.t_warmup + s.t_ramp, 0.0);
        assert_eq!(end.lam_pg, s.lam_pg_max);
        assert!((end.lam_kl - s.lam_kl_min).abs() < 1e-6);
        let before_end = s.hyper(s.t_warmup + s.t_ramp - 1, 0.0);
        assert!(before_end.lam_pg < s.lam_pg_max);
    }

    #[test]
    fn baseline_and_lr_pass_through() {
        let s = Schedule::new(Objective::Dvi);
        let h = s.hyper(123, 0.73);
        assert_eq!(h.baseline, 0.73);
        assert_eq!(h.lr, s.lr);
        assert_eq!(h.step, 124.0);
    }
}
