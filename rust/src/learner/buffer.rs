//! Online replay buffer (paper §3.3).
//!
//! One tuple per drafted position up to and including the first reject:
//! (h_k, action, verifier logits, r). Positions past the first reject are
//! counterfactual — the engine never logs them, and this module's tests
//! assert the invariant on the engine's behalf (reward pattern 1..1 0?).

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Tuple {
    /// Raw residual stream at the split layer (length d_model).
    pub hk: Vec<f32>,
    /// The drafted token id.
    pub action: u32,
    /// Frozen verifier logits at the same position (length vocab).
    pub logits_phi: Vec<f32>,
    /// 1.0 accepted, 0.0 first reject.
    pub reward: f32,
}

/// Fixed-capacity ring buffer with recency-biased sampling: the paper's
/// update mixes fresh on-policy tuples (the policy-gradient term) with
/// replayed ones (KD calibration), so minibatches draw half from the
/// newest entries and half uniformly.
pub struct ReplayBuffer {
    data: Vec<Tuple>,
    capacity: usize,
    head: usize,
    /// Monotone count of tuples ever pushed.
    pub pushed: u64,
}

impl ReplayBuffer {
    pub fn new(capacity: usize) -> ReplayBuffer {
        assert!(capacity > 0);
        ReplayBuffer { data: Vec::with_capacity(capacity), capacity, head: 0, pushed: 0 }
    }

    pub fn push(&mut self, t: Tuple) {
        debug_assert!(t.reward == 0.0 || t.reward == 1.0);
        if self.data.len() < self.capacity {
            self.data.push(t);
        } else {
            self.data[self.head] = t;
            self.head = (self.head + 1) % self.capacity;
        }
        self.pushed += 1;
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Index of the i-th most recent tuple (i = 0 -> newest).
    fn recent_idx(&self, i: usize) -> usize {
        debug_assert!(i < self.data.len());
        if self.data.len() < self.capacity {
            self.data.len() - 1 - i
        } else {
            (self.head + self.capacity - 1 - i) % self.capacity
        }
    }

    /// Sample a minibatch: ceil(n/2) newest tuples + uniform remainder.
    pub fn sample(&self, n: usize, rng: &mut Rng) -> Vec<&Tuple> {
        assert!(self.len() >= n, "buffer {} < batch {}", self.len(), n);
        let n_recent = (n + 1) / 2;
        let mut out = Vec::with_capacity(n);
        for i in 0..n_recent {
            out.push(&self.data[self.recent_idx(i)]);
        }
        for _ in n_recent..n {
            out.push(&self.data[rng.usize_below(self.data.len())]);
        }
        out
    }

    /// Mean reward currently stored (diagnostic; the EMA baseline uses
    /// per-batch values from the trainer instead).
    pub fn mean_reward(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|t| t.reward as f64).sum::<f64>()
            / self.data.len() as f64
    }

    pub fn clear(&mut self) {
        self.data.clear();
        self.head = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;

    fn tup(action: u32, reward: f32) -> Tuple {
        Tuple { hk: vec![0.0; 4], action, logits_phi: vec![0.0; 8], reward }
    }

    #[test]
    fn push_and_wrap() {
        let mut b = ReplayBuffer::new(3);
        for i in 0..5 {
            b.push(tup(i, 1.0));
        }
        assert_eq!(b.len(), 3);
        assert_eq!(b.pushed, 5);
        // newest is action 4
        assert_eq!(b.data[b.recent_idx(0)].action, 4);
        assert_eq!(b.data[b.recent_idx(2)].action, 2);
    }

    #[test]
    fn sample_mixes_recent() {
        let mut b = ReplayBuffer::new(100);
        for i in 0..50 {
            b.push(tup(i, 1.0));
        }
        let mut rng = Rng::new(0);
        let batch = b.sample(8, &mut rng);
        assert_eq!(batch.len(), 8);
        // first half must be the newest tuples in order
        assert_eq!(batch[0].action, 49);
        assert_eq!(batch[3].action, 46);
    }

    #[test]
    #[should_panic]
    fn sample_underflow_panics() {
        let b = ReplayBuffer::new(10);
        let mut rng = Rng::new(0);
        b.sample(1, &mut rng);
    }

    #[test]
    fn prop_recent_indexing_consistent() {
        run_prop("buffer-recent", 256, |rng| {
            let cap = 1 + rng.usize_below(20);
            let mut b = ReplayBuffer::new(cap);
            let n = rng.usize_below(60);
            for i in 0..n {
                b.push(tup(i as u32, 0.0));
            }
            if b.len() > 0 {
                // newest tuple is always the last pushed
                assert_eq!(b.data[b.recent_idx(0)].action as usize, n - 1);
                // oldest stored = n - len
                assert_eq!(
                    b.data[b.recent_idx(b.len() - 1)].action as usize,
                    n - b.len()
                );
            }
        });
    }

    #[test]
    fn mean_reward() {
        let mut b = ReplayBuffer::new(4);
        b.push(tup(0, 1.0));
        b.push(tup(1, 0.0));
        assert_eq!(b.mean_reward(), 0.5);
    }

    #[test]
    fn capacity_eviction_drops_oldest_only() {
        let mut b = ReplayBuffer::new(4);
        for i in 0..7 {
            b.push(tup(i, 1.0));
        }
        assert_eq!(b.len(), 4);
        // Survivors are exactly the 4 newest, in recency order 6,5,4,3.
        let actions: Vec<u32> =
            (0..4).map(|i| b.data[b.recent_idx(i)].action).collect();
        assert_eq!(actions, vec![6, 5, 4, 3]);
    }

    #[test]
    fn pushed_is_monotone_and_survives_clear() {
        let mut b = ReplayBuffer::new(3);
        let mut prev = b.pushed;
        for i in 0..10 {
            b.push(tup(i, 0.0));
            assert!(b.pushed > prev, "pushed must strictly increase");
            prev = b.pushed;
        }
        assert_eq!(b.pushed, 10);
        // clear() empties storage but keeps the monotone counter: the
        // learner's freshness gate depends on it never going backwards.
        b.clear();
        assert_eq!(b.len(), 0);
        assert_eq!(b.pushed, 10);
        b.push(tup(99, 1.0));
        assert_eq!(b.pushed, 11);
    }

    #[test]
    fn prop_pushed_monotone_under_any_op_sequence() {
        run_prop("buffer-pushed-monotone", 128, |rng| {
            let mut b = ReplayBuffer::new(1 + rng.usize_below(8));
            let mut prev = 0u64;
            for i in 0..rng.usize_below(40) {
                if rng.bool(0.2) {
                    b.clear();
                } else {
                    b.push(tup(i as u32, if rng.bool(0.5) { 1.0 } else { 0.0 }));
                }
                assert!(b.pushed >= prev);
                assert!(b.len() <= b.capacity);
                prev = b.pushed;
            }
        });
    }

    #[test]
    fn mean_reward_on_mixed_batches() {
        let mut b = ReplayBuffer::new(8);
        assert_eq!(b.mean_reward(), 0.0); // empty buffer is defined as 0
        for i in 0..6 {
            b.push(tup(i, if i % 3 == 0 { 1.0 } else { 0.0 }));
        }
        // rewards: 1,0,0,1,0,0 -> mean 2/6
        assert!((b.mean_reward() - 2.0 / 6.0).abs() < 1e-12);
        // Eviction shifts the mean to the surviving window.
        for i in 6..10 {
            b.push(tup(i, 1.0)); // evicts 0,1 (rewards 1,0)
        }
        // survivors: 2..9 -> rewards 0,1,0,0,1,1,1,1 -> 5/8
        assert!((b.mean_reward() - 5.0 / 8.0).abs() < 1e-12);
    }
}
