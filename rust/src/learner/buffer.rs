//! Online replay buffer (paper §3.3).
//!
//! One tuple per drafted position up to and including the first reject:
//! (h_k, action, verifier logits, r). Positions past the first reject are
//! counterfactual — the engine never logs them, and this module's tests
//! assert the invariant on the engine's behalf (reward pattern 1..1 0?).

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Tuple {
    /// Raw residual stream at the split layer (length d_model).
    pub hk: Vec<f32>,
    /// The drafted token id.
    pub action: u32,
    /// Frozen verifier logits at the same position (length vocab).
    pub logits_phi: Vec<f32>,
    /// 1.0 accepted, 0.0 first reject.
    pub reward: f32,
}

/// Fixed-capacity ring buffer with recency-biased sampling: the paper's
/// update mixes fresh on-policy tuples (the policy-gradient term) with
/// replayed ones (KD calibration), so minibatches draw half from the
/// newest entries and half uniformly.
pub struct ReplayBuffer {
    data: Vec<Tuple>,
    capacity: usize,
    head: usize,
    /// **Lifetime** count of tuples ever pushed — deliberately monotone
    /// across [`ReplayBuffer::clear`], because the online learner's
    /// freshness gate compares successive readings and must never see
    /// the counter go backwards. Per-epoch diagnostics should read
    /// [`ReplayBuffer::pushed_since_clear`] instead.
    pub pushed: u64,
    /// Tuples pushed since the last [`ReplayBuffer::clear`] (or
    /// construction). Reset by `clear`, so post-clear diagnostics don't
    /// over-report by the pre-clear lifetime total.
    pub pushed_since_clear: u64,
}

impl ReplayBuffer {
    pub fn new(capacity: usize) -> ReplayBuffer {
        assert!(capacity > 0);
        ReplayBuffer {
            data: Vec::with_capacity(capacity),
            capacity,
            head: 0,
            pushed: 0,
            pushed_since_clear: 0,
        }
    }

    pub fn push(&mut self, t: Tuple) {
        debug_assert!(t.reward == 0.0 || t.reward == 1.0);
        if self.data.len() < self.capacity {
            self.data.push(t);
        } else {
            self.data[self.head] = t;
            self.head = (self.head + 1) % self.capacity;
        }
        self.pushed += 1;
        self.pushed_since_clear += 1;
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Index of the i-th most recent tuple (i = 0 -> newest).
    fn recent_idx(&self, i: usize) -> usize {
        debug_assert!(i < self.data.len());
        if self.data.len() < self.capacity {
            self.data.len() - 1 - i
        } else {
            (self.head + self.capacity - 1 - i) % self.capacity
        }
    }

    /// Sample a minibatch: the ceil(n/2) newest tuples, plus a uniform
    /// remainder drawn **without replacement from outside the recency
    /// half**. Drawing the remainder from the whole buffer would let
    /// the newest tuples appear twice in one minibatch, double-weighting
    /// the freshest accept/reject signals in the update — so a batch
    /// never contains the same stored tuple twice (property-tested).
    pub fn sample(&self, n: usize, rng: &mut Rng) -> Vec<&Tuple> {
        assert!(self.len() >= n, "buffer {} < batch {}", self.len(), n);
        let n_recent = (n + 1) / 2;
        let mut out = Vec::with_capacity(n);
        for i in 0..n_recent {
            out.push(&self.data[self.recent_idx(i)]);
        }
        // Floyd's algorithm over the older region: a uniform k-subset
        // of the recency ranks [n_recent, len) in O(k) draws and O(k^2)
        // membership checks on a small k — no O(len) allocation while
        // the serving path contends on the buffer lock.
        let k = n - n_recent;
        let older = self.data.len() - n_recent;
        let mut picked: Vec<usize> = Vec::with_capacity(k);
        for i in older - k..older {
            let j = rng.usize_below(i + 1);
            let choice = if picked.contains(&j) { i } else { j };
            picked.push(choice);
        }
        for off in picked {
            out.push(&self.data[self.recent_idx(n_recent + off)]);
        }
        out
    }

    /// Mean reward currently stored (diagnostic; the EMA baseline uses
    /// per-batch values from the trainer instead).
    pub fn mean_reward(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|t| t.reward as f64).sum::<f64>()
            / self.data.len() as f64
    }

    /// Empty the stored tuples. `pushed` keeps its lifetime semantic
    /// (see its doc — the learner's freshness gate relies on
    /// monotonicity); `pushed_since_clear` resets to zero.
    pub fn clear(&mut self) {
        self.data.clear();
        self.head = 0;
        self.pushed_since_clear = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;

    fn tup(action: u32, reward: f32) -> Tuple {
        Tuple { hk: vec![0.0; 4], action, logits_phi: vec![0.0; 8], reward }
    }

    #[test]
    fn push_and_wrap() {
        let mut b = ReplayBuffer::new(3);
        for i in 0..5 {
            b.push(tup(i, 1.0));
        }
        assert_eq!(b.len(), 3);
        assert_eq!(b.pushed, 5);
        // newest is action 4
        assert_eq!(b.data[b.recent_idx(0)].action, 4);
        assert_eq!(b.data[b.recent_idx(2)].action, 2);
    }

    #[test]
    fn sample_mixes_recent() {
        let mut b = ReplayBuffer::new(100);
        for i in 0..50 {
            b.push(tup(i, 1.0));
        }
        let mut rng = Rng::new(0);
        let batch = b.sample(8, &mut rng);
        assert_eq!(batch.len(), 8);
        // first half must be the newest tuples in order
        assert_eq!(batch[0].action, 49);
        assert_eq!(batch[3].action, 46);
    }

    #[test]
    #[should_panic]
    fn sample_underflow_panics() {
        let b = ReplayBuffer::new(10);
        let mut rng = Rng::new(0);
        b.sample(1, &mut rng);
    }

    #[test]
    fn prop_recent_indexing_consistent() {
        run_prop("buffer-recent", 256, |rng| {
            let cap = 1 + rng.usize_below(20);
            let mut b = ReplayBuffer::new(cap);
            let n = rng.usize_below(60);
            for i in 0..n {
                b.push(tup(i as u32, 0.0));
            }
            if b.len() > 0 {
                // newest tuple is always the last pushed
                assert_eq!(b.data[b.recent_idx(0)].action as usize, n - 1);
                // oldest stored = n - len
                assert_eq!(
                    b.data[b.recent_idx(b.len() - 1)].action as usize,
                    n - b.len()
                );
            }
        });
    }

    #[test]
    fn mean_reward() {
        let mut b = ReplayBuffer::new(4);
        b.push(tup(0, 1.0));
        b.push(tup(1, 0.0));
        assert_eq!(b.mean_reward(), 0.5);
    }

    #[test]
    fn capacity_eviction_drops_oldest_only() {
        let mut b = ReplayBuffer::new(4);
        for i in 0..7 {
            b.push(tup(i, 1.0));
        }
        assert_eq!(b.len(), 4);
        // Survivors are exactly the 4 newest, in recency order 6,5,4,3.
        let actions: Vec<u32> =
            (0..4).map(|i| b.data[b.recent_idx(i)].action).collect();
        assert_eq!(actions, vec![6, 5, 4, 3]);
    }

    #[test]
    fn pushed_is_monotone_and_survives_clear() {
        let mut b = ReplayBuffer::new(3);
        let mut prev = b.pushed;
        for i in 0..10 {
            b.push(tup(i, 0.0));
            assert!(b.pushed > prev, "pushed must strictly increase");
            prev = b.pushed;
        }
        assert_eq!(b.pushed, 10);
        // clear() empties storage but keeps the monotone counter: the
        // learner's freshness gate depends on it never going backwards.
        b.clear();
        assert_eq!(b.len(), 0);
        assert_eq!(b.pushed, 10);
        b.push(tup(99, 1.0));
        assert_eq!(b.pushed, 11);
    }

    /// Regression: per-epoch diagnostics read `pushed_since_clear`,
    /// which must reset on clear() while `pushed` stays lifetime.
    #[test]
    fn pushed_since_clear_resets_on_clear() {
        let mut b = ReplayBuffer::new(4);
        for i in 0..6 {
            b.push(tup(i, 1.0));
        }
        assert_eq!(b.pushed, 6);
        assert_eq!(b.pushed_since_clear, 6);
        b.clear();
        assert_eq!(b.pushed_since_clear, 0, "clear must reset the epoch count");
        assert_eq!(b.pushed, 6, "clear must not rewind the lifetime count");
        b.push(tup(9, 0.0));
        b.push(tup(10, 0.0));
        assert_eq!(b.pushed_since_clear, 2);
        assert_eq!(b.pushed, 8);
    }

    #[test]
    fn prop_pushed_monotone_under_any_op_sequence() {
        run_prop("buffer-pushed-monotone", 128, |rng| {
            let mut b = ReplayBuffer::new(1 + rng.usize_below(8));
            let mut prev = 0u64;
            for i in 0..rng.usize_below(40) {
                if rng.bool(0.2) {
                    b.clear();
                } else {
                    b.push(tup(i as u32, if rng.bool(0.5) { 1.0 } else { 0.0 }));
                }
                assert!(b.pushed >= prev);
                assert!(b.pushed_since_clear <= b.pushed);
                // Everything stored arrived after the last clear.
                assert!(b.len() as u64 <= b.pushed_since_clear);
                assert!(b.len() <= b.capacity);
                prev = b.pushed;
            }
        });
    }

    /// Regression: the uniform remainder must come from OUTSIDE the
    /// recency half. Pre-fix, it was drawn from the whole buffer, so a
    /// newest tuple could appear twice in one minibatch (double-weighting
    /// fresh signals) — with 64 independent draws below, that happened
    /// with overwhelming probability.
    #[test]
    fn sample_remainder_excludes_recency_half() {
        let mut b = ReplayBuffer::new(100);
        for i in 0..40 {
            b.push(tup(i, 1.0)); // action == push index, all distinct
        }
        run_prop("sample-remainder-older-only", 64, |rng| {
            let batch = b.sample(8, rng);
            // recency half: the 4 newest, in order
            let recent: Vec<u32> = batch[..4].iter().map(|t| t.action).collect();
            assert_eq!(recent, vec![39, 38, 37, 36]);
            for t in &batch[4..] {
                assert!(
                    t.action < 36,
                    "remainder drew tuple {} from the recency half",
                    t.action
                );
            }
        });
    }

    /// A minibatch never contains the same stored tuple twice — the
    /// recency half is distinct by construction and the remainder is
    /// drawn without replacement from the older region.
    #[test]
    fn prop_sample_has_no_duplicates() {
        run_prop("sample-no-duplicates", 128, |rng| {
            let cap = 2 + rng.usize_below(24);
            let mut b = ReplayBuffer::new(cap);
            let pushes = 1 + rng.usize_below(3 * cap);
            for i in 0..pushes {
                b.push(tup(i as u32, 0.0));
            }
            let n = 1 + rng.usize_below(b.len());
            let batch = b.sample(n, rng);
            assert_eq!(batch.len(), n);
            let mut ptrs: Vec<*const Tuple> =
                batch.iter().map(|t| *t as *const Tuple).collect();
            ptrs.sort_unstable();
            ptrs.dedup();
            assert_eq!(ptrs.len(), n, "duplicate tuple in one minibatch");
            // The recency half is the newest ceil(n/2), newest first.
            let newest = b.data[b.recent_idx(0)].action;
            for (i, t) in batch[..(n + 1) / 2].iter().enumerate() {
                assert_eq!(t.action, newest - i as u32);
            }
        });
    }

    #[test]
    fn mean_reward_on_mixed_batches() {
        let mut b = ReplayBuffer::new(8);
        assert_eq!(b.mean_reward(), 0.0); // empty buffer is defined as 0
        for i in 0..6 {
            b.push(tup(i, if i % 3 == 0 { 1.0 } else { 0.0 }));
        }
        // rewards: 1,0,0,1,0,0 -> mean 2/6
        assert!((b.mean_reward() - 2.0 / 6.0).abs() < 1e-12);
        // Eviction shifts the mean to the surviving window.
        for i in 6..10 {
            b.push(tup(i, 1.0)); // evicts 0,1 (rewards 1,0)
        }
        // survivors: 2..9 -> rewards 0,1,0,0,1,1,1,1 -> 5/8
        assert!((b.mean_reward() - 5.0 / 8.0).abs() < 1e-12);
    }
}
