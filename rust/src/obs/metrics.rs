//! Process-wide metrics registry: counters, gauges, and fixed-bucket
//! log-scale histograms with quantile estimation.
//!
//! Design constraints, in order:
//!
//!   1. **Observation-only.** Recording a sample touches nothing but the
//!      metric's own atomics — no RNG, no model state, no control flow
//!      in the instrumented code — so instrumented streams are bitwise
//!      identical to uninstrumented ones (the repo-wide losslessness
//!      gate, asserted in `tests/obs.rs`).
//!   2. **Lock-free hot path.** Handles are `Arc`s to atomic storage;
//!      the registry mutex is taken only at get-or-create and snapshot
//!      time. Call sites that record per-round (`sched/seq.rs`) cache
//!      their handles at construction.
//!   3. **Mergeable.** Every snapshot is elementwise-additive, so
//!      per-shard histograms merge into fleet aggregates (associative
//!      and commutative; property-tested).
//!
//! Histogram buckets are log-scale with [`SUB_BUCKETS`] linear
//! sub-buckets per power-of-two octave: bucket widths are base/8 of the
//! octave base, so a reported quantile over-estimates the true sample
//! by at most 12.5% (plus one integer step). Values are plain `u64`s;
//! by convention duration metrics carry a `_ns` name suffix.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::json::escape;

/// Linear sub-buckets per power-of-two octave. 8 keeps the relative
/// quantile error ≤ 1/8 while the whole bucket array stays 4 KiB.
pub const SUB_BUCKETS: usize = 8;
/// One octave per possible `u64` leading bit position.
pub const OCTAVES: usize = 64;
/// Total bucket count (512).
pub const BUCKETS: usize = OCTAVES * SUB_BUCKETS;

/// Bucket index for a sample. 0 maps with 1 into bucket 0; otherwise
/// the octave is the leading-bit position and the sub-bucket is the
/// linear position of the remainder within the octave.
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        return 0;
    }
    let o = 63 - v.leading_zeros() as usize;
    let base = 1u64 << o;
    // (v - base) * SUB / 2^o, widened so the multiply cannot overflow.
    let sub = (((v - base) as u128 * SUB_BUCKETS as u128) >> o) as usize;
    o * SUB_BUCKETS + sub
}

/// Smallest value that maps at or above bucket `idx` (the bucket's
/// inclusive lower bound, modulo the empty buckets in low octaves).
pub fn bucket_lower(idx: usize) -> u64 {
    let o = idx / SUB_BUCKETS;
    let s = (idx % SUB_BUCKETS) as u128;
    let base = 1u128 << o;
    let sub = SUB_BUCKETS as u128;
    (base + (s * base + (sub - 1)) / sub) as u64
}

/// Largest value that maps into bucket `idx` (inclusive upper bound).
/// Quantiles report this bound, so they never under-estimate.
pub fn bucket_upper(idx: usize) -> u64 {
    if idx + 1 >= BUCKETS {
        u64::MAX
    } else {
        bucket_lower(idx + 1).saturating_sub(1)
    }
}

/// Lock-free histogram. All updates are relaxed atomics: snapshots are
/// only approximately consistent across fields, which is fine for
/// observability (counts never go backwards).
pub struct Hist {
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
    buckets: Vec<AtomicU64>,
}

/// Shared handle to a registered histogram.
pub type HistHandle = Arc<Hist>;

impl Hist {
    pub fn new() -> Hist {
        Hist {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn observe(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            min: self.min.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
            buckets: self
                .buckets
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
        }
    }
}

impl Default for Hist {
    fn default() -> Hist {
        Hist::new()
    }
}

/// Point-in-time copy of a histogram; additive across shards/processes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    /// `u64::MAX` when empty (additive identity for `fetch_min`).
    pub min: u64,
    pub max: u64,
    pub buckets: Vec<u64>,
}

impl HistSnapshot {
    pub fn empty() -> HistSnapshot {
        HistSnapshot {
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
            buckets: vec![0; BUCKETS],
        }
    }

    /// Elementwise-additive merge (associative and commutative), the
    /// cross-shard aggregation primitive.
    pub fn merge(&mut self, other: &HistSnapshot) {
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += *b;
        }
    }

    /// Upper bound on the q-quantile (0 < q ≤ 1) of the recorded
    /// samples: the inclusive upper edge of the bucket holding the
    /// rank-⌈q·count⌉ sample, clamped to the observed max. Returns 0
    /// for an empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (idx, c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_upper(idx).min(self.max);
            }
        }
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Compact stable-JSON rendering (no raw bucket dump; quantiles
    /// are recomputed from the buckets at snapshot time).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"count\":{},\"sum\":{},\"mean\":{:.1},\"min\":{},\
             \"max\":{},\"p50\":{},\"p95\":{},\"p99\":{}}}",
            self.count,
            self.sum,
            self.mean(),
            if self.count == 0 { 0 } else { self.min },
            self.max,
            self.quantile(0.50),
            self.quantile(0.95),
            self.quantile(0.99),
        )
    }
}

#[derive(Clone)]
enum Slot {
    Counter(Arc<AtomicU64>),
    Gauge(Arc<AtomicI64>),
    Hist(HistHandle),
}

/// Named metric store. One process-wide instance lives behind
/// [`global`]; tests construct their own.
pub struct Registry {
    slots: Mutex<BTreeMap<String, Slot>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry { slots: Mutex::new(BTreeMap::new()) }
    }

    /// Get-or-create. Panics if `name` is already registered as a
    /// different metric kind — that is a programming error, not a
    /// runtime condition.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut g = self.slots.lock().unwrap();
        match g
            .entry(name.to_string())
            .or_insert_with(|| Slot::Counter(Arc::new(AtomicU64::new(0))))
        {
            Slot::Counter(c) => c.clone(),
            _ => panic!("metric '{name}' already registered as a non-counter"),
        }
    }

    pub fn gauge(&self, name: &str) -> Arc<AtomicI64> {
        let mut g = self.slots.lock().unwrap();
        match g
            .entry(name.to_string())
            .or_insert_with(|| Slot::Gauge(Arc::new(AtomicI64::new(0))))
        {
            Slot::Gauge(v) => v.clone(),
            _ => panic!("metric '{name}' already registered as a non-gauge"),
        }
    }

    pub fn hist(&self, name: &str) -> HistHandle {
        let mut g = self.slots.lock().unwrap();
        match g
            .entry(name.to_string())
            .or_insert_with(|| Slot::Hist(Arc::new(Hist::new())))
        {
            Slot::Hist(h) => h.clone(),
            _ => panic!("metric '{name}' already registered as a non-histogram"),
        }
    }

    pub fn snapshot(&self) -> Snapshot {
        let g = self.slots.lock().unwrap();
        let mut out = Snapshot::default();
        for (name, slot) in g.iter() {
            match slot {
                Slot::Counter(c) => {
                    out.counters
                        .insert(name.clone(), c.load(Ordering::Relaxed));
                }
                Slot::Gauge(v) => {
                    out.gauges.insert(name.clone(), v.load(Ordering::Relaxed));
                }
                Slot::Hist(h) => {
                    out.hists.insert(name.clone(), h.snapshot());
                }
            }
        }
        out
    }
}

impl Default for Registry {
    fn default() -> Registry {
        Registry::new()
    }
}

/// Point-in-time copy of a whole registry.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Snapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub hists: BTreeMap<String, HistSnapshot>,
}

impl Snapshot {
    /// Merge another snapshot in: counters and gauges add, histograms
    /// merge bucketwise. Used to aggregate per-shard registries.
    pub fn merge(&mut self, other: &Snapshot) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            *self.gauges.entry(k.clone()).or_insert(0) += v;
        }
        for (k, h) in &other.hists {
            self.hists
                .entry(k.clone())
                .and_modify(|a| a.merge(h))
                .or_insert_with(|| h.clone());
        }
    }

    /// Derive fleet-wide aggregates from per-shard metric families:
    /// every `<prefix>.s<digits><suffix>` histogram gains a merged
    /// `<prefix>.all<suffix>` entry (e.g. `rpc.verify_block.s0_ns` +
    /// `rpc.verify_block.s1_ns` → `rpc.verify_block.all_ns`), and
    /// per-shard counters (`rpc.errors.s0` + `rpc.errors.s1`) sum into
    /// the same `.all` form — a flaky shard stays attributable while
    /// dashboards keep one fleet-wide series.
    pub fn rollup_shards(&mut self) {
        fn family_key(name: &str) -> Option<String> {
            let (prefix, rest) = name.rsplit_once(".s")?;
            let digits_end =
                rest.bytes().take_while(|b| b.is_ascii_digit()).count();
            if digits_end == 0 {
                return None;
            }
            let suffix = &rest[digits_end..];
            Some(format!("{prefix}.all{suffix}"))
        }
        let mut agg: BTreeMap<String, HistSnapshot> = BTreeMap::new();
        for (name, h) in &self.hists {
            let Some(key) = family_key(name) else { continue };
            agg.entry(key)
                .and_modify(|a| a.merge(h))
                .or_insert_with(|| h.clone());
        }
        self.hists.extend(agg);
        let mut cagg: BTreeMap<String, u64> = BTreeMap::new();
        for (name, v) in &self.counters {
            let Some(key) = family_key(name) else { continue };
            *cagg.entry(key).or_insert(0) += v;
        }
        self.counters.extend(cagg);
    }

    /// Stable JSON document: keys sorted (BTreeMap order), histograms
    /// summarized to count/sum/mean/min/max/p50/p95/p99.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"counters\":{");
        for (i, (k, v)) in self.counters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("{}:{}", escape(k), v));
        }
        out.push_str("},\"gauges\":{");
        for (i, (k, v)) in self.gauges.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("{}:{}", escape(k), v));
        }
        out.push_str("},\"hists\":{");
        for (i, (k, h)) in self.hists.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("{}:{}", escape(k), h.to_json()));
        }
        out.push_str("}}");
        out
    }
}

static GLOBAL: OnceLock<Registry> = OnceLock::new();

/// The process-wide registry every instrumented subsystem records into.
pub fn global() -> &'static Registry {
    GLOBAL.get_or_init(Registry::new)
}

/// Convenience: get-or-create on the global registry.
pub fn counter(name: &str) -> Arc<AtomicU64> {
    global().counter(name)
}

pub fn gauge(name: &str) -> Arc<AtomicI64> {
    global().gauge(name)
}

pub fn hist(name: &str) -> HistHandle {
    global().hist(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_bounds_contain_their_values() {
        let samples = [
            0u64,
            1,
            2,
            3,
            7,
            8,
            100,
            1_000,
            65_535,
            65_536,
            1_000_000,
            123_456_789,
            u64::MAX / 2,
            u64::MAX,
        ];
        for &v in &samples {
            let idx = bucket_index(v);
            assert!(idx < BUCKETS, "index out of range for {v}");
            assert!(
                bucket_upper(idx) >= v,
                "upper({idx}) = {} < sample {v}",
                bucket_upper(idx)
            );
            if idx > 0 {
                assert!(
                    bucket_lower(idx) <= v,
                    "lower({idx}) = {} > sample {v}",
                    bucket_lower(idx)
                );
            }
        }
    }

    #[test]
    fn bucket_index_is_monotone() {
        let mut prev = 0usize;
        let mut v = 1u64;
        while v < u64::MAX / 3 {
            let idx = bucket_index(v);
            assert!(idx >= prev, "bucket_index not monotone at {v}");
            prev = idx;
            v = v * 3 / 2 + 1;
        }
    }

    #[test]
    fn relative_error_bounded_by_sub_bucket_width() {
        // For any sample v, the reported bucket upper bound exceeds v
        // by at most one sub-bucket width (base/8 ≤ v/8) plus rounding.
        let mut v = 8u64;
        while v < 1u64 << 60 {
            let up = bucket_upper(bucket_index(v));
            assert!(
                up <= v + v / (SUB_BUCKETS as u64) + 1,
                "upper bound {up} over-estimates {v} by more than 12.5%"
            );
            v = v * 7 / 4 + 3;
        }
    }

    #[test]
    fn quantiles_bound_the_exact_quantile() {
        let h = Hist::new();
        let vals: Vec<u64> = (1..=1000u64).map(|i| i * 37).collect();
        for &v in &vals {
            h.observe(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 1000);
        for (q, exact) in [(0.5, 500 * 37), (0.95, 950 * 37), (0.99, 990 * 37)] {
            let est = s.quantile(q);
            let exact = exact as u64;
            assert!(est >= exact, "q={q}: est {est} < exact {exact}");
            assert!(
                est <= exact + exact / 8 + 1,
                "q={q}: est {est} over-estimates {exact} beyond the bound"
            );
        }
        assert_eq!(s.quantile(1.0), *vals.last().unwrap());
    }

    #[test]
    fn empty_and_singleton_quantiles() {
        let h = Hist::new();
        assert_eq!(h.snapshot().quantile(0.5), 0);
        h.observe(42);
        let s = h.snapshot();
        assert_eq!(s.quantile(0.5), 42); // clamped to observed max
        assert_eq!(s.min, 42);
        assert_eq!(s.max, 42);
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let mk = |vals: &[u64]| {
            let h = Hist::new();
            for &v in vals {
                h.observe(v);
            }
            h.snapshot()
        };
        let a = mk(&[1, 5, 9, 1000]);
        let b = mk(&[2, 2, 70_000]);
        let c = mk(&[u64::MAX, 0, 3]);

        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);
        let mut a_bc = b.clone();
        a_bc.merge(&c);
        let mut left = a.clone();
        left.merge(&a_bc);
        assert_eq!(ab_c, left, "merge not associative");

        let mut ba = b.clone();
        ba.merge(&a);
        let mut ab = a.clone();
        ab.merge(&b);
        assert_eq!(ab, ba, "merge not commutative");
        assert_eq!(ab.count, a.count + b.count);
        assert_eq!(ab.sum, a.sum + b.sum);
    }

    #[test]
    fn merged_quantiles_match_single_histogram() {
        // Splitting a sample set across shards and merging must give
        // the same quantiles as observing everything in one histogram.
        let whole = Hist::new();
        let s0 = Hist::new();
        let s1 = Hist::new();
        for i in 0..500u64 {
            let v = i * 13 + 1;
            whole.observe(v);
            if i % 2 == 0 { &s0 } else { &s1 }.observe(v);
        }
        let mut merged = s0.snapshot();
        merged.merge(&s1.snapshot());
        assert_eq!(merged, whole.snapshot());
    }

    #[test]
    fn registry_get_or_create_and_snapshot() {
        let r = Registry::new();
        r.counter("a").fetch_add(3, Ordering::Relaxed);
        r.counter("a").fetch_add(2, Ordering::Relaxed); // same handle
        r.gauge("g").store(-7, Ordering::Relaxed);
        r.hist("h").observe(100);
        let s = r.snapshot();
        assert_eq!(s.counters["a"], 5);
        assert_eq!(s.gauges["g"], -7);
        assert_eq!(s.hists["h"].count, 1);
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn kind_mismatch_panics() {
        let r = Registry::new();
        r.counter("x");
        r.hist("x");
    }

    #[test]
    fn shard_rollup_aggregates_families() {
        let r = Registry::new();
        r.hist("rpc.verify_block.s0_ns").observe(10);
        r.hist("rpc.verify_block.s1_ns").observe(20);
        r.hist("rpc.verify_block.s1_ns").observe(30);
        r.hist("sched.queue_wait_ns").observe(5); // no shard suffix
        let mut s = r.snapshot();
        s.rollup_shards();
        let all = &s.hists["rpc.verify_block.all_ns"];
        assert_eq!(all.count, 3);
        assert_eq!(all.sum, 60);
        assert!(!s.hists.contains_key("sched.queue_wait_ns.all"));
    }

    /// Satellite regression: per-shard COUNTER families roll up too —
    /// `rpc.errors.s0` + `rpc.errors.s1` → `rpc.errors.all` — with
    /// unsuffixed counters untouched.
    #[test]
    fn shard_rollup_aggregates_counter_families() {
        let r = Registry::new();
        r.counter("rpc.errors.s0").fetch_add(2, Ordering::Relaxed);
        r.counter("rpc.errors.s1").fetch_add(3, Ordering::Relaxed);
        r.counter("sched.cache.hits").fetch_add(9, Ordering::Relaxed);
        let mut s = r.snapshot();
        s.rollup_shards();
        assert_eq!(s.counters["rpc.errors.all"], 5);
        assert_eq!(s.counters["rpc.errors.s0"], 2, "per-shard entry kept");
        assert!(!s.counters.contains_key("sched.cache.hits.all"));
    }

    #[test]
    fn snapshot_json_is_valid_and_stable() {
        use crate::util::json::Json;
        let r = Registry::new();
        r.counter("c").fetch_add(1, Ordering::Relaxed);
        r.hist("h_ns").observe(1234);
        let mut s = r.snapshot();
        s.rollup_shards();
        let doc = s.to_json();
        let j = Json::parse(&doc).expect("snapshot JSON parses");
        assert_eq!(j.get("counters").get("c").as_f64(), Some(1.0));
        assert_eq!(j.get("hists").get("h_ns").get("count").as_f64(), Some(1.0));
        assert!(j.get("hists").get("h_ns").get("p99").as_f64().unwrap() >= 1234.0);
    }
}
