//! Low-overhead structured event tracer.
//!
//! Events land in a bounded per-thread ring buffer (overwrite-oldest,
//! with a process-global drop counter so overflow is never silent) and
//! are collected by [`drain`] for export (Chrome trace JSON via
//! `obs::chrome`). When tracing is off — the default — every emit call
//! is a single relaxed atomic load and an early return, so the
//! instrumentation compiled into the serving hot paths is a near-no-op.
//!
//! Enabling: `DVI_TRACE=1` (read once per process), or programmatically
//! via [`set_forced`] (used by `serve --trace-out` and by tests, which
//! must not race on process-global env state).
//!
//! **Losslessness:** emitting is observation-only — no RNG, no model or
//! scheduler state is touched — so traced streams are bitwise identical
//! to untraced ones (asserted in `tests/obs.rs` and the `DVI_TRACE=1`
//! CI lane).

use std::sync::atomic::{AtomicI8, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Default per-thread ring capacity (events). Override with
/// `DVI_TRACE_BUF`.
pub const DEFAULT_RING_CAP: usize = 8192;

/// Structured argument value attached to an event.
#[derive(Debug, Clone, PartialEq)]
pub enum Arg {
    I(i64),
    F(f64),
    S(String),
}

/// One trace event. `ph` follows the Chrome trace-event phase codes we
/// emit: `'X'` complete (has `dur_ns`) or `'i'` instant.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub name: &'static str,
    pub cat: &'static str,
    pub ph: char,
    /// Nanoseconds since the process trace epoch.
    pub ts_ns: u64,
    /// Duration for `'X'` events; 0 for instants.
    pub dur_ns: u64,
    /// Stable per-thread track id (assigned at first emit).
    pub tid: u64,
    pub args: Vec<(&'static str, Arg)>,
}

/// An [`Event`] with owned strings and a signed timestamp: the shape a
/// trace event takes once it has crossed a process boundary. Events
/// decoded from a remote executor's `ObsDump` cannot borrow `&'static`
/// names, and clock-aligning them onto the client's trace epoch can
/// legitimately shift a timestamp below zero (an executor span that
/// started before the client process's epoch), hence `ts_ns: i64`.
#[derive(Debug, Clone, PartialEq)]
pub struct OwnedEvent {
    pub name: String,
    pub cat: String,
    pub ph: char,
    /// Nanoseconds on the *client's* trace epoch after alignment (or
    /// the origin process's epoch before it).
    pub ts_ns: i64,
    pub dur_ns: u64,
    pub tid: u64,
    pub args: Vec<(String, Arg)>,
}

impl Event {
    /// Owned copy, for export across a process boundary.
    pub fn to_owned_event(&self) -> OwnedEvent {
        OwnedEvent {
            name: self.name.to_string(),
            cat: self.cat.to_string(),
            ph: self.ph,
            ts_ns: self.ts_ns as i64,
            dur_ns: self.dur_ns,
            tid: self.tid,
            args: self
                .args
                .iter()
                .map(|(k, v)| (k.to_string(), v.clone()))
                .collect(),
        }
    }
}

static DROPPED: AtomicU64 = AtomicU64::new(0);
/// -1 = follow `DVI_TRACE`, 0 = forced off, 1 = forced on.
static FORCED: AtomicI8 = AtomicI8::new(-1);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
/// 0 = follow `DVI_TRACE_BUF` / default (applies to rings created
/// after the store; tests spawn a fresh thread to get a fresh ring).
static FORCED_RING_CAP: AtomicUsize = AtomicUsize::new(0);

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process trace epoch (first call wins).
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

fn env_enabled() -> bool {
    static ENV: OnceLock<bool> = OnceLock::new();
    *ENV.get_or_init(|| {
        matches!(
            std::env::var("DVI_TRACE").as_deref(),
            Ok("1") | Ok("true") | Ok("on")
        )
    })
}

/// Is tracing active? One relaxed load on the common (off) path.
#[inline]
pub fn enabled() -> bool {
    match FORCED.load(Ordering::Relaxed) {
        0 => false,
        1 => true,
        _ => env_enabled(),
    }
}

/// Force tracing on/off regardless of `DVI_TRACE` (`None` restores env
/// behaviour). Process-global; tests serialize around it.
pub fn set_forced(on: Option<bool>) {
    let v = match on {
        None => -1,
        Some(false) => 0,
        Some(true) => 1,
    };
    FORCED.store(v, Ordering::Relaxed);
}

/// Force the capacity of rings created *after* this call (`None`
/// restores env/default). Test hook.
pub fn set_forced_ring_cap(cap: Option<usize>) {
    FORCED_RING_CAP.store(cap.unwrap_or(0), Ordering::Relaxed);
}

fn ring_cap() -> usize {
    let forced = FORCED_RING_CAP.load(Ordering::Relaxed);
    if forced > 0 {
        return forced.max(2);
    }
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("DVI_TRACE_BUF")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n >= 2)
            .unwrap_or(DEFAULT_RING_CAP)
    })
}

/// Bounded event ring: overwrite-oldest once full, counting every
/// overwritten event in the global drop counter.
struct Ring {
    buf: Vec<Event>,
    head: usize,
    cap: usize,
    tid: u64,
}

impl Ring {
    fn push(&mut self, mut ev: Event) {
        ev.tid = self.tid;
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            DROPPED.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Remove and return the buffered events in emit order.
    fn take(&mut self) -> Vec<Event> {
        let head = self.head;
        self.head = 0;
        let mut out = std::mem::take(&mut self.buf);
        out.rotate_left(head);
        out
    }
}

/// All rings ever created, including those of exited threads (their
/// last events still export on the next drain).
fn rings() -> &'static Mutex<Vec<Arc<Mutex<Ring>>>> {
    static RINGS: OnceLock<Mutex<Vec<Arc<Mutex<Ring>>>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static LOCAL: Arc<Mutex<Ring>> = {
        let ring = Arc::new(Mutex::new(Ring {
            buf: Vec::new(),
            head: 0,
            cap: ring_cap(),
            tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        }));
        rings().lock().unwrap().push(ring.clone());
        ring
    };
}

fn emit(ev: Event) {
    LOCAL.with(|r| r.lock().unwrap().push(ev));
}

/// Emit an instant event (`ph: 'i'`) at the current time.
pub fn instant(name: &'static str, cat: &'static str, args: Vec<(&'static str, Arg)>) {
    if !enabled() {
        return;
    }
    emit(Event {
        name,
        cat,
        ph: 'i',
        ts_ns: now_ns(),
        dur_ns: 0,
        tid: 0,
        args,
    });
}

/// Emit a complete span (`ph: 'X'`) that started at `start_ns` (a prior
/// [`now_ns`] reading) and ends now.
pub fn complete(
    name: &'static str,
    cat: &'static str,
    start_ns: u64,
    args: Vec<(&'static str, Arg)>,
) {
    if !enabled() {
        return;
    }
    let now = now_ns();
    emit(Event {
        name,
        cat,
        ph: 'X',
        ts_ns: start_ns.min(now),
        dur_ns: now.saturating_sub(start_ns),
        tid: 0,
        args,
    });
}

/// Emit a complete span that ends now and lasted `dur_ns`. Lets call
/// sites that already hold an elapsed duration (e.g. `sched/seq.rs`
/// timing fields) trace without keeping a second timestamp.
pub fn complete_with_dur(
    name: &'static str,
    cat: &'static str,
    dur_ns: u64,
    args: Vec<(&'static str, Arg)>,
) {
    if !enabled() {
        return;
    }
    let now = now_ns();
    emit(Event {
        name,
        cat,
        ph: 'X',
        ts_ns: now.saturating_sub(dur_ns),
        dur_ns,
        tid: 0,
        args,
    });
}

/// Total events lost to ring overflow since process start.
pub fn drop_count() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Collect-and-clear every thread's ring, globally ordered by
/// timestamp (ties broken by track).
pub fn drain() -> Vec<Event> {
    let list: Vec<Arc<Mutex<Ring>>> = rings().lock().unwrap().clone();
    let mut out = Vec::new();
    for ring in list {
        out.append(&mut ring.lock().unwrap().take());
    }
    out.sort_by_key(|e| (e.ts_ns, e.tid));
    out
}
