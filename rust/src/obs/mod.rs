//! Observability layer: process-wide quantile metrics, structured
//! event tracing, and Chrome-trace export.
//!
//! * [`metrics`] — counters/gauges/log-bucket histograms behind a
//!   named registry; snapshots are additive across shards and render
//!   to stable JSON (the `{"metrics": true}` serve probe).
//! * [`trace`] — bounded per-thread event rings with an explicit drop
//!   counter; near-no-op unless `DVI_TRACE=1` (or forced on by
//!   `serve --trace-out`).
//! * [`chrome`] — Perfetto-loadable trace-event JSON export plus the
//!   `dvi trace-summary` reduction.
//!
//! Everything here is observation-only: with tracing and metrics on,
//! every decode stream is bitwise identical to the uninstrumented run
//! (asserted in `tests/obs.rs` and the `DVI_TRACE=1` CI lane).

pub mod chrome;
pub mod metrics;
pub mod trace;

pub use chrome::TraceSink;
pub use metrics::{HistHandle, HistSnapshot, Registry, Snapshot};
pub use trace::{Arg, Event};
