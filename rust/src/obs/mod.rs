//! Observability layer: process-wide quantile metrics, structured
//! event tracing, Chrome-trace export, and serving health.
//!
//! * [`metrics`] — counters/gauges/log-bucket histograms behind a
//!   named registry; snapshots are additive across shards and render
//!   to stable JSON (the `{"metrics": true}` serve probe).
//! * [`trace`] — bounded per-thread event rings with an explicit drop
//!   counter; near-no-op unless `DVI_TRACE=1` (or forced on by
//!   `serve --trace-out`).
//! * [`chrome`] — Perfetto-loadable trace-event JSON export (local and
//!   clock-aligned merged fleet documents) plus the `dvi trace-summary`
//!   reduction and per-shard client/server/wire decomposition.
//! * [`health`] — per-tenant latency-SLO attainment and the
//!   acceptance-EMA drift detector behind the `{"health": true}` probe.
//!
//! Everything here is observation-only: with tracing, collection, and
//! health monitoring on, every decode stream is bitwise identical to
//! the uninstrumented run (asserted in `tests/obs.rs` and the
//! `DVI_TRACE=1` CI lane).

pub mod chrome;
pub mod health;
pub mod metrics;
pub mod trace;

pub use chrome::TraceSink;
pub use health::HealthMonitor;
pub use metrics::{HistHandle, HistSnapshot, Registry, Snapshot};
pub use trace::{Arg, Event, OwnedEvent};
