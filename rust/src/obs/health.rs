//! Serving-health subsystem: per-tenant latency-SLO attainment and an
//! acceptance-EMA drift detector keyed to the learner's KL→RL phase.
//!
//! Two failure modes the raw metrics quantiles hide:
//!
//! * **SLO misses concentrated in one tenant.** Fleet-wide p95 can look
//!   healthy while a single tenant (task tag) blows its deadline on
//!   every request. The monitor tracks completions per tenant against
//!   the deadline each request carried (threaded through
//!   `Scheduler::submit_with_deadline`) and reports attainment and
//!   **SLO goodput** — tokens from in-deadline completions only.
//! * **Acceptance drift.** In DVI the draft's acceptance rate is the
//!   training-health signal: a sustained drop means the learner is
//!   regressing, not that traffic changed. The detector folds each
//!   verified round's acceptance (per-mille) into fixed-size windows,
//!   keeps a trailing baseline of healthy window means, and raises an
//!   alarm after `sustain` consecutive windows at least `drop_milli`
//!   below baseline. The learner's phase transitions (KL warmup → ramp
//!   → RL) *legitimately* change acceptance, so a phase change resets
//!   the window and baseline instead of alarming.
//!
//! Knobs: `DVI_DRIFT_WINDOW` (samples per window, default 64),
//! `DVI_DRIFT_DROP` (per-mille drop vs baseline that counts as low,
//! default 100), `DVI_DRIFT_SUSTAIN` (consecutive low windows before
//! the alarm, default 3).
//!
//! Everything here is observation-only: recording never touches model,
//! RNG, or scheduler state, so decode streams stay bitwise identical
//! with the monitor attached (asserted by the losslessness gate in
//! `tests/obs.rs`). State is mirrored to `sched.health.*` metrics so
//! snapshots and the `{"health": true}` probe agree.

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::Mutex;

use super::metrics;

/// Drift-detector tuning (see module docs for the knobs).
#[derive(Debug, Clone, Copy)]
pub struct DriftConfig {
    /// Acceptance samples folded into one window.
    pub window: usize,
    /// A window mean this many per-mille below baseline counts as low.
    pub drop_milli: u64,
    /// Consecutive low windows before the alarm raises.
    pub sustain: u32,
}

impl Default for DriftConfig {
    fn default() -> Self {
        DriftConfig { window: 64, drop_milli: 100, sustain: 3 }
    }
}

impl DriftConfig {
    /// Defaults overridden by `DVI_DRIFT_WINDOW` / `DVI_DRIFT_DROP` /
    /// `DVI_DRIFT_SUSTAIN`.
    pub fn from_env() -> DriftConfig {
        fn num<T: std::str::FromStr>(key: &str) -> Option<T> {
            std::env::var(key).ok().and_then(|s| s.parse().ok())
        }
        let d = DriftConfig::default();
        DriftConfig {
            window: num::<usize>("DVI_DRIFT_WINDOW")
                .filter(|&n| n >= 2)
                .unwrap_or(d.window),
            drop_milli: num::<u64>("DVI_DRIFT_DROP")
                .filter(|&n| n >= 1)
                .unwrap_or(d.drop_milli),
            sustain: num::<u32>("DVI_DRIFT_SUSTAIN")
                .filter(|&n| n >= 1)
                .unwrap_or(d.sustain),
        }
    }
}

/// Per-tenant SLO ledger. `tokens` counts every completion's output;
/// `goodput_tokens` only those that met their deadline — the ratio is
/// what an operator actually sells.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct TenantSlo {
    pub completed: u64,
    pub in_deadline: u64,
    pub tokens: u64,
    pub goodput_tokens: u64,
}

impl TenantSlo {
    /// In-deadline completions per thousand (1000 when nothing has a
    /// deadline to miss).
    pub fn attainment_milli(&self) -> u64 {
        if self.completed == 0 {
            1000
        } else {
            self.in_deadline * 1000 / self.completed
        }
    }
}

/// Point-in-time copy of the monitor (probe/report/test surface).
#[derive(Debug, Clone)]
pub struct HealthSnapshot {
    pub phase: u8,
    pub phase_name: String,
    pub alarm: bool,
    /// Trailing mean of healthy windows (None until one window fills).
    pub baseline_milli: Option<u64>,
    /// Mean of the last completed window (None until one fills).
    pub last_window_milli: Option<u64>,
    pub low_windows: u32,
    pub tenants: BTreeMap<String, TenantSlo>,
}

struct Inner {
    cfg: DriftConfig,
    phase: u8,
    phase_name: String,
    window: Vec<u64>,
    baseline_milli: Option<u64>,
    last_window_milli: Option<u64>,
    low_windows: u32,
    alarm: bool,
    tenants: BTreeMap<String, TenantSlo>,
}

/// Tenant bucket for completions submitted without a task tag.
pub const UNTAGGED: &str = "_untagged";

/// The monitor itself: shared (`Arc`) between the scheduler loop that
/// records and the probe/report paths that read.
pub struct HealthMonitor {
    inner: Mutex<Inner>,
}

impl HealthMonitor {
    pub fn new() -> HealthMonitor {
        HealthMonitor::with_config(DriftConfig::from_env())
    }

    pub fn with_config(cfg: DriftConfig) -> HealthMonitor {
        HealthMonitor {
            inner: Mutex::new(Inner {
                cfg,
                phase: 0,
                phase_name: "warmup".to_string(),
                window: Vec::new(),
                baseline_milli: None,
                last_window_milli: None,
                low_windows: 0,
                alarm: false,
                tenants: BTreeMap::new(),
            }),
        }
    }

    /// Learner phase transition (KL warmup → ramp → RL). Acceptance is
    /// *expected* to move across phases, so the detector starts a fresh
    /// window and baseline rather than flagging the shift as drift.
    pub fn set_phase(&self, phase: u8, name: &str) {
        let mut g = self.inner.lock().unwrap();
        if g.phase == phase {
            return;
        }
        g.phase = phase;
        g.phase_name = name.to_string();
        g.window.clear();
        g.baseline_milli = None;
        g.last_window_milli = None;
        g.low_windows = 0;
        g.alarm = false;
        metrics::gauge("sched.health.drift_alarm").store(0, Ordering::Relaxed);
        metrics::gauge("sched.health.phase")
            .store(phase as i64, Ordering::Relaxed);
    }

    /// Fold one verified round's acceptance (per-mille) into the
    /// current window; runs the window/baseline logic when it fills.
    pub fn record_accept(&self, accept_milli: u64) {
        let mut g = self.inner.lock().unwrap();
        g.window.push(accept_milli);
        if g.window.len() < g.cfg.window {
            return;
        }
        let mean = g.window.iter().sum::<u64>() / g.window.len() as u64;
        g.window.clear();
        g.last_window_milli = Some(mean);
        match g.baseline_milli {
            None => g.baseline_milli = Some(mean),
            Some(base) if base.saturating_sub(mean) >= g.cfg.drop_milli => {
                // Low window: count toward the alarm and *freeze* the
                // baseline — folding the drop in would let a slow
                // regression walk the baseline down and never alarm.
                g.low_windows += 1;
                g.alarm = g.low_windows >= g.cfg.sustain;
            }
            Some(base) => {
                // Healthy window: recover and track slow drift up/down
                // with a 1/8 EMA step.
                g.low_windows = 0;
                g.alarm = false;
                g.baseline_milli = Some((base * 7 + mean) / 8);
            }
        }
        metrics::gauge("sched.health.accept_window_milli")
            .store(mean as i64, Ordering::Relaxed);
        metrics::gauge("sched.health.drift_alarm")
            .store(g.alarm as i64, Ordering::Relaxed);
    }

    /// One finished request: `tokens` generated, observed `latency_ns`,
    /// against the deadline it was submitted with (`None` = no SLO —
    /// counts as in-deadline, contributes to goodput). `ok = false`
    /// (failed/rejected request) always counts as a miss: an error is
    /// never goodput, deadline or not.
    pub fn record_completion(
        &self,
        tenant: Option<&str>,
        ok: bool,
        latency_ns: u64,
        deadline_ns: Option<u64>,
        tokens: u64,
    ) {
        let met = ok && deadline_ns.map_or(true, |d| latency_ns <= d);
        let mut g = self.inner.lock().unwrap();
        let slo = g
            .tenants
            .entry(tenant.unwrap_or(UNTAGGED).to_string())
            .or_default();
        slo.completed += 1;
        slo.tokens += tokens;
        if met {
            slo.in_deadline += 1;
            slo.goodput_tokens += tokens;
        }
        metrics::counter("sched.health.completed")
            .fetch_add(1, Ordering::Relaxed);
        if met {
            metrics::counter("sched.health.in_deadline")
                .fetch_add(1, Ordering::Relaxed);
        } else {
            metrics::counter("sched.health.slo_miss")
                .fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn drift_alarm(&self) -> bool {
        self.inner.lock().unwrap().alarm
    }

    pub fn snapshot(&self) -> HealthSnapshot {
        let g = self.inner.lock().unwrap();
        HealthSnapshot {
            phase: g.phase,
            phase_name: g.phase_name.clone(),
            alarm: g.alarm,
            baseline_milli: g.baseline_milli,
            last_window_milli: g.last_window_milli,
            low_windows: g.low_windows,
            tenants: g.tenants.clone(),
        }
    }

    /// Stable JSON for the `{"health": true}` probe.
    pub fn to_json(&self) -> String {
        let s = self.snapshot();
        let mut out = String::from("{\"schema\":\"dvi.health/1\"");
        out.push_str(&format!(
            ",\"drift\":{{\"phase\":{},\"phase_name\":\"{}\",\"alarm\":{},\
             \"baseline_milli\":{},\"last_window_milli\":{},\
             \"low_windows\":{}}}",
            s.phase,
            escape(&s.phase_name),
            s.alarm,
            opt(s.baseline_milli),
            opt(s.last_window_milli),
            s.low_windows,
        ));
        out.push_str(",\"tenants\":{");
        for (i, (name, t)) in s.tenants.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\"{}\":{{\"completed\":{},\"in_deadline\":{},\
                 \"attainment_milli\":{},\"tokens\":{},\
                 \"slo_goodput_tokens\":{}}}",
                escape(name),
                t.completed,
                t.in_deadline,
                t.attainment_milli(),
                t.tokens,
                t.goodput_tokens,
            ));
        }
        out.push_str("}}");
        out
    }

    /// One-line operator summary for the periodic `serve` report.
    pub fn report_line(&self) -> String {
        let s = self.snapshot();
        let (completed, in_deadline): (u64, u64) = s
            .tenants
            .values()
            .fold((0, 0), |(c, d), t| (c + t.completed, d + t.in_deadline));
        let attain = if completed == 0 {
            1000
        } else {
            in_deadline * 1000 / completed
        };
        format!(
            "health: phase={} slo={}/{} ({}.{}%) drift={}{}",
            s.phase_name,
            in_deadline,
            completed,
            attain / 10,
            attain % 10,
            if s.alarm { "ALARM" } else { "ok" },
            match (s.alarm, s.baseline_milli, s.last_window_milli) {
                (true, Some(b), Some(w)) =>
                    format!(" (accept {w}‰ vs baseline {b}‰)"),
                _ => String::new(),
            },
        )
    }
}

impl Default for HealthMonitor {
    fn default() -> Self {
        Self::new()
    }
}

fn opt(v: Option<u64>) -> String {
    v.map_or_else(|| "null".to_string(), |n| n.to_string())
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(window: usize, drop_milli: u64, sustain: u32) -> DriftConfig {
        DriftConfig { window, drop_milli, sustain }
    }

    #[test]
    fn slo_ledger_counts_goodput_per_tenant() {
        let h = HealthMonitor::with_config(cfg(4, 100, 3));
        let ms = |n: u64| n * 1_000_000;
        h.record_completion(Some("chat"), true, ms(40), Some(ms(50)), 10);
        h.record_completion(Some("chat"), true, ms(90), Some(ms(50)), 10);
        h.record_completion(Some("batch"), true, ms(900), Some(ms(1000)), 100);
        h.record_completion(None, true, ms(10), None, 7); // no SLO: good
        // A failure is never goodput, even without a deadline.
        h.record_completion(Some("batch"), false, ms(1), None, 0);
        let s = h.snapshot();
        let chat = &s.tenants["chat"];
        assert_eq!(
            (chat.completed, chat.in_deadline, chat.tokens, chat.goodput_tokens),
            (2, 1, 20, 10)
        );
        assert_eq!(chat.attainment_milli(), 500);
        assert_eq!(s.tenants["batch"].attainment_milli(), 500);
        assert_eq!(s.tenants["batch"].goodput_tokens, 100);
        let untagged = &s.tenants[UNTAGGED];
        assert_eq!((untagged.in_deadline, untagged.goodput_tokens), (1, 7));
    }

    #[test]
    fn drift_alarm_needs_sustained_low_windows() {
        let h = HealthMonitor::with_config(cfg(2, 100, 2));
        // Two healthy windows: baseline settles at 800.
        for _ in 0..4 {
            h.record_accept(800);
        }
        assert!(!h.drift_alarm());
        assert_eq!(h.snapshot().baseline_milli, Some(800));
        // One low window is not an alarm...
        h.record_accept(600);
        h.record_accept(600);
        assert!(!h.drift_alarm(), "one low window must not alarm");
        // ...the second consecutive one is.
        h.record_accept(600);
        h.record_accept(600);
        assert!(h.drift_alarm());
        assert_eq!(
            h.snapshot().baseline_milli,
            Some(800),
            "baseline must freeze through low windows, not chase the drop"
        );
        // Recovery clears the alarm.
        h.record_accept(800);
        h.record_accept(800);
        assert!(!h.drift_alarm());
    }

    #[test]
    fn phase_change_resets_instead_of_alarming() {
        let h = HealthMonitor::with_config(cfg(2, 100, 1));
        for _ in 0..4 {
            h.record_accept(900);
        }
        // KL→RL hand-off: acceptance legitimately drops.
        h.set_phase(2, "rl");
        h.record_accept(600);
        h.record_accept(600);
        assert!(
            !h.drift_alarm(),
            "first window after a phase change seeds the new baseline"
        );
        let s = h.snapshot();
        assert_eq!((s.phase, s.baseline_milli), (2, Some(600)));
        assert_eq!(s.phase_name, "rl");
    }

    #[test]
    fn json_is_parseable_and_carries_the_schema() {
        let h = HealthMonitor::with_config(cfg(2, 100, 2));
        h.record_completion(Some("a\"b"), true, 5, Some(3), 2);
        let json = h.to_json();
        let doc =
            crate::util::json::Json::parse(&json).expect("health json parses");
        assert_eq!(doc.get("schema").as_str(), Some("dvi.health/1"));
        let t = doc.get("tenants").get("a\"b");
        assert!(!t.is_null(), "escaped tenant key must survive");
        assert_eq!(t.get("completed").as_f64(), Some(1.0));
        assert_eq!(t.get("slo_goodput_tokens").as_f64(), Some(0.0));
        assert_eq!(doc.get("drift").get("alarm").as_bool(), Some(false));
    }

    #[test]
    fn report_line_reads_like_an_operator_summary() {
        let h = HealthMonitor::with_config(cfg(2, 100, 1));
        h.record_completion(Some("chat"), true, 10, Some(20), 5);
        h.record_completion(Some("chat"), true, 30, Some(20), 5);
        let line = h.report_line();
        assert!(line.contains("slo=1/2"), "got: {line}");
        assert!(line.contains("drift=ok"), "got: {line}");
    }
}
