//! Chrome trace-event JSON export and reduction.
//!
//! The emitted document follows the Trace Event Format's JSON-object
//! form (`{"traceEvents": [...]}`) with `'X'` complete and `'i'`
//! instant events, microsecond timestamps, and one track per source
//! thread — loadable in Perfetto / `chrome://tracing` as-is. Writes go
//! to a temp file renamed into place, so the output path always holds
//! a complete, parseable document even if the process dies mid-flush.
//!
//! [`summarize`] is the inverse reduction used by `dvi trace-summary`:
//! it groups complete events by name (and shard, when tagged) and
//! reports exact latency quantiles over the recorded durations.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::trace::{self, Arg, Event};
use crate::util::json::{escape, Json};

fn push_f64(out: &mut String, v: f64) {
    if v.is_finite() {
        out.push_str(&format!("{v}"));
    } else {
        out.push('0');
    }
}

fn push_event(out: &mut String, e: &Event) {
    out.push_str(&format!(
        "{{\"name\":{},\"cat\":{},\"ph\":\"{}\",\"ts\":{:.3},\"pid\":1,\"tid\":{}",
        escape(e.name),
        escape(e.cat),
        e.ph,
        e.ts_ns as f64 / 1e3,
        e.tid
    ));
    if e.ph == 'X' {
        out.push_str(&format!(",\"dur\":{:.3}", e.dur_ns as f64 / 1e3));
    }
    if e.ph == 'i' {
        // thread-scoped instant marker
        out.push_str(",\"s\":\"t\"");
    }
    if !e.args.is_empty() {
        out.push_str(",\"args\":{");
        for (i, (k, v)) in e.args.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&escape(k));
            out.push(':');
            match v {
                Arg::I(n) => out.push_str(&n.to_string()),
                Arg::F(f) => push_f64(out, *f),
                Arg::S(s) => out.push_str(&escape(s)),
            }
        }
        out.push('}');
    }
    out.push('}');
}

/// Render a full trace document. Events are sorted by (ts, tid) so
/// every track is time-monotonic regardless of drain interleaving.
pub fn render(events: &[Event], dropped: u64) -> String {
    let mut sorted: Vec<&Event> = events.iter().collect();
    sorted.sort_by_key(|e| (e.ts_ns, e.tid));
    let mut out = String::with_capacity(events.len() * 112 + 128);
    out.push_str("{\"traceEvents\":[");
    for (i, e) in sorted.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        push_event(&mut out, e);
    }
    out.push_str(&format!(
        "],\"displayTimeUnit\":\"ms\",\"otherData\":{{\"tool\":\"dvi\",\
         \"dropped_events\":{dropped}}}}}"
    ));
    out
}

/// Write a trace document atomically (temp file + rename): the target
/// path never holds a torn document.
pub fn write_atomic(path: &Path, events: &[Event], dropped: u64) -> Result<()> {
    let doc = render(events, dropped);
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    std::fs::write(&tmp, doc)
        .with_context(|| format!("write {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename into {}", path.display()))?;
    Ok(())
}

/// Accumulating export sink for `serve --trace-out`: each flush drains
/// the live rings into an in-memory event log (bounded by
/// `DVI_TRACE_MAX`, default 1M events; overflow counts as drops) and
/// rewrites the output file atomically.
pub struct TraceSink {
    path: PathBuf,
    events: Vec<Event>,
    max_events: usize,
    truncated: u64,
}

impl TraceSink {
    pub fn new(path: PathBuf) -> TraceSink {
        let max_events = std::env::var("DVI_TRACE_MAX")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or(1_000_000);
        TraceSink { path, events: Vec::new(), max_events, truncated: 0 }
    }

    pub fn flush(&mut self) -> Result<()> {
        for ev in trace::drain() {
            if self.events.len() < self.max_events {
                self.events.push(ev);
            } else {
                self.truncated += 1;
            }
        }
        write_atomic(
            &self.path,
            &self.events,
            trace::drop_count() + self.truncated,
        )
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Latency summary for one (event name, shard) group of complete
/// events. Quantiles are exact over the recorded durations.
#[derive(Debug, Clone)]
pub struct PhaseStat {
    /// Event name, suffixed `/s<shard>` when the span carried a shard tag.
    pub key: String,
    pub count: usize,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
    pub total_ms: f64,
}

fn exact_quantile(sorted: &[f64], q: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Reduce a Chrome trace document to per-phase/per-shard stats.
pub fn summarize(doc: &str) -> Result<(Vec<PhaseStat>, u64)> {
    let j = Json::parse(doc).context("parse trace JSON")?;
    let Some(events) = j.get("traceEvents").as_arr() else {
        bail!("no traceEvents array in trace document");
    };
    let dropped = j
        .get("otherData")
        .get("dropped_events")
        .as_f64()
        .unwrap_or(0.0) as u64;
    let mut groups: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for e in events {
        if e.get("ph").as_str() != Some("X") {
            continue;
        }
        let Some(name) = e.get("name").as_str() else {
            bail!("complete event without a name");
        };
        let Some(dur) = e.get("dur").as_f64() else {
            bail!("complete event '{name}' without a dur");
        };
        let key = match e.get("args").get("shard").as_f64() {
            Some(s) => format!("{name}/s{}", s as i64),
            None => name.to_string(),
        };
        groups.entry(key).or_default().push(dur);
    }
    let mut out = Vec::with_capacity(groups.len());
    for (key, mut durs) in groups {
        durs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out.push(PhaseStat {
            count: durs.len(),
            p50_us: exact_quantile(&durs, 0.50),
            p95_us: exact_quantile(&durs, 0.95),
            p99_us: exact_quantile(&durs, 0.99),
            max_us: *durs.last().unwrap(),
            total_ms: durs.iter().sum::<f64>() / 1e3,
            key,
        });
    }
    Ok((out, dropped))
}

/// Render the summary as a markdown table (the `dvi trace-summary`
/// output).
pub fn summary_table(stats: &[PhaseStat]) -> String {
    let mut out = String::new();
    out.push_str("| phase | count | p50 us | p95 us | p99 us | max us | total ms |\n");
    out.push_str("|---|---|---|---|---|---|---|\n");
    for s in stats {
        out.push_str(&format!(
            "| {} | {} | {:.1} | {:.1} | {:.1} | {:.1} | {:.2} |\n",
            s.key, s.count, s.p50_us, s.p95_us, s.p99_us, s.max_us, s.total_ms
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &'static str, ph: char, ts: u64, dur: u64, tid: u64) -> Event {
        Event {
            name,
            cat: "test",
            ph,
            ts_ns: ts,
            dur_ns: dur,
            tid,
            args: vec![("shard", Arg::I(0)), ("note", Arg::S("a\"b".into()))],
        }
    }

    #[test]
    fn render_parses_and_roundtrips_fields() {
        let events =
            vec![ev("b", 'X', 2000, 500, 2), ev("a", 'i', 1000, 0, 1)];
        let doc = render(&events, 3);
        let j = Json::parse(&doc).expect("rendered trace parses");
        let arr = j.get("traceEvents").as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        // sorted by ts: the instant comes first
        assert_eq!(arr[0].get("name").as_str(), Some("a"));
        assert_eq!(arr[0].get("ph").as_str(), Some("i"));
        assert_eq!(arr[1].get("dur").as_f64(), Some(0.5));
        assert_eq!(arr[1].get("args").get("note").as_str(), Some("a\"b"));
        assert_eq!(j.get("otherData").get("dropped_events").as_f64(), Some(3.0));
    }

    #[test]
    fn summarize_groups_by_name_and_shard() {
        let mut events = Vec::new();
        for i in 0..10u64 {
            events.push(Event {
                name: "rpc.call",
                cat: "rpc",
                ph: 'X',
                ts_ns: i * 1000,
                dur_ns: (i + 1) * 1000,
                tid: 1,
                args: vec![("shard", Arg::I((i % 2) as i64))],
            });
        }
        let doc = render(&events, 0);
        let (stats, dropped) = summarize(&doc).unwrap();
        assert_eq!(dropped, 0);
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].key, "rpc.call/s0");
        assert_eq!(stats[0].count, 5);
        // shard 0 durations: 1,3,5,7,9 us; p50 = 5
        assert_eq!(stats[0].p50_us, 5.0);
        assert_eq!(stats[0].max_us, 9.0);
    }

    #[test]
    fn summarize_rejects_garbage() {
        assert!(summarize("not json").is_err());
        assert!(summarize("{\"x\":1}").is_err());
    }
}
