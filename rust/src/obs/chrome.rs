//! Chrome trace-event JSON export and reduction.
//!
//! The emitted document follows the Trace Event Format's JSON-object
//! form (`{"traceEvents": [...]}`) with `'X'` complete and `'i'`
//! instant events, microsecond timestamps, and one track per source
//! thread — loadable in Perfetto / `chrome://tracing` as-is. Writes go
//! to a temp file renamed into place, so the output path always holds
//! a complete, parseable document even if the process dies mid-flush.
//!
//! [`summarize`] is the inverse reduction used by `dvi trace-summary`:
//! it groups complete events by name (and shard, when tagged) and
//! reports exact latency quantiles over the recorded durations.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::trace::{self, Arg, Event, OwnedEvent};
use crate::util::json::{escape, Json};

/// The `pid` every locally drained event renders under. Merged fleet
/// documents keep the client on this pid and place shard `N` on
/// [`shard_pid`]`(N)`.
pub const CLIENT_PID: u64 = 1;

/// Chrome `pid` assigned to executor shard `N` in a merged document.
pub fn shard_pid(shard: u32) -> u64 {
    CLIENT_PID + 1 + shard as u64
}

fn push_f64(out: &mut String, v: f64) {
    if v.is_finite() {
        out.push_str(&format!("{v}"));
    } else {
        out.push('0');
    }
}

fn push_args<K: AsRef<str>>(out: &mut String, args: &[(K, Arg)]) {
    if args.is_empty() {
        return;
    }
    out.push_str(",\"args\":{");
    for (i, (k, v)) in args.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&escape(k.as_ref()));
        out.push(':');
        match v {
            Arg::I(n) => out.push_str(&n.to_string()),
            Arg::F(f) => push_f64(out, *f),
            Arg::S(s) => out.push_str(&escape(s)),
        }
    }
    out.push('}');
}

fn push_event(out: &mut String, e: &Event) {
    out.push_str(&format!(
        "{{\"name\":{},\"cat\":{},\"ph\":\"{}\",\"ts\":{:.3},\"pid\":{CLIENT_PID},\"tid\":{}",
        escape(e.name),
        escape(e.cat),
        e.ph,
        e.ts_ns as f64 / 1e3,
        e.tid
    ));
    if e.ph == 'X' {
        out.push_str(&format!(",\"dur\":{:.3}", e.dur_ns as f64 / 1e3));
    }
    if e.ph == 'i' {
        // thread-scoped instant marker
        out.push_str(",\"s\":\"t\"");
    }
    push_args(out, &e.args);
    out.push('}');
}

fn push_owned_event(out: &mut String, e: &OwnedEvent, pid: u64) {
    out.push_str(&format!(
        "{{\"name\":{},\"cat\":{},\"ph\":\"{}\",\"ts\":{:.3},\"pid\":{pid},\"tid\":{}",
        escape(&e.name),
        escape(&e.cat),
        e.ph,
        e.ts_ns as f64 / 1e3,
        e.tid
    ));
    if e.ph == 'X' {
        out.push_str(&format!(",\"dur\":{:.3}", e.dur_ns as f64 / 1e3));
    }
    if e.ph == 'i' {
        out.push_str(",\"s\":\"t\"");
    }
    push_args(out, &e.args);
    out.push('}');
}

/// Chrome `M` metadata event naming a process track.
fn push_process_name(out: &mut String, pid: u64, label: &str) {
    out.push_str(&format!(
        "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\
         \"args\":{{\"name\":{}}}}}",
        escape(label)
    ));
}

/// One process track of a merged fleet trace: the client or one
/// executor shard, with its events already clock-aligned onto the
/// client's trace epoch (see `runtime::remote`'s offset estimator).
#[derive(Debug, Clone)]
pub struct ProcessTrack {
    pub pid: u64,
    /// Human label for the Perfetto process row
    /// (`"dvi client"`, `"executor shard 0 @ host:port"`).
    pub label: String,
    pub events: Vec<OwnedEvent>,
    /// Ring-overflow drops reported by this track's process.
    pub dropped: u64,
}

/// Render a merged multi-process trace document: one `process_name`
/// metadata track per process, then every event sorted by
/// (ts, pid, tid) so each track is time-monotonic. `truncated` > 0
/// additionally records an explicit `trace.truncated` marker (the
/// sink-cap analogue of the ring-overflow drop counter).
pub fn render_merged(tracks: &[ProcessTrack], truncated: u64) -> String {
    let n_events: usize = tracks.iter().map(|t| t.events.len()).sum();
    let dropped: u64 = tracks.iter().map(|t| t.dropped).sum();
    let mut out = String::with_capacity(n_events * 112 + 256);
    out.push_str("{\"traceEvents\":[");
    let mut first = true;
    for t in tracks {
        if !first {
            out.push(',');
        }
        first = false;
        push_process_name(&mut out, t.pid, &t.label);
    }
    let mut sorted: Vec<(u64, &OwnedEvent)> = tracks
        .iter()
        .flat_map(|t| t.events.iter().map(move |e| (t.pid, e)))
        .collect();
    sorted.sort_by_key(|(pid, e)| (e.ts_ns, *pid, e.tid));
    for (pid, e) in sorted {
        out.push(',');
        push_owned_event(&mut out, e, pid);
    }
    if truncated > 0 {
        out.push_str(&format!(
            ",{{\"name\":\"trace.truncated\",\"cat\":\"meta\",\"ph\":\"i\",\
             \"ts\":0,\"pid\":{CLIENT_PID},\"tid\":0,\"s\":\"g\",\
             \"args\":{{\"truncated_events\":{truncated}}}}}"
        ));
    }
    out.push_str(&format!(
        "],\"displayTimeUnit\":\"ms\",\"otherData\":{{\"tool\":\"dvi\",\
         \"dropped_events\":{dropped},\"truncated_events\":{truncated},\
         \"processes\":{}}}}}",
        tracks.len()
    ));
    out
}

/// Render a full trace document. Events are sorted by (ts, tid) so
/// every track is time-monotonic regardless of drain interleaving.
pub fn render(events: &[Event], dropped: u64) -> String {
    render_with_truncated(events, dropped, 0)
}

/// [`render`], recording `truncated` sink-cap casualties explicitly: a
/// `trace.truncated` marker event plus an `otherData` counter, so a
/// capped export is never mistaken for a complete one (satellite of
/// the ring-overflow drop-counter convention).
pub fn render_with_truncated(
    events: &[Event],
    dropped: u64,
    truncated: u64,
) -> String {
    let mut sorted: Vec<&Event> = events.iter().collect();
    sorted.sort_by_key(|e| (e.ts_ns, e.tid));
    let mut out = String::with_capacity(events.len() * 112 + 128);
    out.push_str("{\"traceEvents\":[");
    for (i, e) in sorted.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        push_event(&mut out, e);
    }
    if truncated > 0 {
        if !sorted.is_empty() {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"name\":\"trace.truncated\",\"cat\":\"meta\",\"ph\":\"i\",\
             \"ts\":0,\"pid\":{CLIENT_PID},\"tid\":0,\"s\":\"g\",\
             \"args\":{{\"truncated_events\":{truncated}}}}}"
        ));
    }
    out.push_str(&format!(
        "],\"displayTimeUnit\":\"ms\",\"otherData\":{{\"tool\":\"dvi\",\
         \"dropped_events\":{dropped},\"truncated_events\":{truncated}}}}}"
    ));
    out
}

/// Write a trace document atomically (temp file + rename): the target
/// path never holds a torn document.
pub fn write_atomic(path: &Path, events: &[Event], dropped: u64) -> Result<()> {
    write_doc_atomic(path, &render(events, dropped))
}

/// Atomically persist an already-rendered document (merged fleet
/// traces, capped sink flushes).
pub fn write_doc_atomic(path: &Path, doc: &str) -> Result<()> {
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    std::fs::write(&tmp, doc)
        .with_context(|| format!("write {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename into {}", path.display()))?;
    Ok(())
}

/// Accumulating export sink for `serve --trace-out`: each flush drains
/// the live rings into an in-memory event log (bounded by
/// `DVI_TRACE_MAX`, default 1M events; overflow counts as drops) and
/// rewrites the output file atomically.
pub struct TraceSink {
    path: PathBuf,
    events: Vec<Event>,
    max_events: usize,
    truncated: u64,
}

impl TraceSink {
    pub fn new(path: PathBuf) -> TraceSink {
        let max_events = std::env::var("DVI_TRACE_MAX")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or(1_000_000);
        TraceSink { path, events: Vec::new(), max_events, truncated: 0 }
    }

    /// Drain the live ring into the capped accumulator *without*
    /// writing — used by merged fleet flushes, which render their own
    /// multi-process document around the accumulated client events.
    pub fn absorb(&mut self) {
        for ev in trace::drain() {
            if self.events.len() < self.max_events {
                self.events.push(ev);
            } else {
                self.truncated += 1;
            }
        }
    }

    pub fn flush(&mut self) -> Result<()> {
        self.absorb();
        // Truncation is reported in its own channel (marker event +
        // otherData counter), NOT folded into the ring-drop count: an
        // operator raising DVI_TRACE_BUF to cure "drops" that were
        // really sink-cap truncation would be chasing the wrong knob.
        write_doc_atomic(
            &self.path,
            &render_with_truncated(
                &self.events,
                trace::drop_count(),
                self.truncated,
            ),
        )
    }

    /// Events discarded by the `DVI_TRACE_MAX` cap so far.
    pub fn truncated(&self) -> u64 {
        self.truncated
    }

    /// Take the accumulated (already-drained) client events, e.g. to
    /// fold them into a merged fleet document instead of a flat flush.
    pub fn take_events(&mut self) -> Vec<Event> {
        std::mem::take(&mut self.events)
    }

    /// The accumulated (already-drained) client events, borrowed — the
    /// merged fleet flush re-renders them on every cadence tick.
    pub fn events(&self) -> &[Event] {
        &self.events
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Latency summary for one (event name, shard) group of complete
/// events. Quantiles are exact over the recorded durations.
#[derive(Debug, Clone)]
pub struct PhaseStat {
    /// Event name, suffixed `/s<shard>` when the span carried a shard tag.
    pub key: String,
    pub count: usize,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
    pub total_ms: f64,
}

fn exact_quantile(sorted: &[f64], q: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Reduce a Chrome trace document to per-phase/per-shard stats.
/// Returns `(stats, ring-dropped events, sink-truncated events)`.
pub fn summarize(doc: &str) -> Result<(Vec<PhaseStat>, u64, u64)> {
    let j = Json::parse(doc).context("parse trace JSON")?;
    let Some(events) = j.get("traceEvents").as_arr() else {
        bail!("no traceEvents array in trace document");
    };
    let dropped = j
        .get("otherData")
        .get("dropped_events")
        .as_f64()
        .unwrap_or(0.0) as u64;
    let truncated = j
        .get("otherData")
        .get("truncated_events")
        .as_f64()
        .unwrap_or(0.0) as u64;
    let mut groups: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for e in events {
        if e.get("ph").as_str() != Some("X") {
            continue;
        }
        let Some(name) = e.get("name").as_str() else {
            bail!("complete event without a name");
        };
        let Some(dur) = e.get("dur").as_f64() else {
            bail!("complete event '{name}' without a dur");
        };
        let key = match e.get("args").get("shard").as_f64() {
            Some(s) => format!("{name}/s{}", s as i64),
            None => name.to_string(),
        };
        groups.entry(key).or_default().push(dur);
    }
    let mut out = Vec::with_capacity(groups.len());
    for (key, mut durs) in groups {
        durs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out.push(PhaseStat {
            count: durs.len(),
            p50_us: exact_quantile(&durs, 0.50),
            p95_us: exact_quantile(&durs, 0.95),
            p99_us: exact_quantile(&durs, 0.99),
            max_us: *durs.last().unwrap(),
            total_ms: durs.iter().sum::<f64>() / 1e3,
            key,
        });
    }
    Ok((out, dropped, truncated))
}

/// Per-shard client/server/wire latency split from a *merged* fleet
/// trace: each client `rpc.call` span is paired with the executor
/// `exec` span carrying the same call id and shard, and the wire+queue
/// residual is `client dur − exec dur` (clamped at zero — an exec span
/// can only exceed its enclosing rpc span through clock-offset error).
#[derive(Debug, Clone)]
pub struct ShardDecomp {
    pub shard: i64,
    /// rpc spans with a matched exec span / total rpc spans on the shard.
    pub matched: usize,
    pub total: usize,
    pub client_p50_us: f64,
    pub client_p95_us: f64,
    pub server_p50_us: f64,
    pub server_p95_us: f64,
    pub wire_p50_us: f64,
    pub wire_p95_us: f64,
}

/// Compute the decomposition. Empty when the document holds no merged
/// executor tracks (a plain single-process trace).
pub fn decompose(doc: &str) -> Result<Vec<ShardDecomp>> {
    let j = Json::parse(doc).context("parse trace JSON")?;
    let Some(events) = j.get("traceEvents").as_arr() else {
        bail!("no traceEvents array in trace document");
    };
    // (shard, call id) -> dur us
    let mut rpc: BTreeMap<(i64, i64), f64> = BTreeMap::new();
    let mut exec: BTreeMap<(i64, i64), f64> = BTreeMap::new();
    for e in events {
        if e.get("ph").as_str() != Some("X") {
            continue;
        }
        let name = e.get("name").as_str().unwrap_or("");
        let args = e.get("args");
        let (Some(id), Some(shard)) =
            (args.get("id").as_f64(), args.get("shard").as_f64())
        else {
            continue;
        };
        let key = (shard as i64, id as i64);
        let dur = e.get("dur").as_f64().unwrap_or(0.0);
        match name {
            "rpc.call" => {
                rpc.insert(key, dur);
            }
            "exec" => {
                exec.insert(key, dur);
            }
            _ => {}
        }
    }
    let mut per_shard: BTreeMap<i64, (Vec<f64>, Vec<f64>, Vec<f64>, usize)> =
        BTreeMap::new();
    for (&(shard, id), &client_us) in &rpc {
        let slot = per_shard.entry(shard).or_default();
        slot.3 += 1;
        let Some(&server_us) = exec.get(&(shard, id)) else {
            continue;
        };
        slot.0.push(client_us);
        slot.1.push(server_us);
        slot.2.push((client_us - server_us).max(0.0));
    }
    let mut out = Vec::new();
    for (shard, (mut client, mut server, mut wire, total)) in per_shard {
        if client.is_empty() {
            continue;
        }
        let cmp = |a: &f64, b: &f64| a.partial_cmp(b).unwrap();
        client.sort_by(cmp);
        server.sort_by(cmp);
        wire.sort_by(cmp);
        out.push(ShardDecomp {
            shard,
            matched: client.len(),
            total,
            client_p50_us: exact_quantile(&client, 0.50),
            client_p95_us: exact_quantile(&client, 0.95),
            server_p50_us: exact_quantile(&server, 0.50),
            server_p95_us: exact_quantile(&server, 0.95),
            wire_p50_us: exact_quantile(&wire, 0.50),
            wire_p95_us: exact_quantile(&wire, 0.95),
        });
    }
    Ok(out)
}

/// Render the decomposition as a markdown table (appended by
/// `dvi trace-summary` when the trace holds merged executor tracks).
pub fn decomp_table(rows: &[ShardDecomp]) -> String {
    let mut out = String::new();
    out.push_str(
        "| shard | matched | client p50 us | client p95 us | server p50 us \
         | server p95 us | wire p50 us | wire p95 us |\n",
    );
    out.push_str("|---|---|---|---|---|---|---|---|\n");
    for r in rows {
        out.push_str(&format!(
            "| s{} | {}/{} | {:.1} | {:.1} | {:.1} | {:.1} | {:.1} | {:.1} |\n",
            r.shard,
            r.matched,
            r.total,
            r.client_p50_us,
            r.client_p95_us,
            r.server_p50_us,
            r.server_p95_us,
            r.wire_p50_us,
            r.wire_p95_us,
        ));
    }
    out
}

/// Render the summary as a markdown table (the `dvi trace-summary`
/// output).
pub fn summary_table(stats: &[PhaseStat]) -> String {
    let mut out = String::new();
    out.push_str("| phase | count | p50 us | p95 us | p99 us | max us | total ms |\n");
    out.push_str("|---|---|---|---|---|---|---|\n");
    for s in stats {
        out.push_str(&format!(
            "| {} | {} | {:.1} | {:.1} | {:.1} | {:.1} | {:.2} |\n",
            s.key, s.count, s.p50_us, s.p95_us, s.p99_us, s.max_us, s.total_ms
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &'static str, ph: char, ts: u64, dur: u64, tid: u64) -> Event {
        Event {
            name,
            cat: "test",
            ph,
            ts_ns: ts,
            dur_ns: dur,
            tid,
            args: vec![("shard", Arg::I(0)), ("note", Arg::S("a\"b".into()))],
        }
    }

    #[test]
    fn render_parses_and_roundtrips_fields() {
        let events =
            vec![ev("b", 'X', 2000, 500, 2), ev("a", 'i', 1000, 0, 1)];
        let doc = render(&events, 3);
        let j = Json::parse(&doc).expect("rendered trace parses");
        let arr = j.get("traceEvents").as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        // sorted by ts: the instant comes first
        assert_eq!(arr[0].get("name").as_str(), Some("a"));
        assert_eq!(arr[0].get("ph").as_str(), Some("i"));
        assert_eq!(arr[1].get("dur").as_f64(), Some(0.5));
        assert_eq!(arr[1].get("args").get("note").as_str(), Some("a\"b"));
        assert_eq!(j.get("otherData").get("dropped_events").as_f64(), Some(3.0));
    }

    #[test]
    fn summarize_groups_by_name_and_shard() {
        let mut events = Vec::new();
        for i in 0..10u64 {
            events.push(Event {
                name: "rpc.call",
                cat: "rpc",
                ph: 'X',
                ts_ns: i * 1000,
                dur_ns: (i + 1) * 1000,
                tid: 1,
                args: vec![("shard", Arg::I((i % 2) as i64))],
            });
        }
        let doc = render(&events, 0);
        let (stats, dropped, truncated) = summarize(&doc).unwrap();
        assert_eq!(dropped, 0);
        assert_eq!(truncated, 0);
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].key, "rpc.call/s0");
        assert_eq!(stats[0].count, 5);
        // shard 0 durations: 1,3,5,7,9 us; p50 = 5
        assert_eq!(stats[0].p50_us, 5.0);
        assert_eq!(stats[0].max_us, 9.0);
    }

    #[test]
    fn summarize_rejects_garbage() {
        assert!(summarize("not json").is_err());
        assert!(summarize("{\"x\":1}").is_err());
    }

    /// Satellite: a capped export must announce its truncation — marker
    /// event in the stream AND an otherData counter summarize reports —
    /// instead of silently folding it into ring drops.
    #[test]
    fn truncation_is_reported_not_silent() {
        let events = vec![ev("a", 'X', 1000, 500, 1)];
        let doc = render_with_truncated(&events, 2, 7);
        let j = Json::parse(&doc).unwrap();
        assert_eq!(j.get("otherData").get("dropped_events").as_f64(), Some(2.0));
        assert_eq!(
            j.get("otherData").get("truncated_events").as_f64(),
            Some(7.0)
        );
        let arr = j.get("traceEvents").as_arr().unwrap();
        let marker = arr
            .iter()
            .find(|e| e.get("name").as_str() == Some("trace.truncated"))
            .expect("truncation marker present");
        assert_eq!(
            marker.get("args").get("truncated_events").as_f64(),
            Some(7.0)
        );
        let (_, dropped, truncated) = summarize(&doc).unwrap();
        assert_eq!((dropped, truncated), (2, 7));
    }

    fn owned(
        name: &str,
        ts_ns: i64,
        dur_ns: u64,
        args: Vec<(String, Arg)>,
    ) -> OwnedEvent {
        OwnedEvent {
            name: name.to_string(),
            cat: "t".to_string(),
            ph: 'X',
            ts_ns,
            dur_ns,
            tid: 1,
            args,
        }
    }

    #[test]
    fn merged_render_names_processes_and_stays_parseable() {
        let sargs = |shard: i64, id: i64| {
            vec![
                ("shard".to_string(), Arg::I(shard)),
                ("id".to_string(), Arg::I(id)),
            ]
        };
        let tracks = vec![
            ProcessTrack {
                pid: CLIENT_PID,
                label: "dvi client".into(),
                events: vec![owned("rpc.call", 1000, 9000, sargs(0, 3))],
                dropped: 1,
            },
            ProcessTrack {
                pid: shard_pid(0),
                label: "executor shard 0".into(),
                // negative ts: aligned onto a client epoch that started
                // after this span
                events: vec![owned("exec", -500, 4000, sargs(0, 3))],
                dropped: 2,
            },
        ];
        let doc = render_merged(&tracks, 0);
        let j = Json::parse(&doc).expect("merged doc parses");
        let arr = j.get("traceEvents").as_arr().unwrap();
        let names: Vec<_> = arr
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("M"))
            .map(|e| e.get("args").get("name").as_str().unwrap().to_string())
            .collect();
        assert_eq!(names, vec!["dvi client", "executor shard 0"]);
        assert_eq!(j.get("otherData").get("dropped_events").as_f64(), Some(3.0));
        assert_eq!(j.get("otherData").get("processes").as_f64(), Some(2.0));
        // the negative-ts exec event survives with its sign
        let exec = arr
            .iter()
            .find(|e| e.get("name").as_str() == Some("exec"))
            .unwrap();
        assert_eq!(exec.get("ts").as_f64(), Some(-0.5));
        assert_eq!(exec.get("pid").as_f64(), Some(shard_pid(0) as f64));
    }

    #[test]
    fn decompose_pairs_rpc_and_exec_by_call_id() {
        let sargs = |shard: i64, id: i64| {
            vec![
                ("shard".to_string(), Arg::I(shard)),
                ("id".to_string(), Arg::I(id)),
            ]
        };
        let mut client = Vec::new();
        let mut exec0 = Vec::new();
        for id in 0..4i64 {
            client.push(owned("rpc.call", id * 10_000, 10_000, sargs(0, id)));
            // server half: 6us of the 10us rpc span
            exec0.push(owned("exec", id * 10_000 + 2000, 6000, sargs(0, id)));
        }
        // one unmatched rpc span (in-flight when the dump was pulled)
        client.push(owned("rpc.call", 90_000, 8000, sargs(0, 99)));
        let tracks = vec![
            ProcessTrack {
                pid: CLIENT_PID,
                label: "dvi client".into(),
                events: client,
                dropped: 0,
            },
            ProcessTrack {
                pid: shard_pid(0),
                label: "executor shard 0".into(),
                events: exec0,
                dropped: 0,
            },
        ];
        let doc = render_merged(&tracks, 0);
        let rows = decompose(&doc).unwrap();
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert_eq!(r.shard, 0);
        assert_eq!(r.matched, 4);
        assert_eq!(r.total, 5);
        assert_eq!(r.client_p50_us, 10.0);
        assert_eq!(r.server_p50_us, 6.0);
        assert_eq!(r.wire_p50_us, 4.0);
        let table = decomp_table(&rows);
        assert!(table.contains("| s0 | 4/5 |"));
    }
}
