//! Per-sequence position bookkeeping for speculative decoding.
//!
//! Invariant (mirrors `python/compile/model.py` conventions):
//!   * KV slot j holds state for sequence position j;
//!   * a step at position p writes slot p before attending (query i of a
//!     block: slots j <= p+i are visible);
//!   * slots > the current feed position may hold stale speculative
//!     garbage; they are always overwritten before becoming attendable.
//!
//! `SeqPos` tracks the *feed point*: the (token, position) pair to feed
//! next. Rollback after a partial accept is just arithmetic on these —
//! O(1), no cache clearing (the whole point of position-masked caches).

/// Feed-point state for one decoding sequence.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SeqPos {
    /// All committed tokens (prompt + generated).
    pub tokens: Vec<u32>,
    /// Number of positions whose KV is valid-and-committed. The next feed
    /// writes KV at this position.
    pub kv_len: usize,
}

impl SeqPos {
    /// After prefill of an n-token prompt: KV covers 0..n-1.
    pub fn after_prefill(prompt: &[u32]) -> SeqPos {
        SeqPos { tokens: prompt.to_vec(), kv_len: prompt.len() }
    }

    /// The token that must be fed next (the newest token whose KV has not
    /// been written yet), and the position it occupies.
    pub fn feed(&self) -> (u32, usize) {
        debug_assert!(self.kv_len < self.tokens.len(),
                      "nothing to feed: kv covers all tokens");
        (self.tokens[self.kv_len], self.kv_len)
    }

    /// Number of generated tokens given the original prompt length.
    pub fn generated(&self, prompt_len: usize) -> usize {
        self.tokens.len() - prompt_len
    }

    /// Record the first verifier token after prefill (prefill's logits
    /// already give the continuation "for free").
    pub fn push_committed(&mut self, tok: u32) {
        self.tokens.push(tok);
    }

    /// Apply a verified round: `drafted_fed` = number of draft-path steps
    /// that wrote KV this round (k_spec), `committed` = tokens to append
    /// (accepted + optional bonus), `accepted` = m.
    ///
    /// KV validity advances by m + 1 *wait* — by the number of fed
    /// positions whose context turned out to be committed: the feed at
    /// round start (1) plus the accepted drafted tokens fed after it...
    /// Draft feeds occupy positions kv_len..kv_len+k-1 with tokens
    /// [t_feed, d_1.. d_{k-1}]; positions kv_len..kv_len+m hold committed
    /// context (t_feed plus d_1..d_m each fed at the position it
    /// occupies); the first m+1 fed slots are valid. But slot kv_len+m
    /// holds d_m's KV ONLY if m < k... see `advance` body for exact rule.
    pub fn advance(&mut self, k_spec: usize, accepted: usize,
                   committed: &[u32]) {
        debug_assert!(accepted <= k_spec);
        debug_assert!(!committed.is_empty());
        // Positions fed this round: kv_len .. kv_len + k_spec - 1, holding
        // tokens [feed, d_1, .., d_{k_spec-1}]. Token d_i occupies
        // position kv_len + i. Valid slots = those whose token is now
        // committed AND whose context was committed:
        //   feed (always) + d_1..d_min(accepted, k_spec-1).
        let valid_fed = 1 + accepted.min(k_spec - 1);
        self.tokens.extend_from_slice(committed);
        self.kv_len += valid_fed;
        debug_assert!(self.kv_len < self.tokens.len(),
                      "feed point must stay behind committed tokens");
    }

    /// Apply a plain AR step: fed one token at kv_len, got one new token.
    pub fn advance_ar(&mut self, new_tok: u32) {
        self.kv_len += 1;
        self.tokens.push(new_tok);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_prop;
    use crate::util::rng::Rng;

    fn setup() -> SeqPos {
        let mut s = SeqPos::after_prefill(&[10, 11, 12]);
        s.push_committed(20); // first token from prefill logits
        s
    }

    #[test]
    fn prefill_state() {
        let s = setup();
        assert_eq!(s.kv_len, 3);
        assert_eq!(s.feed(), (20, 3));
        assert_eq!(s.generated(3), 1);
    }

    #[test]
    fn full_accept_round() {
        let mut s = setup();
        // k_spec=4: feed 20@3, draft d1..d4 = 21,22,23,24 (d1..d3 fed @4,5,6)
        s.advance(4, 4, &[21, 22, 23, 24]);
        assert_eq!(s.kv_len, 3 + 4); // feed + d1..d3
        assert_eq!(s.feed(), (24, 7)); // d4 next to feed
        assert_eq!(s.generated(3), 5);
    }

    #[test]
    fn partial_accept_round() {
        let mut s = setup();
        // accepted=1 (d1), bonus=30
        s.advance(4, 1, &[21, 30]);
        // valid slots: feed(3) + d1(4) => kv_len 5
        assert_eq!(s.kv_len, 5);
        assert_eq!(s.feed(), (30, 5)); // bonus next
    }

    #[test]
    fn zero_accept_round() {
        let mut s = setup();
        s.advance(4, 0, &[30]);
        assert_eq!(s.kv_len, 4); // only the feed slot
        assert_eq!(s.feed(), (30, 4));
    }

    #[test]
    fn ar_step() {
        let mut s = setup();
        s.advance_ar(25);
        assert_eq!(s.kv_len, 4);
        assert_eq!(s.feed(), (25, 4));
    }

    #[test]
    fn prop_feed_point_always_behind() {
        // Liveness/sanity: after any sequence of rounds the feed point is
        // exactly one batch of unwritten tokens behind the committed set,
        // and positions grow monotonically.
        run_prop("seq-invariants", 512, |rng: &mut Rng| {
            let mut s = setup();
            let mut last_kv = s.kv_len;
            for _ in 0..rng.usize_below(20) {
                let k = 1 + rng.usize_below(6);
                let m = rng.usize_below(k + 1);
                let mut committed: Vec<u32> =
                    (0..m as u32).map(|i| 100 + i).collect();
                if m < k {
                    committed.push(999); // bonus
                }
                s.advance(k, m, &committed);
                assert!(s.kv_len > last_kv, "progress in kv");
                assert!(s.kv_len < s.tokens.len(), "feed exists");
                // unwritten suffix = tokens not yet in kv; bounded by the
                // tokens committed this round (+1 carry).
                assert!(s.tokens.len() - s.kv_len <= k + 2);
                last_kv = s.kv_len;
            }
        });
    }
}
