//! Pure speculation logic: the longest-agreeing-prefix acceptance rule and
//! per-sequence position/KV bookkeeping. No PJRT types here — this module
//! is exhaustively unit- and property-tested in isolation, because every
//! engine (DVI, SpS, PLD, Medusa, Hydra, EAGLE) routes its commit
//! decisions through it.

pub mod accept;
pub mod seq;

pub use accept::{longest_prefix, VerifyOutcome};
pub use seq::SeqPos;
