//! The canonical longest-prefix verification rule (paper §3.1/§3.3).
//!
//! Given drafted tokens d_1..d_k and the verifier's greedy tokens
//! y*_1..y*_k (row i of the verify block = the verifier's choice for the
//! position d_{i+1} occupies):
//!
//!   m = max { i : d_j == y*_j for all j <= i }
//!
//! Commit d_1..d_m. If m < k, additionally emit the verifier's token
//! y*_{m+1} ("bonus" / correction token — the standard lossless-SD move:
//! the verifier already computed the right continuation at the first
//! mismatch). If m == k there is no extra row to harvest.

/// Result of verifying one drafted block.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VerifyOutcome {
    /// Number of drafted tokens accepted (m).
    pub accepted: usize,
    /// Tokens to append to the sequence: d_1..d_m (+ bonus if any).
    pub committed: Vec<u32>,
    /// The verifier correction token, present iff m < k.
    pub bonus: Option<u32>,
}

impl VerifyOutcome {
    /// Tokens committed this round (accepted + bonus).
    pub fn total_committed(&self) -> usize {
        self.committed.len()
    }
}

/// Apply the rule. `drafted.len() == verifier.len()` is required.
pub fn longest_prefix(drafted: &[u32], verifier: &[u32]) -> VerifyOutcome {
    assert_eq!(
        drafted.len(),
        verifier.len(),
        "verify block must cover every drafted token"
    );
    let mut m = 0;
    while m < drafted.len() && drafted[m] == verifier[m] {
        m += 1;
    }
    let mut committed: Vec<u32> = drafted[..m].to_vec();
    let bonus = if m < drafted.len() {
        committed.push(verifier[m]);
        Some(verifier[m])
    } else {
        None
    };
    VerifyOutcome { accepted: m, committed, bonus }
}

/// Losslessness check used by tests and debug assertions: replaying the
/// committed tokens must equal what greedy AR decoding of the verifier
/// would have produced for the same positions.
pub fn is_lossless(outcome: &VerifyOutcome, verifier: &[u32]) -> bool {
    // Every committed token at index i must equal verifier[i]: accepted
    // tokens agreed by definition, and the bonus IS verifier[m].
    outcome
        .committed
        .iter()
        .zip(verifier)
        .all(|(c, v)| c == v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run_prop, vec_u32_below};

    #[test]
    fn all_accepted() {
        let o = longest_prefix(&[1, 2, 3, 4], &[1, 2, 3, 4]);
        assert_eq!(o.accepted, 4);
        assert_eq!(o.committed, vec![1, 2, 3, 4]);
        assert_eq!(o.bonus, None);
    }

    #[test]
    fn first_rejected() {
        let o = longest_prefix(&[9, 2, 3, 4], &[1, 2, 3, 4]);
        assert_eq!(o.accepted, 0);
        assert_eq!(o.committed, vec![1]); // bonus only
        assert_eq!(o.bonus, Some(1));
    }

    #[test]
    fn middle_rejected() {
        let o = longest_prefix(&[1, 2, 9, 9], &[1, 2, 3, 4]);
        assert_eq!(o.accepted, 2);
        assert_eq!(o.committed, vec![1, 2, 3]);
        assert_eq!(o.bonus, Some(3));
    }

    #[test]
    fn later_agreement_does_not_resurrect() {
        // d_3 "agrees" with y*_3 but sits after a mismatch: must not count.
        let o = longest_prefix(&[1, 9, 3, 4], &[1, 2, 3, 4]);
        assert_eq!(o.accepted, 1);
        assert_eq!(o.committed, vec![1, 2]);
    }

    #[test]
    fn empty_block() {
        let o = longest_prefix(&[], &[]);
        assert_eq!(o.accepted, 0);
        assert!(o.committed.is_empty());
        assert_eq!(o.bonus, None);
    }

    #[test]
    #[should_panic]
    fn length_mismatch_panics() {
        longest_prefix(&[1, 2], &[1]);
    }

    #[test]
    fn prop_always_lossless() {
        run_prop("accept-lossless", 512, |rng| {
            let k = 1 + rng.usize_below(8);
            let drafted = vec_u32_below(rng, k, 4); // small vocab => collisions
            let verifier = vec_u32_below(rng, k, 4);
            let o = longest_prefix(&drafted, &verifier);
            assert!(is_lossless(&o, &verifier));
        });
    }

    #[test]
    fn prop_commit_count() {
        run_prop("accept-count", 512, |rng| {
            let k = 1 + rng.usize_below(8);
            let drafted = vec_u32_below(rng, k, 3);
            let verifier = vec_u32_below(rng, k, 3);
            let o = longest_prefix(&drafted, &verifier);
            // always commits at least 1 token, at most k
            assert!(1 <= o.total_committed() && o.total_committed() <= k);
            // bonus iff not all accepted
            assert_eq!(o.bonus.is_some(), o.accepted < k);
            assert_eq!(o.total_committed(),
                       o.accepted + o.bonus.is_some() as usize);
        });
    }

    #[test]
    fn prop_accepted_prefix_matches_both_sides() {
        // The accepted prefix must equal BOTH the drafted and the
        // verifier prefix (that is what "accepted" means), and it must
        // be maximal: if m < k the next pair disagrees.
        run_prop("accept-prefix", 512, |rng| {
            let k = 1 + rng.usize_below(8);
            let drafted = vec_u32_below(rng, k, 3);
            let verifier = vec_u32_below(rng, k, 3);
            let o = longest_prefix(&drafted, &verifier);
            let m = o.accepted;
            assert!(m <= k, "accepted count exceeds k");
            assert_eq!(&o.committed[..m], &drafted[..m]);
            assert_eq!(&o.committed[..m], &verifier[..m]);
            if m < k {
                assert_ne!(drafted[m], verifier[m], "prefix not maximal");
            }
        });
    }

    #[test]
    fn prop_committed_is_accepted_plus_one_bonus() {
        // committed = accepted + exactly one bonus token iff m < k;
        // total never exceeds k (full accept) / k+1 is impossible
        // because the bonus replaces the first reject.
        run_prop("accept-committed-len", 512, |rng| {
            let k = 1 + rng.usize_below(8);
            let drafted = vec_u32_below(rng, k, 2);
            let verifier = vec_u32_below(rng, k, 2);
            let o = longest_prefix(&drafted, &verifier);
            if o.accepted == k {
                assert_eq!(o.bonus, None);
                assert_eq!(o.total_committed(), k);
            } else {
                assert_eq!(o.bonus, Some(verifier[o.accepted]));
                assert_eq!(o.total_committed(), o.accepted + 1);
                assert_eq!(o.committed.last().copied(), o.bonus);
            }
            assert!(o.total_committed() <= k);
        });
    }

    #[test]
    fn prop_progress_guarantee() {
        // Speculative decoding's liveness property: every round commits
        // >= 1 token, so generation always terminates.
        run_prop("accept-progress", 256, |rng| {
            let k = 1 + rng.usize_below(6);
            let drafted = vec_u32_below(rng, k, 2);
            let verifier = vec_u32_below(rng, k, 2);
            assert!(longest_prefix(&drafted, &verifier).total_committed() >= 1);
        });
    }
}
