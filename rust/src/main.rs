//! `dvi` — the serving/benchmark launcher.
//!
//! Subcommands:
//!   info                         inspect artifacts/manifest
//!   run      --method dvi --task qa --n 5 [--online]
//!   train    --objective dvi --prompts 2000 [--curve out.csv]
//!   table1                       training-budget comparison (Table 1)
//!   table2   --n 40 [--methods dvi,ar,...] [--train 2000]
//!   table3   --train 2000 --n 25  objective ablations (Table 3)
//!   fig2     --train 2000        ablation learning curves (Figure 2)
//!   serve    --port 7501 --workers 2 [--no-online]
//!            [--batched --max-batch 8 --slots 16]   continuous batching
//!            [--prefix-cache --cache-cap 64]   radix prefix/KV reuse
//!            (batched mode; or DVI_PREFIX_CACHE=1)
//!            [--metrics] [--trace-out FILE] [--report-secs 30]
//!            [--smoke N]  observability: quantile metrics in the
//!            periodic report, Chrome-trace export (forces tracing on),
//!            or a self-driven N-prompt smoke run (no listener)
//!   trace-summary FILE.json      reduce a Chrome trace to per-phase
//!            latency quantiles (from `serve --trace-out` / DVI_TRACE)
//!   bench-compare OLD.json NEW.json [--tol 0.10] [--warn-only]
//!            trajectory gate: diff two schema-versioned BENCH_*.json
//!            artifacts of the same bench; exits non-zero when a metric
//!            regresses beyond the tolerance band (see BENCHMARKS.md)
//!   serve-backend --listen 127.0.0.1:7600           executor server:
//!            front the local backend (reference/pjrt) for remote
//!            clients (`--backend remote --remote HOST:PORT`, or
//!            DVI_REMOTE=HOST:PORT with any subcommand). Run several
//!            and pass a comma list (`--remote h1:p1,h2:p2` /
//!            DVI_REMOTE=h1:p1,h2:p2) for a sharded fleet: sequences
//!            round-robin across executors, KV stays put per shard,
//!            and a dead executor degrades (its lanes fail) instead of
//!            wedging serving
//!
//! Everything reads `--artifacts DIR` (default: ./artifacts).

use std::path::PathBuf;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Context, Result};

use dvi::engine::Engine;
use dvi::harness;
use dvi::learner::Objective;
use dvi::obs::{chrome, trace, TraceSink};
use dvi::runtime::{log, Runtime};
use dvi::sched::{AdaptiveK, CacheConfig};
use dvi::server::{api, Router, RouterConfig};
use dvi::util::cli::Args;
use dvi::util::plot::ascii_plot;

const FLAGS: [&str; 9] = [
    "online", "no-online", "quiet", "verbose", "batched", "adaptive-k",
    "metrics", "prefix-cache", "warn-only",
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv, &FLAGS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if args.flag("quiet") {
        log::set_level(0);
    }
    if args.flag("verbose") {
        log::set_level(2);
    }
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Backend selection: `--backend reference` forces the hermetic
/// pure-Rust backend; `--backend pjrt` requires compiled artifacts (and
/// the `pjrt` cargo feature); `--backend remote` ships every artifact
/// call to `dvi serve-backend` executor(s) (`--remote HOST:PORT`, or a
/// comma list `h1:p1,h2:p2` for a sharded fleet; DVI_REMOTE accepts the
/// same syntax); the default `auto` prefers DVI_REMOTE, then PJRT when
/// available, and falls back to the reference backend.
fn load_runtime(args: &Args) -> Result<Arc<Runtime>> {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let rt = match args.get_or("backend", "auto").as_str() {
        "reference" => {
            let seed = args
                .get_usize("seed", dvi::runtime::REFERENCE_SEED as usize)
                .map_err(anyhow::Error::msg)? as u64;
            Runtime::load_reference(seed)?
        }
        "pjrt" => Runtime::load(&dir, None)?,
        "remote" => {
            let addr = match args.get("remote") {
                Some(a) => a.to_string(),
                None => std::env::var("DVI_REMOTE").context(
                    "--backend remote needs --remote HOST:PORT (or DVI_REMOTE)",
                )?,
            };
            Runtime::load_remote(&addr)?
        }
        "auto" => Runtime::load_auto(&dir)?,
        other => bail!("unknown --backend '{other}' (auto|reference|pjrt|remote)"),
    };
    Ok(Arc::new(rt))
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("info") => info(args),
        Some("run") => run(args),
        Some("train") => train(args),
        Some("table1") => table1(args),
        Some("table2") => table2(args),
        Some("table3") => table3(args),
        Some("fig2") => fig2(args),
        Some("serve") => serve(args),
        Some("serve-backend") => serve_backend(args),
        Some("trace-summary") => trace_summary(args),
        Some("bench-compare") => bench_compare(args),
        Some(other) => bail!("unknown subcommand '{other}' (see src/main.rs docs)"),
        None => bail!(
            "usage: dvi <info|run|train|table1|table2|table3|fig2|serve|\
             serve-backend|trace-summary|bench-compare> [...]"
        ),
    }
}

fn info(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    println!("backend: {}", rt.backend_name());
    for s in rt.executor_status() {
        match s.metrics {
            Some(m) => println!(
                "  shard {} @ {}: {} calls, occupancy {:.2}, {} buffers, \
                 {} sessions, inflight {}/{} (now/max)",
                s.shard, s.endpoint, m.calls, m.occupancy(), m.buffers,
                m.sessions, m.inflight, m.max_inflight
            ),
            None => println!("  shard {} @ {}: UNREACHABLE", s.shard, s.endpoint),
        }
    }
    println!(
        "trace: {} (dropped events: {})",
        if trace::enabled() { "on" } else { "off (set DVI_TRACE=1)" },
        trace::drop_count()
    );
    println!("artifacts: {}", rt.manifest.dir.display());
    println!("model config: {}", rt.manifest.config.get("model"));
    println!("spec config: {}", rt.manifest.config.get("spec"));
    for (name, spec) in &rt.manifest.artifacts {
        println!(
            "  {name}: {} params, {} outputs",
            spec.params.len(),
            spec.outputs.len()
        );
    }
    Ok(())
}

fn run(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let method = args.get_or("method", "dvi");
    let task = args.get_or("task", "qa");
    let n = args.get_usize("n", 5).map_err(anyhow::Error::msg)?;
    let tok = rt.tokenizer()?;

    if args.flag("online") {
        let prompts = args.get_usize("train", 300).map_err(anyhow::Error::msg)?;
        log::info(&format!("online pre-training on {prompts} prompts"));
        harness::online_train(rt.clone(), Objective::Dvi, prompts, false)?;
    }

    let set = harness::load_prompts(&rt, &task)?;
    let mut engine = harness::make_engine(rt.clone(), &method)?;
    for s in set.samples.iter().take(n) {
        let r = engine.generate(&s.prompt, s.max_new)?;
        println!(
            "--- task={task} prompt: {}",
            tok.decode(&s.prompt[1..s.prompt.len().min(24)])
        );
        println!("    output: {}", tok.decode(&r.tokens));
        println!(
            "    mat={:.2} accept={:.2} decode={:.1}ms tokens={}",
            r.mat(),
            r.acceptance_rate(),
            r.decode_ns as f64 / 1e6,
            r.tokens.len()
        );
    }
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let objective = Objective::parse(&args.get_or("objective", "dvi"))
        .context("bad --objective (dvi|kl|pg|ce)")?;
    let prompts = args.get_usize("prompts", 2000).map_err(anyhow::Error::msg)?;
    let report = harness::online_train(rt, objective, prompts, false)?;
    println!(
        "trained {} steps over {} prompts",
        report.trainer_steps, report.prompts_seen
    );
    if let Some(path) = args.get("curve") {
        let mut csv = String::from("step,batch_accept\n");
        for (s, a) in &report.curve {
            csv.push_str(&format!("{s},{a:.5}\n"));
        }
        std::fs::write(path, csv)?;
        println!("curve written to {path}");
    }
    println!(
        "{}",
        ascii_plot(
            &format!("batch acceptance vs steps [{}]", objective.name()),
            &[("accept", &report.curve)],
            70,
            14
        )
    );
    Ok(())
}

fn table1(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let prompts = args.get_usize("prompts", 2000).map_err(anyhow::Error::msg)?;
    println!("{}", harness::table1(&rt, prompts));
    Ok(())
}

fn table2(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let n = args.get_usize("n", 40).map_err(anyhow::Error::msg)?;
    let train = args.get_usize("train", 0).map_err(anyhow::Error::msg)?;
    let methods_arg = args.get_or("methods", &harness::METHODS.join(","));
    let methods: Vec<&str> = methods_arg.split(',').collect();

    if train > 0 && methods.contains(&"dvi") {
        log::info(&format!("online-training DVI on {train} prompts first"));
        harness::online_train(rt.clone(), Objective::Dvi, train, false)?;
    }
    let result = harness::table2(rt, &methods, n)?;
    println!("{}", result.markdown);
    if let Some(path) = args.get("csv") {
        std::fs::write(path, &result.csv)?;
        log::info(&format!("csv written to {path}"));
    }
    Ok(())
}

fn table3(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let train = args.get_usize("train", 2000).map_err(anyhow::Error::msg)?;
    let n = args.get_usize("n", 25).map_err(anyhow::Error::msg)?;
    let objectives = [Objective::KlOnly, Objective::PgOnly, Objective::CeOnly];
    let results = harness::ablations(rt, &objectives, train, n)?;
    println!("{}", harness::table3_markdown(&results));
    Ok(())
}

fn fig2(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let train = args.get_usize("train", 2000).map_err(anyhow::Error::msg)?;
    let out_dir = PathBuf::from(args.get_or("out", "results"));
    std::fs::create_dir_all(&out_dir)?;
    for obj in [
        Objective::KlOnly,
        Objective::PgOnly,
        Objective::CeOnly,
        Objective::Dvi,
    ] {
        let report = harness::online_train(rt.clone(), obj, train, false)?;
        let path = out_dir.join(format!("fig2_{}.csv", obj.name()));
        let mut csv = String::from("step,batch_accept\n");
        for (s, a) in &report.curve {
            csv.push_str(&format!("{s},{a:.5}\n"));
        }
        std::fs::write(&path, csv)?;
        println!(
            "{}",
            ascii_plot(
                &format!("Fig2 [{}]: batch acceptance vs steps", obj.name()),
                &[("accept", &report.curve)],
                70,
                12
            )
        );
        println!("written {}", path.display());
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    // Tracing must be forced on before the router spawns its threads so
    // prefill/learner spans from the very first request are captured.
    let trace_out = args.get("trace-out").map(PathBuf::from);
    if trace_out.is_some() {
        trace::set_forced(Some(true));
    }
    let mut sink = trace_out.map(TraceSink::new);
    let rt = load_runtime(args)?;
    let port = args.get_usize("port", 7501).map_err(anyhow::Error::msg)?;
    let workers = args.get_usize("workers", 2).map_err(anyhow::Error::msg)?;
    let method = args.get_or("method", "dvi");
    let online = !args.flag("no-online");
    let batched = args.flag("batched");
    let max_batch = args.get_usize("max-batch", 8).map_err(anyhow::Error::msg)?;
    let max_slots = args.get_usize("slots", 16).map_err(anyhow::Error::msg)?;
    // Adaptive speculation depth: --adaptive-k (or DVI_ADAPTIVE_K=1)
    // turns it on; the knobs tune floor/ceiling/EMA/target. Off, every
    // round drafts the manifest k_spec (the bitwise-reference mode).
    let adaptive = if args.flag("adaptive-k") {
        let mut ad = AdaptiveK::from_env().unwrap_or_default();
        ad.floor = args.get_usize("k-floor", ad.floor).map_err(anyhow::Error::msg)?;
        ad.ceiling =
            args.get_usize("k-ceil", ad.ceiling).map_err(anyhow::Error::msg)?;
        ad.alpha = args.get_f64("k-alpha", ad.alpha).map_err(anyhow::Error::msg)?;
        ad.target =
            args.get_f64("k-target", ad.target).map_err(anyhow::Error::msg)?;
        Some(ad)
    } else {
        AdaptiveK::from_env()
    };
    // Prefix cache (batched mode): --prefix-cache (or DVI_PREFIX_CACHE=1)
    // turns it on; --cache-cap sizes the segment pool.
    let cache = if args.flag("prefix-cache") {
        let capacity =
            args.get_usize("cache-cap", 64).map_err(anyhow::Error::msg)?.max(1);
        Some(CacheConfig { capacity })
    } else {
        CacheConfig::from_env()
    };
    let cache_cap = cache.as_ref().map(|c| c.capacity);
    let tok = Arc::new(rt.tokenizer()?);
    let router = Arc::new(Router::start(
        rt.clone(),
        RouterConfig {
            workers,
            method,
            online,
            objective: Objective::Dvi,
            buffer_capacity: 8192,
            batched,
            max_batch,
            max_slots,
            adaptive,
            cache,
        },
    )?);
    let metrics_on = args.flag("metrics");
    let smoke = args.get_usize("smoke", 0).map_err(anyhow::Error::msg)?;
    if smoke > 0 {
        // Self-driven smoke run: push N prompts through the router
        // without binding a listener, print the observability surfaces,
        // flush the trace, and exit. CI drives this to validate the
        // trace/metrics pipeline end to end.
        let set = harness::load_prompts(&rt, &args.get_or("task", "qa"))?;
        ensure!(!set.samples.is_empty(), "no prompts for the smoke run");
        let rxs: Vec<_> = (0..smoke)
            .map(|i| {
                let s = &set.samples[i % set.samples.len()];
                router.submit(s.prompt.clone(), s.max_new)
            })
            .collect();
        let served = rxs.into_iter().filter(|rx| rx.recv().is_ok()).count();
        ensure!(served == smoke, "smoke run served {served}/{smoke}");
        println!("smoke: served {served}/{smoke}");
        println!("stats: {}", router.stats_json());
        if metrics_on {
            println!("metrics: {}", router.metrics_json());
        }
        if let Some(sink) = sink.as_mut() {
            sink.flush()?;
            println!("trace written to {}", sink.path().display());
        }
        return Ok(());
    }
    let listener = std::net::TcpListener::bind(("127.0.0.1", port as u16))?;
    let stop = Arc::new(AtomicBool::new(false));
    for s in router.executor_status() {
        match s.metrics {
            Some(m) => println!(
                "remote executor shard {} @ {}: {} buffers, {} sessions, \
                 inflight {}/{} (now/max)",
                s.shard, s.endpoint, m.buffers, m.sessions, m.inflight,
                m.max_inflight
            ),
            None => println!(
                "remote executor shard {} @ {}: UNREACHABLE",
                s.shard, s.endpoint
            ),
        }
    }
    let mut mode = if batched {
        format!("batched scheduler, max_batch={max_batch}, slots={max_slots}")
    } else {
        format!("{workers} workers")
    };
    if let Some(ad) = adaptive {
        let ceil = if ad.ceiling == usize::MAX {
            "k_spec".to_string()
        } else {
            ad.ceiling.to_string()
        };
        mode.push_str(&format!(
            ", adaptive-k [{}..{ceil}] target={} alpha={}",
            ad.floor, ad.target, ad.alpha
        ));
    }
    if let Some(cap) = cache_cap {
        mode.push_str(&format!(", prefix-cache cap={cap}"));
    }
    println!(
        "serving on 127.0.0.1:{port} ({mode}, online={online}); try:\n  \
         echo '{{\"prompt\": \"question : what owns ent01 ? <sep>\"}}' | nc 127.0.0.1 {port}\n  \
         echo '{{\"metrics\": true}}' | nc 127.0.0.1 {port}"
    );
    // Periodic report: serving stats, executor health (incl. the mux
    // pipelining gauges), a never-silent trace-overflow warning, and —
    // with --metrics — the quantile registry. Also the flush cadence
    // for --trace-out. `--report-secs 0` silences the report but keeps
    // flushing an active trace sink.
    let report_secs =
        args.get_usize("report-secs", 30).map_err(anyhow::Error::msg)?;
    if report_secs > 0 || sink.is_some() {
        let quiet = report_secs == 0;
        let secs = if quiet { 5 } else { report_secs as u64 };
        let r2 = router.clone();
        let mut sink = sink.take();
        std::thread::Builder::new().name("dvi-report".into()).spawn(
            move || loop {
                std::thread::sleep(std::time::Duration::from_secs(secs));
                if !quiet {
                    println!("stats: {}", r2.stats_json());
                    for s in r2.executor_status() {
                        if let Some(m) = s.metrics {
                            println!(
                                "  shard {} @ {}: {} calls, occupancy \
                                 {:.2}, inflight {}/{} (now/max)",
                                s.shard,
                                s.endpoint,
                                m.calls,
                                m.occupancy(),
                                m.inflight,
                                m.max_inflight
                            );
                        }
                    }
                    if metrics_on {
                        println!("metrics: {}", r2.metrics_json());
                    }
                }
                let dropped = trace::drop_count();
                if dropped > 0 {
                    println!(
                        "WARNING: trace ring overflow — {dropped} events \
                         dropped so far (raise DVI_TRACE_BUF)"
                    );
                }
                if let Some(sink) = sink.as_mut() {
                    if let Err(e) = sink.flush() {
                        log::info(&format!("trace flush failed: {e:#}"));
                    }
                }
            },
        )?;
    }
    api::serve(listener, router, tok, stop)
}

/// Reduce a Chrome trace (from `serve --trace-out` or an externally
/// captured `DVI_TRACE=1` run) to per-phase/per-shard latency quantiles.
fn trace_summary(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.get("trace"))
        .context("usage: dvi trace-summary FILE.json")?
        .to_string();
    let doc = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {path}"))?;
    let (stats, dropped) = chrome::summarize(&doc)?;
    ensure!(!stats.is_empty(), "trace {path} holds no complete events");
    print!("{}", chrome::summary_table(&stats));
    if dropped > 0 {
        println!("(dropped events: {dropped})");
    }
    Ok(())
}

/// Trajectory gate: diff two schema-versioned `BENCH_*.json` artifacts
/// of the same bench (see `dvi::metrics::bench` and BENCHMARKS.md).
/// Exits non-zero when any judged metric regresses beyond the relative
/// tolerance band, unless `--warn-only` (CI's cross-machine mode, where
/// absolute timings are advisory) downgrades that to a printed warning.
fn bench_compare(args: &Args) -> Result<()> {
    let usage =
        "usage: dvi bench-compare OLD.json NEW.json [--tol 0.10] [--warn-only]";
    let old_path = args.positional.first().context(usage)?;
    let new_path = args.positional.get(1).context(usage)?;
    let tol = args.get_f64("tol", 0.10).map_err(anyhow::Error::msg)?;
    let load = |path: &str| -> Result<dvi::util::json::Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        dvi::util::json::Json::parse(&text)
            .map_err(|e| anyhow!("parsing {path}: {e}"))
    };
    let report =
        dvi::metrics::bench::compare(&load(old_path)?, &load(new_path)?, tol)?;
    print!("{}", report.render());
    if report.has_regression() {
        if args.flag("warn-only") {
            println!(
                "bench-compare: {} regression(s) beyond +/-{:.1}% \
                 (warn-only: exit 0)",
                report.regressions(),
                tol * 100.0
            );
        } else {
            bail!(
                "{} metric(s) regressed beyond the +/-{:.1}% band",
                report.regressions(),
                tol * 100.0
            );
        }
    }
    Ok(())
}

/// Executor-server mode: front the locally selected backend over the
/// remote-executor wire protocol, so `serve --batched --backend remote`
/// (or any other subcommand) in another process can point its lanes
/// here.
fn serve_backend(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    if rt.backend_name().starts_with("remote") {
        bail!(
            "refusing to re-export a remote backend \
             (serve-backend must front a local backend)"
        );
    }
    let listen = args.get_or("listen", "127.0.0.1:7600");
    let listener = std::net::TcpListener::bind(listen.as_str())
        .with_context(|| format!("binding executor listener on {listen}"))?;
    println!(
        "executor backend '{}' listening on {listen}; point a client at it:\n  \
         dvi serve --batched --backend remote --remote {listen}",
        rt.backend_name()
    );
    // The CLI has no graceful-shutdown trigger: the server runs until
    // the process is killed. The stop flag exists for embedders (and
    // tests) that drive serve_tcp directly.
    let stop = Arc::new(AtomicBool::new(false));
    dvi::runtime::remote::server::serve_tcp(listener, rt, stop)
}
