//! `dvi` — the serving/benchmark launcher.
//!
//! Subcommands:
//!   info                         inspect artifacts/manifest
//!   run      --method dvi --task qa --n 5 [--online]
//!   train    --objective dvi --prompts 2000 [--curve out.csv]
//!   table1                       training-budget comparison (Table 1)
//!   table2   --n 40 [--methods dvi,ar,...] [--train 2000]
//!   table3   --train 2000 --n 25  objective ablations (Table 3)
//!   fig2     --train 2000        ablation learning curves (Figure 2)
//!   serve    --port 7501 --workers 2 [--no-online]
//!            [--batched --max-batch 8 --slots 16]   continuous batching
//!            [--prefix-cache --cache-cap 64]   radix prefix/KV reuse
//!            (batched mode; or DVI_PREFIX_CACHE=1)
//!            [--metrics] [--trace-out FILE] [--report-secs 30]
//!            [--smoke N]  observability: quantile metrics in the
//!            periodic report, Chrome-trace export (forces tracing on),
//!            or a self-driven N-prompt smoke run (no listener)
//!   trace-summary FILE.json      reduce a Chrome trace to per-phase
//!            latency quantiles (from `serve --trace-out` / DVI_TRACE);
//!            merged fleet traces additionally get a per-shard
//!            client/server/wire latency decomposition
//!   trace-collect [OUT.json] --backend remote --remote h1:p1,h2:p2
//!            drain every executor's trace ring + metrics over the wire
//!            and write ONE merged, clock-aligned Chrome trace (client
//!            track + one process track per shard)
//!   bench-compare OLD.json NEW.json [--tol 0.10] [--warn-only]
//!            trajectory gate: diff two schema-versioned BENCH_*.json
//!            artifacts of the same bench; exits non-zero when a metric
//!            regresses beyond the tolerance band (see BENCHMARKS.md)
//!   serve-backend --listen 127.0.0.1:7600           executor server:
//!            front the local backend (reference/pjrt) for remote
//!            clients (`--backend remote --remote HOST:PORT`, or
//!            DVI_REMOTE=HOST:PORT with any subcommand). Run several
//!            and pass a comma list (`--remote h1:p1,h2:p2` /
//!            DVI_REMOTE=h1:p1,h2:p2) for a sharded fleet: sequences
//!            round-robin across executors, KV stays put per shard,
//!            and a dead executor degrades (its lanes fail) instead of
//!            wedging serving
//!
//! Everything reads `--artifacts DIR` (default: ./artifacts).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Context, Result};

use dvi::engine::Engine;
use dvi::harness;
use dvi::learner::Objective;
use dvi::obs::{chrome, trace, TraceSink};
use dvi::runtime::{log, Runtime};
use dvi::sched::{AdaptiveK, CacheConfig};
use dvi::server::{api, Router, RouterConfig};
use dvi::util::cli::Args;
use dvi::util::plot::ascii_plot;

const FLAGS: [&str; 9] = [
    "online", "no-online", "quiet", "verbose", "batched", "adaptive-k",
    "metrics", "prefix-cache", "warn-only",
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv, &FLAGS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if args.flag("quiet") {
        log::set_level(0);
    }
    if args.flag("verbose") {
        log::set_level(2);
    }
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Backend selection: `--backend reference` forces the hermetic
/// pure-Rust backend; `--backend pjrt` requires compiled artifacts (and
/// the `pjrt` cargo feature); `--backend remote` ships every artifact
/// call to `dvi serve-backend` executor(s) (`--remote HOST:PORT`, or a
/// comma list `h1:p1,h2:p2` for a sharded fleet; DVI_REMOTE accepts the
/// same syntax); the default `auto` prefers DVI_REMOTE, then PJRT when
/// available, and falls back to the reference backend.
fn load_runtime(args: &Args) -> Result<Arc<Runtime>> {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let rt = match args.get_or("backend", "auto").as_str() {
        "reference" => {
            let seed = args
                .get_usize("seed", dvi::runtime::REFERENCE_SEED as usize)
                .map_err(anyhow::Error::msg)? as u64;
            Runtime::load_reference(seed)?
        }
        "pjrt" => Runtime::load(&dir, None)?,
        "remote" => {
            let addr = match args.get("remote") {
                Some(a) => a.to_string(),
                None => std::env::var("DVI_REMOTE").context(
                    "--backend remote needs --remote HOST:PORT (or DVI_REMOTE)",
                )?,
            };
            Runtime::load_remote(&addr)?
        }
        "auto" => Runtime::load_auto(&dir)?,
        other => bail!("unknown --backend '{other}' (auto|reference|pjrt|remote)"),
    };
    Ok(Arc::new(rt))
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("info") => info(args),
        Some("run") => run(args),
        Some("train") => train(args),
        Some("table1") => table1(args),
        Some("table2") => table2(args),
        Some("table3") => table3(args),
        Some("fig2") => fig2(args),
        Some("serve") => serve(args),
        Some("serve-backend") => serve_backend(args),
        Some("trace-summary") => trace_summary(args),
        Some("trace-collect") => trace_collect(args),
        Some("bench-compare") => bench_compare(args),
        Some(other) => bail!("unknown subcommand '{other}' (see src/main.rs docs)"),
        None => bail!(
            "usage: dvi <info|run|train|table1|table2|table3|fig2|serve|\
             serve-backend|trace-summary|trace-collect|bench-compare> [...]"
        ),
    }
}

fn info(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    println!("backend: {}", rt.backend_name());
    for s in rt.executor_status() {
        match s.metrics {
            Some(m) => println!(
                "  shard {} @ {}: {} calls, occupancy {:.2}, {} buffers, \
                 {} sessions, inflight {}/{} (now/max)",
                s.shard, s.endpoint, m.calls, m.occupancy(), m.buffers,
                m.sessions, m.inflight, m.max_inflight
            ),
            None => println!("  shard {} @ {}: UNREACHABLE", s.shard, s.endpoint),
        }
    }
    println!(
        "trace: {} (dropped events: {})",
        if trace::enabled() { "on" } else { "off (set DVI_TRACE=1)" },
        trace::drop_count()
    );
    println!("artifacts: {}", rt.manifest.dir.display());
    println!("model config: {}", rt.manifest.config.get("model"));
    println!("spec config: {}", rt.manifest.config.get("spec"));
    for (name, spec) in &rt.manifest.artifacts {
        println!(
            "  {name}: {} params, {} outputs",
            spec.params.len(),
            spec.outputs.len()
        );
    }
    Ok(())
}

fn run(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let method = args.get_or("method", "dvi");
    let task = args.get_or("task", "qa");
    let n = args.get_usize("n", 5).map_err(anyhow::Error::msg)?;
    let tok = rt.tokenizer()?;

    if args.flag("online") {
        let prompts = args.get_usize("train", 300).map_err(anyhow::Error::msg)?;
        log::info(&format!("online pre-training on {prompts} prompts"));
        harness::online_train(rt.clone(), Objective::Dvi, prompts, false)?;
    }

    let set = harness::load_prompts(&rt, &task)?;
    let mut engine = harness::make_engine(rt.clone(), &method)?;
    for s in set.samples.iter().take(n) {
        let r = engine.generate(&s.prompt, s.max_new)?;
        println!(
            "--- task={task} prompt: {}",
            tok.decode(&s.prompt[1..s.prompt.len().min(24)])
        );
        println!("    output: {}", tok.decode(&r.tokens));
        println!(
            "    mat={:.2} accept={:.2} decode={:.1}ms tokens={}",
            r.mat(),
            r.acceptance_rate(),
            r.decode_ns as f64 / 1e6,
            r.tokens.len()
        );
    }
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let objective = Objective::parse(&args.get_or("objective", "dvi"))
        .context("bad --objective (dvi|kl|pg|ce)")?;
    let prompts = args.get_usize("prompts", 2000).map_err(anyhow::Error::msg)?;
    let report = harness::online_train(rt, objective, prompts, false)?;
    println!(
        "trained {} steps over {} prompts",
        report.trainer_steps, report.prompts_seen
    );
    if let Some(path) = args.get("curve") {
        let mut csv = String::from("step,batch_accept\n");
        for (s, a) in &report.curve {
            csv.push_str(&format!("{s},{a:.5}\n"));
        }
        std::fs::write(path, csv)?;
        println!("curve written to {path}");
    }
    println!(
        "{}",
        ascii_plot(
            &format!("batch acceptance vs steps [{}]", objective.name()),
            &[("accept", &report.curve)],
            70,
            14
        )
    );
    Ok(())
}

fn table1(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let prompts = args.get_usize("prompts", 2000).map_err(anyhow::Error::msg)?;
    println!("{}", harness::table1(&rt, prompts));
    Ok(())
}

fn table2(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let n = args.get_usize("n", 40).map_err(anyhow::Error::msg)?;
    let train = args.get_usize("train", 0).map_err(anyhow::Error::msg)?;
    let methods_arg = args.get_or("methods", &harness::METHODS.join(","));
    let methods: Vec<&str> = methods_arg.split(',').collect();

    if train > 0 && methods.contains(&"dvi") {
        log::info(&format!("online-training DVI on {train} prompts first"));
        harness::online_train(rt.clone(), Objective::Dvi, train, false)?;
    }
    let result = harness::table2(rt, &methods, n)?;
    println!("{}", result.markdown);
    if let Some(path) = args.get("csv") {
        std::fs::write(path, &result.csv)?;
        log::info(&format!("csv written to {path}"));
    }
    Ok(())
}

fn table3(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let train = args.get_usize("train", 2000).map_err(anyhow::Error::msg)?;
    let n = args.get_usize("n", 25).map_err(anyhow::Error::msg)?;
    let objectives = [Objective::KlOnly, Objective::PgOnly, Objective::CeOnly];
    let results = harness::ablations(rt, &objectives, train, n)?;
    println!("{}", harness::table3_markdown(&results));
    Ok(())
}

fn fig2(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let train = args.get_usize("train", 2000).map_err(anyhow::Error::msg)?;
    let out_dir = PathBuf::from(args.get_or("out", "results"));
    std::fs::create_dir_all(&out_dir)?;
    for obj in [
        Objective::KlOnly,
        Objective::PgOnly,
        Objective::CeOnly,
        Objective::Dvi,
    ] {
        let report = harness::online_train(rt.clone(), obj, train, false)?;
        let path = out_dir.join(format!("fig2_{}.csv", obj.name()));
        let mut csv = String::from("step,batch_accept\n");
        for (s, a) in &report.curve {
            csv.push_str(&format!("{s},{a:.5}\n"));
        }
        std::fs::write(&path, csv)?;
        println!(
            "{}",
            ascii_plot(
                &format!("Fig2 [{}]: batch acceptance vs steps", obj.name()),
                &[("accept", &report.curve)],
                70,
                12
            )
        );
        println!("written {}", path.display());
    }
    Ok(())
}

/// Flush the trace sink as a merged fleet document when the runtime
/// fronts remote executors: the client's accumulated ring stays on
/// [`chrome::CLIENT_PID`] and every shard's drained events land on
/// their own process track, clock-aligned onto the client epoch. Shard
/// events accumulate in `shard_tracks` across flushes (executor pulls
/// are destructive — each event arrives exactly once). Falls back to
/// the flat single-process flush for in-process backends.
fn flush_fleet_trace(
    sink: &mut TraceSink,
    rt: &Runtime,
    shard_tracks: &mut BTreeMap<u64, chrome::ProcessTrack>,
) -> Result<()> {
    let pulls = match rt.obs_pull() {
        Ok(p) => p,
        Err(e) => {
            // A flapping executor must not kill the flush cadence: keep
            // the tracks pulled so far and merge again next tick.
            log::info(&format!("fleet trace pull failed: {e:#}"));
            Vec::new()
        }
    };
    sink.absorb();
    for obs in pulls {
        let track = obs.into_track();
        match shard_tracks.get_mut(&track.pid) {
            Some(t) => {
                t.events.extend(track.events);
                t.dropped = track.dropped;
            }
            None => {
                shard_tracks.insert(track.pid, track);
            }
        }
    }
    if shard_tracks.is_empty() {
        return sink.flush();
    }
    let mut tracks = vec![chrome::ProcessTrack {
        pid: chrome::CLIENT_PID,
        label: "dvi client".to_string(),
        events: sink.events().iter().map(trace::Event::to_owned_event).collect(),
        dropped: trace::drop_count(),
    }];
    tracks.extend(shard_tracks.values().cloned());
    chrome::write_doc_atomic(
        sink.path(),
        &chrome::render_merged(&tracks, sink.truncated()),
    )
}

fn serve(args: &Args) -> Result<()> {
    // Tracing must be forced on before the router spawns its threads so
    // prefill/learner spans from the very first request are captured.
    let trace_out = args.get("trace-out").map(PathBuf::from);
    if trace_out.is_some() {
        trace::set_forced(Some(true));
    }
    let mut sink = trace_out.map(TraceSink::new);
    let rt = load_runtime(args)?;
    let port = args.get_usize("port", 7501).map_err(anyhow::Error::msg)?;
    let workers = args.get_usize("workers", 2).map_err(anyhow::Error::msg)?;
    let method = args.get_or("method", "dvi");
    let online = !args.flag("no-online");
    let batched = args.flag("batched");
    let max_batch = args.get_usize("max-batch", 8).map_err(anyhow::Error::msg)?;
    let max_slots = args.get_usize("slots", 16).map_err(anyhow::Error::msg)?;
    // Adaptive speculation depth: --adaptive-k (or DVI_ADAPTIVE_K=1)
    // turns it on; the knobs tune floor/ceiling/EMA/target. Off, every
    // round drafts the manifest k_spec (the bitwise-reference mode).
    let adaptive = if args.flag("adaptive-k") {
        let mut ad = AdaptiveK::from_env().unwrap_or_default();
        ad.floor = args.get_usize("k-floor", ad.floor).map_err(anyhow::Error::msg)?;
        ad.ceiling =
            args.get_usize("k-ceil", ad.ceiling).map_err(anyhow::Error::msg)?;
        ad.alpha = args.get_f64("k-alpha", ad.alpha).map_err(anyhow::Error::msg)?;
        ad.target =
            args.get_f64("k-target", ad.target).map_err(anyhow::Error::msg)?;
        Some(ad)
    } else {
        AdaptiveK::from_env()
    };
    // Prefix cache (batched mode): --prefix-cache (or DVI_PREFIX_CACHE=1)
    // turns it on; --cache-cap sizes the segment pool.
    let cache = if args.flag("prefix-cache") {
        let capacity =
            args.get_usize("cache-cap", 64).map_err(anyhow::Error::msg)?.max(1);
        Some(CacheConfig { capacity })
    } else {
        CacheConfig::from_env()
    };
    let cache_cap = cache.as_ref().map(|c| c.capacity);
    let tok = Arc::new(rt.tokenizer()?);
    let router = Arc::new(Router::start(
        rt.clone(),
        RouterConfig {
            workers,
            method,
            online,
            objective: Objective::Dvi,
            buffer_capacity: 8192,
            batched,
            max_batch,
            max_slots,
            adaptive,
            cache,
        },
    )?);
    let metrics_on = args.flag("metrics");
    let smoke = args.get_usize("smoke", 0).map_err(anyhow::Error::msg)?;
    if smoke > 0 {
        // Self-driven smoke run: push N prompts through the router
        // without binding a listener, print the observability surfaces,
        // flush the trace, and exit. CI drives this to validate the
        // trace/metrics pipeline end to end.
        let set = harness::load_prompts(&rt, &args.get_or("task", "qa"))?;
        ensure!(!set.samples.is_empty(), "no prompts for the smoke run");
        let rxs: Vec<_> = (0..smoke)
            .map(|i| {
                let s = &set.samples[i % set.samples.len()];
                router.submit(s.prompt.clone(), s.max_new)
            })
            .collect();
        let served = rxs.into_iter().filter(|rx| rx.recv().is_ok()).count();
        ensure!(served == smoke, "smoke run served {served}/{smoke}");
        println!("smoke: served {served}/{smoke}");
        println!("stats: {}", router.stats_json());
        println!("{}", router.health.report_line());
        if metrics_on {
            println!("metrics: {}", router.metrics_json());
        }
        if let Some(sink) = sink.as_mut() {
            let mut shard_tracks = BTreeMap::new();
            flush_fleet_trace(sink, &rt, &mut shard_tracks)?;
            if sink.truncated() > 0 {
                println!(
                    "WARNING: trace export capped — {} events discarded \
                     (raise DVI_TRACE_MAX)",
                    sink.truncated()
                );
            }
            println!(
                "trace written to {}{}",
                sink.path().display(),
                if shard_tracks.is_empty() {
                    String::new()
                } else {
                    format!(" (merged, {} executor tracks)", shard_tracks.len())
                }
            );
        }
        return Ok(());
    }
    let listener = std::net::TcpListener::bind(("127.0.0.1", port as u16))?;
    let stop = Arc::new(AtomicBool::new(false));
    for s in router.executor_status() {
        match s.metrics {
            Some(m) => println!(
                "remote executor shard {} @ {}: {} buffers, {} sessions, \
                 inflight {}/{} (now/max)",
                s.shard, s.endpoint, m.buffers, m.sessions, m.inflight,
                m.max_inflight
            ),
            None => println!(
                "remote executor shard {} @ {}: UNREACHABLE",
                s.shard, s.endpoint
            ),
        }
    }
    let mut mode = if batched {
        format!("batched scheduler, max_batch={max_batch}, slots={max_slots}")
    } else {
        format!("{workers} workers")
    };
    if let Some(ad) = adaptive {
        let ceil = if ad.ceiling == usize::MAX {
            "k_spec".to_string()
        } else {
            ad.ceiling.to_string()
        };
        mode.push_str(&format!(
            ", adaptive-k [{}..{ceil}] target={} alpha={}",
            ad.floor, ad.target, ad.alpha
        ));
    }
    if let Some(cap) = cache_cap {
        mode.push_str(&format!(", prefix-cache cap={cap}"));
    }
    println!(
        "serving on 127.0.0.1:{port} ({mode}, online={online}); try:\n  \
         echo '{{\"prompt\": \"question : what owns ent01 ? <sep>\"}}' | nc 127.0.0.1 {port}\n  \
         echo '{{\"metrics\": true}}' | nc 127.0.0.1 {port}\n  \
         echo '{{\"health\": true}}' | nc 127.0.0.1 {port}"
    );
    // Periodic report: serving stats, executor health (incl. the mux
    // pipelining gauges), a never-silent trace-overflow warning, and —
    // with --metrics — the quantile registry. Also the flush cadence
    // for --trace-out. `--report-secs 0` silences the report but keeps
    // flushing an active trace sink.
    let report_secs =
        args.get_usize("report-secs", 30).map_err(anyhow::Error::msg)?;
    if report_secs > 0 || sink.is_some() {
        let quiet = report_secs == 0;
        let secs = if quiet { 5 } else { report_secs as u64 };
        let r2 = router.clone();
        let rt2 = rt.clone();
        let mut sink = sink.take();
        let mut shard_tracks = BTreeMap::new();
        std::thread::Builder::new().name("dvi-report".into()).spawn(
            move || loop {
                std::thread::sleep(std::time::Duration::from_secs(secs));
                if !quiet {
                    println!("stats: {}", r2.stats_json());
                    println!("{}", r2.health.report_line());
                    for s in r2.executor_status() {
                        if let Some(m) = s.metrics {
                            println!(
                                "  shard {} @ {}: {} calls, occupancy \
                                 {:.2}, inflight {}/{} (now/max)",
                                s.shard,
                                s.endpoint,
                                m.calls,
                                m.occupancy(),
                                m.inflight,
                                m.max_inflight
                            );
                        }
                    }
                    if metrics_on {
                        println!("metrics: {}", r2.metrics_json());
                    }
                }
                let dropped = trace::drop_count();
                if dropped > 0 {
                    println!(
                        "WARNING: trace ring overflow — {dropped} events \
                         dropped so far (raise DVI_TRACE_BUF)"
                    );
                }
                if let Some(sink) = sink.as_mut() {
                    if let Err(e) =
                        flush_fleet_trace(sink, &rt2, &mut shard_tracks)
                    {
                        log::info(&format!("trace flush failed: {e:#}"));
                    }
                    if sink.truncated() > 0 {
                        println!(
                            "WARNING: trace export capped — {} events \
                             discarded so far (raise DVI_TRACE_MAX)",
                            sink.truncated()
                        );
                    }
                }
            },
        )?;
    }
    api::serve(listener, router, tok, stop)
}

/// Reduce a Chrome trace (from `serve --trace-out` or an externally
/// captured `DVI_TRACE=1` run) to per-phase/per-shard latency
/// quantiles. Merged fleet traces (from `trace-collect` or a remote
/// `serve --trace-out`) additionally get the per-shard
/// client/server/wire decomposition: each client `rpc.call` span paired
/// with the executor `exec` span carrying the same call id.
fn trace_summary(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.get("trace"))
        .context("usage: dvi trace-summary FILE.json")?
        .to_string();
    let doc = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {path}"))?;
    let (stats, dropped, truncated) = chrome::summarize(&doc)?;
    ensure!(!stats.is_empty(), "trace {path} holds no complete events");
    print!("{}", chrome::summary_table(&stats));
    let decomp = chrome::decompose(&doc)?;
    if !decomp.is_empty() {
        println!("\nper-shard client/server/wire decomposition:");
        print!("{}", chrome::decomp_table(&decomp));
    }
    if dropped > 0 {
        println!("(dropped events: {dropped})");
    }
    if truncated > 0 {
        println!(
            "WARNING: export was capped — {truncated} events discarded by \
             DVI_TRACE_MAX; quantiles above cover the surviving prefix"
        );
    }
    Ok(())
}

/// Drain trace events + metrics from every executor of a remote fleet
/// and write ONE merged, clock-aligned Chrome trace: this process's
/// ring on the client track, each shard on its own process track with
/// timestamps shifted onto the local epoch by the per-connection offset
/// estimator. Destructive on the executors' rings (each event is
/// collected exactly once), so successive collects tile the timeline.
fn trace_collect(args: &Args) -> Result<()> {
    let out = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.get("out"))
        .unwrap_or("trace_fleet.json")
        .to_string();
    let rt = load_runtime(args)?;
    let pulls = rt.obs_pull()?;
    ensure!(
        !pulls.is_empty(),
        "backend '{}' fronts no remote executors to collect from \
         (use --backend remote --remote h1:p1,h2:p2 or DVI_REMOTE)",
        rt.backend_name()
    );
    let mut tracks = vec![chrome::ProcessTrack {
        pid: chrome::CLIENT_PID,
        label: "dvi client".to_string(),
        events: trace::drain().iter().map(trace::Event::to_owned_event).collect(),
        dropped: trace::drop_count(),
    }];
    for obs in pulls {
        println!(
            "shard {} @ {}: {} events, clock offset {:+} ns (+/- {} ns), \
             {} dropped",
            obs.shard,
            obs.endpoint,
            obs.events.len(),
            obs.offset.offset_ns,
            obs.offset.uncertainty_ns,
            obs.dropped
        );
        tracks.push(obs.into_track());
    }
    let path = PathBuf::from(&out);
    chrome::write_doc_atomic(&path, &chrome::render_merged(&tracks, 0))?;
    println!(
        "merged fleet trace written to {out} ({} process tracks); reduce it \
         with: dvi trace-summary {out}",
        tracks.len()
    );
    Ok(())
}

/// Trajectory gate: diff two schema-versioned `BENCH_*.json` artifacts
/// of the same bench (see `dvi::metrics::bench` and BENCHMARKS.md).
/// Exits non-zero when any judged metric regresses beyond the relative
/// tolerance band, unless `--warn-only` (CI's cross-machine mode, where
/// absolute timings are advisory) downgrades that to a printed warning.
fn bench_compare(args: &Args) -> Result<()> {
    let usage =
        "usage: dvi bench-compare OLD.json NEW.json [--tol 0.10] [--warn-only]";
    let old_path = args.positional.first().context(usage)?;
    let new_path = args.positional.get(1).context(usage)?;
    let tol = args.get_f64("tol", 0.10).map_err(anyhow::Error::msg)?;
    let load = |path: &str| -> Result<dvi::util::json::Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        dvi::util::json::Json::parse(&text)
            .map_err(|e| anyhow!("parsing {path}: {e}"))
    };
    let report =
        dvi::metrics::bench::compare(&load(old_path)?, &load(new_path)?, tol)?;
    print!("{}", report.render());
    if report.has_regression() {
        if args.flag("warn-only") {
            println!(
                "bench-compare: {} regression(s) beyond +/-{:.1}% \
                 (warn-only: exit 0)",
                report.regressions(),
                tol * 100.0
            );
        } else {
            bail!(
                "{} metric(s) regressed beyond the +/-{:.1}% band",
                report.regressions(),
                tol * 100.0
            );
        }
    }
    Ok(())
}

/// Executor-server mode: front the locally selected backend over the
/// remote-executor wire protocol, so `serve --batched --backend remote`
/// (or any other subcommand) in another process can point its lanes
/// here.
fn serve_backend(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    if rt.backend_name().starts_with("remote") {
        bail!(
            "refusing to re-export a remote backend \
             (serve-backend must front a local backend)"
        );
    }
    let listen = args.get_or("listen", "127.0.0.1:7600");
    let listener = std::net::TcpListener::bind(listen.as_str())
        .with_context(|| format!("binding executor listener on {listen}"))?;
    println!(
        "executor backend '{}' listening on {listen}; point a client at it:\n  \
         dvi serve --batched --backend remote --remote {listen}",
        rt.backend_name()
    );
    // The CLI has no graceful-shutdown trigger: the server runs until
    // the process is killed. The stop flag exists for embedders (and
    // tests) that drive serve_tcp directly.
    let stop = Arc::new(AtomicBool::new(false));
    dvi::runtime::remote::server::serve_tcp(listener, rt, stop)
}
