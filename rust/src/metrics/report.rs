//! Markdown/CSV renderers for the paper's tables.

use std::collections::BTreeMap;

use super::RunMetrics;

/// Render Table-2-shaped results: rows = methods, per-task MAT + speedup
/// columns + average speedup. `tasks` fixes column order; `baseline` is
/// the method name speedups are measured against (excluded from rows? no —
/// shown as 1.00x, like Spec-Bench shows vanilla AR implicitly).
pub fn render_table2(
    tasks: &[&str],
    methods: &[&str],
    results: &BTreeMap<(String, String), RunMetrics>,
    baseline: &str,
) -> String {
    let mut out = String::new();
    out.push_str("| Method |");
    for t in tasks {
        out.push_str(&format!(" {t} MAT | {t} Speedup |"));
    }
    out.push_str(" Avg. |\n|---|");
    for _ in tasks {
        out.push_str("---|---|");
    }
    out.push_str("---|\n");
    for m in methods {
        let mut row = format!("| {m} |");
        let mut sum = 0.0;
        let mut cnt = 0;
        for t in tasks {
            let key = (m.to_string(), t.to_string());
            let base_key = (baseline.to_string(), t.to_string());
            match (results.get(&key), results.get(&base_key)) {
                (Some(r), Some(b)) => match r.speedup_opt(b) {
                    Some(sp) => {
                        sum += sp;
                        cnt += 1;
                        row.push_str(&format!(
                            " {:.2} | {:.2}x |", r.mat.mean(), sp));
                    }
                    // Baseline ran but recorded no decode time: show the
                    // MAT, leave speedup unmeasured (and out of the Avg).
                    None => row.push_str(&format!(" {:.2} | - |", r.mat.mean())),
                },
                _ => row.push_str(" - | - |"),
            }
        }
        if cnt > 0 {
            row.push_str(&format!(" {:.2}x |", sum / cnt as f64));
        } else {
            row.push_str(" - |");
        }
        out.push_str(&row);
        out.push('\n');
    }
    out
}

/// CSV export of the same grid (one row per method x task).
pub fn csv_table2(
    tasks: &[&str],
    methods: &[&str],
    results: &BTreeMap<(String, String), RunMetrics>,
    baseline: &str,
) -> String {
    let mut out =
        String::from("method,task,mat,acceptance,tokens_per_sec,speedup,prompts,new_tokens\n");
    for m in methods {
        for t in tasks {
            let key = (m.to_string(), t.to_string());
            let base_key = (baseline.to_string(), t.to_string());
            if let Some(r) = results.get(&key) {
                // Missing/zero baseline -> empty field, not "0.0000":
                // a literal zero poisons any downstream column average,
                // while an empty cell is skipped by CSV consumers.
                let sp = results
                    .get(&base_key)
                    .and_then(|b| r.speedup_opt(b))
                    .map(|s| format!("{s:.4}"))
                    .unwrap_or_default();
                out.push_str(&format!(
                    "{m},{t},{:.4},{:.4},{:.2},{sp},{},{}\n",
                    r.mat.mean(),
                    r.acceptance.mean(),
                    r.tokens_per_sec(),
                    r.prompts,
                    r.new_tokens
                ));
            }
        }
    }
    out
}

/// Table 3 (ablations): objective -> (MAT, speedup).
pub fn render_table3(rows: &[(String, f64, f64)]) -> String {
    let mut out = String::from(
        "| Objective | Mean accepted tokens (MAT) | Speedup |\n|---|---|---|\n",
    );
    for (name, mat, speedup) in rows {
        out.push_str(&format!("| {name} | {mat:.3} | {speedup:.3}x |\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{GenResult, StepRecord};

    fn metrics(tokens: usize, ns: u64) -> RunMetrics {
        let mut m = RunMetrics::default();
        m.add(&GenResult {
            tokens: vec![1; tokens],
            decode_ns: ns,
            prefill_ns: 0,
            steps: vec![StepRecord {
                drafted: 4, accepted: 2, committed: 3,
                draft_ns: 1, verify_ns: 1,
            }],
        });
        m
    }

    #[test]
    fn table2_renders() {
        let mut results = BTreeMap::new();
        results.insert(("dvi".into(), "qa".into()), metrics(20, 1_000));
        results.insert(("ar".into(), "qa".into()), metrics(10, 1_000));
        let md = render_table2(&["qa"], &["dvi", "ar"], &results, "ar");
        assert!(md.contains("| dvi |"));
        assert!(md.contains("2.00x"));
        let csv = csv_table2(&["qa"], &["dvi"], &results, "ar");
        assert!(csv.lines().count() == 2);
    }

    #[test]
    fn csv_missing_baseline_leaves_speedup_empty() {
        let mut results = BTreeMap::new();
        results.insert(("dvi".into(), "qa".into()), metrics(20, 1_000));
        // Baseline absent entirely: speedup column must be empty, not a
        // literal 0.0000 that a consumer would average in.
        let csv = csv_table2(&["qa"], &["dvi"], &results, "ar");
        let row = csv.lines().nth(1).unwrap();
        let fields: Vec<&str> = row.split(',').collect();
        assert_eq!(fields.len(), 8, "row keeps all columns: {row}");
        assert_eq!(fields[5], "", "speedup should be empty: {row}");

        // Baseline present but with zero decode throughput: same rule.
        results.insert(("ar".into(), "qa".into()), RunMetrics::default());
        let csv = csv_table2(&["qa"], &["dvi"], &results, "ar");
        let row = csv.lines().nth(1).unwrap();
        assert_eq!(row.split(',').nth(5), Some(""), "zero baseline: {row}");

        // And the markdown table keeps such cells out of the average.
        let md = render_table2(&["qa"], &["dvi"], &results, "ar");
        let dvi_row = md.lines().find(|l| l.starts_with("| dvi |")).unwrap();
        assert!(dvi_row.ends_with("| - | - |"), "no fake avg: {dvi_row}");
    }

    #[test]
    fn table2_missing_cells() {
        let results = BTreeMap::new();
        let md = render_table2(&["qa"], &["dvi"], &results, "ar");
        assert!(md.contains(" - |"));
    }

    #[test]
    fn table3_renders() {
        let md = render_table3(&[("kl-only".into(), 1.93, 1.43)]);
        assert!(md.contains("kl-only"));
        assert!(md.contains("1.930"));
    }
}
