//! Markdown/CSV renderers for the paper's tables.

use std::collections::BTreeMap;

use super::RunMetrics;

/// Render Table-2-shaped results: rows = methods, per-task MAT + speedup
/// columns + average speedup. `tasks` fixes column order; `baseline` is
/// the method name speedups are measured against (excluded from rows? no —
/// shown as 1.00x, like Spec-Bench shows vanilla AR implicitly).
pub fn render_table2(
    tasks: &[&str],
    methods: &[&str],
    results: &BTreeMap<(String, String), RunMetrics>,
    baseline: &str,
) -> String {
    let mut out = String::new();
    out.push_str("| Method |");
    for t in tasks {
        out.push_str(&format!(" {t} MAT | {t} Speedup |"));
    }
    out.push_str(" Avg. |\n|---|");
    for _ in tasks {
        out.push_str("---|---|");
    }
    out.push_str("---|\n");
    for m in methods {
        let mut row = format!("| {m} |");
        let mut sum = 0.0;
        let mut cnt = 0;
        for t in tasks {
            let key = (m.to_string(), t.to_string());
            let base_key = (baseline.to_string(), t.to_string());
            match (results.get(&key), results.get(&base_key)) {
                (Some(r), Some(b)) => {
                    let sp = r.speedup_vs(b);
                    sum += sp;
                    cnt += 1;
                    row.push_str(&format!(
                        " {:.2} | {:.2}x |", r.mat.mean(), sp));
                }
                _ => row.push_str(" - | - |"),
            }
        }
        if cnt > 0 {
            row.push_str(&format!(" {:.2}x |", sum / cnt as f64));
        } else {
            row.push_str(" - |");
        }
        out.push_str(&row);
        out.push('\n');
    }
    out
}

/// CSV export of the same grid (one row per method x task).
pub fn csv_table2(
    tasks: &[&str],
    methods: &[&str],
    results: &BTreeMap<(String, String), RunMetrics>,
    baseline: &str,
) -> String {
    let mut out =
        String::from("method,task,mat,acceptance,tokens_per_sec,speedup,prompts,new_tokens\n");
    for m in methods {
        for t in tasks {
            let key = (m.to_string(), t.to_string());
            let base_key = (baseline.to_string(), t.to_string());
            if let Some(r) = results.get(&key) {
                let sp = results
                    .get(&base_key)
                    .map(|b| r.speedup_vs(b))
                    .unwrap_or(0.0);
                out.push_str(&format!(
                    "{m},{t},{:.4},{:.4},{:.2},{:.4},{},{}\n",
                    r.mat.mean(),
                    r.acceptance.mean(),
                    r.tokens_per_sec(),
                    sp,
                    r.prompts,
                    r.new_tokens
                ));
            }
        }
    }
    out
}

/// Table 3 (ablations): objective -> (MAT, speedup).
pub fn render_table3(rows: &[(String, f64, f64)]) -> String {
    let mut out = String::from(
        "| Objective | Mean accepted tokens (MAT) | Speedup |\n|---|---|---|\n",
    );
    for (name, mat, speedup) in rows {
        out.push_str(&format!("| {name} | {mat:.3} | {speedup:.3}x |\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{GenResult, StepRecord};

    fn metrics(tokens: usize, ns: u64) -> RunMetrics {
        let mut m = RunMetrics::default();
        m.add(&GenResult {
            tokens: vec![1; tokens],
            decode_ns: ns,
            prefill_ns: 0,
            steps: vec![StepRecord {
                drafted: 4, accepted: 2, committed: 3,
                draft_ns: 1, verify_ns: 1,
            }],
        });
        m
    }

    #[test]
    fn table2_renders() {
        let mut results = BTreeMap::new();
        results.insert(("dvi".into(), "qa".into()), metrics(20, 1_000));
        results.insert(("ar".into(), "qa".into()), metrics(10, 1_000));
        let md = render_table2(&["qa"], &["dvi", "ar"], &results, "ar");
        assert!(md.contains("| dvi |"));
        assert!(md.contains("2.00x"));
        let csv = csv_table2(&["qa"], &["dvi"], &results, "ar");
        assert!(csv.lines().count() == 2);
    }

    #[test]
    fn table2_missing_cells() {
        let results = BTreeMap::new();
        let md = render_table2(&["qa"], &["dvi"], &results, "ar");
        assert!(md.contains(" - |"));
    }

    #[test]
    fn table3_renders() {
        let md = render_table3(&[("kl-only".into(), 1.93, 1.43)]);
        assert!(md.contains("kl-only"));
        assert!(md.contains("1.930"));
    }
}
