//! Schema-versioned bench artifacts and the trajectory comparator.
//!
//! Every bench persists a `BENCH_*.json` with a top-level
//! `"schema": "dvi.bench/1"` and `"bench": <name>` pair. CI uploads the
//! files as one artifact per run; `dvi bench-compare OLD NEW` flattens
//! two runs of the same bench into dot-joined numeric leaves, classifies
//! each shared metric by its leaf name (throughput-like leaves must not
//! drop, latency-like leaves must not grow), and reports a verdict per
//! metric against a relative tolerance band. That is the trajectory
//! gate: a perf regression shows up as a named metric, not as a vague
//! diff between JSON blobs.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use crate::util::json::Json;

/// Current artifact schema. Bump when field semantics change; the
/// comparator refuses to diff across schema versions.
pub const SCHEMA: &str = "dvi.bench/1";

/// Which way a metric is supposed to move.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    HigherIsBetter,
    LowerIsBetter,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Improvement,
    WithinBand,
    Regression,
}

/// One compared metric.
#[derive(Debug, Clone)]
pub struct Delta {
    pub metric: String,
    pub old: f64,
    pub new: f64,
    /// Signed relative change `(new - old) / old`.
    pub change: f64,
    pub direction: Direction,
    pub verdict: Verdict,
}

/// Full comparison of two artifacts of the same bench.
#[derive(Debug)]
pub struct Report {
    pub bench: String,
    pub tol: f64,
    pub deltas: Vec<Delta>,
    /// Metrics present on only one side, or with a non-positive
    /// baseline (no meaningful ratio).
    pub skipped: usize,
}

/// Classify a flattened metric path by its final dot segment. `None`
/// means the leaf is configuration/context (seeds, counts, shard
/// totals) and is not judged. Quantile/aggregate leaves (`p50`, `p95`,
/// `p99`, `mean`, `max`) inherit the direction of their parent family
/// key (`e2e_ms.p99` judges as `_ms`).
pub fn direction_of(path: &str) -> Option<Direction> {
    let mut parts = path.rsplit('.');
    let mut leaf = parts.next().unwrap_or(path);
    if matches!(leaf, "p50" | "p95" | "p99" | "mean" | "max") {
        leaf = parts.next().unwrap_or(leaf);
    }
    if leaf.ends_with("per_sec")
        || leaf.ends_with("per_tick")
        || leaf == "speedup"
        || leaf == "adaptive_over_fixed"
        || leaf == "occupancy"
        || leaf == "hit_rate"
        || leaf == "accept_ema"
    {
        return Some(Direction::HigherIsBetter);
    }
    if leaf.ends_with("_ns")
        || leaf.ends_with("_ms")
        || leaf.ends_with("wall_s")
        || leaf.ends_with("us_per_call")
        || leaf == "warm_prefill_rows"
    {
        return Some(Direction::LowerIsBetter);
    }
    None
}

/// Key an array element by a stable identity field so trajectories
/// line up across runs even if array order shifts.
fn element_key(v: &Json, i: usize) -> String {
    for field in ["label", "name", "artifact"] {
        if let Some(s) = v.get(field).as_str() {
            return s.to_string();
        }
    }
    i.to_string()
}

fn flatten_into(prefix: &str, v: &Json, out: &mut BTreeMap<String, f64>) {
    let join = |key: &str| {
        if prefix.is_empty() {
            key.to_string()
        } else {
            format!("{prefix}.{key}")
        }
    };
    match v {
        Json::Num(n) => {
            out.insert(prefix.to_string(), *n);
        }
        Json::Obj(o) => {
            for (k, child) in o {
                flatten_into(&join(k), child, out);
            }
        }
        Json::Arr(a) => {
            for (i, child) in a.iter().enumerate() {
                flatten_into(&join(&element_key(child, i)), child, out);
            }
        }
        _ => {}
    }
}

/// Dot-joined numeric leaves of an artifact. Array elements are keyed
/// by their `label`/`name`/`artifact` field when present (index
/// otherwise); strings/bools/nulls are dropped.
pub fn flatten(doc: &Json) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    flatten_into("", doc, &mut out);
    out
}

/// Diff two artifacts of the same bench under a relative tolerance
/// band (e.g. `0.10` = ±10%). Fails on schema or bench-name mismatch —
/// cross-version or cross-bench diffs are meaningless.
pub fn compare(old: &Json, new: &Json, tol: f64) -> Result<Report> {
    ensure!(tol.is_finite() && tol > 0.0, "tolerance must be > 0");
    for (side, doc) in [("old", old), ("new", new)] {
        match doc.get("schema").as_str() {
            Some(s) if s == SCHEMA => {}
            Some(s) => bail!(
                "{side} artifact has schema {s:?}, comparator expects \
                 {SCHEMA:?}"
            ),
            None => bail!(
                "{side} artifact has no \"schema\" field (predates \
                 {SCHEMA:?}; re-run the bench on both builds)"
            ),
        }
    }
    let bench = match (old.get("bench").as_str(), new.get("bench").as_str()) {
        (Some(a), Some(b)) if a == b => a.to_string(),
        (Some(a), Some(b)) => {
            bail!("artifacts are different benches: {a:?} vs {b:?}")
        }
        _ => bail!("artifact is missing the \"bench\" field"),
    };
    let old_flat = flatten(old);
    let new_flat = flatten(new);
    let mut deltas = Vec::new();
    let mut skipped = 0usize;
    for (metric, &old_v) in &old_flat {
        let Some(direction) = direction_of(metric) else {
            continue;
        };
        let Some(&new_v) = new_flat.get(metric) else {
            skipped += 1;
            continue;
        };
        if old_v <= 0.0 {
            skipped += 1;
            continue;
        }
        let change = (new_v - old_v) / old_v;
        let verdict = match direction {
            Direction::HigherIsBetter => {
                if change < -tol {
                    Verdict::Regression
                } else if change > tol {
                    Verdict::Improvement
                } else {
                    Verdict::WithinBand
                }
            }
            Direction::LowerIsBetter => {
                if change > tol {
                    Verdict::Regression
                } else if change < -tol {
                    Verdict::Improvement
                } else {
                    Verdict::WithinBand
                }
            }
        };
        deltas.push(Delta {
            metric: metric.clone(),
            old: old_v,
            new: new_v,
            change,
            direction,
            verdict,
        });
    }
    // New-run-only judged metrics have no baseline yet; note them so a
    // shrinking artifact can't silently pass.
    skipped += new_flat
        .keys()
        .filter(|k| direction_of(k).is_some() && !old_flat.contains_key(*k))
        .count();
    // Regressions first, then largest absolute movement.
    deltas.sort_by(|a, b| {
        let rank = |v: Verdict| match v {
            Verdict::Regression => 0,
            Verdict::Improvement => 1,
            Verdict::WithinBand => 2,
        };
        rank(a.verdict).cmp(&rank(b.verdict)).then(
            b.change
                .abs()
                .partial_cmp(&a.change.abs())
                .unwrap_or(std::cmp::Ordering::Equal),
        )
    });
    Ok(Report { bench, tol, deltas, skipped })
}

impl Report {
    pub fn regressions(&self) -> usize {
        self.deltas
            .iter()
            .filter(|d| d.verdict == Verdict::Regression)
            .count()
    }

    pub fn has_regression(&self) -> bool {
        self.regressions() > 0
    }

    /// Human-readable summary, one line per judged metric.
    pub fn render(&self) -> String {
        let mut out = format!(
            "bench-compare: {} (tolerance +/-{:.1}%)\n",
            self.bench,
            self.tol * 100.0
        );
        for d in &self.deltas {
            let tag = match d.verdict {
                Verdict::Regression => "REGRESSION ",
                Verdict::Improvement => "improvement",
                Verdict::WithinBand => "within-band",
            };
            let dir = match d.direction {
                Direction::HigherIsBetter => "higher is better",
                Direction::LowerIsBetter => "lower is better",
            };
            out.push_str(&format!(
                "  {tag}  {}  {:.4} -> {:.4}  ({:+.1}%, {dir})\n",
                d.metric,
                d.old,
                d.new,
                d.change * 100.0
            ));
        }
        let (mut imp, mut band, mut reg) = (0, 0, 0);
        for d in &self.deltas {
            match d.verdict {
                Verdict::Improvement => imp += 1,
                Verdict::WithinBand => band += 1,
                Verdict::Regression => reg += 1,
            }
        }
        out.push_str(&format!(
            "  summary: {imp} improved, {band} within band, {reg} \
             regressed ({} skipped)\n",
            self.skipped
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(goodput: f64, p99: f64) -> Json {
        let text = format!(
            r#"{{"schema":"dvi.bench/1","bench":"serving_load","seed":7,
                "scenarios":[{{"label":"poisson/in-process",
                               "goodput_tok_per_sec":{goodput},
                               "latency":{{"e2e_ms":{{"p99":{p99}}}}},
                               "tenants":[{{"name":"chat",
                                            "tok_per_sec":{goodput}}}]}}]}}"#
        );
        Json::parse(&text).unwrap()
    }

    #[test]
    fn direction_rules() {
        assert_eq!(
            direction_of("scenarios.x.goodput_tok_per_sec"),
            Some(Direction::HigherIsBetter)
        );
        assert_eq!(
            direction_of("runs.shard=2.tok_per_sec"),
            Some(Direction::HigherIsBetter)
        );
        assert_eq!(
            direction_of("adaptive_over_fixed"),
            Some(Direction::HigherIsBetter)
        );
        assert_eq!(
            direction_of("scenarios.x.latency.e2e_ms.p99_ms"),
            Some(Direction::LowerIsBetter)
        );
        assert_eq!(
            direction_of("scenarios.x.latency.e2e_ms.p99"),
            Some(Direction::LowerIsBetter)
        );
        assert_eq!(
            direction_of("scenarios.x.latency.queue_wait_ms.p50"),
            Some(Direction::LowerIsBetter)
        );
        assert_eq!(direction_of("x.counts.mean"), None);
        assert_eq!(
            direction_of("pipelining.serial_wall_s"),
            Some(Direction::LowerIsBetter)
        );
        assert_eq!(
            direction_of("warm.warm_prefill_rows"),
            Some(Direction::LowerIsBetter)
        );
        assert_eq!(
            direction_of("artifacts.target_step.remote_us_per_call"),
            Some(Direction::LowerIsBetter)
        );
        assert_eq!(direction_of("seed"), None);
        assert_eq!(direction_of("scenarios.x.requests"), None);
    }

    #[test]
    fn flatten_keys_arrays_by_label() {
        let flat = flatten(&doc(100.0, 12.0));
        assert_eq!(
            flat.get("scenarios.poisson/in-process.goodput_tok_per_sec"),
            Some(&100.0)
        );
        assert_eq!(
            flat.get(
                "scenarios.poisson/in-process.tenants.chat.tok_per_sec"
            ),
            Some(&100.0)
        );
        assert_eq!(flat.get("seed"), Some(&7.0));
        // Strings (schema, bench, label) never become metrics.
        assert!(flat.keys().all(|k| !k.ends_with("label")));
    }

    #[test]
    fn verdicts_classify_synthetic_fixture() {
        // Goodput -30% and p99 +50%: both regress.
        let report = compare(&doc(100.0, 12.0), &doc(70.0, 18.0), 0.10)
            .unwrap();
        assert!(report.has_regression());
        assert_eq!(report.regressions(), 3); // goodput x2 + p99
        // Within band: +/-5% moves under a 10% tolerance.
        let report = compare(&doc(100.0, 12.0), &doc(105.0, 11.4), 0.10)
            .unwrap();
        assert!(!report.has_regression());
        assert!(report
            .deltas
            .iter()
            .all(|d| d.verdict == Verdict::WithinBand));
        // Improvement: goodput +30%, p99 -40%.
        let report = compare(&doc(100.0, 12.0), &doc(130.0, 7.2), 0.10)
            .unwrap();
        assert!(!report.has_regression());
        assert!(report
            .deltas
            .iter()
            .all(|d| d.verdict == Verdict::Improvement));
        let text = report.render();
        assert!(text.contains("serving_load"));
        assert!(text.contains("improvement"));
    }

    #[test]
    fn schema_and_bench_mismatches_are_rejected() {
        let good = doc(100.0, 12.0);
        let no_schema =
            Json::parse(r#"{"bench":"serving_load","tok_per_sec":1}"#)
                .unwrap();
        assert!(compare(&good, &no_schema, 0.1).is_err());
        let wrong = Json::parse(
            r#"{"schema":"dvi.bench/0","bench":"serving_load"}"#,
        )
        .unwrap();
        assert!(compare(&wrong, &good, 0.1).is_err());
        let other = Json::parse(
            r#"{"schema":"dvi.bench/1","bench":"shard_scaling"}"#,
        )
        .unwrap();
        assert!(compare(&good, &other, 0.1).is_err());
        assert!(compare(&good, &good, 0.0).is_err());
    }

    #[test]
    fn missing_metrics_are_counted_not_ignored() {
        let old = doc(100.0, 12.0);
        let new = Json::parse(
            r#"{"schema":"dvi.bench/1","bench":"serving_load",
                "scenarios":[{"label":"poisson/in-process",
                              "goodput_tok_per_sec":100.0}]}"#,
        )
        .unwrap();
        let report = compare(&old, &new, 0.1).unwrap();
        assert!(report.skipped >= 2, "dropped p99 + tenant tok_per_sec");
        assert!(!report.has_regression());
    }

    #[test]
    fn artifact_round_trips_through_display() {
        let d = doc(123.5, 9.25);
        let back = Json::parse(&d.to_string()).unwrap();
        assert_eq!(flatten(&d), flatten(&back));
        let report = compare(&d, &back, 0.05).unwrap();
        assert!(!report.has_regression());
        assert!(report
            .deltas
            .iter()
            .all(|x| x.verdict == Verdict::WithinBand && x.change == 0.0));
    }
}
