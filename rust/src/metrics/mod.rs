//! Spec-Bench-style metrics aggregation and report rendering.

pub mod bench;
pub mod report;

use crate::engine::GenResult;
use crate::util::math::Stats;

/// Aggregated metrics over a set of generations for one (method, task).
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub prompts: usize,
    pub new_tokens: u64,
    pub decode_ns: u64,
    pub prefill_ns: u64,
    pub mat: Stats,
    pub acceptance: Stats,
    pub committed_per_step: Stats,
    pub verify_calls: u64,
    pub draft_ns: u64,
    pub verify_ns: u64,
}

impl RunMetrics {
    pub fn add(&mut self, r: &GenResult) {
        self.prompts += 1;
        self.new_tokens += r.tokens.len() as u64;
        self.decode_ns += r.decode_ns;
        self.prefill_ns += r.prefill_ns;
        if r.steps.iter().any(|s| s.drafted > 0) {
            self.mat.add(r.mat());
            self.acceptance.add(r.acceptance_rate());
        }
        self.committed_per_step.add(r.tokens_per_step());
        self.verify_calls += r.steps.len() as u64;
        self.draft_ns += r.steps.iter().map(|s| s.draft_ns).sum::<u64>();
        self.verify_ns += r.steps.iter().map(|s| s.verify_ns).sum::<u64>();
    }

    /// Decode-phase tokens/second (excludes prefill, matching Spec-Bench's
    /// per-token latency focus).
    pub fn tokens_per_sec(&self) -> f64 {
        if self.decode_ns == 0 {
            return 0.0;
        }
        self.new_tokens as f64 / (self.decode_ns as f64 / 1e9)
    }

    /// Wall-time speedup vs a baseline run over the same prompts.
    pub fn speedup_vs(&self, baseline: &RunMetrics) -> f64 {
        self.speedup_opt(baseline).unwrap_or(0.0)
    }

    /// Speedup vs baseline, or `None` when the baseline has no decode
    /// throughput to compare against. Reports must not render the `None`
    /// case as a literal 0x — downstream averaging would read that as
    /// "infinitely slow" instead of "not measured".
    pub fn speedup_opt(&self, baseline: &RunMetrics) -> Option<f64> {
        let base = baseline.tokens_per_sec();
        if base == 0.0 {
            None
        } else {
            Some(self.tokens_per_sec() / base)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::StepRecord;

    fn gen(tokens: usize, decode_ns: u64, drafted: usize, accepted: usize) -> GenResult {
        GenResult {
            tokens: vec![9; tokens],
            decode_ns,
            prefill_ns: 1,
            steps: vec![StepRecord {
                drafted,
                accepted,
                committed: accepted + 1,
                draft_ns: 10,
                verify_ns: 20,
            }],
        }
    }

    #[test]
    fn aggregates() {
        let mut m = RunMetrics::default();
        m.add(&gen(10, 1_000_000_000, 4, 2));
        m.add(&gen(10, 1_000_000_000, 4, 4));
        assert_eq!(m.prompts, 2);
        assert_eq!(m.new_tokens, 20);
        assert!((m.mat.mean() - 3.0).abs() < 1e-12);
        assert!((m.tokens_per_sec() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn speedup() {
        let mut fast = RunMetrics::default();
        fast.add(&gen(20, 1_000_000_000, 4, 4));
        let mut slow = RunMetrics::default();
        slow.add(&gen(10, 1_000_000_000, 0, 0));
        assert!((fast.speedup_vs(&slow) - 2.0).abs() < 1e-9);
        assert_eq!(fast.speedup_vs(&RunMetrics::default()), 0.0);
    }

    #[test]
    fn speedup_opt_none_for_dead_baseline() {
        let mut fast = RunMetrics::default();
        fast.add(&gen(20, 1_000_000_000, 4, 4));
        assert_eq!(fast.speedup_opt(&RunMetrics::default()), None);
        let mut slow = RunMetrics::default();
        slow.add(&gen(10, 1_000_000_000, 0, 0));
        let sp = fast.speedup_opt(&slow).unwrap();
        assert!((sp - 2.0).abs() < 1e-9);
    }

    #[test]
    fn ar_runs_have_no_mat() {
        let mut m = RunMetrics::default();
        m.add(&gen(5, 100, 0, 0));
        assert_eq!(m.mat.n, 0);
    }
}
