//! The pure-Rust split-transformer interpreter behind
//! [`super::ReferenceBackend`].
//!
//! A deliberately tiny llama-shaped model (single attention head,
//! RMSNorm, SiLU MLP) evaluated strictly **one position at a time**:
//! prefill is a loop over the same per-position step the decode path
//! uses, and the full model is the shallow stack composed with the deep
//! stack over the *same* layer weights. That makes the losslessness
//! contract hold bitwise by construction:
//!
//!   * prefill vs. step-by-step decode produce identical hidden states
//!     (same f32 ops in the same order per (layer, position) cell);
//!   * `prefill_full`/`target_step` equal `prefill_shallow→prefill_deep`
//!     and `draft path→verify_block` (the deep stack consumes exactly
//!     the shallow stack's output rows).
//!
//! KV caches are position-indexed `[n_layers, max_seq, d]` tensors; a
//! step at position p writes slot p before attending, and queries only
//! attend slots j <= p — stale speculative slots are never visible,
//! mirroring `spec::seq`'s invariants.

use anyhow::{ensure, Result};

use crate::util::math::argmax;
use crate::util::rng::Rng;

/// One transformer layer's weights. Matrices are row-major `[in, out]`
/// (`y[o] = Σ_i x[i] * w[i*out + o]`), norm gains are `[d]`.
pub struct LayerW {
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub w1: Vec<f32>,
    pub w2: Vec<f32>,
    pub rms_attn: Vec<f32>,
    pub rms_mlp: Vec<f32>,
}

/// A complete model: embedding, `n_layers` layers, final norm, LM head.
/// The DVI split views `layers[..split]` as the shallow (draft) stack
/// and `layers[split..]` as the deep (verify) stack.
pub struct ModelW {
    pub d: usize,
    pub ff: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub eps: f32,
    /// `[vocab, d]`, row per token id.
    pub embed: Vec<f32>,
    pub layers: Vec<LayerW>,
    /// `[d]` gain of the pre-head RMSNorm.
    pub final_norm: Vec<f32>,
    /// `[vocab, d]`, row per vocab entry: `logits[v] = head[v] · hn`.
    pub lm_head: Vec<f32>,
}

/// One sequence's slice of a lane-blocked batched step: its current
/// hidden state, its own KV cache pair, and the position it occupies.
/// See [`ModelW::step_layers_lanes`].
pub struct StepLane {
    pub h: Vec<f32>,
    pub kc: Vec<f32>,
    pub vc: Vec<f32>,
    pub pos: usize,
}

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f32;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// `y = x @ W` with `W` row-major `[in, out]`.
pub fn matvec(x: &[f32], w: &[f32], n_out: usize) -> Vec<f32> {
    debug_assert_eq!(x.len() * n_out, w.len());
    let mut y = vec![0.0f32; n_out];
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * n_out..(i + 1) * n_out];
        for o in 0..n_out {
            y[o] += xi * row[o];
        }
    }
    y
}

pub fn rmsnorm(x: &[f32], gain: &[f32], eps: f32) -> Vec<f32> {
    let ms = dot(x, x) / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    x.iter().zip(gain).map(|(&xi, &g)| xi * inv * g).collect()
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

impl ModelW {
    /// Seeded random init. Residual-branch output projections get a
    /// smaller scale so deep layers perturb rather than scramble the
    /// shallow representation — the drafter starts plausibly aligned
    /// with the verifier, like a trained split backbone would.
    pub fn init(rng: &mut Rng, d: usize, ff: usize, vocab: usize,
                n_layers: usize, max_seq: usize, eps: f32) -> ModelW {
        let g = |rng: &mut Rng, n: usize, scale: f32| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32 * scale).collect()
        };
        let proj = 1.0 / (d as f32).sqrt();
        let layers = (0..n_layers)
            .map(|_| LayerW {
                wq: g(rng, d * d, proj),
                wk: g(rng, d * d, proj),
                wv: g(rng, d * d, proj),
                wo: g(rng, d * d, 0.1),
                w1: g(rng, d * ff, proj),
                w2: g(rng, ff * d, 0.1),
                rms_attn: vec![1.0; d],
                rms_mlp: vec![1.0; d],
            })
            .collect();
        ModelW {
            d,
            ff,
            vocab,
            max_seq,
            eps,
            embed: g(rng, vocab * d, 1.0),
            layers,
            final_norm: vec![1.0; d],
            lm_head: g(rng, vocab * d, 0.7),
        }
    }

    pub fn embed_row(&self, tok: usize) -> Result<Vec<f32>> {
        ensure!(tok < self.vocab, "token id {tok} >= vocab {}", self.vocab);
        Ok(self.embed[tok * self.d..(tok + 1) * self.d].to_vec())
    }

    /// The per-(layer, position) update — the shared body of
    /// [`Self::step_layers`] and [`Self::step_layers_lanes`]. Keeping one
    /// body is what makes lane-blocked batched execution bitwise-lossless:
    /// both paths run exactly this op sequence per lane.
    fn layer_pos_step(
        &self,
        layer: &LayerW,
        base: usize,
        h: &mut [f32],
        kc: &mut [f32],
        vc: &mut [f32],
        pos: usize,
        inv_sqrt_d: f32,
    ) {
        let d = self.d;
        let xn = rmsnorm(h, &layer.rms_attn, self.eps);
        let q = matvec(&xn, &layer.wq, d);
        let k = matvec(&xn, &layer.wk, d);
        let v = matvec(&xn, &layer.wv, d);
        kc[base + pos * d..base + (pos + 1) * d].copy_from_slice(&k);
        vc[base + pos * d..base + (pos + 1) * d].copy_from_slice(&v);

        // Causal single-head attention over slots 0..=pos.
        let mut scores = Vec::with_capacity(pos + 1);
        let mut max_s = f32::NEG_INFINITY;
        for j in 0..=pos {
            let s = dot(&q, &kc[base + j * d..base + (j + 1) * d]) * inv_sqrt_d;
            max_s = max_s.max(s);
            scores.push(s);
        }
        let mut denom = 0.0f32;
        for s in scores.iter_mut() {
            *s = (*s - max_s).exp();
            denom += *s;
        }
        let mut attn = vec![0.0f32; d];
        for (j, &w) in scores.iter().enumerate() {
            let vrow = &vc[base + j * d..base + (j + 1) * d];
            let wn = w / denom;
            for di in 0..d {
                attn[di] += wn * vrow[di];
            }
        }
        let o = matvec(&attn, &layer.wo, d);
        for di in 0..d {
            h[di] += o[di];
        }

        let xm = rmsnorm(h, &layer.rms_mlp, self.eps);
        let mut a = matvec(&xm, &layer.w1, self.ff);
        for x in a.iter_mut() {
            *x = silu(*x);
        }
        let m = matvec(&a, &layer.w2, d);
        for di in 0..d {
            h[di] += m[di];
        }
    }

    /// Run layers `lo..hi` for one position. `kc`/`vc` are the caches
    /// for exactly those layers, `[(hi-lo), max_seq, d]` flattened;
    /// slot `pos` is written before attending and queries see slots
    /// `0..=pos` only.
    pub fn step_layers(
        &self,
        lo: usize,
        hi: usize,
        h: &mut Vec<f32>,
        kc: &mut [f32],
        vc: &mut [f32],
        pos: usize,
    ) -> Result<()> {
        let d = self.d;
        ensure!(pos < self.max_seq, "position {pos} >= max_seq {}", self.max_seq);
        ensure!(hi <= self.layers.len() && lo <= hi, "bad layer range {lo}..{hi}");
        ensure!(kc.len() == (hi - lo) * self.max_seq * d, "kv cache size mismatch");
        let inv_sqrt_d = 1.0 / (d as f32).sqrt();
        for (row, layer) in self.layers[lo..hi].iter().enumerate() {
            let base = row * self.max_seq * d;
            self.layer_pos_step(layer, base, h, kc, vc, pos, inv_sqrt_d);
        }
        Ok(())
    }

    /// Lane-blocked variant of [`Self::step_layers`]: layers outer, lanes
    /// inner, so each layer's weight matrices stream through the cache
    /// hierarchy once per batch instead of once per sequence (the CPU
    /// interpreter's analogue of turning per-sequence GEMVs into a
    /// batched GEMM). Lanes are fully independent — each has its own
    /// hidden state, KV cache, and position — and each lane runs the
    /// exact [`Self::layer_pos_step`] op sequence, so per-lane results
    /// are bitwise identical to unbatched calls.
    pub fn step_layers_lanes(
        &self,
        lo: usize,
        hi: usize,
        lanes: &mut [StepLane],
    ) -> Result<()> {
        self.step_layers_lanes_masked(lo, hi, lanes, None)
    }

    /// [`Self::step_layers_lanes`] with an optional activity mask:
    /// inactive lanes are skipped entirely (no op executes against their
    /// state), which lets variable-round-length batches (adaptive-k)
    /// share one layer sweep. Because lanes never interact, skipping a
    /// lane cannot perturb any other lane's results — active lanes stay
    /// bitwise identical to an unmasked (or serial) run.
    pub fn step_layers_lanes_masked(
        &self,
        lo: usize,
        hi: usize,
        lanes: &mut [StepLane],
        active: Option<&[bool]>,
    ) -> Result<()> {
        let d = self.d;
        ensure!(hi <= self.layers.len() && lo <= hi, "bad layer range {lo}..{hi}");
        if let Some(mask) = active {
            ensure!(mask.len() == lanes.len(), "mask/lane count mismatch");
        }
        let live = |li: usize| active.map_or(true, |m| m[li]);
        for (li, lane) in lanes.iter().enumerate() {
            if !live(li) {
                continue;
            }
            ensure!(
                lane.pos < self.max_seq,
                "position {} >= max_seq {}",
                lane.pos,
                self.max_seq
            );
            ensure!(
                lane.kc.len() == (hi - lo) * self.max_seq * d,
                "kv cache size mismatch"
            );
        }
        let inv_sqrt_d = 1.0 / (d as f32).sqrt();
        for (row, layer) in self.layers[lo..hi].iter().enumerate() {
            let base = row * self.max_seq * d;
            for (li, lane) in lanes.iter_mut().enumerate() {
                if !live(li) {
                    continue;
                }
                self.layer_pos_step(
                    layer, base, &mut lane.h, &mut lane.kc, &mut lane.vc,
                    lane.pos, inv_sqrt_d,
                );
            }
        }
        Ok(())
    }

    /// Verifier logits: `lm_head @ rmsnorm(h, final_norm)`.
    pub fn logits(&self, h: &[f32]) -> Vec<f32> {
        let hn = rmsnorm(h, &self.final_norm, self.eps);
        (0..self.vocab)
            .map(|v| dot(&self.lm_head[v * self.d..(v + 1) * self.d], &hn))
            .collect()
    }

    /// Draft-head logits (paper §3.1): `(W_S + γ·A@B) @ rmsnorm(h)` with
    /// `A: [vocab, r]`, `B: [r, d]`. Factored as `u = B·hn`,
    /// `logits[v] = W_S[v]·hn + γ·A[v]·u` — the exact formula the
    /// reference `train_step` differentiates.
    pub fn draft_logits(&self, h: &[f32], a: &[f32], b: &[f32], rank: usize,
                        gamma: f32) -> Vec<f32> {
        let hn = rmsnorm(h, &self.final_norm, self.eps);
        let u: Vec<f32> = (0..rank)
            .map(|r| dot(&b[r * self.d..(r + 1) * self.d], &hn))
            .collect();
        (0..self.vocab)
            .map(|v| {
                dot(&self.lm_head[v * self.d..(v + 1) * self.d], &hn)
                    + gamma * dot(&a[v * rank..(v + 1) * rank], &u)
            })
            .collect()
    }

    /// Greedy token from logits — must match `util::math::argmax`
    /// semantics (first max wins) so in-graph and coordinator-side
    /// greedy agree.
    pub fn greedy(logits: &[f32]) -> u32 {
        argmax(logits) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelW {
        let mut rng = Rng::new(11);
        ModelW::init(&mut rng, 8, 16, 32, 3, 24, 1e-5)
    }

    #[test]
    fn step_is_deterministic() {
        let m = tiny();
        let run = || -> Vec<f32> {
            let mut h = m.embed_row(5).unwrap();
            let mut kc = vec![0.0; 3 * 24 * 8];
            let mut vc = vec![0.0; 3 * 24 * 8];
            m.step_layers(0, 3, &mut h, &mut kc, &mut vc, 0).unwrap();
            h
        };
        assert_eq!(run(), run());
    }

    /// The core split-model identity: shallow-then-deep equals full.
    #[test]
    fn split_composes_to_full() {
        let m = tiny();
        let toks = [5usize, 9, 1, 30, 2];
        let split = 1;

        // Full stack, position by position.
        let mut kc_f = vec![0.0; 3 * 24 * 8];
        let mut vc_f = vec![0.0; 3 * 24 * 8];
        let mut full_h = Vec::new();
        for (pos, &t) in toks.iter().enumerate() {
            let mut h = m.embed_row(t).unwrap();
            m.step_layers(0, 3, &mut h, &mut kc_f, &mut vc_f, pos).unwrap();
            full_h.push(h);
        }

        // Shallow stack then deep stack on the shallow outputs.
        let mut kc_s = vec![0.0; split * 24 * 8];
        let mut vc_s = vec![0.0; split * 24 * 8];
        let mut mids = Vec::new();
        for (pos, &t) in toks.iter().enumerate() {
            let mut h = m.embed_row(t).unwrap();
            m.step_layers(0, split, &mut h, &mut kc_s, &mut vc_s, pos).unwrap();
            mids.push(h);
        }
        let deep = 3 - split;
        let mut kc_d = vec![0.0; deep * 24 * 8];
        let mut vc_d = vec![0.0; deep * 24 * 8];
        for (pos, mid) in mids.into_iter().enumerate() {
            let mut h = mid;
            m.step_layers(split, 3, &mut h, &mut kc_d, &mut vc_d, pos).unwrap();
            assert_eq!(h, full_h[pos], "split != full at position {pos}");
        }
    }

    /// Speculative slots are invisible: writing garbage at positions
    /// beyond the current feed then overwriting it must reproduce the
    /// clean run bitwise (the lossless-rollback property).
    #[test]
    fn stale_slots_are_masked() {
        let m = tiny();
        let mut kc_a = vec![0.0; 3 * 24 * 8];
        let mut vc_a = vec![0.0; 3 * 24 * 8];
        let mut kc_b = vec![7.5; 3 * 24 * 8]; // garbage everywhere
        let mut vc_b = vec![-3.25; 3 * 24 * 8];
        for (pos, t) in [4usize, 8, 15].into_iter().enumerate() {
            let mut ha = m.embed_row(t).unwrap();
            m.step_layers(0, 3, &mut ha, &mut kc_a, &mut vc_a, pos).unwrap();
            let mut hb = m.embed_row(t).unwrap();
            m.step_layers(0, 3, &mut hb, &mut kc_b, &mut vc_b, pos).unwrap();
            assert_eq!(ha, hb, "stale cache slots leaked at position {pos}");
        }
    }

    #[test]
    fn draft_head_matches_verifier_at_zero_lora() {
        let m = tiny();
        let h = m.embed_row(3).unwrap();
        let a = vec![0.3; 32 * 2];
        let b = vec![0.0; 2 * 8]; // B = 0 => delta = 0
        let base = m.logits(&h);
        let draft = m.draft_logits(&h, &a, &b, 2, 2.0);
        assert_eq!(base, draft);
    }

    /// Lane-blocked stepping must be bitwise identical to stepping each
    /// lane alone — the contract batched serving losslessness rests on.
    #[test]
    fn lane_blocked_step_matches_serial() {
        let m = tiny();
        // Per-lane histories of different lengths -> different positions.
        let hist: [&[usize]; 3] = [&[5, 9], &[1], &[30, 2, 7]];
        let mk_lane = |toks: &[usize]| {
            let mut kc = vec![0.0; 3 * 24 * 8];
            let mut vc = vec![0.0; 3 * 24 * 8];
            for (pos, &t) in toks.iter().enumerate() {
                let mut h = m.embed_row(t).unwrap();
                m.step_layers(0, 3, &mut h, &mut kc, &mut vc, pos).unwrap();
            }
            (kc, vc, toks.len())
        };
        // Serial: one more step per lane, each lane alone.
        let mut serial = Vec::new();
        for toks in hist {
            let (mut kc, mut vc, pos) = mk_lane(toks);
            let mut h = m.embed_row(3).unwrap();
            m.step_layers(0, 3, &mut h, &mut kc, &mut vc, pos).unwrap();
            serial.push((h, kc, vc));
        }
        // Lane-blocked: the same step for all three lanes at once.
        let mut lanes: Vec<StepLane> = hist
            .iter()
            .map(|toks| {
                let (kc, vc, pos) = mk_lane(toks);
                StepLane { h: m.embed_row(3).unwrap(), kc, vc, pos }
            })
            .collect();
        m.step_layers_lanes(0, 3, &mut lanes).unwrap();
        for (lane, (h, kc, vc)) in lanes.iter().zip(&serial) {
            assert_eq!(&lane.h, h, "hidden state diverged under lane blocking");
            assert_eq!(&lane.kc, kc, "k cache diverged under lane blocking");
            assert_eq!(&lane.vc, vc, "v cache diverged under lane blocking");
        }
    }

    #[test]
    fn rejects_out_of_range() {
        let m = tiny();
        let mut h = vec![0.0; 8];
        let mut kc = vec![0.0; 3 * 24 * 8];
        let mut vc = vec![0.0; 3 * 24 * 8];
        assert!(m.step_layers(0, 3, &mut h, &mut kc, &mut vc, 24).is_err());
        assert!(m.embed_row(32).is_err());
    }
}
