//! In-memory manifest, vocabulary, and prompt-set synthesis for the
//! reference backend. Mirrors what `python/compile/aot.py` writes to
//! `artifacts/` — same artifact names, same port roles and ordering,
//! same config keys — but generated from a [`super::ReferenceConfig`]
//! with zero files on disk.

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::runtime::manifest::{ArtifactSpec, Manifest, Port, Role};
use crate::runtime::tensor::DType;
use crate::tokenizer::{BOS, SEP};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::{PromptSample, PromptSet, TASK_NAMES};

use super::ReferenceConfig;

fn port(name: &str, shape: Vec<usize>, dtype: DType, role: Role) -> Port {
    Port { name: name.to_string(), shape, dtype, role }
}

/// Build the full manifest: every artifact the PJRT exporter would
/// produce, with shapes taken from the reference config.
pub fn manifest(cfg: &ReferenceConfig) -> Manifest {
    let (d, v, p, b, r, n) = (
        cfg.d_model,
        cfg.vocab_size,
        cfg.prefill_seq,
        cfg.k_spec,
        cfg.lora_rank,
        cfg.batch_size,
    );
    let sh_kv = vec![cfg.split_layer, cfg.max_seq, d];
    let dp_kv = vec![cfg.n_layers - cfg.split_layer, cfg.max_seq, d];
    let fl_kv = vec![cfg.n_layers, cfg.max_seq, d];
    let sps_kv = vec![cfg.sps_layers, cfg.max_seq, d];
    let f = DType::F32;
    let i = DType::I32;

    let mut artifacts = BTreeMap::new();
    let mut add = |name: &str, params: Vec<Port>, outputs: Vec<Port>| {
        artifacts.insert(
            name.to_string(),
            ArtifactSpec {
                name: name.to_string(),
                file: PathBuf::from("<reference>"),
                params,
                outputs,
            },
        );
    };

    add(
        "prefill_shallow",
        vec![
            port("kv_sh_k", sh_kv.clone(), f, Role::Kv),
            port("kv_sh_v", sh_kv.clone(), f, Role::Kv),
            port("tokens", vec![p], i, Role::In),
            // Prefix-cache attach point: positions < start are already
            // resident in the input KV (cold prefill passes 0).
            port("start", vec![], i, Role::In),
        ],
        vec![
            port("hk_seq", vec![p, d], f, Role::Out),
            port("kv_sh_k", sh_kv.clone(), f, Role::Kv),
            port("kv_sh_v", sh_kv.clone(), f, Role::Kv),
        ],
    );
    add(
        "prefill_deep",
        vec![
            port("kv_dp_k", dp_kv.clone(), f, Role::Kv),
            port("kv_dp_v", dp_kv.clone(), f, Role::Kv),
            port("hk_seq", vec![p, d], f, Role::In),
            port("length", vec![], i, Role::In),
            // Prefix-cache attach point (must satisfy start < length).
            port("start", vec![], i, Role::In),
        ],
        vec![
            port("logits_last", vec![v], f, Role::Out),
            port("kv_dp_k", dp_kv.clone(), f, Role::Kv),
            port("kv_dp_v", dp_kv.clone(), f, Role::Kv),
        ],
    );
    add(
        "draft_step",
        vec![
            port("lora.A", vec![v, r], f, Role::Global),
            port("lora.B", vec![r, d], f, Role::Global),
            port("kv_sh_k", sh_kv.clone(), f, Role::Kv),
            port("kv_sh_v", sh_kv.clone(), f, Role::Kv),
            port("tok", vec![], i, Role::In),
            port("pos", vec![], i, Role::In),
        ],
        vec![
            port("logits_theta", vec![v], f, Role::Out),
            port("hk", vec![d], f, Role::Out),
            port("kv_sh_k", sh_kv.clone(), f, Role::Kv),
            port("kv_sh_v", sh_kv.clone(), f, Role::Kv),
        ],
    );
    add(
        "draft_block",
        vec![
            port("lora.A", vec![v, r], f, Role::Global),
            port("lora.B", vec![r, d], f, Role::Global),
            port("kv_sh_k", sh_kv.clone(), f, Role::Kv),
            port("kv_sh_v", sh_kv.clone(), f, Role::Kv),
            port("tok", vec![], i, Role::In),
            port("pos", vec![], i, Role::In),
            port("len", vec![], i, Role::In),
        ],
        vec![
            port("drafted", vec![b], i, Role::Out),
            port("hk_rows", vec![b, d], f, Role::Out),
            port("kv_sh_k", sh_kv.clone(), f, Role::Kv),
            port("kv_sh_v", sh_kv, f, Role::Kv),
        ],
    );
    add(
        "verify_block",
        vec![
            port("kv_dp_k", dp_kv.clone(), f, Role::Kv),
            port("kv_dp_v", dp_kv.clone(), f, Role::Kv),
            port("hk_block", vec![b, d], f, Role::In),
            port("pos", vec![], i, Role::In),
            port("len", vec![], i, Role::In),
        ],
        vec![
            port("logits_phi", vec![b, v], f, Role::Out),
            port("kv_dp_k", dp_kv.clone(), f, Role::Kv),
            port("kv_dp_v", dp_kv, f, Role::Kv),
        ],
    );
    // Full-model artifacts (the AR/verifier substrate) and the SpS
    // drafter share a shape family.
    for (prefix, kv_name, kv_shape) in [
        ("", "kv_fl", fl_kv),
        ("sps_", "kv_sps", sps_kv),
    ] {
        let pre = |s: &str| -> String {
            if prefix.is_empty() {
                s.to_string()
            } else {
                format!("{prefix}{s}")
            }
        };
        let (prefill_name, step_name) = if prefix.is_empty() {
            ("prefill_full".to_string(), "target_step".to_string())
        } else {
            (pre("prefill"), pre("draft_step"))
        };
        add(
            &prefill_name,
            vec![
                port(&format!("{kv_name}_k"), kv_shape.clone(), f, Role::Kv),
                port(&format!("{kv_name}_v"), kv_shape.clone(), f, Role::Kv),
                port("tokens", vec![p], i, Role::In),
                port("length", vec![], i, Role::In),
            ],
            vec![
                port("logits_last", vec![v], f, Role::Out),
                port("hl_last", vec![d], f, Role::Out),
                port(&format!("{kv_name}_k"), kv_shape.clone(), f, Role::Kv),
                port(&format!("{kv_name}_v"), kv_shape.clone(), f, Role::Kv),
            ],
        );
        add(
            &step_name,
            vec![
                port(&format!("{kv_name}_k"), kv_shape.clone(), f, Role::Kv),
                port(&format!("{kv_name}_v"), kv_shape.clone(), f, Role::Kv),
                port("tok", vec![], i, Role::In),
                port("pos", vec![], i, Role::In),
            ],
            vec![
                port("logits", vec![v], f, Role::Out),
                port("hl", vec![d], f, Role::Out),
                port(&format!("{kv_name}_k"), kv_shape.clone(), f, Role::Kv),
                port(&format!("{kv_name}_v"), kv_shape.clone(), f, Role::Kv),
            ],
        );
        if prefix.is_empty() {
            add(
                "target_verify_block",
                vec![
                    port(&format!("{kv_name}_k"), kv_shape.clone(), f, Role::Kv),
                    port(&format!("{kv_name}_v"), kv_shape.clone(), f, Role::Kv),
                    port("toks", vec![b], i, Role::In),
                    port("pos", vec![], i, Role::In),
                ],
                vec![
                    port("logits", vec![b, v], f, Role::Out),
                    port("hl_block", vec![b, d], f, Role::Out),
                    port(&format!("{kv_name}_k"), kv_shape.clone(), f, Role::Kv),
                    port(&format!("{kv_name}_v"), kv_shape.clone(), f, Role::Kv),
                ],
            );
        }
    }
    add(
        "medusa_heads",
        vec![port("hl", vec![d], f, Role::In)],
        vec![port("logits", vec![b, v], f, Role::Out)],
    );
    add(
        "hydra_chain",
        vec![
            port("hl", vec![d], f, Role::In),
            port("tok0", vec![], i, Role::In),
        ],
        vec![
            port("toks", vec![b], i, Role::Out),
            port("logits", vec![b, v], f, Role::Out),
        ],
    );
    add(
        "eagle_step",
        vec![
            port("feat", vec![d], f, Role::In),
            port("tok", vec![], i, Role::In),
        ],
        vec![
            port("logits", vec![v], f, Role::Out),
            port("feat_next", vec![d], f, Role::Out),
        ],
    );
    add(
        "train_step",
        vec![
            port("lora.A", vec![v, r], f, Role::Global),
            port("lora.B", vec![r, d], f, Role::Global),
            port("adam.mA", vec![v, r], f, Role::Global),
            port("adam.vA", vec![v, r], f, Role::Global),
            port("adam.mB", vec![r, d], f, Role::Global),
            port("adam.vB", vec![r, d], f, Role::Global),
            port("hk", vec![n, d], f, Role::In),
            port("actions", vec![n], i, Role::In),
            port("logits_phi", vec![n, v], f, Role::In),
            port("rewards", vec![n], f, Role::In),
            port("mask", vec![n], f, Role::In),
            port("hyper", vec![8], f, Role::In),
        ],
        vec![
            port("metrics", vec![8], f, Role::Out),
            port("lora.A", vec![v, r], f, Role::Global),
            port("lora.B", vec![r, d], f, Role::Global),
            port("adam.mA", vec![v, r], f, Role::Global),
            port("adam.vA", vec![v, r], f, Role::Global),
            port("adam.mB", vec![r, d], f, Role::Global),
            port("adam.vB", vec![r, d], f, Role::Global),
        ],
    );

    let config_text = format!(
        r#"{{"model":{{"vocab_size":{v},"d_model":{d},"n_layers":{nl},"split_layer":{sl},"max_seq":{ms}}},"spec":{{"k_spec":{b},"prefill_seq":{p},"max_new_tokens":{mn}}},"train":{{"batch_size":{n}}}}}"#,
        nl = cfg.n_layers,
        sl = cfg.split_layer,
        ms = cfg.max_seq,
        mn = cfg.max_new_tokens,
    );
    let config = Json::parse(&config_text).expect("reference config json");

    Manifest {
        dir: PathBuf::from("<reference>"),
        artifacts,
        prompts: BTreeMap::new(),
        weights_file: PathBuf::from("<reference:weights>"),
        vocab_file: PathBuf::from("<reference:vocab>"),
        config,
        exposures: Json::Null,
    }
}

/// Closed synthetic vocabulary: the four specials then `wNNN` words.
pub fn vocab(cfg: &ReferenceConfig) -> Vec<String> {
    let mut words = vec![
        "<pad>".to_string(),
        "<bos>".to_string(),
        "<eos>".to_string(),
        "<sep>".to_string(),
    ];
    for i in words.len()..cfg.vocab_size {
        words.push(format!("w{i:03}"));
    }
    words
}

/// Synthetic prompt sets for the six Spec-Bench-analogue tasks plus the
/// online "stream". Copy-heavy tasks (mt / summarization / rag) embed a
/// repeated span so n-gram drafters (PLD) get real matches.
pub fn prompt_sets(cfg: &ReferenceConfig) -> BTreeMap<String, PromptSet> {
    let mut out = BTreeMap::new();
    for (ti, task) in TASK_NAMES.iter().enumerate() {
        let mut rng = Rng::new(cfg.seed ^ (0xBEEF00 + ti as u64));
        out.insert(
            task.to_string(),
            gen_set(cfg, &mut rng, ti as u32, cfg.prompts_per_task),
        );
    }
    let mut rng = Rng::new(cfg.seed ^ 0x57AE_A11);
    let mut stream = Vec::with_capacity(cfg.stream_prompts);
    for i in 0..cfg.stream_prompts {
        let task = (i % TASK_NAMES.len()) as u32;
        stream.push(gen_sample(cfg, &mut rng, task));
    }
    out.insert("stream".to_string(), PromptSet { samples: stream });
    out
}

fn gen_set(cfg: &ReferenceConfig, rng: &mut Rng, task: u32, count: usize)
    -> PromptSet
{
    let samples = (0..count).map(|_| gen_sample(cfg, rng, task)).collect();
    PromptSet { samples }
}

fn gen_sample(cfg: &ReferenceConfig, rng: &mut Rng, task: u32) -> PromptSample {
    let word = |rng: &mut Rng| -> u32 {
        4 + rng.usize_below(cfg.vocab_size - 4) as u32
    };
    let mut prompt = vec![BOS];
    // Copy-heavy tasks: span + <sep> + span, like a document + query.
    let copyish = matches!(task, 0 | 2 | 5); // mt, summarization, rag
    if copyish {
        let span: Vec<u32> = (0..3 + rng.usize_below(3))
            .map(|_| word(rng))
            .collect();
        prompt.extend_from_slice(&span);
        for _ in 0..rng.usize_below(3) {
            prompt.push(word(rng));
        }
        prompt.push(SEP);
        prompt.extend_from_slice(&span);
    } else {
        let len = 5 + rng.usize_below(10);
        for _ in 0..len {
            prompt.push(word(rng));
        }
        prompt.push(SEP);
    }
    debug_assert!(prompt.len() <= cfg.prefill_seq);
    PromptSample {
        task,
        max_new: cfg.max_new_tokens,
        prompt,
        answer: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_has_all_artifacts() {
        let cfg = ReferenceConfig::default();
        let m = manifest(&cfg);
        for name in [
            "prefill_shallow", "prefill_deep", "draft_step", "draft_block",
            "verify_block", "prefill_full", "target_step",
            "target_verify_block", "sps_prefill", "sps_draft_step",
            "medusa_heads", "hydra_chain", "eagle_step", "train_step",
        ] {
            assert!(m.artifacts.contains_key(name), "missing {name}");
        }
        assert_eq!(m.spec_usize("k_spec").unwrap(), cfg.k_spec);
        assert_eq!(m.model_usize("d_model").unwrap(), cfg.d_model);
        assert_eq!(m.model_usize("max_seq").unwrap(), cfg.max_seq);
        assert_eq!(m.train_f64("batch_size").unwrap() as usize, cfg.batch_size);
    }

    #[test]
    fn prompts_cover_tasks_and_fit_prefill() {
        let cfg = ReferenceConfig::default();
        let sets = prompt_sets(&cfg);
        for task in TASK_NAMES {
            let set = &sets[task];
            assert_eq!(set.len(), cfg.prompts_per_task);
            for s in &set.samples {
                assert!(s.prompt.len() <= cfg.prefill_seq);
                assert_eq!(s.prompt[0], BOS);
                assert!(s.prompt.iter().all(|&t| (t as usize) < cfg.vocab_size));
            }
        }
        assert_eq!(sets["stream"].len(), cfg.stream_prompts);
    }

    #[test]
    fn vocab_is_closed_and_sized() {
        let cfg = ReferenceConfig::default();
        let v = vocab(&cfg);
        assert_eq!(v.len(), cfg.vocab_size);
        assert_eq!(v[1], "<bos>");
        assert_eq!(v[2], "<eos>");
    }

    #[test]
    fn generation_is_deterministic() {
        let cfg = ReferenceConfig::default();
        let a = prompt_sets(&cfg);
        let b = prompt_sets(&cfg);
        assert_eq!(a["qa"].samples[0].prompt, b["qa"].samples[0].prompt);
    }
}
