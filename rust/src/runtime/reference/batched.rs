//! Lane-blocked batched execution of the reference artifacts
//! (`Backend::call_batched`). Each batched implementation restructures
//! the serial per-sequence loop so the *layer* loop is outermost and the
//! lane loop innermost ([`ModelW::step_layers_lanes`]): weight matrices
//! stream through the cache hierarchy once per batch instead of once per
//! sequence — the CPU interpreter's analogue of fusing per-sequence
//! GEMVs into one batched GEMM, and where continuous batching gets its
//! throughput. Per-lane op order is untouched, so every lane's outputs
//! and KV are bitwise identical to a standalone serial call (asserted by
//! the tests below and by the scheduler's losslessness suite).

use anyhow::{ensure, Result};

use crate::runtime::backend::{BatchItem, CallOut};
use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::tensor::Tensor;

use super::model::{ModelW, StepLane};
use super::ReferenceBackend;

impl ReferenceBackend {
    /// Clone every lane's (k, v) cache pair into mutable lane state,
    /// shape-checked against the artifact's kv ports.
    fn lanes_kv(
        &self,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Result<(Vec<StepLane>, Vec<Vec<usize>>)> {
        let mut lanes = Vec::with_capacity(batch.len());
        let mut shapes = Vec::with_capacity(batch.len());
        for item in batch {
            let (kc, vc, shape) = self.kv_clone(spec, item.kv)?;
            lanes.push(StepLane { h: Vec::new(), kc, vc, pos: 0 });
            shapes.push(shape);
        }
        Ok((lanes, shapes))
    }

    /// Rewrap every lane's final state + host outputs into `CallOut`s.
    fn wrap_lanes(
        lanes: Vec<StepLane>,
        shapes: Vec<Vec<usize>>,
        outputs: Vec<Vec<Tensor>>,
    ) -> Vec<CallOut> {
        lanes
            .into_iter()
            .zip(shapes)
            .zip(outputs)
            .map(|((lane, shape), outputs)| CallOut {
                outputs,
                kv: Self::kv_wrap(&shape, lane.kc, lane.vc),
            })
            .collect()
    }

    pub(super) fn prefill_shallow_batched(
        &self,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Result<Vec<CallOut>> {
        let m = &self.target;
        let split = self.cfg.split_layer;
        let (mut lanes, shapes) = self.lanes_kv(spec, batch)?;
        let toks: Vec<&[i32]> = batch
            .iter()
            .map(|item| item.inputs[0].as_i32())
            .collect::<Result<Vec<_>>>()?;
        let p = toks.first().map_or(0, |t| t.len());
        for t in &toks {
            ensure!(t.len() == p, "ragged prefill batch");
        }
        // Optional per-lane resume point (prefix-cache attach): rows
        // 0..start already live in the lane's KV, so those positions are
        // neither embedded nor stepped; their hk rows stay zero, exactly
        // matching the serial kernel's warm path.
        let starts: Vec<usize> = batch
            .iter()
            .map(|item| {
                Ok(match item.inputs.get(1) {
                    Some(t) => t.as_i32()?[0] as usize,
                    None => 0,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        for &start in &starts {
            ensure!(start < p, "prefill start {start} out of 0..{p}");
        }
        let mut rows: Vec<Vec<f32>> =
            starts.iter().map(|&s| vec![0.0f32; s * m.d]).collect();
        for pos in 0..p {
            let active: Vec<bool> = starts.iter().map(|&s| pos >= s).collect();
            for (li, (lane, t)) in lanes.iter_mut().zip(&toks).enumerate() {
                if active[li] {
                    lane.h = m.embed_row(t[pos] as usize)?;
                    lane.pos = pos;
                }
            }
            m.step_layers_lanes_masked(0, split, &mut lanes, Some(&active))?;
            for (li, (row, lane)) in rows.iter_mut().zip(&lanes).enumerate() {
                if active[li] {
                    row.extend_from_slice(&lane.h);
                }
            }
        }
        let outputs = rows
            .into_iter()
            .map(|r| vec![Tensor::f32(vec![p, m.d], r)])
            .collect();
        Ok(Self::wrap_lanes(lanes, shapes, outputs))
    }

    pub(super) fn prefill_deep_batched(
        &self,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Result<Vec<CallOut>> {
        let m = &self.target;
        let (split, l) = (self.cfg.split_layer, self.cfg.n_layers);
        let (mut lanes, shapes) = self.lanes_kv(spec, batch)?;
        let hks: Vec<&Tensor> = batch.iter().map(|item| &item.inputs[0]).collect();
        let lens: Vec<usize> = batch
            .iter()
            .map(|item| Ok(item.inputs[1].as_i32()?[0] as usize))
            .collect::<Result<Vec<_>>>()?;
        let p = hks.first().map_or(0, |t| t.shape[0]);
        for hk in &hks {
            ensure!(hk.shape[0] == p, "ragged prefill batch");
        }
        for &len in &lens {
            ensure!(len >= 1 && len <= p, "prefill length {len} out of 1..={p}");
        }
        // Optional per-lane resume point; `start < len` so the
        // last-position logits are always computed live, never replayed
        // from a cached row.
        let starts: Vec<usize> = batch
            .iter()
            .map(|item| {
                Ok(match item.inputs.get(2) {
                    Some(t) => t.as_i32()?[0] as usize,
                    None => 0,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        for (&start, &len) in starts.iter().zip(&lens) {
            ensure!(start < len, "prefill start {start} out of 0..{len}");
        }
        let mut lasts: Vec<Vec<f32>> = vec![Vec::new(); batch.len()];
        for pos in 0..p {
            let active: Vec<bool> = starts.iter().map(|&s| pos >= s).collect();
            for (li, (lane, hk)) in lanes.iter_mut().zip(&hks).enumerate() {
                if active[li] {
                    lane.h = hk.row_f32(pos)?.to_vec();
                    lane.pos = pos;
                }
            }
            m.step_layers_lanes_masked(split, l, &mut lanes, Some(&active))?;
            for (li, ((last, lane), &len)) in
                lasts.iter_mut().zip(&lanes).zip(&lens).enumerate()
            {
                if active[li] && pos == len - 1 {
                    *last = lane.h.clone();
                }
            }
        }
        let outputs = lasts
            .into_iter()
            .map(|last| vec![Tensor::f32(vec![m.vocab], m.logits(&last))])
            .collect();
        Ok(Self::wrap_lanes(lanes, shapes, outputs))
    }

    pub(super) fn draft_step_batched(
        &self,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Result<Vec<CallOut>> {
        let m = &self.target;
        let split = self.cfg.split_layer;
        let (a, b) = self.lora()?;
        let (mut lanes, shapes) = self.lanes_kv(spec, batch)?;
        for (lane, item) in lanes.iter_mut().zip(batch) {
            lane.h = m.embed_row(item.inputs[0].as_i32()?[0] as usize)?;
            lane.pos = item.inputs[1].as_i32()?[0] as usize;
        }
        m.step_layers_lanes(0, split, &mut lanes)?;
        let mut outputs = Vec::with_capacity(batch.len());
        for lane in &lanes {
            let logits = m.draft_logits(
                &lane.h, a.as_f32()?, b.as_f32()?, self.cfg.lora_rank,
                self.cfg.lora_gamma,
            );
            outputs.push(vec![
                Tensor::f32(vec![m.vocab], logits),
                Tensor::f32(vec![m.d], lane.h.clone()),
            ]);
        }
        Ok(Self::wrap_lanes(lanes, shapes, outputs))
    }

    pub(super) fn draft_block_batched(
        &self,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Result<Vec<CallOut>> {
        let m = &self.target;
        let split = self.cfg.split_layer;
        let (a, b) = self.lora()?;
        let (mut lanes, shapes) = self.lanes_kv(spec, batch)?;
        let mut toks: Vec<i32> = batch
            .iter()
            .map(|item| Ok(item.inputs[0].as_i32()?[0]))
            .collect::<Result<Vec<_>>>()?;
        let poss: Vec<usize> = batch
            .iter()
            .map(|item| Ok(item.inputs[1].as_i32()?[0] as usize))
            .collect::<Result<Vec<_>>>()?;
        // Per-lane round lengths (adaptive-k); lanes drop out of the
        // shared layer sweep once their own round is drafted.
        let lens: Vec<usize> = batch
            .iter()
            .map(|item| Ok(item.inputs[2].as_i32()?[0] as usize))
            .collect::<Result<Vec<_>>>()?;
        for &len in &lens {
            ensure!(
                len >= 1 && len <= self.cfg.k_spec,
                "draft_block len {len} outside 1..={}",
                self.cfg.k_spec
            );
        }
        let kmax = lens.iter().copied().max().unwrap_or(0);
        let n = batch.len();
        let mut drafted: Vec<Vec<i32>> = vec![Vec::new(); n];
        let mut rows: Vec<Vec<f32>> =
            (0..n).map(|_| Vec::with_capacity(kmax * m.d)).collect();
        for i in 0..kmax {
            let active: Vec<bool> = lens.iter().map(|&l| l > i).collect();
            for (li, lane) in lanes.iter_mut().enumerate() {
                if active[li] {
                    lane.h = m.embed_row(toks[li] as usize)?;
                    lane.pos = poss[li] + i;
                }
            }
            m.step_layers_lanes_masked(0, split, &mut lanes, Some(&active))?;
            for (li, lane) in lanes.iter().enumerate() {
                if !active[li] {
                    continue;
                }
                let logits = m.draft_logits(
                    &lane.h, a.as_f32()?, b.as_f32()?, self.cfg.lora_rank,
                    self.cfg.lora_gamma,
                );
                let t = ModelW::greedy(&logits);
                rows[li].extend_from_slice(&lane.h);
                drafted[li].push(t as i32);
                toks[li] = t as i32;
            }
        }
        let outputs = drafted
            .into_iter()
            .zip(rows)
            .zip(&lens)
            .map(|((dr, r), &len)| {
                vec![Tensor::i32(vec![len], dr), Tensor::f32(vec![len, m.d], r)]
            })
            .collect();
        Ok(Self::wrap_lanes(lanes, shapes, outputs))
    }

    pub(super) fn verify_block_batched(
        &self,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Result<Vec<CallOut>> {
        let m = &self.target;
        let (split, l) = (self.cfg.split_layer, self.cfg.n_layers);
        let (mut lanes, shapes) = self.lanes_kv(spec, batch)?;
        let hks: Vec<&Tensor> = batch.iter().map(|item| &item.inputs[0]).collect();
        let poss: Vec<usize> = batch
            .iter()
            .map(|item| Ok(item.inputs[1].as_i32()?[0] as usize))
            .collect::<Result<Vec<_>>>()?;
        let bsz = hks.first().map_or(0, |t| t.shape[0]);
        for hk in &hks {
            ensure!(hk.shape[0] == bsz, "ragged verify batch");
        }
        // Live row count per lane: hk blocks are padded to a uniform
        // k_spec rows, but only rows 0..len are stepped/committed.
        let lens: Vec<usize> = batch
            .iter()
            .map(|item| Ok(item.inputs[2].as_i32()?[0] as usize))
            .collect::<Result<Vec<_>>>()?;
        for &len in &lens {
            ensure!(
                len >= 1 && len <= bsz,
                "verify_block len {len} outside 1..={bsz}"
            );
        }
        let imax = lens.iter().copied().max().unwrap_or(0);
        let mut logits: Vec<Vec<f32>> = lens
            .iter()
            .map(|&len| Vec::with_capacity(len * m.vocab))
            .collect();
        for i in 0..imax {
            let active: Vec<bool> = lens.iter().map(|&l| l > i).collect();
            for (li, (lane, hk)) in lanes.iter_mut().zip(&hks).enumerate() {
                if active[li] {
                    lane.h = hk.row_f32(i)?.to_vec();
                    lane.pos = poss[li] + i;
                }
            }
            m.step_layers_lanes_masked(split, l, &mut lanes, Some(&active))?;
            for (li, (lg, lane)) in logits.iter_mut().zip(&lanes).enumerate() {
                if active[li] {
                    lg.extend_from_slice(&m.logits(&lane.h));
                }
            }
        }
        let outputs = logits
            .into_iter()
            .zip(&lens)
            .map(|(lg, &len)| vec![Tensor::f32(vec![len, m.vocab], lg)])
            .collect();
        Ok(Self::wrap_lanes(lanes, shapes, outputs))
    }

    pub(super) fn full_prefill_batched(
        &self,
        m: &ModelW,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Result<Vec<CallOut>> {
        let nl = m.layers.len();
        let (mut lanes, shapes) = self.lanes_kv(spec, batch)?;
        let toks: Vec<&[i32]> = batch
            .iter()
            .map(|item| item.inputs[0].as_i32())
            .collect::<Result<Vec<_>>>()?;
        let lens: Vec<usize> = batch
            .iter()
            .map(|item| Ok(item.inputs[1].as_i32()?[0] as usize))
            .collect::<Result<Vec<_>>>()?;
        let p = toks.first().map_or(0, |t| t.len());
        for t in &toks {
            ensure!(t.len() == p, "ragged prefill batch");
        }
        for &len in &lens {
            ensure!(len >= 1 && len <= p, "prefill length {len} bad");
        }
        let mut lasts: Vec<Vec<f32>> = vec![Vec::new(); batch.len()];
        for pos in 0..p {
            for (lane, t) in lanes.iter_mut().zip(&toks) {
                lane.h = m.embed_row(t[pos] as usize)?;
                lane.pos = pos;
            }
            m.step_layers_lanes(0, nl, &mut lanes)?;
            for ((last, lane), &len) in lasts.iter_mut().zip(&lanes).zip(&lens) {
                if pos == len - 1 {
                    *last = lane.h.clone();
                }
            }
        }
        let outputs = lasts
            .into_iter()
            .map(|last| {
                vec![
                    Tensor::f32(vec![m.vocab], m.logits(&last)),
                    Tensor::f32(vec![m.d], last),
                ]
            })
            .collect();
        Ok(Self::wrap_lanes(lanes, shapes, outputs))
    }

    pub(super) fn full_step_batched(
        &self,
        m: &ModelW,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Result<Vec<CallOut>> {
        let nl = m.layers.len();
        let (mut lanes, shapes) = self.lanes_kv(spec, batch)?;
        for (lane, item) in lanes.iter_mut().zip(batch) {
            lane.h = m.embed_row(item.inputs[0].as_i32()?[0] as usize)?;
            lane.pos = item.inputs[1].as_i32()?[0] as usize;
        }
        m.step_layers_lanes(0, nl, &mut lanes)?;
        let outputs = lanes
            .iter()
            .map(|lane| {
                vec![
                    Tensor::f32(vec![m.vocab], m.logits(&lane.h)),
                    Tensor::f32(vec![m.d], lane.h.clone()),
                ]
            })
            .collect();
        Ok(Self::wrap_lanes(lanes, shapes, outputs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::{Backend, Buffer};
    use crate::runtime::reference::{synth, ReferenceConfig};

    fn be() -> ReferenceBackend {
        ReferenceBackend::new(ReferenceConfig::default()).unwrap()
    }

    /// Run `lanes` through `name` serially (one call per lane) and as one
    /// batched call; assert bitwise-identical outputs and KV, and return
    /// the batched results for chaining.
    fn assert_batched_matches(
        be: &ReferenceBackend,
        name: &str,
        lanes: &[(Vec<Buffer>, Vec<Tensor>)],
    ) -> Vec<CallOut> {
        let manifest = synth::manifest(&be.cfg);
        let spec = manifest.artifact(name).unwrap();
        let serial: Vec<CallOut> = lanes
            .iter()
            .map(|(kv, inp)| be.call(spec, kv, inp).unwrap())
            .collect();
        let items: Vec<BatchItem<'_>> = lanes
            .iter()
            .map(|(kv, inp)| BatchItem { kv, inputs: inp })
            .collect();
        let batched = be.call_batched(spec, &items).unwrap();
        assert_eq!(batched.len(), lanes.len());
        for (lane_i, (s, bo)) in serial.iter().zip(&batched).enumerate() {
            assert_eq!(
                s.outputs, bo.outputs,
                "{name} lane {lane_i}: outputs diverged under batching"
            );
            assert_eq!(s.kv.len(), bo.kv.len());
            for (sk, bk) in s.kv.iter().zip(&bo.kv) {
                assert_eq!(
                    sk.as_host().unwrap(),
                    bk.as_host().unwrap(),
                    "{name} lane {lane_i}: kv diverged under batching"
                );
            }
        }
        batched
    }

    /// Three sequences of different lengths through the whole DVI and AR
    /// artifact chains: every batched kernel must match per-lane serial
    /// calls bitwise at every stage.
    #[test]
    fn batched_matches_serial_across_artifacts() {
        let be = be();
        let manifest = synth::manifest(&be.cfg);
        let p = be.cfg.prefill_seq;
        let prompts: Vec<Vec<i32>> = vec![
            vec![1, 10, 11, 3],
            vec![1, 20, 21, 22, 3],
            vec![1, 30, 31, 32, 33, 3],
        ];
        let padded: Vec<Tensor> = prompts
            .iter()
            .map(|pr| {
                let mut t = pr.clone();
                t.resize(p, 0);
                Tensor::i32(vec![p], t)
            })
            .collect();

        let sh_spec = manifest.artifact("prefill_shallow").unwrap();
        let sh_lanes: Vec<(Vec<Buffer>, Vec<Tensor>)> = padded
            .iter()
            .map(|t| (be.fresh_kv(sh_spec).unwrap(), vec![t.clone()]))
            .collect();
        let sh_out = assert_batched_matches(&be, "prefill_shallow", &sh_lanes);

        let dp_spec = manifest.artifact("prefill_deep").unwrap();
        let dp_lanes: Vec<(Vec<Buffer>, Vec<Tensor>)> = sh_out
            .iter()
            .zip(&prompts)
            .map(|(o, pr)| {
                (
                    be.fresh_kv(dp_spec).unwrap(),
                    vec![
                        o.outputs[0].clone(),
                        Tensor::scalar_i32(pr.len() as i32),
                    ],
                )
            })
            .collect();
        let dp_out = assert_batched_matches(&be, "prefill_deep", &dp_lanes);

        // Draft from each lane's feed point (position = prompt length).
        let draft_lanes: Vec<(Vec<Buffer>, Vec<Tensor>)> = sh_out
            .iter()
            .zip(&prompts)
            .map(|(o, pr)| {
                (
                    o.kv.clone(),
                    vec![
                        Tensor::scalar_i32(7),
                        Tensor::scalar_i32(pr.len() as i32),
                    ],
                )
            })
            .collect();
        assert_batched_matches(&be, "draft_step", &draft_lanes);
        // Per-lane round lengths exercise the adaptive-k masking: the
        // batched kernels must match serial calls even when lanes drop
        // out of the shared layer sweep at different steps.
        let k = be.cfg.k_spec;
        let lens: Vec<usize> = (0..prompts.len())
            .map(|i| k - i.min(k - 1))
            .collect();
        let block_lanes: Vec<(Vec<Buffer>, Vec<Tensor>)> = draft_lanes
            .iter()
            .zip(&lens)
            .map(|((kv, inp), &len)| {
                let mut inp = inp.clone();
                inp.push(Tensor::scalar_i32(len as i32));
                (kv.clone(), inp)
            })
            .collect();
        let block_out = assert_batched_matches(&be, "draft_block", &block_lanes);

        let d = be.cfg.d_model;
        let verify_lanes: Vec<(Vec<Buffer>, Vec<Tensor>)> = dp_out
            .iter()
            .zip(&block_out)
            .zip(&prompts)
            .zip(&lens)
            .map(|(((dpo, blo), pr), &len)| {
                // hk blocks travel padded to the uniform [k_spec, d]
                // manifest shape; only rows 0..len are live.
                let mut hk = blo.outputs[1].as_f32().unwrap().to_vec();
                hk.resize(k * d, 0.0);
                (
                    dpo.kv.clone(),
                    vec![
                        Tensor::f32(vec![k, d], hk),
                        Tensor::scalar_i32(pr.len() as i32),
                        Tensor::scalar_i32(len as i32),
                    ],
                )
            })
            .collect();
        assert_batched_matches(&be, "verify_block", &verify_lanes);

        let fl_spec = manifest.artifact("prefill_full").unwrap();
        let fl_lanes: Vec<(Vec<Buffer>, Vec<Tensor>)> = padded
            .iter()
            .zip(&prompts)
            .map(|(t, pr)| {
                (
                    be.fresh_kv(fl_spec).unwrap(),
                    vec![t.clone(), Tensor::scalar_i32(pr.len() as i32)],
                )
            })
            .collect();
        let fl_out = assert_batched_matches(&be, "prefill_full", &fl_lanes);
        let step_lanes: Vec<(Vec<Buffer>, Vec<Tensor>)> = fl_out
            .iter()
            .zip(&prompts)
            .map(|(o, pr)| {
                (
                    o.kv.clone(),
                    vec![
                        Tensor::scalar_i32(9),
                        Tensor::scalar_i32(pr.len() as i32),
                    ],
                )
            })
            .collect();
        assert_batched_matches(&be, "target_step", &step_lanes);
    }

    /// Warm prefill (nonzero per-lane `start`, KV resumed from a cold
    /// prefill of a donor prompt sharing a prefix) matches serial
    /// bitwise AND matches a cold prefill of the full prompt — the
    /// kernel-level half of the prefix-cache losslessness gate. Lanes
    /// attach at different depths to exercise the per-lane masking.
    #[test]
    fn warm_prefill_matches_cold_and_serial() {
        let be = be();
        let manifest = synth::manifest(&be.cfg);
        let p = be.cfg.prefill_seq;
        let d = be.cfg.d_model;
        let pad = |pr: &[i32]| {
            let mut t = pr.to_vec();
            t.resize(p, 0);
            Tensor::i32(vec![p], t)
        };
        let prefix = vec![1, 40, 41, 42];
        let prompts: Vec<Vec<i32>> = vec![
            [&prefix[..], &[50, 3]].concat(),
            [&prefix[..], &[60, 61, 3]].concat(),
        ];
        let sh_spec = manifest.artifact("prefill_shallow").unwrap();
        // Donor: cold prefill of a third prompt sharing the prefix.
        let donor_kv = be.fresh_kv(sh_spec).unwrap();
        let donor = be
            .call(sh_spec, &donor_kv, &[pad(&[&prefix[..], &[70, 3]].concat())])
            .unwrap();
        // Lane 0 attaches at the full shared prefix, lane 1 shallower —
        // any prefix of a cached path is a valid attach point.
        let starts = [prefix.len(), 2];
        let warm_lanes: Vec<(Vec<Buffer>, Vec<Tensor>)> = prompts
            .iter()
            .zip(starts)
            .map(|(pr, s)| {
                (donor.kv.clone(), vec![pad(pr), Tensor::scalar_i32(s as i32)])
            })
            .collect();
        let warm = assert_batched_matches(&be, "prefill_shallow", &warm_lanes);
        for ((pr, w), &s) in prompts.iter().zip(&warm).zip(&starts) {
            let kv = be.fresh_kv(sh_spec).unwrap();
            let cold = be.call(sh_spec, &kv, &[pad(pr)]).unwrap();
            for (ck, wk) in cold.kv.iter().zip(&w.kv) {
                assert_eq!(
                    ck.as_host().unwrap(),
                    wk.as_host().unwrap(),
                    "warm-attach KV diverged from cold prefill"
                );
            }
            // hk rows below the attach point are zero-filled (the deep
            // prefill never reads them when given the same start); rows
            // at and above it must match the cold run bitwise.
            let ch = cold.outputs[0].as_f32().unwrap();
            let wh = w.outputs[0].as_f32().unwrap();
            assert_eq!(&ch[s * d..], &wh[s * d..]);
            assert!(wh[..s * d].iter().all(|&x| x == 0.0));
        }
    }

    /// Artifacts without a lane-blocked kernel fall back to the serial
    /// loop — still one `call_batched`, still bitwise identical.
    #[test]
    fn batched_fallback_for_headless_artifacts() {
        let be = be();
        let d = be.cfg.d_model;
        let hl = Tensor::f32(vec![d], vec![0.1; d]);
        let lanes: Vec<(Vec<Buffer>, Vec<Tensor>)> =
            (0..3).map(|_| (Vec::new(), vec![hl.clone()])).collect();
        assert_batched_matches(&be, "medusa_heads", &lanes);
    }
}
