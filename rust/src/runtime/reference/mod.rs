//! The hermetic reference backend: a deterministic, pure-Rust
//! implementation of every artifact the PJRT exporter produces, driven
//! by a generated in-memory manifest ([`synth`]) and seeded synthetic
//! weights ([`model`]). `Runtime::load_reference(seed)` yields a fully
//! functional runtime with zero files on disk, so the lossless /
//! tuple-logging / online-learning invariant suite runs on every commit
//! with no Python, no XLA, and no artifacts directory.
//!
//! The split-transformer geometry mirrors `python/compile/config.py` at
//! CPU-trivial scale: shallow layers + LoRA draft head feed a deep
//! verify stack over shared layer weights, so DVI's self-speculation is
//! exactly lossless against the full-model AR baseline (bitwise — see
//! `model.rs` for why). The `train_step` artifact reimplements the §3.4
//! composite objective (KL / reward-masked CE / REINFORCE / entropy)
//! with hand-derived gradients through the LoRA factors and a fused
//! bias-corrected Adam update, matching `python/compile/train.py`.

mod batched;
pub mod model;
pub mod synth;

use std::collections::BTreeMap;
use std::sync::RwLock;

use anyhow::{bail, ensure, Context, Result};

use crate::runtime::backend::{Backend, BatchItem, Buffer, CallOut};
use crate::runtime::manifest::{ArtifactSpec, Role};
use crate::runtime::tensor::{DType, Tensor};
use crate::util::math::logsumexp;
use crate::util::rng::Rng;

use model::{dot, matvec, ModelW};

/// Geometry of the synthetic split backbone + heads. Defaults are small
/// enough that the full integration suite runs in seconds under
/// `cargo test` (debug profile), yet structured enough that acceptance,
/// tuple logging, and online-KD dynamics are non-degenerate.
#[derive(Debug, Clone)]
pub struct ReferenceConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub split_layer: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub prefill_seq: usize,
    pub max_new_tokens: usize,
    pub k_spec: usize,
    pub lora_rank: usize,
    pub lora_gamma: f32,
    pub batch_size: usize,
    pub sps_layers: usize,
    pub medusa_hidden: usize,
    pub hydra_hidden: usize,
    pub eagle_hidden: usize,
    pub norm_eps: f32,
    pub adam_b1: f32,
    pub adam_b2: f32,
    pub adam_eps: f32,
    pub seed: u64,
    pub prompts_per_task: usize,
    pub stream_prompts: usize,
}

impl Default for ReferenceConfig {
    fn default() -> ReferenceConfig {
        ReferenceConfig {
            vocab_size: 64,
            d_model: 16,
            n_layers: 4,
            split_layer: 2,
            d_ff: 32,
            max_seq: 160,
            prefill_seq: 48,
            max_new_tokens: 32,
            k_spec: 4,
            lora_rank: 4,
            lora_gamma: 2.0,
            batch_size: 16,
            sps_layers: 2,
            medusa_hidden: 16,
            hydra_hidden: 16,
            eagle_hidden: 32,
            norm_eps: 1e-5,
            adam_b1: 0.9,
            adam_b2: 0.999,
            adam_eps: 1e-8,
            seed: 0xD5EED,
            prompts_per_task: 32,
            stream_prompts: 512,
        }
    }
}

struct MedusaHead {
    u: Vec<f32>, // [d, hidden]
    w: Vec<f32>, // [hidden, vocab]
}

struct HydraW {
    w0: Vec<f32>, // [d, hidden]
    ws: Vec<f32>, // [hidden, hidden]
    we: Vec<f32>, // [d, hidden]
    w: Vec<f32>,  // [hidden, vocab]
}

struct EagleW {
    w1: Vec<f32>, // [2d, hidden]
    w2: Vec<f32>, // [hidden, d]
}

pub struct ReferenceBackend {
    pub cfg: ReferenceConfig,
    /// The split backbone: `layers[..split]` = shallow/draft stack,
    /// `layers[split..]` = deep/verify stack, shared embedding + head.
    target: ModelW,
    /// Independent small drafter LM for the SpS baseline.
    drafter: ModelW,
    medusa: Vec<MedusaHead>,
    hydra: HydraW,
    eagle: EagleW,
    globals: RwLock<BTreeMap<String, Tensor>>,
    init_globals: BTreeMap<String, Tensor>,
    /// Fingerprint of every weight tensor + the initial globals,
    /// computed once at construction; carried in the remote executor
    /// handshake so a sharded fleet with divergent weights is rejected
    /// at connect time (same seed + config ⇒ same fingerprint).
    fingerprint: u64,
}

impl ReferenceBackend {
    pub fn new(cfg: ReferenceConfig) -> Result<ReferenceBackend> {
        ensure!(
            cfg.split_layer >= 1 && cfg.split_layer < cfg.n_layers,
            "split_layer {} must be inside 1..{}",
            cfg.split_layer,
            cfg.n_layers
        );
        ensure!(
            cfg.prefill_seq < cfg.max_seq,
            "prefill_seq must leave decode headroom"
        );
        let (d, v) = (cfg.d_model, cfg.vocab_size);
        let mut rng = Rng::new(cfg.seed);
        let target = ModelW::init(
            &mut rng.fork(1), d, cfg.d_ff, v, cfg.n_layers, cfg.max_seq,
            cfg.norm_eps,
        );
        let drafter = ModelW::init(
            &mut rng.fork(2), d, cfg.d_ff, v, cfg.sps_layers, cfg.max_seq,
            cfg.norm_eps,
        );
        let g = |rng: &mut Rng, n: usize, scale: f32| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32 * scale).collect()
        };
        let mut hrng = rng.fork(3);
        let medusa = (0..cfg.k_spec)
            .map(|_| MedusaHead {
                u: g(&mut hrng, d * cfg.medusa_hidden, 0.3),
                w: g(&mut hrng, cfg.medusa_hidden * v, 0.3),
            })
            .collect();
        let hydra = HydraW {
            w0: g(&mut hrng, d * cfg.hydra_hidden, 0.3),
            ws: g(&mut hrng, cfg.hydra_hidden * cfg.hydra_hidden, 0.3),
            we: g(&mut hrng, d * cfg.hydra_hidden, 0.3),
            w: g(&mut hrng, cfg.hydra_hidden * v, 0.3),
        };
        let eagle = EagleW {
            w1: g(&mut hrng, 2 * d * cfg.eagle_hidden, 0.2),
            w2: g(&mut hrng, cfg.eagle_hidden * d, 0.2),
        };

        // LoRA starts at zero delta (B = 0): the draft head initially
        // equals the transplanted base head, and online KD moves it.
        let mut grng = rng.fork(4);
        let r = cfg.lora_rank;
        let mut init_globals = BTreeMap::new();
        init_globals.insert(
            "lora.A".to_string(),
            Tensor::f32(vec![v, r], g(&mut grng, v * r, 0.02)),
        );
        init_globals.insert(
            "lora.B".to_string(),
            Tensor::zeros_f32(vec![r, d]),
        );
        for name in ["adam.mA", "adam.vA"] {
            init_globals.insert(name.to_string(), Tensor::zeros_f32(vec![v, r]));
        }
        for name in ["adam.mB", "adam.vB"] {
            init_globals.insert(name.to_string(), Tensor::zeros_f32(vec![r, d]));
        }
        let globals = RwLock::new(init_globals.clone());

        let fingerprint = {
            use crate::runtime::weights::Fnv64;
            let mut h = Fnv64::new();
            for (tag, m) in [("target", &target), ("drafter", &drafter)] {
                h.str(tag);
                h.f32s(&m.embed);
                h.u64(m.layers.len() as u64);
                for l in &m.layers {
                    for w in [
                        &l.wq, &l.wk, &l.wv, &l.wo, &l.w1, &l.w2,
                        &l.rms_attn, &l.rms_mlp,
                    ] {
                        h.f32s(w);
                    }
                }
                h.f32s(&m.final_norm);
                h.f32s(&m.lm_head);
            }
            h.str("medusa");
            h.u64(medusa.len() as u64);
            for head in &medusa {
                h.f32s(&head.u);
                h.f32s(&head.w);
            }
            h.str("hydra");
            for w in [&hydra.w0, &hydra.ws, &hydra.we, &hydra.w] {
                h.f32s(w);
            }
            h.str("eagle");
            h.f32s(&eagle.w1);
            h.f32s(&eagle.w2);
            h.str("globals");
            h.u64(init_globals.len() as u64);
            for (name, t) in &init_globals {
                h.str(name);
                h.tensor(t);
            }
            h.finish()
        };

        Ok(ReferenceBackend {
            cfg,
            target,
            drafter,
            medusa,
            hydra,
            eagle,
            globals,
            init_globals,
            fingerprint,
        })
    }

    fn global(&self, name: &str) -> Result<Tensor> {
        self.globals
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .with_context(|| format!("global buffer '{name}' missing"))
    }

    /// Clone the (k, v) cache pair into mutable vectors, shape-checked
    /// against the artifact's kv ports.
    fn kv_clone(&self, spec: &ArtifactSpec, kv: &[Buffer])
        -> Result<(Vec<f32>, Vec<f32>, Vec<usize>)>
    {
        let ports: Vec<_> = spec.params_with_role(Role::Kv).collect();
        ensure!(
            ports.len() == 2 && kv.len() == 2,
            "{}: expected a k/v cache pair, got {}",
            spec.name,
            kv.len()
        );
        let kt = kv[0].as_host()?;
        let vt = kv[1].as_host()?;
        for (t, port) in [(kt, ports[0]), (vt, ports[1])] {
            ensure!(
                t.shape == port.shape,
                "{}: kv '{}' shape {:?} != manifest {:?}",
                spec.name, port.name, t.shape, port.shape
            );
        }
        Ok((kt.as_f32()?.to_vec(), vt.as_f32()?.to_vec(), kt.shape.clone()))
    }

    fn kv_wrap(shape: &[usize], kc: Vec<f32>, vc: Vec<f32>) -> Vec<Buffer> {
        vec![
            Buffer::host(Tensor::f32(shape.to_vec(), kc)),
            Buffer::host(Tensor::f32(shape.to_vec(), vc)),
        ]
    }

    fn lora(&self) -> Result<(Tensor, Tensor)> {
        Ok((self.global("lora.A")?, self.global("lora.B")?))
    }

    // ---- artifact implementations ------------------------------------

    fn prefill_shallow(&self, spec: &ArtifactSpec, kv: &[Buffer],
                       inputs: &[Tensor]) -> Result<CallOut> {
        let toks = inputs[0].as_i32()?;
        // Optional trailing `start` (prefix-cache attach point): rows
        // below it are already resident in the input KV and are neither
        // recomputed nor emitted (their hk rows are zero-filled — the
        // deep prefill never reads below its own matching start).
        // Trailing-optional so direct backend calls predating the port
        // stay valid; `Artifact::check_lane` enforces it when declared.
        let start = match inputs.get(1) {
            Some(t) => t.as_i32()?[0] as usize,
            None => 0,
        };
        ensure!(start < toks.len(), "prefill start {start} >= {}", toks.len());
        let (mut kc, mut vc, shape) = self.kv_clone(spec, kv)?;
        let m = &self.target;
        let split = self.cfg.split_layer;
        let mut rows = vec![0.0f32; toks.len() * m.d];
        for (pos, &t) in toks.iter().enumerate().skip(start) {
            let mut h = m.embed_row(t as usize)?;
            m.step_layers(0, split, &mut h, &mut kc, &mut vc, pos)?;
            rows[pos * m.d..(pos + 1) * m.d].copy_from_slice(&h);
        }
        Ok(CallOut {
            outputs: vec![Tensor::f32(vec![toks.len(), m.d], rows)],
            kv: Self::kv_wrap(&shape, kc, vc),
        })
    }

    fn prefill_deep(&self, spec: &ArtifactSpec, kv: &[Buffer],
                    inputs: &[Tensor]) -> Result<CallOut> {
        let hk = &inputs[0];
        let len = inputs[1].as_i32()?[0] as usize;
        let start = match inputs.get(2) {
            Some(t) => t.as_i32()?[0] as usize,
            None => 0,
        };
        let p = hk.shape[0];
        ensure!(len >= 1 && len <= p, "prefill length {len} out of 1..={p}");
        ensure!(
            start < len,
            "prefill start {start} must stay below length {len} so the \
             last-position logits are computed live"
        );
        let (mut kc, mut vc, shape) = self.kv_clone(spec, kv)?;
        let m = &self.target;
        let (split, l) = (self.cfg.split_layer, self.cfg.n_layers);
        let mut last = Vec::new();
        for pos in start..p {
            let mut h = hk.row_f32(pos)?.to_vec();
            m.step_layers(split, l, &mut h, &mut kc, &mut vc, pos)?;
            if pos == len - 1 {
                last = h.clone();
            }
        }
        Ok(CallOut {
            outputs: vec![Tensor::f32(vec![m.vocab], m.logits(&last))],
            kv: Self::kv_wrap(&shape, kc, vc),
        })
    }

    /// `prefill_full` / `sps_prefill`: a complete model over a padded
    /// prompt; returns last-position logits + hidden state.
    fn full_prefill(&self, m: &ModelW, spec: &ArtifactSpec, kv: &[Buffer],
                    inputs: &[Tensor]) -> Result<CallOut> {
        let toks = inputs[0].as_i32()?;
        let len = inputs[1].as_i32()?[0] as usize;
        ensure!(len >= 1 && len <= toks.len(), "prefill length {len} bad");
        let (mut kc, mut vc, shape) = self.kv_clone(spec, kv)?;
        let nl = m.layers.len();
        let mut last = Vec::new();
        for (pos, &t) in toks.iter().enumerate() {
            let mut h = m.embed_row(t as usize)?;
            m.step_layers(0, nl, &mut h, &mut kc, &mut vc, pos)?;
            if pos == len - 1 {
                last = h.clone();
            }
        }
        Ok(CallOut {
            outputs: vec![
                Tensor::f32(vec![m.vocab], m.logits(&last)),
                Tensor::f32(vec![m.d], last),
            ],
            kv: Self::kv_wrap(&shape, kc, vc),
        })
    }

    /// `target_step` / `sps_draft_step`: one full-model decode step.
    fn full_step(&self, m: &ModelW, spec: &ArtifactSpec, kv: &[Buffer],
                 inputs: &[Tensor]) -> Result<CallOut> {
        let tok = inputs[0].as_i32()?[0];
        let pos = inputs[1].as_i32()?[0] as usize;
        let (mut kc, mut vc, shape) = self.kv_clone(spec, kv)?;
        let nl = m.layers.len();
        let mut h = m.embed_row(tok as usize)?;
        m.step_layers(0, nl, &mut h, &mut kc, &mut vc, pos)?;
        Ok(CallOut {
            outputs: vec![
                Tensor::f32(vec![m.vocab], m.logits(&h)),
                Tensor::f32(vec![m.d], h),
            ],
            kv: Self::kv_wrap(&shape, kc, vc),
        })
    }

    fn target_verify_block(&self, spec: &ArtifactSpec, kv: &[Buffer],
                           inputs: &[Tensor]) -> Result<CallOut> {
        let toks = inputs[0].as_i32()?;
        let pos = inputs[1].as_i32()?[0] as usize;
        let (mut kc, mut vc, shape) = self.kv_clone(spec, kv)?;
        let m = &self.target;
        let nl = m.layers.len();
        let b = toks.len();
        let mut logits = Vec::with_capacity(b * m.vocab);
        let mut hl = Vec::with_capacity(b * m.d);
        for (i, &t) in toks.iter().enumerate() {
            let mut h = m.embed_row(t as usize)?;
            m.step_layers(0, nl, &mut h, &mut kc, &mut vc, pos + i)?;
            logits.extend_from_slice(&m.logits(&h));
            hl.extend_from_slice(&h);
        }
        Ok(CallOut {
            outputs: vec![
                Tensor::f32(vec![b, m.vocab], logits),
                Tensor::f32(vec![b, m.d], hl),
            ],
            kv: Self::kv_wrap(&shape, kc, vc),
        })
    }

    fn draft_step(&self, spec: &ArtifactSpec, kv: &[Buffer],
                  inputs: &[Tensor]) -> Result<CallOut> {
        let tok = inputs[0].as_i32()?[0];
        let pos = inputs[1].as_i32()?[0] as usize;
        let (a, b) = self.lora()?;
        let (mut kc, mut vc, shape) = self.kv_clone(spec, kv)?;
        let m = &self.target;
        let split = self.cfg.split_layer;
        let mut h = m.embed_row(tok as usize)?;
        m.step_layers(0, split, &mut h, &mut kc, &mut vc, pos)?;
        let logits = m.draft_logits(
            &h, a.as_f32()?, b.as_f32()?, self.cfg.lora_rank,
            self.cfg.lora_gamma,
        );
        Ok(CallOut {
            outputs: vec![
                Tensor::f32(vec![m.vocab], logits),
                Tensor::f32(vec![m.d], h),
            ],
            kv: Self::kv_wrap(&shape, kc, vc),
        })
    }

    /// Fused k_spec-step draft loop: greedy argmax between steps happens
    /// "in-graph" (here: in the interpreter), one call instead of k.
    fn draft_block(&self, spec: &ArtifactSpec, kv: &[Buffer],
                   inputs: &[Tensor]) -> Result<CallOut> {
        let mut tok = inputs[0].as_i32()?[0];
        let pos = inputs[1].as_i32()?[0] as usize;
        // Round length: adaptive-k sends 1..=k_spec; k_spec reproduces the
        // historical fixed-k loop bitwise.
        let k = inputs[2].as_i32()?[0] as usize;
        ensure!(
            k >= 1 && k <= self.cfg.k_spec,
            "draft_block len {k} outside 1..={}",
            self.cfg.k_spec
        );
        let (a, b) = self.lora()?;
        let (mut kc, mut vc, shape) = self.kv_clone(spec, kv)?;
        let m = &self.target;
        let split = self.cfg.split_layer;
        let mut drafted = Vec::with_capacity(k);
        let mut rows = Vec::with_capacity(k * m.d);
        for i in 0..k {
            let mut h = m.embed_row(tok as usize)?;
            m.step_layers(0, split, &mut h, &mut kc, &mut vc, pos + i)?;
            let logits = m.draft_logits(
                &h, a.as_f32()?, b.as_f32()?, self.cfg.lora_rank,
                self.cfg.lora_gamma,
            );
            let t = ModelW::greedy(&logits);
            rows.extend_from_slice(&h);
            drafted.push(t as i32);
            tok = t as i32;
        }
        Ok(CallOut {
            outputs: vec![
                Tensor::i32(vec![k], drafted),
                Tensor::f32(vec![k, m.d], rows),
            ],
            kv: Self::kv_wrap(&shape, kc, vc),
        })
    }

    fn verify_block(&self, spec: &ArtifactSpec, kv: &[Buffer],
                    inputs: &[Tensor]) -> Result<CallOut> {
        let hk = &inputs[0];
        let pos = inputs[1].as_i32()?[0] as usize;
        // Rows 0..len of the (k_spec-padded) hk block are live; padding
        // rows are never stepped, so no deep-stack FLOPs are wasted and
        // no KV slot beyond pos+len-1 is written.
        let b = inputs[2].as_i32()?[0] as usize;
        ensure!(
            b >= 1 && b <= hk.shape[0],
            "verify_block len {b} outside 1..={}",
            hk.shape[0]
        );
        let (mut kc, mut vc, shape) = self.kv_clone(spec, kv)?;
        let m = &self.target;
        let (split, l) = (self.cfg.split_layer, self.cfg.n_layers);
        let mut logits = Vec::with_capacity(b * m.vocab);
        for i in 0..b {
            let mut h = hk.row_f32(i)?.to_vec();
            m.step_layers(split, l, &mut h, &mut kc, &mut vc, pos + i)?;
            logits.extend_from_slice(&m.logits(&h));
        }
        Ok(CallOut {
            outputs: vec![Tensor::f32(vec![b, m.vocab], logits)],
            kv: Self::kv_wrap(&shape, kc, vc),
        })
    }

    fn medusa_heads(&self, inputs: &[Tensor]) -> Result<CallOut> {
        let m = &self.target;
        let hn = model::rmsnorm(inputs[0].as_f32()?, &m.final_norm, m.eps);
        let mut logits = Vec::with_capacity(self.medusa.len() * m.vocab);
        for head in &self.medusa {
            let mut a = matvec(&hn, &head.u, self.cfg.medusa_hidden);
            for x in a.iter_mut() {
                *x = *x / (1.0 + (-*x).exp());
            }
            logits.extend_from_slice(&matvec(&a, &head.w, m.vocab));
        }
        Ok(CallOut {
            outputs: vec![Tensor::f32(vec![self.medusa.len(), m.vocab], logits)],
            kv: Vec::new(),
        })
    }

    fn hydra_chain(&self, inputs: &[Tensor]) -> Result<CallOut> {
        let m = &self.target;
        let hh = self.cfg.hydra_hidden;
        let hn = model::rmsnorm(inputs[0].as_f32()?, &m.final_norm, m.eps);
        let mut tok = inputs[1].as_i32()?[0];
        let silu = |v: &mut Vec<f32>| {
            for x in v.iter_mut() {
                *x = *x / (1.0 + (-*x).exp());
            }
        };
        let mut s = matvec(&hn, &self.hydra.w0, hh);
        silu(&mut s);
        let k = self.cfg.k_spec;
        let mut toks = Vec::with_capacity(k);
        let mut logits = Vec::with_capacity(k * m.vocab);
        for _ in 0..k {
            let e = m.embed_row(tok as usize)?;
            let mut pre = matvec(&s, &self.hydra.ws, hh);
            let ee = matvec(&e, &self.hydra.we, hh);
            for j in 0..hh {
                pre[j] += ee[j];
            }
            silu(&mut pre);
            s = pre;
            let lg = matvec(&s, &self.hydra.w, m.vocab);
            let t = ModelW::greedy(&lg);
            toks.push(t as i32);
            logits.extend_from_slice(&lg);
            tok = t as i32;
        }
        Ok(CallOut {
            outputs: vec![
                Tensor::i32(vec![k], toks),
                Tensor::f32(vec![k, m.vocab], logits),
            ],
            kv: Vec::new(),
        })
    }

    fn eagle_step(&self, inputs: &[Tensor]) -> Result<CallOut> {
        let m = &self.target;
        let feat = inputs[0].as_f32()?;
        let tok = inputs[1].as_i32()?[0];
        let e = m.embed_row(tok as usize)?;
        let mut cat = Vec::with_capacity(2 * m.d);
        cat.extend_from_slice(feat);
        cat.extend_from_slice(&e);
        let mut mid = matvec(&cat, &self.eagle.w1, self.cfg.eagle_hidden);
        for x in mid.iter_mut() {
            *x = *x / (1.0 + (-*x).exp());
        }
        let delta = matvec(&mid, &self.eagle.w2, m.d);
        let f: Vec<f32> = feat.iter().zip(&delta).map(|(a, b)| a + b).collect();
        Ok(CallOut {
            outputs: vec![
                Tensor::f32(vec![m.vocab], m.logits(&f)),
                Tensor::f32(vec![m.d], f),
            ],
            kv: Vec::new(),
        })
    }

    /// The §3.4 composite objective with hand-derived LoRA gradients and
    /// a fused bias-corrected Adam step. Hyper/metrics layouts match
    /// `python/compile/train.py` exactly.
    fn train_step(&self, inputs: &[Tensor]) -> Result<CallOut> {
        let m = &self.target;
        let (d, v, r) = (m.d, m.vocab, self.cfg.lora_rank);
        let gamma = self.cfg.lora_gamma;
        let hk = &inputs[0];
        let actions = inputs[1].as_i32()?;
        let logits_phi = &inputs[2];
        let rewards = inputs[3].as_f32()?;
        let mask = inputs[4].as_f32()?;
        let hyper = inputs[5].as_f32()?;
        ensure!(hyper.len() == 8, "hyper vector must be f32[8]");
        let n = actions.len();
        ensure!(hk.shape == vec![n, d], "hk must be [N, d_model]");
        ensure!(logits_phi.shape == vec![n, v], "logits_phi must be [N, vocab]");
        let (lam_pg, lam_kl, w_ce, w_ent, w_rl, baseline, lr, t) = (
            hyper[0], hyper[1], hyper[2], hyper[3], hyper[4], hyper[5],
            hyper[6], hyper[7],
        );

        let (a_t, b_t) = self.lora()?;
        let mut a = a_t.as_f32()?.to_vec();
        let mut b = b_t.as_f32()?.to_vec();

        let mut n_acc = 0.0f32;
        let mut n_all = 0.0f32;
        for i in 0..n {
            n_acc += mask[i] * rewards[i];
            n_all += mask[i];
        }
        let n_acc = n_acc.max(1.0);
        let n_all = n_all.max(1.0);

        let mut ga = vec![0.0f32; v * r];
        let mut gb = vec![0.0f32; r * d];
        let (mut s_pg, mut s_kl, mut s_ent, mut s_rl, mut s_acc) =
            (0.0f32, 0.0f32, 0.0f32, 0.0f32, 0.0f32);

        for i in 0..n {
            let h = hk.row_f32(i)?;
            let hn = model::rmsnorm(h, &m.final_norm, m.eps);
            let u: Vec<f32> = (0..r)
                .map(|rr| dot(&b[rr * d..(rr + 1) * d], &hn))
                .collect();
            let z: Vec<f32> = (0..v)
                .map(|vi| {
                    dot(&m.lm_head[vi * d..(vi + 1) * d], &hn)
                        + gamma * dot(&a[vi * r..(vi + 1) * r], &u)
                })
                .collect();
            let lse = logsumexp(&z);
            let logp: Vec<f32> = z.iter().map(|zi| zi - lse).collect();
            let p: Vec<f32> = logp.iter().map(|lp| lp.exp()).collect();
            let phi = logits_phi.row_f32(i)?;
            let lse_q = logsumexp(phi);
            let logq: Vec<f32> = phi.iter().map(|qi| qi - lse_q).collect();

            let act = actions[i] as usize;
            ensure!(act < v, "action {act} >= vocab {v}");
            let ce = -logp[act];
            let mut kl = 0.0f32;
            let mut ent = 0.0f32;
            for vi in 0..v {
                kl += p[vi] * (logp[vi] - logq[vi]);
                ent -= p[vi] * logp[vi];
            }
            let acc = mask[i] * rewards[i];
            let adv = rewards[i] - baseline;
            s_pg += acc * ce;
            s_kl += mask[i] * kl;
            s_ent += mask[i] * ent;
            s_rl += -mask[i] * adv * logp[act];
            s_acc += acc;

            // dL/dz for this example (see train.py's dvi_loss):
            //   (lam_pg + w_ce) * acc/n_acc        * (p - onehot)
            //   + lam_kl * mask/n_all              * p .* (s - KL),  s = logp - logq
            //   + w_ent * mask/n_all               * p .* (logp + H)
            //   + w_rl  * mask/n_all * adv         * (p - onehot)
            let c_ce = (lam_pg + w_ce) * acc / n_acc;
            let c_kl = lam_kl * mask[i] / n_all;
            let c_ent = w_ent * mask[i] / n_all;
            let c_rl = w_rl * mask[i] * adv / n_all;
            let mut gz = vec![0.0f32; v];
            for vi in 0..v {
                let one = if vi == act { 1.0 } else { 0.0 };
                gz[vi] = (c_ce + c_rl) * (p[vi] - one)
                    + c_kl * p[vi] * ((logp[vi] - logq[vi]) - kl)
                    + c_ent * p[vi] * (logp[vi] + ent);
            }
            // z = W·hn + γ A (B·hn):
            //   dz/dA[vi][rr] = γ gz[vi] u[rr]
            //   dz/dB[rr][dd] = γ (Aᵀ gz)[rr] hn[dd]
            for vi in 0..v {
                if gz[vi] == 0.0 {
                    continue;
                }
                let garow = &mut ga[vi * r..(vi + 1) * r];
                for rr in 0..r {
                    garow[rr] += gamma * gz[vi] * u[rr];
                }
            }
            let mut at_gz = vec![0.0f32; r];
            for vi in 0..v {
                let arow = &a[vi * r..(vi + 1) * r];
                for rr in 0..r {
                    at_gz[rr] += arow[rr] * gz[vi];
                }
            }
            for rr in 0..r {
                let coeff = gamma * at_gz[rr];
                if coeff == 0.0 {
                    continue;
                }
                let gbrow = &mut gb[rr * d..(rr + 1) * d];
                for dd in 0..d {
                    gbrow[dd] += coeff * hn[dd];
                }
            }
        }

        let l_pg = s_pg / n_acc;
        let l_kl = s_kl / n_all;
        let l_ce = l_pg;
        let l_ent = s_ent / n_all;
        let l_rl = s_rl / n_all;
        let total = lam_pg * l_pg + lam_kl * l_kl + w_ce * l_ce
            - w_ent * l_ent + w_rl * l_rl;
        let batch_accept = s_acc / n_all;

        let gnorm = (dot(&ga, &ga) + dot(&gb, &gb)).sqrt();

        // Bias-corrected Adam on A and B (t >= 1 per the hyper contract).
        let (b1, b2, eps) = (self.cfg.adam_b1, self.cfg.adam_b2, self.cfg.adam_eps);
        let bc1 = 1.0 - b1.powf(t);
        let bc2 = 1.0 - b2.powf(t);
        let mut m_a = self.global("adam.mA")?.as_f32()?.to_vec();
        let mut v_a = self.global("adam.vA")?.as_f32()?.to_vec();
        let mut m_b = self.global("adam.mB")?.as_f32()?.to_vec();
        let mut v_b = self.global("adam.vB")?.as_f32()?.to_vec();
        let adam = |p: &mut [f32], g: &[f32], mm: &mut [f32], vv: &mut [f32]| {
            for i in 0..p.len() {
                mm[i] = b1 * mm[i] + (1.0 - b1) * g[i];
                vv[i] = b2 * vv[i] + (1.0 - b2) * g[i] * g[i];
                let mhat = mm[i] / bc1;
                let vhat = vv[i] / bc2;
                p[i] -= lr * mhat / (vhat.sqrt() + eps);
            }
        };
        adam(&mut a, &ga, &mut m_a, &mut v_a);
        adam(&mut b, &gb, &mut m_b, &mut v_b);

        {
            let mut g = self.globals.write().unwrap();
            g.insert("lora.A".to_string(), Tensor::f32(vec![v, r], a));
            g.insert("lora.B".to_string(), Tensor::f32(vec![r, d], b));
            g.insert("adam.mA".to_string(), Tensor::f32(vec![v, r], m_a));
            g.insert("adam.vA".to_string(), Tensor::f32(vec![v, r], v_a));
            g.insert("adam.mB".to_string(), Tensor::f32(vec![r, d], m_b));
            g.insert("adam.vB".to_string(), Tensor::f32(vec![r, d], v_b));
        }

        let metrics = vec![total, l_pg, l_kl, l_ce, l_ent, l_rl, batch_accept, gnorm];
        Ok(CallOut {
            outputs: vec![Tensor::f32(vec![8], metrics)],
            kv: Vec::new(),
        })
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn weights_fingerprint(&self) -> Option<u64> {
        Some(self.fingerprint)
    }

    fn call(&self, spec: &ArtifactSpec, kv: &[Buffer], inputs: &[Tensor])
        -> Result<CallOut>
    {
        match spec.name.as_str() {
            "prefill_shallow" => self.prefill_shallow(spec, kv, inputs),
            "prefill_deep" => self.prefill_deep(spec, kv, inputs),
            "draft_step" => self.draft_step(spec, kv, inputs),
            "draft_block" => self.draft_block(spec, kv, inputs),
            "verify_block" => self.verify_block(spec, kv, inputs),
            "prefill_full" => self.full_prefill(&self.target, spec, kv, inputs),
            "target_step" => self.full_step(&self.target, spec, kv, inputs),
            "target_verify_block" => self.target_verify_block(spec, kv, inputs),
            "sps_prefill" => self.full_prefill(&self.drafter, spec, kv, inputs),
            "sps_draft_step" => self.full_step(&self.drafter, spec, kv, inputs),
            "medusa_heads" => self.medusa_heads(inputs),
            "hydra_chain" => self.hydra_chain(inputs),
            "eagle_step" => self.eagle_step(inputs),
            "train_step" => self.train_step(inputs),
            other => bail!("reference backend: unknown artifact '{other}'"),
        }
    }

    /// Lane-blocked batched execution (see `batched.rs`): the hot
    /// per-sequence artifacts run with the layer loop outermost and the
    /// lane loop innermost, everything else falls back to a serial
    /// per-lane loop. Per-lane results are bitwise identical to `call`.
    fn call_batched(
        &self,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Result<Vec<CallOut>> {
        if batch.len() <= 1 {
            return batch
                .iter()
                .map(|item| self.call(spec, item.kv, item.inputs))
                .collect();
        }
        match spec.name.as_str() {
            "prefill_shallow" => self.prefill_shallow_batched(spec, batch),
            "prefill_deep" => self.prefill_deep_batched(spec, batch),
            "draft_step" => self.draft_step_batched(spec, batch),
            "draft_block" => self.draft_block_batched(spec, batch),
            "verify_block" => self.verify_block_batched(spec, batch),
            "prefill_full" => {
                self.full_prefill_batched(&self.target, spec, batch)
            }
            "target_step" => self.full_step_batched(&self.target, spec, batch),
            "sps_prefill" => {
                self.full_prefill_batched(&self.drafter, spec, batch)
            }
            "sps_draft_step" => {
                self.full_step_batched(&self.drafter, spec, batch)
            }
            _ => batch
                .iter()
                .map(|item| self.call(spec, item.kv, item.inputs))
                .collect(),
        }
    }

    fn fresh_kv(&self, spec: &ArtifactSpec) -> Result<Vec<Buffer>> {
        Ok(spec
            .params_with_role(Role::Kv)
            .map(|port| Buffer::host(Tensor::zeros_f32(port.shape.clone())))
            .collect())
    }

    fn upload(&self, t: &Tensor) -> Result<Buffer> {
        Ok(Buffer::host(t.clone()))
    }

    fn to_host(&self, b: &Buffer, dtype: DType, shape: &[usize]) -> Result<Tensor> {
        let t = b.as_host()?;
        ensure!(
            t.dtype() == dtype && t.shape == shape,
            "to_host: buffer is {:?}{:?}, wanted {:?}{:?}",
            t.dtype(), t.shape, dtype, shape
        );
        Ok(t.clone())
    }

    fn set_global(&self, name: &str, t: &Tensor) -> Result<()> {
        self.globals
            .write()
            .unwrap()
            .insert(name.to_string(), t.clone());
        Ok(())
    }

    fn read_global(&self, name: &str) -> Result<Tensor> {
        self.global(name)
    }

    fn reset_global(&self, name: &str) -> Result<()> {
        let init = self
            .init_globals
            .get(name)
            .with_context(|| format!("no initial value for global '{name}'"))?
            .clone();
        self.globals.write().unwrap().insert(name.to_string(), init);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> ReferenceBackend {
        ReferenceBackend::new(ReferenceConfig::default()).unwrap()
    }

    fn train_inputs(be: &ReferenceBackend, reward: f32) -> Vec<Tensor> {
        let cfg = &be.cfg;
        let (n, d, v) = (cfg.batch_size, cfg.d_model, cfg.vocab_size);
        let mut rng = Rng::new(9);
        let hk: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let actions: Vec<i32> =
            (0..n).map(|_| rng.usize_below(v) as i32).collect();
        let phi: Vec<f32> =
            (0..n * v).map(|_| rng.normal() as f32 * 2.0).collect();
        vec![
            Tensor::f32(vec![n, d], hk),
            Tensor::i32(vec![n], actions),
            Tensor::f32(vec![n, v], phi),
            Tensor::f32(vec![n], vec![reward; n]),
            Tensor::f32(vec![n], vec![1.0; n]),
            // hyper: KL-only with lr 3e-3, step 1
            Tensor::f32(vec![8], vec![0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 3e-3, 1.0]),
        ]
    }

    #[test]
    fn train_step_updates_lora_and_reset_restores() {
        let be = backend();
        let spec = synth::manifest(&be.cfg).artifact("train_step").unwrap().clone();
        let before_a = be.read_global("lora.A").unwrap();
        let before_b = be.read_global("lora.B").unwrap();
        let out = be.call(&spec, &[], &train_inputs(&be, 1.0)).unwrap();
        let m = out.outputs[0].as_f32().unwrap();
        assert!(m.iter().all(|x| x.is_finite()), "metrics {m:?}");
        assert!(m[7] > 0.0, "grad norm must be positive");
        assert!((m[6] - 1.0).abs() < 1e-6, "batch accept with all-1 rewards");
        // B starts at zero, so the KL gradient flows into B first.
        let after_b = be.read_global("lora.B").unwrap();
        assert!(
            after_b.max_abs_diff(&before_b).unwrap() > 0.0,
            "train_step left lora.B unchanged"
        );
        be.reset_global("lora.A").unwrap();
        be.reset_global("lora.B").unwrap();
        assert_eq!(
            be.read_global("lora.A").unwrap().max_abs_diff(&before_a).unwrap(),
            0.0
        );
    }

    #[test]
    fn repeated_kl_steps_reduce_kl() {
        let be = backend();
        let spec = synth::manifest(&be.cfg).artifact("train_step").unwrap().clone();
        let inputs = train_inputs(&be, 1.0);
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for step in 0..60 {
            let mut inp = inputs.clone();
            // keep Adam bias correction honest: step index advances
            inp[5] = Tensor::f32(
                vec![8],
                vec![0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 3e-3, (step + 1) as f32],
            );
            let out = be.call(&spec, &[], &inp).unwrap();
            let kl = out.outputs[0].as_f32().unwrap()[2];
            if step == 0 {
                first = kl;
            }
            last = kl;
        }
        assert!(
            last < first,
            "KL-only training failed to reduce KL: {first} -> {last}"
        );
    }

    #[test]
    fn deterministic_across_instances() {
        let a = backend();
        let b = backend();
        let spec = synth::manifest(&a.cfg).artifact("target_step").unwrap().clone();
        let kv_a = a.fresh_kv(&spec).unwrap();
        let kv_b = b.fresh_kv(&spec).unwrap();
        let inputs = vec![Tensor::scalar_i32(5), Tensor::scalar_i32(0)];
        let oa = a.call(&spec, &kv_a, &inputs).unwrap();
        let ob = b.call(&spec, &kv_b, &inputs).unwrap();
        assert_eq!(oa.outputs[0], ob.outputs[0]);
    }

    #[test]
    fn unknown_artifact_fails_loudly() {
        let be = backend();
        let spec = ArtifactSpec {
            name: "banana".into(),
            file: std::path::PathBuf::from(""),
            params: vec![],
            outputs: vec![],
        };
        assert!(be.call(&spec, &[], &[]).is_err());
    }
}
