//! Host tensor type used at the Rust<->PJRT boundary.

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn from_code(code: u8) -> Result<DType> {
        match code {
            0 => Ok(DType::F32),
            1 => Ok(DType::I32),
            c => bail!("unknown dtype code {c}"),
        }
    }

    pub fn from_name(name: &str) -> Result<DType> {
        match name {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            n => bail!("unknown dtype name {n}"),
        }
    }

    pub fn size_bytes(self) -> usize {
        4
    }

    /// Inverse of [`DType::from_name`].
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host-side dense tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::I32(data) }
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::i32(vec![], vec![v])
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::f32(vec![], vec![v])
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::f32(shape, vec![0.0; n])
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    /// Row `i` of a 2-D f32 tensor.
    pub fn row_f32(&self, i: usize) -> Result<&[f32]> {
        if self.shape.len() != 2 {
            bail!("row_f32 on non-2D tensor (shape {:?})", self.shape);
        }
        let cols = self.shape[1];
        let data = self.as_f32()?;
        Ok(&data[i * cols..(i + 1) * cols])
    }

    /// Max |a - b| for test assertions.
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        let a = self.as_f32()?;
        let b = other.as_f32()?;
        if a.len() != b.len() {
            bail!("length mismatch: {} vs {}", a.len(), b.len());
        }
        Ok(a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        let t = Tensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.row_f32(1).unwrap().len(), 3);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::f32(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn scalars() {
        assert_eq!(Tensor::scalar_i32(7).as_i32().unwrap(), &[7]);
        assert!(Tensor::scalar_f32(1.0).as_i32().is_err());
    }

    #[test]
    fn diff() {
        let a = Tensor::f32(vec![3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::f32(vec![3], vec![1.0, 2.5, 3.0]);
        assert_eq!(a.max_abs_diff(&b).unwrap(), 0.5);
    }
}
