//! The PJRT backend (cargo feature `pjrt`): loads `artifacts/`
//! (manifest + HLO text + weights), compiles executables on the CPU
//! PJRT client, uploads weights once, and executes manifest-driven
//! artifact calls. Python never runs here.
//!
//! Buffer roles (see `python/compile/aot.py`):
//!
//!   weight  -> process-wide immutable buffers (uploaded once at startup)
//!   global  -> named mutable buffers (LoRA adapters / Adam moments);
//!              outputs with the same name atomically replace the slot
//!   kv      -> caller-owned chained buffers (per-sequence KV caches)
//!   in/out  -> per-call host tensors
//!
//! In hermetic builds the `xla` dependency is the in-tree API stub
//! (`rust/vendor/xla-stub`): this module still compiles, and every load
//! attempt reports that the real PJRT fork is absent.
//!
//! Batched execution (`Backend::call_batched`, used by the
//! continuous-batching scheduler) is inherited as the trait's default
//! serial per-lane loop: the exported HLO is batch-size-1, so until a
//! true batched export lands this backend loops lanes — semantically
//! identical, just without the lane-blocked locality win the reference
//! backend gets.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, RwLock};
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::backend::{Backend, Buffer, CallOut};
use super::log;
use super::manifest::{ArtifactSpec, Manifest, Role};
use super::tensor::{DType, Tensor, TensorData};
use super::weights::{self, WeightMap};

pub struct PjrtBackend {
    client: PjRtClient,
    exes: BTreeMap<String, PjRtLoadedExecutable>,
    weights: BTreeMap<String, Arc<PjRtBuffer>>,
    /// Named mutable buffers plus the metadata needed to download them.
    globals: RwLock<BTreeMap<String, (Arc<PjRtBuffer>, DType, Vec<usize>)>>,
    /// Host copies of weights (for buffer re-init, e.g. LoRA reset).
    pub host_weights: WeightMap,
    /// Fingerprint of `host_weights`, hashed once at load — the remote
    /// handshake asks for it on every connection, and re-hashing real
    /// model weights per handshake would cost seconds on the
    /// executor's connection thread.
    fingerprint: u64,
}

impl PjrtBackend {
    /// Load manifest + weights from `dir`, compile the requested
    /// artifacts (all if `names` is None). Compilation is the startup
    /// cost; per-request paths only execute. Returns the manifest and
    /// the specs that were actually compiled.
    pub fn load(dir: &Path, names: Option<&[&str]>)
        -> Result<(Manifest, Vec<ArtifactSpec>, PjrtBackend)>
    {
        let t0 = Instant::now();
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu()?;
        let host_weights = weights::load_weights(&manifest.weights_file)?;

        let chosen: Vec<ArtifactSpec> = match names {
            None => manifest.artifacts.values().cloned().collect(),
            Some(ns) => ns
                .iter()
                .map(|n| manifest.artifact(n).cloned())
                .collect::<Result<Vec<_>>>()?,
        };

        // Upload weight + global tensors referenced by any chosen artifact.
        let mut weight_bufs = BTreeMap::new();
        let mut globals = BTreeMap::new();
        for spec in &chosen {
            for port in &spec.params {
                if !matches!(port.role, Role::Weight | Role::Global) {
                    continue;
                }
                let present = match port.role {
                    Role::Weight => weight_bufs.contains_key(&port.name),
                    _ => globals.contains_key(&port.name),
                };
                if present {
                    continue;
                }
                let t = host_weights.get(&port.name).with_context(|| {
                    format!("weights.bin missing '{}' ({:?})", port.name, port.role)
                })?;
                anyhow::ensure!(
                    t.shape == port.shape,
                    "weights.bin '{}' shape {:?} != manifest {:?}",
                    port.name, t.shape, port.shape
                );
                let buf = Arc::new(upload(&client, t)?);
                match port.role {
                    Role::Weight => {
                        weight_bufs.insert(port.name.clone(), buf);
                    }
                    _ => {
                        globals.insert(
                            port.name.clone(),
                            (buf, port.dtype, port.shape.clone()),
                        );
                    }
                }
            }
        }

        let mut exes = BTreeMap::new();
        for spec in &chosen {
            let tc = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                spec.file.to_str().context("artifact path not utf-8")?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", spec.name))?;
            log::debug(&format!(
                "compiled {} in {:.2}s", spec.name, tc.elapsed().as_secs_f64()
            ));
            exes.insert(spec.name.clone(), exe);
        }
        log::info(&format!(
            "pjrt runtime ready: {} artifacts, {} weight tensors in {:.2}s",
            exes.len(),
            weight_bufs.len(),
            t0.elapsed().as_secs_f64()
        ));
        let fingerprint = weights::fingerprint_weights(&host_weights);
        Ok((
            manifest,
            chosen,
            PjrtBackend {
                client,
                exes,
                weights: weight_bufs,
                globals: RwLock::new(globals),
                host_weights,
                fingerprint,
            },
        ))
    }

    fn global_buf(&self, name: &str) -> Result<Arc<PjRtBuffer>> {
        self.globals
            .read()
            .unwrap()
            .get(name)
            .map(|(b, _, _)| b.clone())
            .with_context(|| format!("global buffer '{name}' missing"))
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn weights_fingerprint(&self) -> Option<u64> {
        // The host copies are what got uploaded, so the load-time hash
        // speaks for the device state (globals included — weights.bin
        // carries their initial values too).
        Some(self.fingerprint)
    }

    /// Assemble the PJRT argument list in manifest (= HLO parameter)
    /// order, execute, and distribute the (untupled — see the
    /// third_party/xla fork) result buffers back by output role.
    fn call(&self, spec: &ArtifactSpec, kv: &[Buffer], inputs: &[Tensor])
        -> Result<CallOut>
    {
        let exe = self
            .exes
            .get(&spec.name)
            .with_context(|| format!("artifact '{}' not compiled", spec.name))?;

        let mut owned: Vec<Arc<PjRtBuffer>> = Vec::with_capacity(spec.params.len());
        let mut kv_it = kv.iter();
        let mut in_it = inputs.iter();
        for port in &spec.params {
            let buf = match port.role {
                Role::Weight => self
                    .weights
                    .get(&port.name)
                    .cloned()
                    .with_context(|| {
                        format!("{}: weight '{}' not uploaded",
                                spec.name, port.name)
                    })?,
                Role::Global => self.global_buf(&port.name)?,
                Role::Kv => kv_it
                    .next()
                    .context("kv buffer count mismatch")?
                    .as_pjrt()?
                    .clone(),
                Role::In => {
                    let t = in_it.next().context("input count mismatch")?;
                    Arc::new(upload(&self.client, t)?)
                }
                Role::Out => bail!("{}: role=out in params", spec.name),
            };
            owned.push(buf);
        }
        let args: Vec<&PjRtBuffer> = owned.iter().map(|a| a.as_ref()).collect();

        let mut results = exe.execute_b(&args)?;
        if results.len() != 1 {
            bail!("{}: expected 1 replica, got {}", spec.name, results.len());
        }
        let bufs = results.pop().unwrap();
        if bufs.len() != spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {} (untuple_result fork missing?)",
                spec.name, spec.outputs.len(), bufs.len()
            );
        }

        let mut outputs = Vec::new();
        let mut kv_out = Vec::new();
        for (port, buf) in spec.outputs.iter().zip(bufs) {
            match port.role {
                Role::Out => outputs.push(download(&buf, port.dtype, &port.shape)?),
                Role::Kv => kv_out.push(Buffer::Pjrt(Arc::new(buf))),
                Role::Global => {
                    self.globals.write().unwrap().insert(
                        port.name.clone(),
                        (Arc::new(buf), port.dtype, port.shape.clone()),
                    );
                }
                _ => bail!("{}: bad output role", spec.name),
            }
        }
        Ok(CallOut { outputs, kv: kv_out })
    }

    /// Fresh per-sequence KV buffers (zeros). Slot garbage is fine
    /// semantically (masked), but zeros make runs reproducible.
    fn fresh_kv(&self, spec: &ArtifactSpec) -> Result<Vec<Buffer>> {
        let mut out = Vec::new();
        for port in spec.params_with_role(Role::Kv) {
            let t = Tensor::zeros_f32(port.shape.clone());
            out.push(Buffer::Pjrt(Arc::new(upload(&self.client, &t)?)));
        }
        Ok(out)
    }

    fn upload(&self, t: &Tensor) -> Result<Buffer> {
        Ok(Buffer::Pjrt(Arc::new(upload(&self.client, t)?)))
    }

    fn to_host(&self, b: &Buffer, dtype: DType, shape: &[usize]) -> Result<Tensor> {
        download(b.as_pjrt()?, dtype, shape)
    }

    fn set_global(&self, name: &str, t: &Tensor) -> Result<()> {
        let buf = Arc::new(upload(&self.client, t)?);
        self.globals.write().unwrap().insert(
            name.to_string(),
            (buf, t.dtype(), t.shape.clone()),
        );
        Ok(())
    }

    fn read_global(&self, name: &str) -> Result<Tensor> {
        let (buf, dtype, shape) = self
            .globals
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .with_context(|| format!("global buffer '{name}' missing"))?;
        download(&buf, dtype, &shape)
    }

    fn reset_global(&self, name: &str) -> Result<()> {
        let t = self
            .host_weights
            .get(name)
            .with_context(|| format!("no initial value for global '{name}'"))?
            .clone();
        self.set_global(name, &t)
    }
}

pub fn upload(client: &PjRtClient, t: &Tensor) -> Result<PjRtBuffer> {
    let buf = match &t.data {
        TensorData::F32(v) => client.buffer_from_host_buffer(v, &t.shape, None)?,
        TensorData::I32(v) => client.buffer_from_host_buffer(v, &t.shape, None)?,
    };
    Ok(buf)
}

pub fn download(buf: &PjRtBuffer, dtype: DType, shape: &[usize]) -> Result<Tensor> {
    let lit = buf.to_literal_sync()?;
    let t = match dtype {
        DType::F32 => Tensor::f32(shape.to_vec(), lit.to_vec::<f32>()?),
        DType::I32 => Tensor::i32(shape.to_vec(), lit.to_vec::<i32>()?),
    };
    Ok(t)
}
