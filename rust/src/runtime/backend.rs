//! The execution seam: everything an engine, the learner, or the router
//! needs from "the thing that runs artifacts" — buffer upload/download,
//! per-sequence KV state, named mutable globals, and artifact execution.
//!
//! Two implementations exist:
//!
//!   * [`crate::runtime::reference::ReferenceBackend`] — a deterministic,
//!     pure-Rust split-transformer interpreter driven by a generated
//!     in-memory manifest + seeded synthetic weights. Always available;
//!     the hermetic test suite runs against it unconditionally.
//!   * `crate::runtime::pjrt::PjrtBackend` (cargo feature `pjrt`) — the
//!     AOT-compiled HLO path through the PJRT CPU client.
//!
//! Engines never see backend-specific buffer types: opaque [`Buffer`]
//! handles flow through [`CallOut`] exactly like the chained PJRT
//! buffers did, so per-sequence KV ownership semantics are unchanged.

use std::sync::Arc;

use anyhow::Result;

use super::manifest::ArtifactSpec;
use super::tensor::{DType, Tensor};

/// Opaque device-buffer handle. Cheap to clone (Arc either way).
#[derive(Clone)]
pub enum Buffer {
    /// Host-resident tensor (reference backend).
    Host(Arc<Tensor>),
    /// Handle to a buffer resident in a remote executor's table
    /// ([`crate::runtime::remote::RemoteBackend`]). Dropping the last
    /// clone queues the id for server-side release.
    Remote(Arc<crate::runtime::remote::RemoteHandle>),
    /// PJRT device buffer.
    #[cfg(feature = "pjrt")]
    Pjrt(Arc<xla::PjRtBuffer>),
}

impl Buffer {
    pub fn host(t: Tensor) -> Buffer {
        Buffer::Host(Arc::new(t))
    }

    /// The host tensor behind this handle; errors on a device buffer.
    pub fn as_host(&self) -> Result<&Tensor> {
        match self {
            Buffer::Host(t) => Ok(t),
            Buffer::Remote(h) => Err(anyhow::anyhow!(
                "buffer {h:?} is remote-resident, not host"
            )),
            #[cfg(feature = "pjrt")]
            Buffer::Pjrt(_) => {
                Err(anyhow::anyhow!("buffer is device-resident, not host"))
            }
        }
    }

    #[cfg(feature = "pjrt")]
    pub fn as_pjrt(&self) -> Result<&Arc<xla::PjRtBuffer>> {
        match self {
            Buffer::Pjrt(b) => Ok(b),
            _ => Err(anyhow::anyhow!("buffer is not PJRT-resident")),
        }
    }
}

impl std::fmt::Debug for Buffer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Buffer::Host(t) => write!(f, "Buffer::Host{:?}", t.shape),
            Buffer::Remote(h) => write!(f, "Buffer::Remote({h:?})"),
            #[cfg(feature = "pjrt")]
            Buffer::Pjrt(_) => write!(f, "Buffer::Pjrt"),
        }
    }
}

/// Result of one artifact call.
pub struct CallOut {
    /// Host outputs (role=out), in manifest order.
    pub outputs: Vec<Tensor>,
    /// New per-sequence state buffers (role=kv), in manifest order.
    pub kv: Vec<Buffer>,
}

/// One lane of a batched artifact call: an independent sequence's KV set
/// plus its per-call host inputs. Lanes never share state — batching is
/// purely an execution-efficiency contract.
pub struct BatchItem<'a> {
    pub kv: &'a [Buffer],
    pub inputs: &'a [Tensor],
}

/// Executor-side serving counters, transport-neutral: in-process code
/// reads them straight off an executor's state, and the remote wire
/// protocol ships them in its `Metrics` reply. All counters are
/// lifetime totals except `buffers`/`sessions`/`inflight`, which are
/// live gauges.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ExecMetrics {
    /// `Call` requests served (batched and single-lane alike).
    pub calls: u64,
    /// Lanes carried by those calls; `lanes / calls` is the executor's
    /// observed batch occupancy.
    pub lanes: u64,
    /// Live buffer-table entries (server-resident KV + staged uploads).
    pub buffers: u64,
    /// Sessions with at least one live connection.
    pub sessions: u64,
    /// Calls currently in flight on this client's connection (submitted
    /// to the pipelined mux, reply not yet matched). Client-side gauge:
    /// the remote backend fills it after the `Metrics` reply decodes; 0
    /// for in-process backends.
    pub inflight: u64,
    /// High-water of `inflight` over the current connection's lifetime
    /// — the realized window depth. > 1 proves calls actually
    /// overlapped on one connection (resets on reconnect).
    pub max_inflight: u64,
}

impl ExecMetrics {
    /// Mean lanes per served call (0 before the first call).
    pub fn occupancy(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            self.lanes as f64 / self.calls as f64
        }
    }
}

/// One remote executor's health, as seen by the client: its shard
/// index, endpoint, and its [`ExecMetrics`] (`None` when the executor
/// is unreachable).
#[derive(Debug, Clone)]
pub struct ExecutorStatus {
    pub shard: u32,
    pub endpoint: String,
    pub metrics: Option<ExecMetrics>,
}

/// Completion handle for a batched call submitted without waiting
/// ([`Backend::call_batched_submit`]). Waiting consumes the handle and
/// yields per-lane results in lane order — the same shape
/// [`Backend::call_batched_partial`] returns. Handles own everything
/// they need (no borrows), so a caller can submit many chunks — across
/// shards and, on a pipelined connection, within one shard's in-flight
/// window — before draining any of them.
pub trait BatchHandle: Send {
    /// Block until every lane resolves.
    fn wait(self: Box<Self>) -> Vec<Result<CallOut>>;
}

/// [`BatchHandle`] for backends that execute synchronously at submit
/// time: the results are already in hand, `wait` just returns them.
pub struct ReadyBatch(pub Vec<Result<CallOut>>);

impl BatchHandle for ReadyBatch {
    fn wait(self: Box<Self>) -> Vec<Result<CallOut>> {
        self.0
    }
}

/// Backend abstraction over artifact execution and buffer management.
///
/// `call` receives the artifact's manifest spec (already shape-checked
/// by [`crate::runtime::Artifact::call`]) plus the caller-owned KV
/// buffers and per-call host inputs; it returns host outputs, new KV
/// buffers, and applies any `global`-role output updates internally.
pub trait Backend: Send + Sync {
    fn name(&self) -> &'static str;

    /// Execute one artifact.
    fn call(&self, spec: &ArtifactSpec, kv: &[Buffer], inputs: &[Tensor])
        -> Result<CallOut>;

    /// Execute one artifact over many independent sequences in a single
    /// backend call. Lane i's result must be bitwise identical to what a
    /// standalone `call(spec, batch[i].kv, batch[i].inputs)` would
    /// return — batching is an execution strategy, never a semantic
    /// change. The default implementation is a serial per-lane loop
    /// (what the PJRT backend uses until a true batched export lands);
    /// the reference backend overrides it with lane-blocked kernels.
    fn call_batched(
        &self,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Result<Vec<CallOut>> {
        batch
            .iter()
            .map(|item| self.call(spec, item.kv, item.inputs))
            .collect()
    }

    /// Batched execution with **per-lane** failure granularity: lane i's
    /// entry is `Err` only if lane i could not be executed. The default
    /// maps a whole-call failure onto every lane (one executor, one
    /// fate); backends that fan lanes out across independent executors
    /// (the sharded remote client) override it so one dead executor
    /// fails only the lanes it owned. Successful lanes keep the bitwise
    /// contract of [`Backend::call_batched`].
    fn call_batched_partial(
        &self,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Vec<Result<CallOut>> {
        match self.call_batched(spec, batch) {
            Ok(outs) => outs.into_iter().map(Ok).collect(),
            Err(e) => {
                let msg = format!("{e:#}");
                batch
                    .iter()
                    .map(|_| Err(anyhow::anyhow!("{msg}")))
                    .collect()
            }
        }
    }

    /// Submit a batched call **without waiting** for its results: the
    /// returned handle resolves to exactly what
    /// [`Backend::call_batched_partial`] would have returned for the
    /// same batch. Encoding/dispatch happens before this returns (the
    /// borrowed batch is released), so a caller can submit several
    /// independent chunks back-to-back and then drain the handles —
    /// on the pipelined remote backends the chunks genuinely overlap
    /// (across shards, and within one shard's in-flight window). The
    /// default executes synchronously at submit time, so in-process
    /// backends keep their exact semantics.
    fn call_batched_submit(
        &self,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Box<dyn BatchHandle> {
        Box::new(ReadyBatch(self.call_batched_partial(spec, batch)))
    }

    /// Fresh zeroed per-sequence KV buffers for an artifact's kv params.
    fn fresh_kv(&self, spec: &ArtifactSpec) -> Result<Vec<Buffer>>;

    /// [`Backend::fresh_kv`] with a caller-supplied **placement key**:
    /// allocations sharing a key land on the same executor, so a
    /// sequence's shallow and deep KV sets stay co-resident and its
    /// server-side state never straddles shards. Single-executor
    /// backends ignore the key.
    fn fresh_kv_keyed(&self, spec: &ArtifactSpec, key: u64) -> Result<Vec<Buffer>> {
        let _ = key;
        self.fresh_kv(spec)
    }

    /// Copy-on-write fork of a set of KV buffers (a prefix-cache
    /// segment being attached to a new sequence). The returned buffers
    /// are **independently owned** — releasing the parent or the fork
    /// never invalidates the other — but share storage until one side
    /// is replaced by a later call's output. Because every backend in
    /// this repo treats KV buffers as immutable (each call returns new
    /// buffers instead of mutating its inputs), the fork point needs no
    /// tensor copy: the default clones the handles (`Buffer` is an Arc
    /// either way), and the remote backends mint fresh server-side ids
    /// aliasing the same storage so per-sequence frees stay exact.
    fn fork_kv(&self, spec: &ArtifactSpec, parents: &[Buffer]) -> Result<Vec<Buffer>> {
        let _ = spec;
        Ok(parents.to_vec())
    }

    /// Placement hint for a sequence with **no** cached prefix: the
    /// shard index the backend would prefer new KV to land on (used as
    /// the placement key for [`Backend::fresh_kv_keyed`]). `None` means
    /// the backend has no placement opinion (in-process backends, or a
    /// fleet whose load cannot be observed) — callers fall back to
    /// their own key scheme (sequential round-robin).
    fn kv_placement_hint(&self) -> Option<u64> {
        None
    }

    /// Upload a host tensor (used by tests to stage KV/global inputs).
    fn upload(&self, t: &Tensor) -> Result<Buffer>;

    /// Download a buffer back to the host.
    fn to_host(&self, b: &Buffer, dtype: DType, shape: &[usize]) -> Result<Tensor>;

    /// Replace a named mutable global buffer (LoRA adapters, Adam moments).
    fn set_global(&self, name: &str, t: &Tensor) -> Result<()>;

    /// Read back a named global buffer.
    fn read_global(&self, name: &str) -> Result<Tensor>;

    /// Reset a global buffer to its initial (weights-file) value.
    fn reset_global(&self, name: &str) -> Result<()>;

    /// Health of the remote executor(s) behind this backend, one entry
    /// per executor. Empty for in-process backends.
    fn executor_status(&self) -> Vec<ExecutorStatus> {
        Vec::new()
    }

    /// Drain observability state (trace events + metrics snapshot) from
    /// the remote executor(s) behind this backend: one clock-aligned
    /// dump per shard. Empty for in-process backends — their events are
    /// already in the local tracer ring. Destructive: each executor
    /// event is returned exactly once across successive pulls.
    fn obs_pull(&self) -> Result<Vec<crate::runtime::remote::ShardObs>> {
        Ok(Vec::new())
    }

    /// Fingerprint of the weights (and initial globals) this backend
    /// serves, used by the remote handshake so a sharded client can
    /// reject a fleet whose executors front divergent weights at
    /// connect time. `None` when the backend cannot hash its weights
    /// (shipped on the wire as 0 = unknown, which skips the check).
    fn weights_fingerprint(&self) -> Option<u64> {
        None
    }
}
