//! Pipelined multiplexed RPC runtime (protocol v3) — the client half of
//! the call-id seam.
//!
//! One [`MuxConn`] owns one handshaken connection and runs a persistent
//! **writer/reader worker pair** for it (replacing the per-chunk
//! `std::thread::scope` churn the sharded client used to pay on the
//! serving hot path):
//!
//! * [`MuxConn::submit`] encodes a request, tags it with a fresh call
//!   id, and hands it to the writer worker — returning a [`CallHandle`]
//!   immediately. Up to `window` calls may be in flight at once;
//!   submission blocks (briefly — the window drains as replies land)
//!   when the window is full, which is the backpressure that bounds
//!   per-connection client state and executor queue depth.
//! * the **writer** worker drains the submission queue onto the
//!   transport's send half. A failed send resolves *exactly the call it
//!   was carrying* and then kills the connection (every other in-flight
//!   call fails as "in flight when the transport died" — at-most-once,
//!   nothing is ever replayed). The dead send half is deliberately
//!   **parked**, not dropped: the server must not observe this
//!   connection closing until a replacement has handshaken, or it would
//!   reap the session (and its KV) mid-reconnect.
//! * the **reader** worker blocks in `recv`, untags each reply, and
//!   resolves the matching entry of the **pending-call table** — by
//!   call id, so replies may arrive in any order. A reply for an id
//!   that is no longer pending (a call failed by chaos whose reply
//!   straggled in) is dropped on the floor. A recv failure kills the
//!   connection and fails everything still pending.
//!
//! Failure is scoped by design: `Reply::Err` resolves only its own
//! call (semantic errors don't tear the connection down), a send fault
//! fails only the call being sent plus whatever was genuinely in
//! flight, and the next submission after a kill gets an immediate error
//! so the owning backend can lazily re-dial. The connection-level
//! `inflight` / `max_inflight` gauges feed
//! [`crate::runtime::backend::ExecMetrics`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, bail, ensure, Result};

use super::proto::{self, Msg, Reply};
use super::transport::{FrameRx, FrameTx};

/// Default in-flight window per connection. Deep enough that a
/// scheduler tick's chunks overlap on one executor, small enough that a
/// slow shard backpressures the client instead of buffering a tick's
/// worth of tensors. Override with `DVI_MUX_WINDOW` (>= 1; 1 restores
/// the strict request/response discipline of protocol v2).
pub const DEFAULT_WINDOW: usize = 8;

/// The configured window: `DVI_MUX_WINDOW` or [`DEFAULT_WINDOW`].
/// A set-but-invalid value (unparseable, or 0 — there is no "off"; use
/// 1 for the serial discipline) is an error, not a silent fallback:
/// a misconfigured fleet should fail at connect time, matching the
/// explicit-window API's validation.
pub fn env_window() -> Result<usize> {
    match std::env::var("DVI_MUX_WINDOW") {
        Ok(s) if !s.is_empty() => {
            let w: usize = s
                .parse()
                .map_err(|_| anyhow!("bad DVI_MUX_WINDOW='{s}' (want an integer >= 1)"))?;
            ensure!(
                w >= 1,
                "DVI_MUX_WINDOW must be >= 1 (got 0); use 1 for the \
                 strict request/response discipline"
            );
            Ok(w)
        }
        _ => Ok(DEFAULT_WINDOW),
    }
}

/// One call's completion cell: filled exactly once (by the reader, the
/// writer's send-failure path, or the kill path), consumed by
/// [`CallHandle::wait`].
struct CallCell {
    slot: Mutex<Option<Result<Reply>>>,
    cv: Condvar,
}

impl CallCell {
    fn new() -> CallCell {
        CallCell { slot: Mutex::new(None), cv: Condvar::new() }
    }

    fn fill(&self, r: Result<Reply>) {
        let mut g = self.slot.lock().unwrap();
        // First resolution wins; late stragglers are dropped.
        if g.is_none() {
            *g = Some(r);
            self.cv.notify_all();
        }
    }
}

/// Completion handle for one submitted call. `wait` blocks until the
/// reader matches the reply (or the call fails) and yields the **raw**
/// reply — mapping `Reply::Err` to an error is the owning backend's
/// job, because only it knows the call's semantics (e.g. requeueing the
/// free-list a failed `Call` was carrying).
pub struct CallHandle {
    cell: Arc<CallCell>,
    id: u64,
}

impl CallHandle {
    /// The wire call id this handle is waiting on (trace correlation).
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn wait(self) -> Result<Reply> {
        let mut g = self.cell.slot.lock().unwrap();
        loop {
            if let Some(r) = g.take() {
                return r;
            }
            g = self.cell.cv.wait(g).unwrap();
        }
    }
}

/// Pending-call table + window accounting, shared by submitters and the
/// two workers. One mutex covers both: resolving a call frees a window
/// slot, so they change together.
struct MuxState {
    pending: HashMap<u64, Arc<CallCell>>,
    /// In-flight calls (window slots in use).
    used: usize,
    /// Why the connection died; `Some` refuses new submissions.
    dead: Option<String>,
}

struct MuxShared {
    state: Mutex<MuxState>,
    /// Signals window-full submitters (slot freed or connection died).
    cv: Condvar,
    /// High-water of `used` over this connection's lifetime.
    max_inflight: AtomicU64,
}

impl MuxShared {
    /// Resolve one pending call, freeing its window slot. Unknown ids
    /// (already failed; straggler reply) are ignored.
    fn resolve(&self, id: u64, r: Result<Reply>) {
        let cell = {
            let mut st = self.state.lock().unwrap();
            match st.pending.remove(&id) {
                Some(cell) => {
                    st.used -= 1;
                    self.cv.notify_all();
                    cell
                }
                None => return,
            }
        };
        cell.fill(r);
    }

    /// Kill the connection: refuse new submissions and fail every call
    /// still in flight (at-most-once — they are never replayed).
    fn kill(&self, reason: &str) {
        let cells: Vec<(u64, Arc<CallCell>)> = {
            let mut st = self.state.lock().unwrap();
            if st.dead.is_some() {
                return; // first death wins
            }
            st.dead = Some(reason.to_string());
            st.used = 0;
            self.cv.notify_all();
            st.pending.drain().collect()
        };
        for (id, cell) in cells {
            cell.fill(Err(anyhow!(
                "transport failure (connection dropped with call #{id} in \
                 flight): {reason}"
            )));
        }
    }

    fn is_dead(&self) -> bool {
        self.state.lock().unwrap().dead.is_some()
    }

    fn dead_reason(&self) -> Option<String> {
        self.state.lock().unwrap().dead.clone()
    }
}

/// A frame queued for the writer worker.
struct Outbound {
    id: u64,
    frame: Vec<u8>,
}

/// One pipelined connection: submission queue, pending-call table,
/// bounded window, and the persistent writer/reader worker pair.
/// Dropping the last handle closes the submission queue, which lets the
/// writer exit and release the transport — only then does the server
/// observe the connection close (session-lifetime ordering).
pub struct MuxConn {
    /// Submission queue into the writer worker. Behind a mutex so the
    /// connection is `Sync` on every toolchain (`mpsc::Sender` only
    /// became `Sync` recently); contention is submitter-vs-submitter
    /// and the critical section is one channel push.
    tx: Mutex<Sender<Outbound>>,
    shared: Arc<MuxShared>,
    next_id: AtomicU64,
    window: usize,
}

impl MuxConn {
    /// Spin up the worker pair over an already-handshaken connection's
    /// split halves. `window` >= 1 bounds the in-flight calls.
    pub fn start(
        tx_half: Box<dyn FrameTx>,
        rx_half: Box<dyn FrameRx>,
        window: usize,
    ) -> MuxConn {
        assert!(window >= 1, "mux window must be >= 1");
        let shared = Arc::new(MuxShared {
            state: Mutex::new(MuxState {
                pending: HashMap::new(),
                used: 0,
                dead: None,
            }),
            cv: Condvar::new(),
            max_inflight: AtomicU64::new(0),
        });
        let (tx, out_rx) = channel::<Outbound>();
        let w_shared = shared.clone();
        std::thread::Builder::new()
            .name("dvi-mux-writer".into())
            .spawn(move || writer_loop(tx_half, out_rx, w_shared))
            .expect("spawning mux writer worker");
        let r_shared = shared.clone();
        std::thread::Builder::new()
            .name("dvi-mux-reader".into())
            .spawn(move || reader_loop(rx_half, r_shared))
            .expect("spawning mux reader worker");
        MuxConn { tx: Mutex::new(tx), shared, next_id: AtomicU64::new(1), window }
    }

    /// Submit one request; returns its completion handle. Blocks while
    /// the in-flight window is full; errors immediately once the
    /// connection is dead (the owner should re-dial).
    pub fn submit(&self, msg: &Msg) -> Result<CallHandle> {
        let cell = Arc::new(CallCell::new());
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        {
            let mut st = self.shared.state.lock().unwrap();
            loop {
                if let Some(reason) = &st.dead {
                    bail!("connection dead: {reason}");
                }
                if st.used < self.window {
                    break;
                }
                st = self.shared.cv.wait(st).unwrap();
            }
            st.used += 1;
            self.shared
                .max_inflight
                .fetch_max(st.used as u64, Ordering::Relaxed);
            st.pending.insert(id, cell.clone());
        }
        let frame = msg.encode_tagged(id);
        if self.tx.lock().unwrap().send(Outbound { id, frame }).is_err() {
            // Writer gone: the connection died between the window check
            // and the enqueue. The frame was never sent (at-most-once).
            self.shared.resolve(id, Err(anyhow!("connection closed")));
            bail!("connection dead: submission queue closed");
        }
        Ok(CallHandle { cell, id })
    }

    /// True once a transport fault killed this connection (new
    /// submissions are refused; the owner should re-dial).
    pub fn is_dead(&self) -> bool {
        self.shared.is_dead()
    }

    /// Calls currently in flight (window slots in use).
    pub fn inflight(&self) -> u64 {
        self.shared.state.lock().unwrap().used as u64
    }

    /// High-water of [`MuxConn::inflight`] over this connection's
    /// lifetime — the realized pipelining depth.
    pub fn max_inflight(&self) -> u64 {
        self.shared.max_inflight.load(Ordering::Relaxed)
    }

    /// The configured window (for status lines).
    pub fn window(&self) -> usize {
        self.window
    }
}

/// Writer worker: drain the submission queue onto the send half. On a
/// send failure, fail exactly the call being carried, kill the
/// connection, and then keep draining (failing) until every `MuxConn`
/// handle is gone — *holding the send half open the whole time*, so the
/// server cannot observe this connection closing (and reap the session)
/// before a replacement connection has handshaken.
fn writer_loop(
    mut tx_half: Box<dyn FrameTx>,
    out_rx: Receiver<Outbound>,
    shared: Arc<MuxShared>,
) {
    // Once the connection dies (our own send fault, or the reader's
    // recv fault), queued frames are failed instead of sent — nobody
    // would read their replies. The dead-check is **best-effort**, not
    // a guarantee: a reader-side kill can race a send already past the
    // check, so a call failed by the kill may still reach (and execute
    // on) the executor — the same server-side ambiguity as a lost
    // reply. What IS guaranteed is at-most-once: this layer never sends
    // a frame twice, so a failed call is failed, not retried, and its
    // only possible server-side residue is orphaned minted buffers
    // (reclaimed at session end) or, for a broadcast, a fork the caller
    // is told to treat as fatal.
    let mut parked: Option<String> = None;
    while let Ok(out) = out_rx.recv() {
        if parked.is_none() {
            parked = shared.dead_reason();
        }
        if let Some(reason) = &parked {
            shared
                .resolve(out.id, Err(anyhow!("connection dead: {reason}")));
            continue;
        }
        if let Err(e) = tx_half.send(&out.frame) {
            let reason = format!("send failed: {e:#}");
            // This call's frame never reached the executor; everything
            // else in flight dies with the connection (at-most-once).
            shared.resolve(out.id, Err(anyhow!("{reason}")));
            shared.kill(&reason);
            parked = Some(reason);
        }
    }
    // Submission queue closed (every MuxConn handle dropped): teardown.
    // Only now does the send half drop — a parked (dead) connection
    // holds it open until the owner has a handshaken replacement, so
    // the server never sees the session's connection count dip to zero
    // mid-reconnect.
}

/// Reader worker: match tagged replies to pending calls by id. Any
/// framing violation or recv failure kills the connection.
fn reader_loop(mut rx_half: Box<dyn FrameRx>, shared: Arc<MuxShared>) {
    loop {
        let frame = match rx_half.recv() {
            Ok(f) => f,
            Err(e) => {
                shared.kill(&format!("recv failed: {e:#}"));
                return;
            }
        };
        let (id, payload) = match proto::untag(&frame) {
            Ok(x) => x,
            Err(e) => {
                shared.kill(&format!("malformed reply frame: {e:#}"));
                return;
            }
        };
        match Reply::decode(payload) {
            Ok(reply) => shared.resolve(id, Ok(reply)),
            Err(e) => {
                // An undecodable reply means the streams have lost
                // framing sync — no later reply can be trusted.
                shared.kill(&format!("malformed reply for call #{id}: {e:#}"));
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tensor::Tensor;
    use std::time::Duration;

    /// Scripted send half: counts frames and forwards the observed
    /// (id, payload) pairs to the test.
    struct ScriptTx {
        seen: Sender<(u64, Vec<u8>)>,
        fail_after: usize,
        sent: usize,
    }

    impl FrameTx for ScriptTx {
        fn send(&mut self, frame: &[u8]) -> Result<()> {
            if self.sent >= self.fail_after {
                bail!("scripted send failure");
            }
            self.sent += 1;
            let (id, payload) = proto::untag(frame)?;
            let _ = self.seen.send((id, payload.to_vec()));
            Ok(())
        }
    }

    /// Scripted recv half: a sequence of thunks, each either producing
    /// a frame (possibly after waiting on the sent-frame channel) or an
    /// error. After the script, every recv errors (connection over).
    struct ScriptRx {
        frames: Receiver<Vec<u8>>,
    }

    impl FrameRx for ScriptRx {
        fn recv(&mut self) -> Result<Vec<u8>> {
            self.frames
                .recv()
                .map_err(|_| anyhow!("scripted transport closed"))
        }
    }

    fn reply_scalar(v: f32) -> Reply {
        Reply::Tensor(Tensor::scalar_f32(v))
    }

    /// Replies delivered in REVERSE submission order must still resolve
    /// each handle with its own call's payload — matching is by call
    /// id, not arrival order.
    #[test]
    fn out_of_order_replies_match_by_call_id() {
        let (seen_tx, seen_rx) = channel();
        let (frame_tx, frame_rx) = channel::<Vec<u8>>();
        let conn = MuxConn::start(
            Box::new(ScriptTx { seen: seen_tx, fail_after: usize::MAX, sent: 0 }),
            Box::new(ScriptRx { frames: frame_rx }),
            4,
        );
        let h1 = conn.submit(&Msg::ReadGlobal { name: "a".into() }).unwrap();
        let h2 = conn.submit(&Msg::ReadGlobal { name: "b".into() }).unwrap();
        let h3 = conn.submit(&Msg::ReadGlobal { name: "c".into() }).unwrap();
        // Wait until the writer delivered all three requests, recording
        // their ids; submission order assigns ascending ids.
        let ids: Vec<u64> = (0..3).map(|_| seen_rx.recv().unwrap().0).collect();
        assert_eq!(ids.len(), 3);
        assert!(ids[0] < ids[1] && ids[1] < ids[2], "ids must ascend");
        // Window filled to 3 while nothing had resolved.
        assert_eq!(conn.inflight(), 3);
        assert_eq!(conn.max_inflight(), 3);
        // Deliver replies 3, 1, 2 — fully out of order.
        for (id, v) in [(ids[2], 3.0f32), (ids[0], 1.0), (ids[1], 2.0)] {
            frame_tx.send(proto::tag(id, &reply_scalar(v).encode())).unwrap();
        }
        let got1 = h1.wait().unwrap();
        let got2 = h2.wait().unwrap();
        let got3 = h3.wait().unwrap();
        assert_eq!(got1, reply_scalar(1.0), "call 1 got someone else's reply");
        assert_eq!(got2, reply_scalar(2.0), "call 2 got someone else's reply");
        assert_eq!(got3, reply_scalar(3.0), "call 3 got someone else's reply");
        assert_eq!(conn.inflight(), 0, "window must drain as replies match");
        assert_eq!(conn.max_inflight(), 3);
    }

    /// A reply that never arrives fails exactly its own call when the
    /// connection dies; calls whose replies landed first are untouched.
    #[test]
    fn dropped_reply_fails_exactly_one_call() {
        let (seen_tx, seen_rx) = channel();
        let (frame_tx, frame_rx) = channel::<Vec<u8>>();
        let conn = MuxConn::start(
            Box::new(ScriptTx { seen: seen_tx, fail_after: usize::MAX, sent: 0 }),
            Box::new(ScriptRx { frames: frame_rx }),
            4,
        );
        let dropped = conn.submit(&Msg::ReadGlobal { name: "a".into() }).unwrap();
        let answered = conn.submit(&Msg::ReadGlobal { name: "b".into() }).unwrap();
        let ids: Vec<u64> = (0..2).map(|_| seen_rx.recv().unwrap().0).collect();
        // The second call's reply arrives; the first call's is dropped
        // by the network, then the connection dies (scripted EOF).
        frame_tx
            .send(proto::tag(ids[1], &reply_scalar(2.0).encode()))
            .unwrap();
        let got = answered.wait().unwrap();
        assert_eq!(got, reply_scalar(2.0));
        drop(frame_tx); // EOF → reader kills the connection
        let err = dropped.wait().unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("in flight"),
            "dropped call must fail as in-flight on a dead transport: {msg}"
        );
        assert!(conn.is_dead());
        // New submissions are refused — the owner must re-dial.
        assert!(conn.submit(&Msg::Metrics).is_err());
        assert_eq!(conn.inflight(), 0);
    }

    /// A send failure resolves the call it was carrying and kills the
    /// connection; a call whose reply already landed is unaffected.
    #[test]
    fn send_failure_fails_the_carried_call() {
        let (seen_tx, seen_rx) = channel();
        let (frame_tx, frame_rx) = channel::<Vec<u8>>();
        let conn = MuxConn::start(
            Box::new(ScriptTx { seen: seen_tx, fail_after: 1, sent: 0 }),
            Box::new(ScriptRx { frames: frame_rx }),
            4,
        );
        let ok = conn.submit(&Msg::ReadGlobal { name: "a".into() }).unwrap();
        let (id, _) = seen_rx.recv().unwrap();
        frame_tx.send(proto::tag(id, &reply_scalar(1.0).encode())).unwrap();
        assert_eq!(ok.wait().unwrap(), reply_scalar(1.0));
        // Second send is scripted to fail.
        let doomed = conn.submit(&Msg::ReadGlobal { name: "b".into() }).unwrap();
        let err = doomed.wait().unwrap_err();
        assert!(format!("{err:#}").contains("send failed"), "{err:#}");
        assert!(conn.is_dead());
    }

    /// A straggler reply for an id that already failed must be ignored,
    /// not corrupt the window accounting or a later call.
    #[test]
    fn straggler_replies_are_ignored() {
        let (seen_tx, seen_rx) = channel();
        let (frame_tx, frame_rx) = channel::<Vec<u8>>();
        let conn = MuxConn::start(
            Box::new(ScriptTx { seen: seen_tx, fail_after: usize::MAX, sent: 0 }),
            Box::new(ScriptRx { frames: frame_rx }),
            2,
        );
        let h = conn.submit(&Msg::Metrics).unwrap();
        let (id, _) = seen_rx.recv().unwrap();
        // A reply for a never-issued id, then the real one.
        frame_tx
            .send(proto::tag(id + 1000, &reply_scalar(9.0).encode()))
            .unwrap();
        frame_tx.send(proto::tag(id, &reply_scalar(1.0).encode())).unwrap();
        assert_eq!(h.wait().unwrap(), reply_scalar(1.0));
        assert_eq!(conn.inflight(), 0);
        // Give the reader a beat to process the straggler before
        // checking it did not poison the connection.
        std::thread::sleep(Duration::from_millis(5));
        assert!(!conn.is_dead());
    }

    /// The window blocks the (window+1)-th submission until a slot
    /// frees — bounded in-flight state, not an unbounded queue.
    #[test]
    fn window_bounds_inflight_submissions() {
        let (seen_tx, seen_rx) = channel();
        let (frame_tx, frame_rx) = channel::<Vec<u8>>();
        let conn = Arc::new(MuxConn::start(
            Box::new(ScriptTx { seen: seen_tx, fail_after: usize::MAX, sent: 0 }),
            Box::new(ScriptRx { frames: frame_rx }),
            2,
        ));
        let _h1 = conn.submit(&Msg::Metrics).unwrap();
        let _h2 = conn.submit(&Msg::Metrics).unwrap();
        assert_eq!(conn.inflight(), 2);
        // Third submission must block until one reply lands.
        let c2 = conn.clone();
        let (done_tx, done_rx) = channel();
        std::thread::spawn(move || {
            let h3 = c2.submit(&Msg::Metrics).unwrap();
            done_tx.send(()).unwrap();
            let _ = h3.wait();
        });
        assert!(
            done_rx.recv_timeout(Duration::from_millis(50)).is_err(),
            "third submission went through a full window"
        );
        let (id, _) = seen_rx.recv().unwrap();
        frame_tx.send(proto::tag(id, &reply_scalar(0.0).encode())).unwrap();
        done_rx
            .recv_timeout(Duration::from_secs(10))
            .expect("freed slot must unblock the submitter");
        assert_eq!(conn.max_inflight(), 2, "window cap must hold");
    }
}
