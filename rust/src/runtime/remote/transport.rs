//! Framed transports for the remote-executor protocol.
//!
//! A [`Transport`] moves whole frames (the length prefix is the
//! transport's concern, not the codec's). Since protocol v3 the remote
//! runtime is *pipelined*: after the handshake, a connection is
//! [`Transport::split`] into an independently usable sending half
//! ([`FrameTx`]) and receiving half ([`FrameRx`]) so the mux's
//! persistent writer/reader worker pair can overlap sends with receives
//! on one connection. Implementations:
//!
//! * [`TcpTransport`] — `u32` length prefix over a `TcpStream`; the
//!   production path behind `dvi serve-backend --listen`. Splitting
//!   clones the stream; dropping the send half shuts the socket down so
//!   a reader blocked in `recv` wakes up and exits.
//! * loopback ([`loopback_pair`]) — a pair of in-process byte channels,
//!   used by the hermetic test suite and CI (`DVI_TEST_REMOTE=loopback`)
//!   so the full encode → frame → decode path runs with no sockets.
//!   Splitting hands out the two channel ends.
//! * [`ChaosTransport`] — wraps any transport and fails every Nth send,
//!   injecting deterministic transport faults for the scheduler's
//!   fail-lane tests. Splitting wraps the send half (faults are send
//!   faults); the shared counters keep fault spacing across reconnects
//!   *and* across the split.
//! * [`KillSwitch`] / [`GatedTransport`] — a latch that permanently
//!   kills a connector and every transport it minted, simulating a dead
//!   executor (shard) deterministically: once killed, sends, recvs, and
//!   re-dials all fail until the end of the test. Splitting gates both
//!   halves on the same latch.
//!
//! A [`Connector`] mints fresh transports, which is what gives the
//! client its bounded-reconnect behavior: a dead connection is dropped
//! and the next backend call dials again.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::proto::MAX_FRAME;

/// Sending half of a split transport (the mux writer worker's handle).
pub trait FrameTx: Send {
    fn send(&mut self, frame: &[u8]) -> Result<()>;
}

/// Receiving half of a split transport (the mux reader worker's handle).
pub trait FrameRx: Send {
    fn recv(&mut self) -> Result<Vec<u8>>;
}

/// One framed, ordered, bidirectional byte channel.
pub trait Transport: Send {
    fn send(&mut self, frame: &[u8]) -> Result<()>;
    fn recv(&mut self) -> Result<Vec<u8>>;

    /// Split into independently usable halves so a writer worker can
    /// send while a reader worker blocks in `recv` — the seam the
    /// pipelined mux runtime is built on. Consumes the transport; the
    /// halves share its fate (chaos plans, kill switches, the socket).
    fn split(self: Box<Self>) -> Result<(Box<dyn FrameTx>, Box<dyn FrameRx>)>;
}

/// Mints fresh connections (dial + nothing else; the protocol handshake
/// is the client's job).
pub trait Connector: Send + Sync {
    fn connect(&self) -> Result<Box<dyn Transport>>;
    /// Human-readable endpoint for error messages.
    fn endpoint(&self) -> String;
}

// ----------------------------------------------------------------------------
// TCP
// ----------------------------------------------------------------------------

pub struct TcpTransport {
    stream: TcpStream,
}

impl TcpTransport {
    pub fn new(stream: TcpStream) -> TcpTransport {
        // Frames are already whole messages; don't let Nagle delay them.
        let _ = stream.set_nodelay(true);
        TcpTransport { stream }
    }

    pub fn connect(addr: &str) -> Result<TcpTransport> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to executor at {addr}"))?;
        Ok(TcpTransport::new(stream))
    }
}

fn tcp_send(stream: &mut TcpStream, frame: &[u8]) -> Result<()> {
    ensure!(frame.len() <= MAX_FRAME, "frame too large: {}", frame.len());
    stream.write_all(&(frame.len() as u32).to_le_bytes())?;
    stream.write_all(frame)?;
    stream.flush()?;
    Ok(())
}

fn tcp_recv(stream: &mut TcpStream) -> Result<Vec<u8>> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    ensure!(len <= MAX_FRAME, "oversized frame announced: {len}");
    let mut frame = vec![0u8; len];
    stream.read_exact(&mut frame)?;
    Ok(frame)
}

impl Transport for TcpTransport {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        tcp_send(&mut self.stream, frame)
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        tcp_recv(&mut self.stream)
    }

    fn split(self: Box<Self>) -> Result<(Box<dyn FrameTx>, Box<dyn FrameRx>)> {
        let rx = self
            .stream
            .try_clone()
            .context("cloning tcp stream for the reader half")?;
        Ok((
            Box::new(TcpSendHalf { stream: self.stream }),
            Box::new(TcpRecvHalf { stream: rx }),
        ))
    }
}

/// Write side of a split TCP connection. Dropping it shuts the socket
/// down both ways so the peer — and our own reader half blocked in
/// `read_exact` — observe the close instead of hanging forever.
pub struct TcpSendHalf {
    stream: TcpStream,
}

impl FrameTx for TcpSendHalf {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        tcp_send(&mut self.stream, frame)
    }
}

impl Drop for TcpSendHalf {
    fn drop(&mut self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }
}

pub struct TcpRecvHalf {
    stream: TcpStream,
}

impl FrameRx for TcpRecvHalf {
    fn recv(&mut self) -> Result<Vec<u8>> {
        tcp_recv(&mut self.stream)
    }
}

pub struct TcpConnector {
    pub addr: String,
}

impl Connector for TcpConnector {
    fn connect(&self) -> Result<Box<dyn Transport>> {
        Ok(Box::new(TcpTransport::connect(&self.addr)?))
    }

    fn endpoint(&self) -> String {
        format!("tcp://{}", self.addr)
    }
}

// ----------------------------------------------------------------------------
// In-process loopback
// ----------------------------------------------------------------------------

pub struct LoopbackTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

/// Two connected in-process endpoints.
pub fn loopback_pair() -> (LoopbackTransport, LoopbackTransport) {
    let (atx, brx) = channel();
    let (btx, arx) = channel();
    (
        LoopbackTransport { tx: atx, rx: arx },
        LoopbackTransport { tx: btx, rx: brx },
    )
}

fn loopback_send(tx: &Sender<Vec<u8>>, frame: &[u8]) -> Result<()> {
    tx.send(frame.to_vec())
        .map_err(|_| anyhow!("loopback peer hung up"))
}

fn loopback_recv(rx: &Receiver<Vec<u8>>) -> Result<Vec<u8>> {
    rx.recv().map_err(|_| anyhow!("loopback peer hung up"))
}

impl Transport for LoopbackTransport {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        loopback_send(&self.tx, frame)
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        loopback_recv(&self.rx)
    }

    fn split(self: Box<Self>) -> Result<(Box<dyn FrameTx>, Box<dyn FrameRx>)> {
        Ok((
            Box::new(LoopbackSendHalf { tx: self.tx }),
            Box::new(LoopbackRecvHalf { rx: self.rx }),
        ))
    }
}

pub struct LoopbackSendHalf {
    tx: Sender<Vec<u8>>,
}

impl FrameTx for LoopbackSendHalf {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        loopback_send(&self.tx, frame)
    }
}

pub struct LoopbackRecvHalf {
    rx: Receiver<Vec<u8>>,
}

impl FrameRx for LoopbackRecvHalf {
    fn recv(&mut self) -> Result<Vec<u8>> {
        loopback_recv(&self.rx)
    }
}

/// Dials the in-process executor's accept loop
/// (`server::spawn_loopback`): each `connect` mints a fresh channel pair
/// and hands the server end across, mirroring a TCP accept.
pub struct LoopbackConnector {
    pub(super) accept_tx: Mutex<Sender<LoopbackTransport>>,
    /// Fault-injection plan applied to every minted client transport
    /// (shared counters, so fault spacing spans reconnects).
    pub(super) chaos: Option<ChaosPlan>,
    /// Shared executor-death latch: once tripped, dials fail and every
    /// previously minted transport errors (see [`KillSwitch`]).
    pub(super) kill: KillSwitch,
}

impl Clone for LoopbackConnector {
    fn clone(&self) -> Self {
        LoopbackConnector {
            accept_tx: Mutex::new(self.accept_tx.lock().unwrap().clone()),
            chaos: self.chaos.clone(),
            kill: self.kill.clone(),
        }
    }
}

impl Connector for LoopbackConnector {
    fn connect(&self) -> Result<Box<dyn Transport>> {
        if self.kill.is_killed() {
            bail!("loopback executor killed");
        }
        let (client, server) = loopback_pair();
        self.accept_tx
            .lock()
            .unwrap()
            .send(server)
            .map_err(|_| anyhow!("loopback executor has shut down"))?;
        let inner: Box<dyn Transport> = match &self.chaos {
            Some(plan) => Box::new(ChaosTransport {
                inner: Box::new(client),
                plan: plan.clone(),
            }),
            None => Box::new(client),
        };
        Ok(Box::new(GatedTransport { inner, kill: self.kill.clone() }))
    }

    fn endpoint(&self) -> String {
        "loopback".to_string()
    }
}

// ----------------------------------------------------------------------------
// Fault injection
// ----------------------------------------------------------------------------

/// Latch simulating a permanently dead executor: tests flip it to kill
/// one shard and the sharded client must degrade (fail that shard's
/// lanes) without wedging. Unlike [`ChaosPlan`] this is not transient —
/// there is no cap and no recovery.
#[derive(Clone, Default)]
pub struct KillSwitch(Arc<AtomicBool>);

impl KillSwitch {
    pub fn new() -> KillSwitch {
        KillSwitch::default()
    }

    /// Trip the latch: every gated transport and connector dies now.
    pub fn kill(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_killed(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// Transport wrapper honoring a [`KillSwitch`]: both directions error
/// once the latch trips, modeling an executor process that is gone (not
/// just one dropped frame, which is [`ChaosTransport`]'s job). Both
/// split halves stay gated on the same latch, so the mux's reader
/// worker observes the death just like its writer does.
pub struct GatedTransport {
    pub(super) inner: Box<dyn Transport>,
    pub(super) kill: KillSwitch,
}

impl Transport for GatedTransport {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        if self.kill.is_killed() {
            bail!("executor killed");
        }
        self.inner.send(frame)
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        if self.kill.is_killed() {
            bail!("executor killed");
        }
        self.inner.recv()
    }

    fn split(self: Box<Self>) -> Result<(Box<dyn FrameTx>, Box<dyn FrameRx>)> {
        let (tx, rx) = self.inner.split()?;
        Ok((
            Box::new(GatedSendHalf { inner: tx, kill: self.kill.clone() }),
            Box::new(GatedRecvHalf { inner: rx, kill: self.kill }),
        ))
    }
}

pub struct GatedSendHalf {
    inner: Box<dyn FrameTx>,
    kill: KillSwitch,
}

impl FrameTx for GatedSendHalf {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        if self.kill.is_killed() {
            bail!("executor killed");
        }
        self.inner.send(frame)
    }
}

pub struct GatedRecvHalf {
    inner: Box<dyn FrameRx>,
    kill: KillSwitch,
}

impl FrameRx for GatedRecvHalf {
    fn recv(&mut self) -> Result<Vec<u8>> {
        if self.kill.is_killed() {
            bail!("executor killed");
        }
        self.inner.recv()
    }
}

/// Deterministic fault-injection plan, shared across reconnects: every
/// `every`-th send fails, at most `max_failures` times in total. The
/// cap lets chaos tests bound worst-case damage (each failure can kill
/// at most one scheduler chunk) while the modulo guarantees the first
/// failure actually fires.
#[derive(Clone)]
pub struct ChaosPlan {
    pub every: u64,
    pub max_failures: u64,
    sends: Arc<AtomicU64>,
    injected: Arc<AtomicU64>,
}

impl ChaosPlan {
    pub fn new(every: u64, max_failures: u64) -> ChaosPlan {
        // every=2 locks into a handshake-ok / call-fail cycle (sends
        // alternate dial-Hello and the retried call), so every request
        // would fail until the cap runs out; >= 3 keeps reconnects able
        // to make progress between injected faults.
        assert!(every >= 3, "every < 3 would starve reconnects");
        ChaosPlan {
            every,
            max_failures,
            sends: Arc::new(AtomicU64::new(0)),
            injected: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Count one send; `Some(n)` means send number `n` must fail.
    fn trip(&self) -> Option<u64> {
        let n = self.sends.fetch_add(1, Ordering::Relaxed) + 1;
        if n % self.every != 0 {
            return None;
        }
        let k = self.injected.fetch_add(1, Ordering::Relaxed);
        (k < self.max_failures).then_some(n)
    }

    /// Failures injected so far.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed).min(self.max_failures)
    }
}

/// Transport wrapper executing a [`ChaosPlan`]: a tripped send errors
/// and the frame is *not* delivered, modeling a connection dropped
/// before the request reached the executor — the at-most-once case the
/// client maps onto per-call failures. Splitting moves the plan onto
/// the send half (faults are send faults); counters stay shared.
pub struct ChaosTransport {
    inner: Box<dyn Transport>,
    plan: ChaosPlan,
}

impl ChaosTransport {
    pub fn new(inner: Box<dyn Transport>, plan: ChaosPlan) -> ChaosTransport {
        ChaosTransport { inner, plan }
    }
}

impl Transport for ChaosTransport {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        if let Some(n) = self.plan.trip() {
            bail!("injected transport failure (send #{n})");
        }
        self.inner.send(frame)
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        self.inner.recv()
    }

    fn split(self: Box<Self>) -> Result<(Box<dyn FrameTx>, Box<dyn FrameRx>)> {
        let (tx, rx) = self.inner.split()?;
        Ok((Box::new(ChaosSendHalf { inner: tx, plan: self.plan }), rx))
    }
}

pub struct ChaosSendHalf {
    inner: Box<dyn FrameTx>,
    plan: ChaosPlan,
}

impl FrameTx for ChaosSendHalf {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        if let Some(n) = self.plan.trip() {
            bail!("injected transport failure (send #{n})");
        }
        self.inner.send(frame)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_frames_roundtrip_in_order() {
        let (mut a, mut b) = loopback_pair();
        a.send(&[1, 2, 3]).unwrap();
        a.send(&[]).unwrap();
        assert_eq!(b.recv().unwrap(), vec![1, 2, 3]);
        assert_eq!(b.recv().unwrap(), Vec::<u8>::new());
        b.send(&[9]).unwrap();
        assert_eq!(a.recv().unwrap(), vec![9]);
    }

    #[test]
    fn loopback_hangup_errors() {
        let (mut a, b) = loopback_pair();
        drop(b);
        assert!(a.send(&[1]).is_err());
        assert!(a.recv().is_err());
    }

    #[test]
    fn split_halves_keep_the_channel_alive() {
        let (a, mut b) = loopback_pair();
        let (mut tx, mut rx) = (Box::new(a) as Box<dyn Transport>).split().unwrap();
        tx.send(&[7, 8]).unwrap();
        assert_eq!(b.recv().unwrap(), vec![7, 8]);
        b.send(&[9]).unwrap();
        assert_eq!(rx.recv().unwrap(), vec![9]);
        // Dropping the send half hangs up the peer's recv...
        drop(tx);
        assert!(b.recv().is_err());
        // ...and the peer dropping hangs up our recv half.
        drop(b);
        assert!(rx.recv().is_err());
    }

    #[test]
    fn chaos_fails_every_nth_send_up_to_cap() {
        let (a, mut b) = loopback_pair();
        let plan = ChaosPlan::new(3, 1);
        let mut c = ChaosTransport::new(Box::new(a), plan.clone());
        assert!(c.send(&[1]).is_ok());
        assert!(c.send(&[2]).is_ok());
        assert!(c.send(&[3]).is_err()); // injected; frame not delivered
        assert!(c.send(&[4]).is_ok());
        assert!(c.send(&[5]).is_ok());
        assert!(c.send(&[6]).is_ok()); // would trip, but capped at 1
        assert_eq!(plan.injected(), 1);
        assert_eq!(b.recv().unwrap(), vec![1]);
        assert_eq!(b.recv().unwrap(), vec![2]);
        assert_eq!(b.recv().unwrap(), vec![4]);
        assert_eq!(b.recv().unwrap(), vec![5]);
        assert_eq!(b.recv().unwrap(), vec![6]);
    }

    #[test]
    fn chaos_split_keeps_counting_sends() {
        let (a, mut b) = loopback_pair();
        let plan = ChaosPlan::new(3, 10);
        let chaos = Box::new(ChaosTransport::new(Box::new(a), plan.clone()));
        let (mut tx, _rx) = (chaos as Box<dyn Transport>).split().unwrap();
        assert!(tx.send(&[1]).is_ok());
        assert!(tx.send(&[2]).is_ok());
        assert!(tx.send(&[3]).is_err()); // 3rd send trips through the half
        assert_eq!(plan.injected(), 1);
        assert_eq!(b.recv().unwrap(), vec![1]);
        assert_eq!(b.recv().unwrap(), vec![2]);
    }

    #[test]
    fn kill_switch_is_permanent_and_shared() {
        let (a, mut b) = loopback_pair();
        let kill = KillSwitch::new();
        let mut g = GatedTransport { inner: Box::new(a), kill: kill.clone() };
        assert!(g.send(&[1]).is_ok());
        assert_eq!(b.recv().unwrap(), vec![1]);
        kill.kill();
        assert!(g.send(&[2]).is_err());
        assert!(g.recv().is_err());
        assert!(kill.is_killed());
        // Still dead on the next attempt: a latch, not a counter.
        assert!(g.send(&[3]).is_err());
    }

    #[test]
    fn kill_switch_gates_both_split_halves() {
        let (a, _b) = loopback_pair();
        let kill = KillSwitch::new();
        let gated =
            Box::new(GatedTransport { inner: Box::new(a), kill: kill.clone() });
        let (mut tx, mut rx) = (gated as Box<dyn Transport>).split().unwrap();
        assert!(tx.send(&[1]).is_ok());
        kill.kill();
        assert!(tx.send(&[2]).is_err());
        assert!(rx.recv().is_err());
    }

    #[test]
    fn tcp_transport_roundtrips() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream);
            let f = t.recv().unwrap();
            t.send(&f).unwrap(); // echo
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        c.send(&[5, 6, 7]).unwrap();
        assert_eq!(c.recv().unwrap(), vec![5, 6, 7]);
        server.join().unwrap();
    }

    #[test]
    fn tcp_split_send_half_drop_wakes_the_reader() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream);
            let f = t.recv().unwrap();
            t.send(&f).unwrap();
            // Block until the client side is torn down.
            let _ = t.recv();
        });
        let c = Box::new(TcpTransport::connect(&addr.to_string()).unwrap());
        let (mut tx, mut rx) = (c as Box<dyn Transport>).split().unwrap();
        tx.send(&[1, 2]).unwrap();
        assert_eq!(rx.recv().unwrap(), vec![1, 2]);
        // Dropping the send half shuts the socket down; the reader half
        // must observe an error instead of blocking forever.
        drop(tx);
        assert!(rx.recv().is_err());
        server.join().unwrap();
    }
}
