//! Executor-server side of the remote backend: fronts any local
//! [`crate::runtime::Backend`] (reference or PJRT) over a framed
//! transport.
//!
//! State model: one **shared buffer table** per server, not per
//! connection, with every entry **owned by the session** (client) that
//! allocated it. Sessions are identified by the client-minted id in the
//! `Hello` handshake and span reconnects: per-sequence KV handles
//! therefore survive a client reconnect — a dropped connection costs
//! exactly the in-flight call (the scheduler fails that chunk's lanes),
//! never the KV state of co-resident sequences. Ids are minted from one
//! atomic counter, so a reconnecting client can never collide with its
//! pre-drop handles.
//!
//! Leak discipline (the fix the ROADMAP flagged): when a session's
//! **last** connection closes, every buffer it still owns is freed —
//! a permanently dead client cannot leak executor buffer-table entries,
//! even if it never sent its piggybacked frees. The client keeps its
//! dead transport alive as a "zombie" until a replacement connection
//! has completed its handshake (see `remote/mod.rs`), so a reconnect
//! whose failure was observed client-side keeps the session's
//! live-connection count above zero and its buffers survive — the
//! deterministic case the loopback/chaos suite pins down. When the
//! *server* observes the drop first (TCP RST, partition), the session
//! ends and its buffers are freed; the reconnecting client's resident
//! sequences then fail per-call and the scheduler degrades instead of
//! wedging. Co-resident sessions are isolated: one client's death frees
//! only its own entries. A reply that fails to send also frees the
//! buffers it minted (the client can never learn their ids); the one
//! residual window is a reply the transport accepted but the client
//! never read — those orphans last until their session ends.
//!
//! Error discipline: a malformed or semantically invalid request gets a
//! `Reply::Err` and the connection stays up (the client surfaces it as
//! a per-call error); only transport failures tear a connection down.
//! A request sent before the connection's `Hello` is rejected — buffer
//! ownership needs a session before anything can allocate.

use std::collections::HashMap;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::runtime::backend::{BatchItem, Buffer};
use crate::runtime::manifest::Role;
use crate::runtime::{log, Runtime};

use super::proto::{hello_json, BufInfo, ExecMetrics, LaneOut, Msg, Reply, VERSION};
use super::transport::{
    ChaosPlan, KillSwitch, LoopbackConnector, LoopbackTransport, TcpTransport,
    Transport,
};

/// Server-resident buffer store: id → (owner session, backend-native
/// buffer handle).
pub struct BufferTable {
    next: AtomicU64,
    bufs: Mutex<HashMap<u64, (u64, Buffer)>>,
}

impl BufferTable {
    pub fn new() -> BufferTable {
        BufferTable { next: AtomicU64::new(1), bufs: Mutex::new(HashMap::new()) }
    }

    fn insert(
        &self,
        owner: u64,
        buf: Buffer,
        dtype: crate::runtime::DType,
        shape: Vec<usize>,
    ) -> BufInfo {
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        self.bufs.lock().unwrap().insert(id, (owner, buf));
        BufInfo { id, dtype, shape }
    }

    fn get(&self, id: u64) -> Result<Buffer> {
        self.bufs
            .lock()
            .unwrap()
            .get(&id)
            .map(|(_, b)| b.clone())
            .with_context(|| format!("unknown buffer id {id} (freed or never allocated)"))
    }

    fn free(&self, ids: &[u64]) {
        if ids.is_empty() {
            return;
        }
        let mut bufs = self.bufs.lock().unwrap();
        for id in ids {
            bufs.remove(id);
        }
    }

    /// Drop every entry owned by `session`; returns how many were freed.
    fn free_session(&self, session: u64) -> usize {
        let mut bufs = self.bufs.lock().unwrap();
        let before = bufs.len();
        bufs.retain(|_, (owner, _)| *owner != session);
        before - bufs.len()
    }

    pub fn len(&self) -> usize {
        self.bufs.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for BufferTable {
    fn default() -> Self {
        Self::new()
    }
}

/// Executor-lifetime serving counters behind the `Metrics` message.
#[derive(Default)]
pub struct ExecStats {
    /// `Call` requests served successfully.
    pub calls: AtomicU64,
    /// Lanes carried by those calls.
    pub lanes: AtomicU64,
}

/// Everything one executor server shares across its connections.
pub struct ExecutorState {
    pub table: BufferTable,
    pub stats: ExecStats,
    /// session id → live connection count. A session leaves the map
    /// (and its buffers are freed) when its last connection closes.
    sessions: Mutex<HashMap<u64, usize>>,
}

impl ExecutorState {
    pub fn new() -> ExecutorState {
        ExecutorState {
            table: BufferTable::new(),
            stats: ExecStats::default(),
            sessions: Mutex::new(HashMap::new()),
        }
    }

    pub fn live_sessions(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }

    fn open_session(&self, session: u64) {
        *self.sessions.lock().unwrap().entry(session).or_insert(0) += 1;
    }

    /// Close one connection of `session`; frees its buffers when this
    /// was the last.
    fn close_session(&self, session: u64) {
        let mut sessions = self.sessions.lock().unwrap();
        let last = match sessions.get_mut(&session) {
            Some(n) if *n > 1 => {
                *n -= 1;
                false
            }
            Some(_) => {
                sessions.remove(&session);
                true
            }
            None => false,
        };
        drop(sessions);
        if last {
            let freed = self.table.free_session(session);
            if freed > 0 {
                log::debug(&format!(
                    "executor: session {session:#x} ended; freed {freed} \
                     orphaned buffers"
                ));
            }
        }
    }

    fn metrics(&self) -> ExecMetrics {
        ExecMetrics {
            calls: self.stats.calls.load(Ordering::Relaxed),
            lanes: self.stats.lanes.load(Ordering::Relaxed),
            buffers: self.table.len() as u64,
            sessions: self.live_sessions() as u64,
        }
    }
}

impl Default for ExecutorState {
    fn default() -> Self {
        Self::new()
    }
}

/// Execute one request against the fronted runtime on behalf of
/// `session`. Pure with respect to the connection: all state lives in
/// `rt` and `state`.
fn execute(
    rt: &Runtime,
    state: &ExecutorState,
    session: u64,
    msg: Msg,
) -> Result<Reply> {
    let table = &state.table;
    match msg {
        Msg::Hello { version, want_manifest, session: _ } => {
            anyhow::ensure!(
                version == VERSION,
                "protocol version mismatch: client {version}, server {VERSION}"
            );
            let manifest_json = want_manifest.then(|| {
                hello_json(&rt.manifest, &rt.prompts, rt.vocab.as_deref())
            });
            Ok(Reply::Hello {
                backend: rt.backend_name().to_string(),
                manifest_json,
            })
        }
        Msg::Call { artifact, frees, lanes } => {
            table.free(&frees);
            let art = rt.artifact(&artifact)?;
            let kvs: Vec<Vec<Buffer>> = lanes
                .iter()
                .map(|lane| lane.kv.iter().map(|&id| table.get(id)).collect())
                .collect::<Result<_>>()?;
            let items: Vec<BatchItem<'_>> = lanes
                .iter()
                .zip(&kvs)
                .map(|(lane, kv)| BatchItem { kv, inputs: &lane.inputs })
                .collect();
            let outs = art.call_batched(&items)?;
            state.stats.calls.fetch_add(1, Ordering::Relaxed);
            state.stats.lanes.fetch_add(lanes.len() as u64, Ordering::Relaxed);
            let kv_ports: Vec<_> = art.spec.outputs_with_role(Role::Kv).collect();
            let lanes_out = outs
                .into_iter()
                .map(|out| LaneOut {
                    outputs: out.outputs,
                    kv: out
                        .kv
                        .into_iter()
                        .zip(&kv_ports)
                        .map(|(b, p)| {
                            table.insert(session, b, p.dtype, p.shape.clone())
                        })
                        .collect(),
                })
                .collect();
            Ok(Reply::Lanes(lanes_out))
        }
        Msg::FreshKv { artifact } => {
            let art = rt.artifact(&artifact)?;
            let bufs = rt.fresh_kv(&artifact)?;
            let ports: Vec<_> = art.spec.params_with_role(Role::Kv).collect();
            Ok(Reply::Buffers(
                bufs.into_iter()
                    .zip(&ports)
                    .map(|(b, p)| table.insert(session, b, p.dtype, p.shape.clone()))
                    .collect(),
            ))
        }
        Msg::Upload { tensor } => {
            let dtype = tensor.dtype();
            let shape = tensor.shape.clone();
            let buf = rt.upload(&tensor)?;
            Ok(Reply::Buffers(vec![table.insert(session, buf, dtype, shape)]))
        }
        Msg::Download { id, dtype, shape } => {
            let buf = table.get(id)?;
            Ok(Reply::Tensor(rt.to_host(&buf, dtype, &shape)?))
        }
        Msg::SetGlobal { name, tensor } => {
            rt.set_global(&name, &tensor)?;
            Ok(Reply::Unit)
        }
        Msg::ReadGlobal { name } => Ok(Reply::Tensor(rt.read_global(&name)?)),
        Msg::ResetGlobal { name } => {
            rt.reset_global(&name)?;
            Ok(Reply::Unit)
        }
        Msg::Free { ids } => {
            table.free(&ids);
            Ok(Reply::Unit)
        }
        Msg::Metrics => Ok(Reply::Metrics(state.metrics())),
    }
}

/// Serve one connection until the peer hangs up. Request errors are
/// answered with `Reply::Err`; only a transport failure returns. On any
/// exit, the connection is unregistered from its session — and if it
/// was the session's last, the session's buffers are freed.
pub fn serve_connection(
    rt: &Runtime,
    state: &ExecutorState,
    transport: &mut dyn Transport,
) -> Result<()> {
    let mut session: Option<u64> = None;
    let result = (|| -> Result<()> {
        loop {
            let frame = match transport.recv() {
                Ok(f) => f,
                Err(_) => return Ok(()), // peer gone: normal teardown
            };
            let reply = match Msg::decode(&frame) {
                Ok(msg) => {
                    if let Msg::Hello { version, session: s, .. } = &msg {
                        if *version == VERSION && session.is_none() {
                            state.open_session(*s);
                            session = Some(*s);
                        }
                    }
                    // A Hello always reaches execute (so a version
                    // mismatch gets its real error); anything else
                    // needs the session that buffer ownership hangs on.
                    let owner = match (&msg, session) {
                        (Msg::Hello { .. }, s) => Some(s.unwrap_or(0)),
                        (_, s) => s,
                    };
                    match owner {
                        None => Reply::Err(
                            "handshake required before any other request".into(),
                        ),
                        Some(owner) => match execute(rt, state, owner, msg) {
                            Ok(reply) => reply,
                            Err(e) => Reply::Err(format!("{e:#}")),
                        },
                    }
                }
                Err(e) => Reply::Err(format!("malformed request: {e:#}")),
            };
            if let Err(e) = transport.send(&reply.encode()) {
                // The reply never reached the client, so any buffer ids
                // it minted are unreachable — the client can never name
                // them in a free-list. Reclaim them now; otherwise a
                // session that survives the reconnect (zombie-parked
                // client) would carry the orphans until it ends.
                free_minted(state, &reply);
                return Err(e.context("sending reply (client connection lost)"));
            }
        }
    })();
    if let Some(s) = session {
        state.close_session(s);
    }
    result
}

/// Free every server-resident buffer a reply minted (fresh KV outputs,
/// fresh_kv allocations, uploads) — used when the reply could not be
/// delivered, making those ids permanently unreachable from the client.
fn free_minted(state: &ExecutorState, reply: &Reply) {
    let ids: Vec<u64> = match reply {
        Reply::Lanes(lanes) => {
            lanes.iter().flat_map(|l| l.kv.iter().map(|b| b.id)).collect()
        }
        Reply::Buffers(bs) => bs.iter().map(|b| b.id).collect(),
        _ => return,
    };
    state.table.free(&ids);
}

/// TCP executor server: accept loop, one thread + shared
/// [`ExecutorState`] across connections. Runs until `stop` is set
/// (checked per accept) or the listener dies. This is what
/// `dvi serve-backend --listen` runs.
pub fn serve_tcp(
    listener: TcpListener,
    rt: Arc<Runtime>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let state = Arc::new(ExecutorState::new());
    for stream in listener.incoming() {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match stream {
            Ok(stream) => {
                let peer = stream
                    .peer_addr()
                    .map(|a| a.to_string())
                    .unwrap_or_else(|_| "<unknown>".to_string());
                log::info(&format!("executor: connection from {peer}"));
                let rt = rt.clone();
                let state = state.clone();
                std::thread::Builder::new()
                    .name("dvi-executor-conn".into())
                    .spawn(move || {
                        let mut t = TcpTransport::new(stream);
                        if let Err(e) = serve_connection(&rt, &state, &mut t) {
                            log::info(&format!("executor: {peer} dropped: {e}"));
                        }
                    })?;
            }
            Err(e) => log::info(&format!("executor: accept failed: {e}")),
        }
    }
    Ok(())
}

/// One in-process executor with the handles tests need: the connector
/// (clone it per client), the shared state (buffer table / metrics for
/// leak assertions), and the kill switch that simulates the executor
/// dying permanently.
pub struct LoopbackShard {
    pub connector: LoopbackConnector,
    pub state: Arc<ExecutorState>,
    pub kill: KillSwitch,
}

/// In-process executor: an accept thread fronting `rt`'s backend over
/// loopback transports, with optional per-transport fault injection.
/// The returned connector behaves exactly like a TCP connector
/// (including reconnects after an injected failure), so the hermetic
/// test suite exercises the full remote path.
pub fn spawn_loopback_shard(
    rt: Arc<Runtime>,
    chaos: Option<ChaosPlan>,
) -> LoopbackShard {
    let (accept_tx, accept_rx) =
        std::sync::mpsc::channel::<LoopbackTransport>();
    let state = Arc::new(ExecutorState::new());
    let conn_state = state.clone();
    std::thread::Builder::new()
        .name("dvi-executor-loopback".into())
        .spawn(move || {
            // Accept loop ends when every connector clone (the only
            // senders) is dropped; per-connection threads end when their
            // client endpoint is dropped. No explicit shutdown required.
            while let Ok(mut transport) = accept_rx.recv() {
                let rt = rt.clone();
                let state = conn_state.clone();
                let spawned = std::thread::Builder::new()
                    .name("dvi-executor-loopback-conn".into())
                    .spawn(move || {
                        let _ = serve_connection(&rt, &state, &mut transport);
                    });
                if spawned.is_err() {
                    break;
                }
            }
        })
        .expect("spawning loopback executor thread");
    let kill = KillSwitch::new();
    LoopbackShard {
        connector: LoopbackConnector {
            accept_tx: Mutex::new(accept_tx),
            chaos,
            kill: kill.clone(),
        },
        state,
        kill,
    }
}

/// [`spawn_loopback_shard`] × N: one independent executor (own accept
/// thread, buffer table, metrics, kill switch) per entry of `rts` —
/// the hermetic stand-in for N `serve-backend` hosts. For bitwise
/// losslessness across shards, every runtime must front identically
/// seeded weights.
pub fn spawn_loopback_shards(rts: Vec<Arc<Runtime>>) -> Vec<LoopbackShard> {
    rts.into_iter().map(|rt| spawn_loopback_shard(rt, None)).collect()
}

/// Back-compat single-executor spawn (no test handles).
pub fn spawn_loopback(rt: Arc<Runtime>) -> LoopbackConnector {
    spawn_loopback_shard(rt, None).connector
}

/// Like [`spawn_loopback`], with a fault injector executing `plan` on
/// every client transport (counted across reconnects).
pub fn spawn_loopback_chaos(rt: Arc<Runtime>, plan: ChaosPlan) -> LoopbackConnector {
    spawn_loopback_shard(rt, Some(plan)).connector
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffer_table_frees_by_session() {
        let t = BufferTable::new();
        let host = |v: f32| Buffer::host(crate::runtime::Tensor::scalar_f32(v));
        let a1 = t.insert(1, host(0.0), crate::runtime::DType::F32, vec![]);
        let a2 = t.insert(1, host(1.0), crate::runtime::DType::F32, vec![]);
        let b1 = t.insert(2, host(2.0), crate::runtime::DType::F32, vec![]);
        assert_eq!(t.len(), 3);
        assert_eq!(t.free_session(1), 2);
        assert!(t.get(a1.id).is_err());
        assert!(t.get(a2.id).is_err());
        assert!(t.get(b1.id).is_ok(), "other session's buffers must survive");
        assert_eq!(t.free_session(1), 0, "double-free is a no-op");
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn session_refcount_frees_only_on_last_close() {
        let s = ExecutorState::new();
        s.open_session(7);
        s.open_session(7); // reconnect overlap: two live connections
        let info = s.table.insert(
            7,
            Buffer::host(crate::runtime::Tensor::scalar_f32(0.5)),
            crate::runtime::DType::F32,
            vec![],
        );
        s.close_session(7);
        assert!(
            s.table.get(info.id).is_ok(),
            "one connection closing must not free a session with another live"
        );
        assert_eq!(s.live_sessions(), 1);
        s.close_session(7);
        assert!(s.table.get(info.id).is_err(), "last close frees the session");
        assert_eq!(s.live_sessions(), 0);
    }
}
