//! Executor-server side of the remote backend: fronts any local
//! [`crate::runtime::Backend`] (reference or PJRT) over a framed
//! transport.
//!
//! State model: one **shared buffer table** per server, not per
//! connection. Per-sequence KV handles therefore survive a client
//! reconnect — a dropped connection costs exactly the in-flight call
//! (the scheduler fails that chunk's lanes), never the KV state of
//! co-resident sequences. Ids are minted from one atomic counter, so a
//! reconnecting client can never collide with its pre-drop handles.
//!
//! Known tradeoff of that sharing: buffers are only released by client
//! free-lists, so a client that dies permanently (or a reply lost
//! after execution) leaks its entries until the executor restarts.
//! Session-scoped ownership (free-all-for-client) is deferred to the
//! sharding work that will give clients identities — see ROADMAP.
//!
//! Error discipline: a malformed or semantically invalid request gets a
//! `Reply::Err` and the connection stays up (the client surfaces it as
//! a per-call error); only transport failures tear a connection down.

use std::collections::HashMap;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::runtime::backend::{BatchItem, Buffer};
use crate::runtime::manifest::Role;
use crate::runtime::{log, Runtime};

use super::proto::{hello_json, BufInfo, LaneOut, Msg, Reply, VERSION};
use super::transport::{
    ChaosPlan, LoopbackConnector, LoopbackTransport, TcpTransport, Transport,
};

/// Server-resident buffer store: id → backend-native buffer handle.
pub struct BufferTable {
    next: AtomicU64,
    bufs: Mutex<HashMap<u64, Buffer>>,
}

impl BufferTable {
    pub fn new() -> BufferTable {
        BufferTable { next: AtomicU64::new(1), bufs: Mutex::new(HashMap::new()) }
    }

    fn insert(&self, buf: Buffer, dtype: crate::runtime::DType, shape: Vec<usize>)
        -> BufInfo
    {
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        self.bufs.lock().unwrap().insert(id, buf);
        BufInfo { id, dtype, shape }
    }

    fn get(&self, id: u64) -> Result<Buffer> {
        self.bufs
            .lock()
            .unwrap()
            .get(&id)
            .cloned()
            .with_context(|| format!("unknown buffer id {id} (freed or never allocated)"))
    }

    fn free(&self, ids: &[u64]) {
        if ids.is_empty() {
            return;
        }
        let mut bufs = self.bufs.lock().unwrap();
        for id in ids {
            bufs.remove(id);
        }
    }

    pub fn len(&self) -> usize {
        self.bufs.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for BufferTable {
    fn default() -> Self {
        Self::new()
    }
}

/// Execute one request against the fronted runtime. Pure with respect
/// to the connection: all state lives in `rt` and `table`.
fn execute(rt: &Runtime, table: &BufferTable, msg: Msg) -> Result<Reply> {
    match msg {
        Msg::Hello { version, want_manifest } => {
            anyhow::ensure!(
                version == VERSION,
                "protocol version mismatch: client {version}, server {VERSION}"
            );
            let manifest_json = want_manifest.then(|| {
                hello_json(&rt.manifest, &rt.prompts, rt.vocab.as_deref())
            });
            Ok(Reply::Hello {
                backend: rt.backend_name().to_string(),
                manifest_json,
            })
        }
        Msg::Call { artifact, frees, lanes } => {
            table.free(&frees);
            let art = rt.artifact(&artifact)?;
            let kvs: Vec<Vec<Buffer>> = lanes
                .iter()
                .map(|lane| lane.kv.iter().map(|&id| table.get(id)).collect())
                .collect::<Result<_>>()?;
            let items: Vec<BatchItem<'_>> = lanes
                .iter()
                .zip(&kvs)
                .map(|(lane, kv)| BatchItem { kv, inputs: &lane.inputs })
                .collect();
            let outs = art.call_batched(&items)?;
            let kv_ports: Vec<_> = art.spec.outputs_with_role(Role::Kv).collect();
            let lanes_out = outs
                .into_iter()
                .map(|out| LaneOut {
                    outputs: out.outputs,
                    kv: out
                        .kv
                        .into_iter()
                        .zip(&kv_ports)
                        .map(|(b, p)| table.insert(b, p.dtype, p.shape.clone()))
                        .collect(),
                })
                .collect();
            Ok(Reply::Lanes(lanes_out))
        }
        Msg::FreshKv { artifact } => {
            let art = rt.artifact(&artifact)?;
            let bufs = rt.fresh_kv(&artifact)?;
            let ports: Vec<_> = art.spec.params_with_role(Role::Kv).collect();
            Ok(Reply::Buffers(
                bufs.into_iter()
                    .zip(&ports)
                    .map(|(b, p)| table.insert(b, p.dtype, p.shape.clone()))
                    .collect(),
            ))
        }
        Msg::Upload { tensor } => {
            let dtype = tensor.dtype();
            let shape = tensor.shape.clone();
            let buf = rt.upload(&tensor)?;
            Ok(Reply::Buffers(vec![table.insert(buf, dtype, shape)]))
        }
        Msg::Download { id, dtype, shape } => {
            let buf = table.get(id)?;
            Ok(Reply::Tensor(rt.to_host(&buf, dtype, &shape)?))
        }
        Msg::SetGlobal { name, tensor } => {
            rt.set_global(&name, &tensor)?;
            Ok(Reply::Unit)
        }
        Msg::ReadGlobal { name } => Ok(Reply::Tensor(rt.read_global(&name)?)),
        Msg::ResetGlobal { name } => {
            rt.reset_global(&name)?;
            Ok(Reply::Unit)
        }
        Msg::Free { ids } => {
            table.free(&ids);
            Ok(Reply::Unit)
        }
    }
}

/// Serve one connection until the peer hangs up. Request errors are
/// answered with `Reply::Err`; only a transport failure returns.
pub fn serve_connection(
    rt: &Runtime,
    table: &BufferTable,
    transport: &mut dyn Transport,
) -> Result<()> {
    loop {
        let frame = match transport.recv() {
            Ok(f) => f,
            Err(_) => return Ok(()), // peer gone: normal teardown
        };
        let reply = match Msg::decode(&frame) {
            Ok(msg) => match execute(rt, table, msg) {
                Ok(reply) => reply,
                Err(e) => Reply::Err(format!("{e:#}")),
            },
            Err(e) => Reply::Err(format!("malformed request: {e:#}")),
        };
        transport
            .send(&reply.encode())
            .context("sending reply (client connection lost)")?;
    }
}

/// TCP executor server: accept loop, one thread + shared buffer table
/// across connections. Runs until `stop` is set (checked per accept) or
/// the listener dies. This is what `dvi serve-backend --listen` runs.
pub fn serve_tcp(
    listener: TcpListener,
    rt: Arc<Runtime>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let table = Arc::new(BufferTable::new());
    for stream in listener.incoming() {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match stream {
            Ok(stream) => {
                let peer = stream
                    .peer_addr()
                    .map(|a| a.to_string())
                    .unwrap_or_else(|_| "<unknown>".to_string());
                log::info(&format!("executor: connection from {peer}"));
                let rt = rt.clone();
                let table = table.clone();
                std::thread::Builder::new()
                    .name("dvi-executor-conn".into())
                    .spawn(move || {
                        let mut t = TcpTransport::new(stream);
                        if let Err(e) = serve_connection(&rt, &table, &mut t) {
                            log::info(&format!("executor: {peer} dropped: {e}"));
                        }
                    })?;
            }
            Err(e) => log::info(&format!("executor: accept failed: {e}")),
        }
    }
    Ok(())
}

fn spawn_loopback_inner(
    rt: Arc<Runtime>,
    chaos: Option<ChaosPlan>,
) -> LoopbackConnector {
    let (accept_tx, accept_rx) =
        std::sync::mpsc::channel::<LoopbackTransport>();
    let table = Arc::new(BufferTable::new());
    std::thread::Builder::new()
        .name("dvi-executor-loopback".into())
        .spawn(move || {
            // Accept loop ends when the connector (the only sender) is
            // dropped; per-connection threads end when their client
            // endpoint is dropped. No explicit shutdown required.
            while let Ok(mut transport) = accept_rx.recv() {
                let rt = rt.clone();
                let table = table.clone();
                let spawned = std::thread::Builder::new()
                    .name("dvi-executor-loopback-conn".into())
                    .spawn(move || {
                        let _ = serve_connection(&rt, &table, &mut transport);
                    });
                if spawned.is_err() {
                    break;
                }
            }
        })
        .expect("spawning loopback executor thread");
    LoopbackConnector { accept_tx: Mutex::new(accept_tx), chaos }
}

/// In-process executor: an accept thread fronting `rt`'s backend over
/// loopback transports. The returned connector behaves exactly like a
/// TCP connector (including reconnects after an injected failure), so
/// the hermetic test suite exercises the full remote path.
pub fn spawn_loopback(rt: Arc<Runtime>) -> LoopbackConnector {
    spawn_loopback_inner(rt, None)
}

/// Like [`spawn_loopback`], with a fault injector executing `plan` on
/// every client transport (counted across reconnects).
pub fn spawn_loopback_chaos(rt: Arc<Runtime>, plan: ChaosPlan) -> LoopbackConnector {
    spawn_loopback_inner(rt, Some(plan))
}
