//! Executor-server side of the remote backend: fronts any local
//! [`crate::runtime::Backend`] (reference or PJRT) over a framed
//! transport.
//!
//! Protocol v3 connection lifecycle: the first frame each way is the
//! **untagged** `Hello` exchange — the version check happens there,
//! in-band (a v2 peer gets a clean `Reply::Err` naming both versions,
//! because the `Hello` request layout is shared across v2/v3), and the
//! reply carries the executor's weights fingerprint. After a
//! successful handshake the transport is split: the connection thread
//! decodes **call-id-tagged** requests and executes them in arrival
//! order, handing each `(call_id, reply)` to a writer worker that
//! sends tagged replies as they complete — so reply serialization
//! overlaps the next request's execution, and a pipelining client can
//! keep several calls in flight on one connection. Errors are scoped
//! by id: a malformed or semantically invalid request gets a tagged
//! `Reply::Err` and the connection stays up; only transport failures
//! (or framing loss) tear it down.
//!
//! State model: one **shared buffer table** per server, not per
//! connection, with every entry **owned by the session** (client) that
//! allocated it. Sessions are identified by the client-minted id in the
//! `Hello` handshake and span reconnects: per-sequence KV handles
//! therefore survive a client reconnect — a dropped connection costs
//! exactly the in-flight call (the scheduler fails that chunk's lanes),
//! never the KV state of co-resident sequences. Ids are minted from one
//! atomic counter, so a reconnecting client can never collide with its
//! pre-drop handles.
//!
//! Leak discipline (the fix the ROADMAP flagged): when a session's
//! **last** connection closes, every buffer it still owns is freed —
//! a permanently dead client cannot leak executor buffer-table entries,
//! even if it never sent its piggybacked frees. The client keeps its
//! dead transport alive as a "zombie" until a replacement connection
//! has completed its handshake (see `remote/mod.rs`), so a reconnect
//! whose failure was observed client-side keeps the session's
//! live-connection count above zero and its buffers survive — the
//! deterministic case the loopback/chaos suite pins down. When the
//! *server* observes the drop first (TCP RST, partition), the session
//! ends and its buffers are freed; the reconnecting client's resident
//! sequences then fail per-call and the scheduler degrades instead of
//! wedging. Co-resident sessions are isolated: one client's death frees
//! only its own entries. A reply that fails to send also frees the
//! buffers it minted (the client can never learn their ids); the one
//! residual window is a reply the transport accepted but the client
//! never read — those orphans last until their session ends.
//!
//! Error discipline: a malformed or semantically invalid request gets a
//! `Reply::Err` and the connection stays up (the client surfaces it as
//! a per-call error); only transport failures tear a connection down.
//! A request sent before the connection's `Hello` is rejected — buffer
//! ownership needs a session before anything can allocate.

use std::collections::HashMap;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::obs::{metrics, trace};
use crate::runtime::backend::{BatchItem, Buffer};
use crate::runtime::manifest::Role;
use crate::runtime::{log, Runtime};

use super::proto::{
    self, hello_json, BufInfo, ExecMetrics, LaneOut, Msg, Reply, VERSION,
};
use super::transport::{
    ChaosPlan, KillSwitch, LoopbackConnector, LoopbackTransport, TcpTransport,
    Transport,
};

/// Server-resident buffer store: id → (owner session, backend-native
/// buffer handle).
pub struct BufferTable {
    next: AtomicU64,
    bufs: Mutex<HashMap<u64, (u64, Buffer)>>,
}

impl BufferTable {
    pub fn new() -> BufferTable {
        BufferTable { next: AtomicU64::new(1), bufs: Mutex::new(HashMap::new()) }
    }

    fn insert(
        &self,
        owner: u64,
        buf: Buffer,
        dtype: crate::runtime::DType,
        shape: Vec<usize>,
    ) -> BufInfo {
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        self.bufs.lock().unwrap().insert(id, (owner, buf));
        BufInfo { id, dtype, shape }
    }

    fn get(&self, id: u64) -> Result<Buffer> {
        self.bufs
            .lock()
            .unwrap()
            .get(&id)
            .map(|(_, b)| b.clone())
            .with_context(|| format!("unknown buffer id {id} (freed or never allocated)"))
    }

    fn free(&self, ids: &[u64]) {
        if ids.is_empty() {
            return;
        }
        let mut bufs = self.bufs.lock().unwrap();
        for id in ids {
            bufs.remove(id);
        }
    }

    /// Drop every entry owned by `session`; returns how many were freed.
    fn free_session(&self, session: u64) -> usize {
        let mut bufs = self.bufs.lock().unwrap();
        let before = bufs.len();
        bufs.retain(|_, (owner, _)| *owner != session);
        before - bufs.len()
    }

    pub fn len(&self) -> usize {
        self.bufs.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for BufferTable {
    fn default() -> Self {
        Self::new()
    }
}

/// Executor-lifetime serving counters behind the `Metrics` message.
#[derive(Default)]
pub struct ExecStats {
    /// `Call` requests served successfully.
    pub calls: AtomicU64,
    /// Lanes carried by those calls.
    pub lanes: AtomicU64,
}

/// Everything one executor server shares across its connections.
pub struct ExecutorState {
    pub table: BufferTable,
    pub stats: ExecStats,
    /// session id → live connection count. A session leaves the map
    /// (and its buffers are freed) when its last connection closes.
    sessions: Mutex<HashMap<u64, usize>>,
}

impl ExecutorState {
    pub fn new() -> ExecutorState {
        ExecutorState {
            table: BufferTable::new(),
            stats: ExecStats::default(),
            sessions: Mutex::new(HashMap::new()),
        }
    }

    pub fn live_sessions(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }

    fn open_session(&self, session: u64) {
        *self.sessions.lock().unwrap().entry(session).or_insert(0) += 1;
    }

    /// Close one connection of `session`; frees its buffers when this
    /// was the last.
    fn close_session(&self, session: u64) {
        let mut sessions = self.sessions.lock().unwrap();
        let last = match sessions.get_mut(&session) {
            Some(n) if *n > 1 => {
                *n -= 1;
                false
            }
            Some(_) => {
                sessions.remove(&session);
                true
            }
            None => false,
        };
        drop(sessions);
        if last {
            let freed = self.table.free_session(session);
            if freed > 0 {
                log::debug(&format!(
                    "executor: session {session:#x} ended; freed {freed} \
                     orphaned buffers"
                ));
            }
        }
    }

    fn metrics(&self) -> ExecMetrics {
        // `inflight` / `max_inflight` stay default (0): the window is a
        // client-connection property the client overlays after decode.
        ExecMetrics {
            calls: self.stats.calls.load(Ordering::Relaxed),
            lanes: self.stats.lanes.load(Ordering::Relaxed),
            buffers: self.table.len() as u64,
            sessions: self.live_sessions() as u64,
            ..ExecMetrics::default()
        }
    }
}

impl Default for ExecutorState {
    fn default() -> Self {
        Self::new()
    }
}

/// Build the handshake reply: backend name, (optionally) the manifest
/// document, and the fingerprint of the weights this executor fronts
/// (0 when the backend cannot hash them).
fn hello_reply(rt: &Runtime, want_manifest: bool) -> Reply {
    let manifest_json = want_manifest
        .then(|| hello_json(&rt.manifest, &rt.prompts, rt.vocab.as_deref()));
    Reply::Hello {
        backend: rt.backend_name().to_string(),
        manifest_json,
        weights_hash: rt.weights_fingerprint().unwrap_or(0),
    }
}

/// Wire opcode name of a request (trace/metrics label).
fn opcode(msg: &Msg) -> &'static str {
    match msg {
        Msg::Hello { .. } => "hello",
        Msg::Call { .. } => "call",
        Msg::FreshKv { .. } => "fresh_kv",
        Msg::ForkKv { .. } => "fork_kv",
        Msg::Upload { .. } => "upload",
        Msg::Download { .. } => "download",
        Msg::SetGlobal { .. } => "set_global",
        Msg::ReadGlobal { .. } => "read_global",
        Msg::ResetGlobal { .. } => "reset_global",
        Msg::Free { .. } => "free",
        Msg::Metrics => "metrics",
        Msg::ObsPull { .. } => "obs_pull",
    }
}

/// Execute one request against the fronted runtime on behalf of
/// `session`. Pure with respect to the connection: all state lives in
/// `rt` and `state`.
fn execute(
    rt: &Runtime,
    state: &ExecutorState,
    session: u64,
    msg: Msg,
) -> Result<Reply> {
    let table = &state.table;
    match msg {
        // A tagged re-Hello on an established connection is legal (and
        // answered in place); the version was already checked by the
        // untagged negotiation, but stays checked here for the tests
        // that drive `execute` directly.
        Msg::Hello { version, want_manifest, session: _ } => {
            anyhow::ensure!(
                version == VERSION,
                "protocol version mismatch: client {version}, server {VERSION}"
            );
            Ok(hello_reply(rt, want_manifest))
        }
        Msg::Call { artifact, frees, lanes } => {
            table.free(&frees);
            let art = rt.artifact(&artifact)?;
            let kvs: Vec<Vec<Buffer>> = lanes
                .iter()
                .map(|lane| lane.kv.iter().map(|&id| table.get(id)).collect())
                .collect::<Result<_>>()?;
            let items: Vec<BatchItem<'_>> = lanes
                .iter()
                .zip(&kvs)
                .map(|(lane, kv)| BatchItem { kv, inputs: &lane.inputs })
                .collect();
            let outs = art.call_batched(&items)?;
            state.stats.calls.fetch_add(1, Ordering::Relaxed);
            state.stats.lanes.fetch_add(lanes.len() as u64, Ordering::Relaxed);
            let kv_ports: Vec<_> = art.spec.outputs_with_role(Role::Kv).collect();
            let lanes_out = outs
                .into_iter()
                .map(|out| LaneOut {
                    outputs: out.outputs,
                    kv: out
                        .kv
                        .into_iter()
                        .zip(&kv_ports)
                        .map(|(b, p)| {
                            table.insert(session, b, p.dtype, p.shape.clone())
                        })
                        .collect(),
                })
                .collect();
            Ok(Reply::Lanes(lanes_out))
        }
        Msg::FreshKv { artifact } => {
            let art = rt.artifact(&artifact)?;
            let bufs = rt.fresh_kv(&artifact)?;
            let ports: Vec<_> = art.spec.params_with_role(Role::Kv).collect();
            Ok(Reply::Buffers(
                bufs.into_iter()
                    .zip(&ports)
                    .map(|(b, p)| table.insert(session, b, p.dtype, p.shape.clone()))
                    .collect(),
            ))
        }
        Msg::ForkKv { parents } => {
            // Copy-on-write alias: the child id shares the parent's
            // storage (buffers are immutable once written — every call
            // mints fresh output KV, never rewrites) but has its own
            // table entry under the caller's session, so parent and
            // child free independently. Dtype/shape echo the client's
            // request; only the id is server-minted.
            let bufs: Vec<Buffer> = parents
                .iter()
                .map(|p| table.get(p.id))
                .collect::<Result<_>>()?;
            Ok(Reply::Buffers(
                bufs.into_iter()
                    .zip(&parents)
                    .map(|(b, p)| {
                        table.insert(session, b, p.dtype, p.shape.clone())
                    })
                    .collect(),
            ))
        }
        Msg::Upload { tensor } => {
            let dtype = tensor.dtype();
            let shape = tensor.shape.clone();
            let buf = rt.upload(&tensor)?;
            Ok(Reply::Buffers(vec![table.insert(session, buf, dtype, shape)]))
        }
        Msg::Download { id, dtype, shape } => {
            let buf = table.get(id)?;
            Ok(Reply::Tensor(rt.to_host(&buf, dtype, &shape)?))
        }
        Msg::SetGlobal { name, tensor } => {
            rt.set_global(&name, &tensor)?;
            Ok(Reply::Unit)
        }
        Msg::ReadGlobal { name } => Ok(Reply::Tensor(rt.read_global(&name)?)),
        Msg::ResetGlobal { name } => {
            rt.reset_global(&name)?;
            Ok(Reply::Unit)
        }
        Msg::Free { ids } => {
            table.free(&ids);
            Ok(Reply::Unit)
        }
        Msg::Metrics => Ok(Reply::Metrics(state.metrics())),
        Msg::ObsPull { drain } => {
            // drain=false is the clock ping: the reply carries only the
            // executor's trace-epoch clock (plus the running drop
            // counter, which is free). drain=true additionally hands
            // over the buffered trace events and a metrics snapshot.
            // Draining is destructive by design — each collector pull
            // sees every event exactly once — and never *emits*, so
            // the losslessness guarantee is untouched.
            let (events, metrics_json) = if drain {
                let events: Vec<_> = trace::drain()
                    .iter()
                    .map(trace::Event::to_owned_event)
                    .collect();
                (events, metrics::global().snapshot().to_json())
            } else {
                (Vec::new(), String::new())
            };
            Ok(Reply::ObsDump {
                now_ns: trace::now_ns(),
                dropped: trace::drop_count(),
                events,
                metrics_json,
            })
        }
    }
}

/// Serve one connection until the peer hangs up.
///
/// Phase 1 (untagged): the first frame must be a `Hello`; a version
/// mismatch or a non-`Hello` first frame is answered with an untagged
/// `Reply::Err` and the connection closes — no session is opened, no
/// tagged frame is ever exchanged with an incompatible peer.
///
/// Phase 2 (tagged, pipelined): requests are decoded by call id and
/// executed in arrival order; tagged replies go through a writer worker
/// so sending overlaps the next request's execution. Request errors are
/// answered with a tagged `Reply::Err`; only transport failures (or
/// framing loss) return. On any exit, the connection is unregistered
/// from its session — and if it was the session's last, the session's
/// buffers are freed.
pub fn serve_connection(
    rt: &Runtime,
    state: &ExecutorState,
    mut transport: Box<dyn Transport>,
) -> Result<()> {
    // ---- phase 1: untagged version negotiation --------------------------
    let first = match transport.recv() {
        Ok(f) => f,
        Err(_) => return Ok(()), // peer gone before the handshake
    };
    let (version, want_manifest, session) = match Msg::decode(&first) {
        Ok(Msg::Hello { version, want_manifest, session }) => {
            (version, want_manifest, session)
        }
        Ok(_) => {
            let err =
                Reply::Err("handshake required before any other request".into());
            let _ = transport.send(&err.encode());
            return Ok(());
        }
        Err(e) => {
            let err = Reply::Err(format!("malformed handshake: {e:#}"));
            let _ = transport.send(&err.encode());
            return Ok(());
        }
    };
    if version != VERSION {
        // The Hello layout is stable across v2/v3, so a mixed-version
        // peer lands here and gets a clean in-band rejection.
        let err = Reply::Err(format!(
            "protocol version mismatch: client {version}, server {VERSION}"
        ));
        let _ = transport.send(&err.encode());
        return Ok(());
    }
    state.open_session(session);
    if let Err(e) = transport.send(&hello_reply(rt, want_manifest).encode()) {
        state.close_session(session);
        return Err(e.context("sending handshake reply"));
    }

    // ---- phase 2: pipelined tagged dispatch -----------------------------
    let halves = transport.split();
    let (mut tx, mut rx) = match halves {
        Ok(h) => h,
        Err(e) => {
            state.close_session(session);
            return Err(e.context("splitting executor transport"));
        }
    };
    // Set by the writer the moment a reply proves undeliverable, so the
    // dispatch loop stops *executing* a lost client's pipelined backlog
    // (up to a full window of requests could already be in the pipe).
    let client_lost = AtomicBool::new(false);
    let client_lost = &client_lost;
    let result = std::thread::scope(|scope| -> Result<()> {
        let (reply_tx, reply_rx) =
            std::sync::mpsc::channel::<(u64, Reply)>();
        let writer = scope.spawn(move || -> Result<()> {
            while let Ok((id, reply)) = reply_rx.recv() {
                if let Err(e) = tx.send(&reply.encode_tagged(id)) {
                    client_lost.store(true, Ordering::Relaxed);
                    // The reply never reached the client, so any buffer
                    // ids it minted are unreachable — the client can
                    // never name them in a free-list. Reclaim them (and
                    // everything queued behind them); otherwise a
                    // session that survives the reconnect (zombie-
                    // parked client) would carry the orphans until it
                    // ends.
                    free_minted(state, &reply);
                    while let Ok((_, queued)) = reply_rx.recv() {
                        free_minted(state, &queued);
                    }
                    return Err(
                        e.context("sending reply (client connection lost)")
                    );
                }
            }
            Ok(())
        });
        loop {
            let frame = match rx.recv() {
                Ok(f) => f,
                Err(_) => break, // peer gone: normal teardown
            };
            if client_lost.load(Ordering::Relaxed) {
                break; // writer hit an undeliverable reply: stop executing
            }
            let (id, reply) = match proto::untag(&frame) {
                Ok((id, payload)) => {
                    let reply = match Msg::decode(payload) {
                        Ok(msg) => {
                            // Dispatch timing is observation-only: the
                            // reply is whatever execute() produced.
                            let op = opcode(&msg);
                            let is_call = matches!(&msg, Msg::Call { .. });
                            let artifact = match (&msg, trace::enabled()) {
                                (Msg::Call { artifact, .. }, true) => {
                                    Some(artifact.clone())
                                }
                                _ => None,
                            };
                            let t0_ns = trace::now_ns();
                            let reply = match execute(rt, state, session, msg)
                            {
                                Ok(reply) => reply,
                                Err(e) => Reply::Err(format!("{e:#}")),
                            };
                            let exec_ns =
                                trace::now_ns().saturating_sub(t0_ns);
                            if is_call {
                                metrics::hist("exec.call_ns")
                                    .observe(exec_ns);
                            }
                            if trace::enabled() {
                                // The call id doubles as the cross-
                                // process correlation key: the client's
                                // `rpc.call` span for this request
                                // carries the same id, so a merged
                                // fleet trace can pair them.
                                let mut args = vec![
                                    ("op", trace::Arg::S(op.to_string())),
                                    ("id", trace::Arg::I(id as i64)),
                                ];
                                if let Some(a) = artifact {
                                    args.push((
                                        "artifact",
                                        trace::Arg::S(a),
                                    ));
                                }
                                trace::complete_with_dur(
                                    "exec", "exec", exec_ns, args,
                                );
                            }
                            reply
                        }
                        Err(e) => {
                            Reply::Err(format!("malformed request: {e:#}"))
                        }
                    };
                    (id, reply)
                }
                // An untaggable frame means framing sync is lost; no
                // later frame on this connection can be trusted.
                Err(_) => break,
            };
            if reply_tx.send((id, reply)).is_err() {
                break; // writer exited before any reply failed
            }
        }
        drop(reply_tx);
        writer.join().expect("executor writer worker panicked")
    });
    state.close_session(session);
    result
}

/// Free every server-resident buffer a reply minted (fresh KV outputs,
/// fresh_kv allocations, uploads) — used when the reply could not be
/// delivered, making those ids permanently unreachable from the client.
fn free_minted(state: &ExecutorState, reply: &Reply) {
    let ids: Vec<u64> = match reply {
        Reply::Lanes(lanes) => {
            lanes.iter().flat_map(|l| l.kv.iter().map(|b| b.id)).collect()
        }
        Reply::Buffers(bs) => bs.iter().map(|b| b.id).collect(),
        _ => return,
    };
    state.table.free(&ids);
}

/// TCP executor server: accept loop, one thread + shared
/// [`ExecutorState`] across connections. Runs until `stop` is set
/// (checked per accept) or the listener dies. This is what
/// `dvi serve-backend --listen` runs.
pub fn serve_tcp(
    listener: TcpListener,
    rt: Arc<Runtime>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let state = Arc::new(ExecutorState::new());
    for stream in listener.incoming() {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match stream {
            Ok(stream) => {
                let peer = stream
                    .peer_addr()
                    .map(|a| a.to_string())
                    .unwrap_or_else(|_| "<unknown>".to_string());
                log::info(&format!("executor: connection from {peer}"));
                let rt = rt.clone();
                let state = state.clone();
                std::thread::Builder::new()
                    .name("dvi-executor-conn".into())
                    .spawn(move || {
                        let t = Box::new(TcpTransport::new(stream));
                        if let Err(e) = serve_connection(&rt, &state, t) {
                            log::info(&format!("executor: {peer} dropped: {e}"));
                        }
                    })?;
            }
            Err(e) => log::info(&format!("executor: accept failed: {e}")),
        }
    }
    Ok(())
}

/// One in-process executor with the handles tests need: the connector
/// (clone it per client), the shared state (buffer table / metrics for
/// leak assertions), and the kill switch that simulates the executor
/// dying permanently.
pub struct LoopbackShard {
    pub connector: LoopbackConnector,
    pub state: Arc<ExecutorState>,
    pub kill: KillSwitch,
}

/// In-process executor: an accept thread fronting `rt`'s backend over
/// loopback transports, with optional per-transport fault injection.
/// The returned connector behaves exactly like a TCP connector
/// (including reconnects after an injected failure), so the hermetic
/// test suite exercises the full remote path.
pub fn spawn_loopback_shard(
    rt: Arc<Runtime>,
    chaos: Option<ChaosPlan>,
) -> LoopbackShard {
    let (accept_tx, accept_rx) =
        std::sync::mpsc::channel::<LoopbackTransport>();
    let state = Arc::new(ExecutorState::new());
    let conn_state = state.clone();
    std::thread::Builder::new()
        .name("dvi-executor-loopback".into())
        .spawn(move || {
            // Accept loop ends when every connector clone (the only
            // senders) is dropped; per-connection threads end when their
            // client endpoint is dropped. No explicit shutdown required.
            while let Ok(transport) = accept_rx.recv() {
                let rt = rt.clone();
                let state = conn_state.clone();
                let spawned = std::thread::Builder::new()
                    .name("dvi-executor-loopback-conn".into())
                    .spawn(move || {
                        let _ =
                            serve_connection(&rt, &state, Box::new(transport));
                    });
                if spawned.is_err() {
                    break;
                }
            }
        })
        .expect("spawning loopback executor thread");
    let kill = KillSwitch::new();
    LoopbackShard {
        connector: LoopbackConnector {
            accept_tx: Mutex::new(accept_tx),
            chaos,
            kill: kill.clone(),
        },
        state,
        kill,
    }
}

/// [`spawn_loopback_shard`] × N: one independent executor (own accept
/// thread, buffer table, metrics, kill switch) per entry of `rts` —
/// the hermetic stand-in for N `serve-backend` hosts. For bitwise
/// losslessness across shards, every runtime must front identically
/// seeded weights.
pub fn spawn_loopback_shards(rts: Vec<Arc<Runtime>>) -> Vec<LoopbackShard> {
    rts.into_iter().map(|rt| spawn_loopback_shard(rt, None)).collect()
}

/// Back-compat single-executor spawn (no test handles).
pub fn spawn_loopback(rt: Arc<Runtime>) -> LoopbackConnector {
    spawn_loopback_shard(rt, None).connector
}

/// Like [`spawn_loopback`], with a fault injector executing `plan` on
/// every client transport (counted across reconnects).
pub fn spawn_loopback_chaos(rt: Arc<Runtime>, plan: ChaosPlan) -> LoopbackConnector {
    spawn_loopback_shard(rt, Some(plan)).connector
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffer_table_frees_by_session() {
        let t = BufferTable::new();
        let host = |v: f32| Buffer::host(crate::runtime::Tensor::scalar_f32(v));
        let a1 = t.insert(1, host(0.0), crate::runtime::DType::F32, vec![]);
        let a2 = t.insert(1, host(1.0), crate::runtime::DType::F32, vec![]);
        let b1 = t.insert(2, host(2.0), crate::runtime::DType::F32, vec![]);
        assert_eq!(t.len(), 3);
        assert_eq!(t.free_session(1), 2);
        assert!(t.get(a1.id).is_err());
        assert!(t.get(a2.id).is_err());
        assert!(t.get(b1.id).is_ok(), "other session's buffers must survive");
        assert_eq!(t.free_session(1), 0, "double-free is a no-op");
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn session_refcount_frees_only_on_last_close() {
        let s = ExecutorState::new();
        s.open_session(7);
        s.open_session(7); // reconnect overlap: two live connections
        let info = s.table.insert(
            7,
            Buffer::host(crate::runtime::Tensor::scalar_f32(0.5)),
            crate::runtime::DType::F32,
            vec![],
        );
        s.close_session(7);
        assert!(
            s.table.get(info.id).is_ok(),
            "one connection closing must not free a session with another live"
        );
        assert_eq!(s.live_sessions(), 1);
        s.close_session(7);
        assert!(s.table.get(info.id).is_err(), "last close frees the session");
        assert_eq!(s.live_sessions(), 0);
    }
}
