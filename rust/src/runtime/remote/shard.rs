//! Sharded remote client: one [`crate::runtime::Backend`] fronting N
//! `serve-backend` executors, so batched serving fans out across
//! machines without the scheduler, engines, or learner changing.
//!
//! ## Placement: KV stays put
//!
//! Per-sequence KV is server-resident, so the unit of placement is the
//! sequence: [`shard_for_key`] maps a sequence's placement key to one
//! shard, *all* of its KV allocations land there
//! ([`crate::runtime::Backend::fresh_kv_keyed`] — the seq machines pass
//! one key for both their shallow and deep KV sets), and every handle
//! carries its owning shard ([`RemoteHandle::shard`]), which descendant
//! handles inherit because a lane's reply is minted by the shard that
//! executed it. A sequence's state therefore **never migrates**: the
//! mapping is a pure function of the key, and reconnects re-dial the
//! same shard (`tests/sched.rs` property-tests this under transport
//! chaos). Sequential keys round-robin, so offered load balances.
//!
//! With the scheduler's prefix cache enabled, placement becomes
//! **affinity-aware**: a cached-prefix hit forks its KV on the shard
//! already holding the prefix (`fork_kv` routes to the parents' shard —
//! an alias cannot live anywhere else), and cache misses consult
//! `kv_placement_hint` (least-loaded shard by buffer-table size,
//! deterministic lowest-index tiebreak) instead of pure round-robin;
//! any metrics failure falls back to sequential keying.
//!
//! ## Execution: split, submit, drain
//!
//! A batched call is split by the shard of each lane's KV and the
//! per-shard sub-calls are **submitted without waiting** onto each
//! shard's pipelined connection ([`RemoteBackend::submit_lanes`] — the
//! protocol-v3 mux); completion handles are then drained and replies
//! reassembled in lane order. No threads are spawned on the hot path:
//! the per-connection writer/reader worker pair is persistent, and one
//! scheduler tick can keep *every* shard's pipe full by submitting all
//! of its chunks before draining any
//! ([`crate::runtime::Backend::call_batched_submit`]). Artifacts with
//! *no* KV params (`train_step`) are **broadcast**: the call is
//! submitted to every shard concurrently, every shard must succeed, and
//! a bitwise cross-shard check on the returned outputs turns any drift
//! into a loud error instead of silent divergence. `set_global` /
//! `reset_global` broadcast the same way; `read_global` reads shard 0.
//!
//! Broadcasts are not serialized against in-flight lane calls: while
//! an update is in flight, lanes on different shards (even within one
//! chunk) may observe different global versions — the same transient
//! read-skew online training already exhibits across chunks on a
//! single executor. Every individual lane call still sees one
//! consistent snapshot, and per-shard update *order* is total (one
//! learner thread submitting to per-shard FIFO connections), so shards
//! re-converge the moment the broadcast lands; losslessness guarantees
//! are, as everywhere in this repo, stated for fixed weights.
//! Connect-time identity checking covers artifact specs, config, *and*
//! weight contents: every executor's handshake carries a fingerprint of
//! its loaded weights + initial globals, and a fleet whose fingerprints
//! differ is refused before a single lane is routed.
//!
//! ## Failure: a dead shard degrades, never wedges
//!
//! [`crate::runtime::Backend::call_batched_partial`] is the seam the
//! scheduler drives: a shard's transport failure maps to `Err` for
//! **that shard's lanes only**, which the scheduler turns into
//! `fail_lane` for those sequences while every other shard's lanes
//! commit normally — bitwise identical to an in-process run
//! (`tests/sched.rs` kills a shard mid-run and checks survivors).
//! Broadcast calls are all-or-nothing: losing a shard mid-`train_step`
//! could fork the global state, so the whole call errors and the
//! learner skips that step.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::runtime::backend::{
    Backend, BatchHandle, BatchItem, Buffer, CallOut, ExecutorStatus,
};
use crate::runtime::manifest::{ArtifactSpec, Role};
use crate::runtime::tensor::{DType, Tensor, TensorData};

use super::proto::{HelloInfo, Lane, Msg, Reply};
use super::transport::Connector;
use super::{LanesFuture, RemoteBackend, ShardObs};

/// Pure placement function: which shard owns the KV of a sequence with
/// this placement key. Deliberately the identity modulo — sequential
/// keys (what the scheduler and engines mint) round-robin into an even
/// spread, and the mapping is trivially stable across reconnects.
pub fn shard_for_key(key: u64, shards: usize) -> usize {
    debug_assert!(shards > 0);
    (key % shards.max(1) as u64) as usize
}

/// True bitwise tensor equality for the drift check: float `PartialEq`
/// would flag bitwise-identical NaNs as drift and miss a +0.0 / -0.0
/// divergence — the lockstep invariant is about bits, not float math.
fn bitwise_eq(a: &Tensor, b: &Tensor) -> bool {
    if a.shape != b.shape {
        return false;
    }
    match (&a.data, &b.data) {
        (TensorData::F32(x), TensorData::F32(y)) => {
            x.len() == y.len()
                && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
        }
        (TensorData::I32(x), TensorData::I32(y)) => x == y,
        _ => false,
    }
}

pub struct ShardedRemoteBackend {
    shards: Vec<RemoteBackend>,
    /// Placement keys for un-keyed allocations (`fresh_kv`, `upload`):
    /// sequential, so standalone allocations round-robin too.
    alloc: AtomicU64,
}

impl ShardedRemoteBackend {
    /// Dial every executor, handshake each, and verify they front the
    /// same model: artifact port layouts and config must match shard
    /// 0's ([`crate::runtime::Manifest::identity_json`] equality, which
    /// deliberately excludes per-host filesystem layout so identical
    /// fleets at different addresses pass), **and** the handshake
    /// weights fingerprints must agree — two executors with the same
    /// manifest but different weights.bin would otherwise serve
    /// divergent models undetected until a train-step drift check.
    pub fn connect(
        connectors: Vec<Box<dyn Connector>>,
    ) -> Result<(ShardedRemoteBackend, HelloInfo)> {
        ensure!(!connectors.is_empty(), "sharded backend needs >= 1 executor");
        let mut shards = Vec::with_capacity(connectors.len());
        let mut first: Option<HelloInfo> = None;
        for (i, connector) in connectors.into_iter().enumerate() {
            let endpoint = connector.endpoint();
            let (be, info) = RemoteBackend::connect_shard(connector, i as u32)
                .with_context(|| format!("connecting shard {i} ({endpoint})"))?;
            if let Some(head) = first.as_ref() {
                let a = head.manifest.identity_json().to_string();
                let b = info.manifest.identity_json().to_string();
                ensure!(
                    a == b,
                    "shard {i} ({endpoint}) serves a different manifest \
                     than shard 0 — all executors must front identical \
                     artifacts/config"
                );
                ensure!(
                    head.weights_hash == 0
                        || info.weights_hash == 0
                        || head.weights_hash == info.weights_hash,
                    "shard {i} ({endpoint}) serves different weights than \
                     shard 0 (fingerprint {:#018x} != {:#018x}) — a mixed \
                     fleet would decode divergent models; restore identical \
                     weights on every executor",
                    info.weights_hash,
                    head.weights_hash
                );
            } else {
                first = Some(info);
            }
            shards.push(be);
        }
        let info = first.expect("at least one shard connected");
        Ok((ShardedRemoteBackend { shards, alloc: AtomicU64::new(0) }, info))
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Drain every executor's trace ring and metrics snapshot, one
    /// [`ShardObs`] per shard in shard order. Sequential on purpose:
    /// each pull re-estimates that shard's clock offset with
    /// `DVI_CLOCK_PINGS` serial ping exchanges, and interleaving pings
    /// across shards would inflate every RTT (and thus every alignment
    /// uncertainty) with cross-shard queueing. Collection is a
    /// diagnostic path, not a serving path.
    pub fn obs_pull_all(&self) -> Result<Vec<ShardObs>> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, be)| {
                be.obs_pull().with_context(|| {
                    format!("draining observability from shard {i}")
                })
            })
            .collect()
    }

    /// The shard owning a lane's KV set; every buffer in the lane must
    /// agree (a sequence's KV never straddles executors).
    fn lane_shard(&self, kv: &[Buffer]) -> Result<usize> {
        let mut shard: Option<u32> = None;
        for b in kv {
            let Buffer::Remote(h) = b else {
                bail!(
                    "sharded backend received a non-remote kv buffer \
                     ({b:?}); stage it with upload() first"
                );
            };
            match shard {
                None => shard = Some(h.shard),
                Some(s) => ensure!(
                    s == h.shard,
                    "lane mixes kv buffers from shards {s} and {} — a \
                     sequence's KV must stay on one executor",
                    h.shard
                ),
            }
        }
        let s = shard.context(
            "lane has no kv buffers; stateless artifacts go through \
             broadcast call(), not lane routing",
        )? as usize;
        ensure!(
            s < self.shards.len(),
            "kv buffer names shard {s} but only {} shards are connected",
            self.shards.len()
        );
        Ok(s)
    }

    /// Broadcast a stateless (no-KV) call: submit to every shard's
    /// pipelined connection, then drain — all shards execute
    /// concurrently with no thread spawned here. Demand that all
    /// succeed, and bitwise-compare the outputs so shard drift
    /// (diverged globals, mismatched weights) fails loudly.
    fn broadcast_call(
        &self,
        spec: &ArtifactSpec,
        inputs: &[Tensor],
    ) -> Result<CallOut> {
        let futures: Vec<LanesFuture> = self
            .shards
            .iter()
            .map(|be| {
                let lane = Lane { kv: Vec::new(), inputs: inputs.to_vec() };
                be.submit_lanes(spec, vec![lane])
            })
            .collect();
        // Drain every future before error-checking: an early return
        // would drop un-waited futures, losing the free-lists their
        // calls were carrying (requeueing happens inside wait_lanes).
        let results: Vec<Result<CallOut>> = futures
            .into_iter()
            .map(|future| {
                let mut lanes = future.wait_lanes();
                debug_assert_eq!(lanes.len(), 1);
                lanes.pop().expect("single broadcast lane")
            })
            .collect();
        let mut outs: Vec<CallOut> = Vec::with_capacity(results.len());
        for (i, r) in results.into_iter().enumerate() {
            outs.push(r.with_context(|| {
                format!(
                    "{}: broadcast failed on shard {i} — global state may \
                     have forked; restore the shard or restart the fleet",
                    spec.name
                )
            })?);
        }
        let mut outs = outs.into_iter();
        let head = outs.next().expect("shard 0 result present");
        for (i, out) in outs.enumerate() {
            let same = out.outputs.len() == head.outputs.len()
                && out
                    .outputs
                    .iter()
                    .zip(&head.outputs)
                    .all(|(a, b)| bitwise_eq(a, b));
            ensure!(
                same,
                "{}: shard {} drifted from shard 0 (broadcast outputs \
                 differ bitwise) — executors are no longer in lockstep",
                spec.name,
                i + 1
            );
        }
        Ok(head)
    }

    /// Broadcast a non-`Call` request to every shard concurrently and
    /// demand unanimity; `what` labels errors.
    fn broadcast_msg(
        &self,
        msg: &Msg,
        what: &str,
    ) -> Result<Vec<Reply>> {
        let futures: Vec<_> =
            self.shards.iter().map(|be| be.submit_msg(msg)).collect();
        let mut replies = Vec::with_capacity(futures.len());
        for (i, f) in futures.into_iter().enumerate() {
            replies.push(f.wait().with_context(|| {
                format!(
                    "{what} failed on shard {i} — global state may have \
                     forked; restore the shard or restart the fleet"
                )
            })?);
        }
        Ok(replies)
    }

    /// Group lane indices by owning shard, preserving lane order within
    /// each group. A routing error (mixed/missing KV) is reported on
    /// the offending lane alone.
    fn group_lanes(
        &self,
        batch: &[BatchItem<'_>],
    ) -> (Vec<Vec<usize>>, Vec<Option<anyhow::Error>>) {
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.shards.len()];
        let mut routing_errs: Vec<Option<anyhow::Error>> =
            batch.iter().map(|_| None).collect();
        for (i, item) in batch.iter().enumerate() {
            match self.lane_shard(item.kv) {
                Ok(s) => groups[s].push(i),
                Err(e) => routing_errs[i] = Some(e),
            }
        }
        (groups, routing_errs)
    }
}

/// In-flight sharded batched call: per-shard submission futures plus
/// the lane bookkeeping to reassemble replies in lane order.
struct ShardedBatch {
    total: usize,
    /// (shard index, endpoint, lane indices, submission future).
    subs: Vec<(usize, String, Vec<usize>, LanesFuture)>,
    routing_errs: Vec<Option<anyhow::Error>>,
}

impl BatchHandle for ShardedBatch {
    fn wait(self: Box<Self>) -> Vec<Result<CallOut>> {
        let ShardedBatch { total, subs, routing_errs } = *self;
        let mut out: Vec<Option<Result<CallOut>>> =
            (0..total).map(|_| None).collect();
        for (i, e) in routing_errs.into_iter().enumerate() {
            if let Some(e) = e {
                out[i] = Some(Err(e));
            }
        }
        // Drain shard futures in submission order; each shard's reply
        // may already be in (executors finish independently — the wait
        // only blocks on the slowest shard actually needed).
        for (shard, endpoint, idxs, future) in subs {
            let lanes = future.wait_lanes();
            debug_assert_eq!(lanes.len(), idxs.len());
            for (&i, lane_out) in idxs.iter().zip(lanes) {
                out[i] = Some(lane_out.map_err(|e| {
                    // Only this shard's lanes fail; the scheduler maps
                    // them onto fail_lane while other shards' lanes
                    // commit.
                    anyhow!("shard {shard} ({endpoint}): {e:#}")
                }));
            }
        }
        out.into_iter()
            .map(|r| r.expect("every lane routed or errored"))
            .collect()
    }
}

impl Backend for ShardedRemoteBackend {
    fn name(&self) -> &'static str {
        "remote-sharded"
    }

    fn call(&self, spec: &ArtifactSpec, kv: &[Buffer], inputs: &[Tensor])
        -> Result<CallOut>
    {
        if spec.params_with_role(Role::Kv).count() == 0 {
            // Stateless (train_step): every shard applies the identical
            // deterministic update so globals stay in lockstep.
            return self.broadcast_call(spec, inputs);
        }
        let shard = self.lane_shard(kv)?;
        self.shards[shard]
            .call(spec, kv, inputs)
            .with_context(|| format!("{}: shard {shard} call failed", spec.name))
    }

    fn call_batched(
        &self,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Result<Vec<CallOut>> {
        // All-or-nothing view of the partial path: the first failing
        // lane's error surfaces; successful lanes' fresh KV handles are
        // dropped here, which queues their ids for server-side release.
        let mut outs = Vec::with_capacity(batch.len());
        for r in self.call_batched_partial(spec, batch) {
            outs.push(r?);
        }
        Ok(outs)
    }

    fn call_batched_partial(
        &self,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Vec<Result<CallOut>> {
        self.call_batched_submit(spec, batch).wait()
    }

    fn call_batched_submit(
        &self,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Box<dyn BatchHandle> {
        let (groups, routing_errs) = self.group_lanes(batch);
        // One pipelined sub-call per involved shard, all submitted
        // before any reply is awaited — every shard's pipe fills.
        let subs = groups
            .into_iter()
            .enumerate()
            .filter(|(_, idxs)| !idxs.is_empty())
            .map(|(shard, idxs)| {
                let be = &self.shards[shard];
                let lanes: Result<Vec<Lane>> = idxs
                    .iter()
                    .map(|&i| be.assemble_lane(&batch[i]))
                    .collect();
                let future = match lanes {
                    Ok(lanes) => be.submit_lanes(spec, lanes),
                    // kv_ids cannot fail here (group_lanes already
                    // routed every lane), but stay total: surface the
                    // error through the future's per-lane errs.
                    Err(e) => be.submit_lanes_poisoned(spec, idxs.len(), e),
                };
                (shard, be.endpoint(), idxs, future)
            })
            .collect();
        Box::new(ShardedBatch { total: batch.len(), subs, routing_errs })
    }

    fn fresh_kv(&self, spec: &ArtifactSpec) -> Result<Vec<Buffer>> {
        let key = self.alloc.fetch_add(1, Ordering::Relaxed);
        self.fresh_kv_keyed(spec, key)
    }

    fn fresh_kv_keyed(&self, spec: &ArtifactSpec, key: u64) -> Result<Vec<Buffer>> {
        let shard = shard_for_key(key, self.shards.len());
        self.shards[shard]
            .fresh_kv(spec)
            .with_context(|| format!("{}: fresh_kv on shard {shard}", spec.name))
    }

    fn fork_kv(&self, spec: &ArtifactSpec, parents: &[Buffer]) -> Result<Vec<Buffer>> {
        // A fork is an alias of server-resident storage, so it can only
        // live where its parents live: route to their (unanimous) shard.
        // This is what makes prefix affinity work — a cache hit pins the
        // child sequence to the shard already holding the prefix KV.
        let shard = self.lane_shard(parents)?;
        self.shards[shard]
            .fork_kv(spec, parents)
            .with_context(|| format!("{}: fork_kv on shard {shard}", spec.name))
    }

    fn kv_placement_hint(&self) -> Option<u64> {
        // Least-loaded placement for cache misses: ask every shard for
        // its buffer-table size (the count of live server-resident KV
        // buffers — the stable proxy for resident sequences) and hint
        // the emptiest shard's index, which `fresh_kv_keyed` maps back
        // via `shard_for_key(hint, n) == hint`. Deterministic tiebreak
        // (lowest index) keeps placement reproducible; any metrics
        // failure falls back to the caller's sequential keying.
        if self.shards.len() <= 1 {
            return None;
        }
        let mut best: Option<(u64, usize)> = None;
        for (i, be) in self.shards.iter().enumerate() {
            let m = be.metrics().ok()?;
            let better = match best {
                None => true,
                Some((load, _)) => m.buffers < load,
            };
            if better {
                best = Some((m.buffers, i));
            }
        }
        best.map(|(_, i)| i as u64)
    }

    fn upload(&self, t: &Tensor) -> Result<Buffer> {
        let key = self.alloc.fetch_add(1, Ordering::Relaxed);
        self.shards[shard_for_key(key, self.shards.len())].upload(t)
    }

    fn to_host(&self, b: &Buffer, dtype: DType, shape: &[usize]) -> Result<Tensor> {
        match b {
            Buffer::Remote(h) => {
                let s = h.shard as usize;
                ensure!(
                    s < self.shards.len(),
                    "buffer {h:?} names shard {s} but only {} are connected",
                    self.shards.len()
                );
                self.shards[s].to_host(b, dtype, shape)
            }
            other => bail!("to_host on a non-remote buffer {other:?}"),
        }
    }

    fn set_global(&self, name: &str, t: &Tensor) -> Result<()> {
        let msg = Msg::SetGlobal { name: name.to_string(), tensor: t.clone() };
        for reply in
            self.broadcast_msg(&msg, &format!("set_global('{name}')"))?
        {
            ensure!(
                matches!(reply, Reply::Unit),
                "unexpected reply to set_global"
            );
        }
        Ok(())
    }

    fn read_global(&self, name: &str) -> Result<Tensor> {
        // Shards are in lockstep (broadcast writes + drift checks), so
        // shard 0 speaks for the fleet.
        self.shards[0].read_global(name)
    }

    fn reset_global(&self, name: &str) -> Result<()> {
        let msg = Msg::ResetGlobal { name: name.to_string() };
        for reply in
            self.broadcast_msg(&msg, &format!("reset_global('{name}')"))?
        {
            ensure!(
                matches!(reply, Reply::Unit),
                "unexpected reply to reset_global"
            );
        }
        Ok(())
    }

    fn executor_status(&self) -> Vec<ExecutorStatus> {
        self.shards.iter().flat_map(|be| be.executor_status()).collect()
    }

    fn weights_fingerprint(&self) -> Option<u64> {
        // Connect-time checking guarantees the fleet agrees; shard 0
        // speaks for it.
        self.shards[0].weights_fingerprint()
    }

    fn obs_pull(&self) -> Result<Vec<ShardObs>> {
        self.obs_pull_all()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitwise_eq_is_about_bits_not_float_semantics() {
        let nan = Tensor::f32(vec![1], vec![f32::NAN]);
        assert!(bitwise_eq(&nan, &nan.clone()), "identical NaN bits must match");
        let pos = Tensor::f32(vec![1], vec![0.0]);
        let neg = Tensor::f32(vec![1], vec![-0.0]);
        assert!(!bitwise_eq(&pos, &neg), "+0.0 vs -0.0 is drift");
        assert!(!bitwise_eq(&pos, &Tensor::f32(vec![1, 1], vec![0.0])));
        assert!(!bitwise_eq(&pos, &Tensor::i32(vec![1], vec![0])));
    }

    #[test]
    fn shard_for_key_is_stable_and_balanced() {
        for n in 1..=4usize {
            for key in 0..32u64 {
                let a = shard_for_key(key, n);
                assert_eq!(a, shard_for_key(key, n), "placement must be pure");
                assert!(a < n);
            }
            // Sequential keys round-robin: n consecutive keys cover all
            // n shards exactly once.
            let covered: std::collections::BTreeSet<usize> =
                (0..n as u64).map(|k| shard_for_key(k, n)).collect();
            assert_eq!(covered.len(), n, "sequential keys must spread evenly");
        }
    }
}
