//! Remote-executor backend: ships batched artifact calls to a separate
//! process/host over the length-prefixed [`proto`] wire format.
//!
//! The client side ([`RemoteBackend`]) implements the full
//! [`crate::runtime::Backend`] trait, so every engine, the scheduler,
//! the router, and the online learner run unmodified against an
//! executor living across a socket. Per-sequence KV state is
//! **server-resident**: the client holds [`RemoteHandle`]s (ids), and a
//! `call_batched` ships only the small per-call inputs — the seam that
//! sharding and multi-host serving build on.
//!
//! ## Failure semantics (what the scheduler sees)
//!
//! * Execution is **at-most-once**: a call is sent exactly once; if the
//!   transport dies before the reply arrives, the call returns `Err`
//!   and is never replayed (replaying could double-apply a `train_step`
//!   global update). The scheduler maps that `Err` onto its existing
//!   per-chunk `fail_lane` path, so one dropped connection costs one
//!   chunk of lanes — never a wedged tick.
//! * Reconnect is **lazy and bounded**: the dead transport is marked
//!   unusable; the *next* call dials again (up to
//!   [`RECONNECT_ATTEMPTS`] times, with a version re-handshake). The
//!   executor's buffer table is shared across a session's connections,
//!   so surviving sequences keep their KV and decode bitwise-identically
//!   after a reconnect (`tests/remote.rs`, `tests/sched.rs`).
//! * Semantic errors (unknown artifact, bad shapes) come back as
//!   `Reply::Err` on a healthy connection and do not tear it down.
//!
//! Dropped client handles are released server-side by piggybacking a
//! free-list on the next `Call` — no per-drop round trip. Buffers are
//! additionally **session-owned**: every backend instance mints one
//! session id, presents it in every handshake, and the executor frees
//! everything the session still owns when its last connection closes —
//! so a client that dies without sending its frees cannot leak executor
//! buffer-table entries. To keep KV alive across a *reconnect* (same
//! session, new connection), the dead transport is retained as a zombie
//! until the replacement has completed its handshake — as long as the
//! *server* has not observed the old connection close, the session's
//! live-connection count never touches zero. That is deterministic for
//! client-side failures (the loopback/chaos suite, a send that errored
//! locally); if the server observed the drop first — a real TCP
//! RST/partition — the session ends, its buffers are freed, and the
//! resident sequences fail cleanly on their next call (the scheduler's
//! `fail_lane` absorbs them; serving continues). Bounded state was
//! chosen over best-effort KV survival for server-observed drops.
//!
//! [`shard::ShardedRemoteBackend`] fans the same seam out across N
//! executors; each [`RemoteHandle`] carries the shard that owns it.

pub mod proto;
pub mod server;
pub mod shard;
pub mod transport;

use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::runtime::backend::{
    Backend, BatchItem, Buffer, CallOut, ExecutorStatus,
};
use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::tensor::{DType, Tensor};

use self::proto::{BufInfo, ExecMetrics, HelloInfo, Lane, Msg, Reply, VERSION};
use self::transport::{Connector, Transport};

/// Dial attempts per call before giving up on a dead executor.
pub const RECONNECT_ATTEMPTS: u32 = 3;

/// Mint a process-unique session id: time entropy (distinct across
/// processes sharing an executor) mixed with a counter (distinct across
/// backends within one process).
fn mint_session_id() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(1);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let seq = COUNTER.fetch_add(1, Ordering::Relaxed);
    let mut z = nanos
        ^ ((std::process::id() as u64) << 32)
        ^ seq.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    // splitmix64 finalizer: spreads the low-entropy inputs.
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Client handle to a server-resident buffer. Dropping the last clone
/// queues the id for release on the next call. `shard` names the
/// executor that owns the buffer (always 0 for a single-executor
/// backend); the sharded client routes by it.
pub struct RemoteHandle {
    pub id: u64,
    pub shard: u32,
    pub dtype: DType,
    pub shape: Vec<usize>,
    freelist: Arc<Mutex<Vec<u64>>>,
}

impl Drop for RemoteHandle {
    fn drop(&mut self) {
        if let Ok(mut frees) = self.freelist.lock() {
            frees.push(self.id);
        }
    }
}

impl std::fmt::Debug for RemoteHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "remote#{}@{}{:?}", self.id, self.shard, self.shape)
    }
}

/// Connection slot: the live transport plus, during a reconnect, the
/// previous (dead) transport held as a **zombie**. Keeping the zombie
/// until a replacement connection has completed its handshake means the
/// executor never sees this session's connection count reach zero
/// mid-reconnect — so session-owned KV survives (the executor frees a
/// session's buffers only when its *last* connection closes).
#[derive(Default)]
struct ConnSlot {
    live: Option<Box<dyn Transport>>,
    zombie: Option<Box<dyn Transport>>,
}

pub struct RemoteBackend {
    connector: Box<dyn Connector>,
    /// Which shard of a sharded deployment this client is (0 standalone);
    /// stamped on every minted handle so the router can send a lane back
    /// to the executor that holds its KV.
    shard: u32,
    /// Session identity presented in every handshake; stable across
    /// reconnects, so the executor can scope buffer ownership to it.
    session: u64,
    conn: Mutex<ConnSlot>,
    freelist: Arc<Mutex<Vec<u64>>>,
}

impl RemoteBackend {
    /// Dial the executor and fetch its manifest handshake. Returns the
    /// backend plus everything needed to assemble a
    /// [`crate::runtime::Runtime`] over it.
    pub fn connect(connector: Box<dyn Connector>) -> Result<(RemoteBackend, HelloInfo)> {
        RemoteBackend::connect_shard(connector, 0)
    }

    /// [`RemoteBackend::connect`] tagging every minted handle with
    /// `shard` — used by the sharded client so buffers know which
    /// executor owns them.
    pub fn connect_shard(
        connector: Box<dyn Connector>,
        shard: u32,
    ) -> Result<(RemoteBackend, HelloInfo)> {
        let be = RemoteBackend {
            connector,
            shard,
            session: mint_session_id(),
            conn: Mutex::new(ConnSlot::default()),
            freelist: Arc::new(Mutex::new(Vec::new())),
        };
        let reply = be.roundtrip(&Msg::Hello {
            version: VERSION,
            want_manifest: true,
            session: be.session,
        })?;
        let Reply::Hello { backend, manifest_json: Some(doc) } = reply else {
            bail!("executor handshake did not include a manifest");
        };
        let info = proto::parse_hello(&be.connector.endpoint(), backend, &doc)?;
        Ok((be, info))
    }

    /// Human-readable executor address (for metrics/status lines).
    pub fn endpoint(&self) -> String {
        self.connector.endpoint()
    }

    /// Dial + version handshake (manifest skipped on reconnects).
    fn dial(&self) -> Result<Box<dyn Transport>> {
        let mut last: Option<anyhow::Error> = None;
        for _ in 0..RECONNECT_ATTEMPTS {
            let attempt = (|| -> Result<Box<dyn Transport>> {
                let mut t = self.connector.connect()?;
                let hello = Msg::Hello {
                    version: VERSION,
                    want_manifest: false,
                    session: self.session,
                };
                t.send(&hello.encode())?;
                match Reply::decode(&t.recv()?)? {
                    Reply::Hello { .. } => Ok(t),
                    Reply::Err(e) => bail!("executor rejected handshake: {e}"),
                    _ => bail!("unexpected handshake reply"),
                }
            })();
            match attempt {
                Ok(t) => return Ok(t),
                Err(e) => last = Some(e),
            }
        }
        Err(last.expect("at least one dial attempt")).with_context(|| {
            format!(
                "remote executor at {} unreachable after {RECONNECT_ATTEMPTS} attempts",
                self.connector.endpoint()
            )
        })
    }

    /// One request/response. At-most-once: a transport failure marks
    /// the connection dead and surfaces as `Err` without resending. The
    /// dead transport is parked as a zombie until the next successful
    /// dial completes its handshake, keeping the server-side session
    /// (and its buffers) alive across the gap.
    fn roundtrip(&self, msg: &Msg) -> Result<Reply> {
        let mut slot = self.conn.lock().unwrap();
        if slot.live.is_none() {
            // A dial failure keeps the zombie: the session should stay
            // open server-side while this client is alive and retrying.
            slot.live = Some(self.dial()?);
            // The replacement has handshaken (the server counted it), so
            // the old connection can close without ending the session.
            slot.zombie = None;
        }
        let t = slot.live.as_mut().expect("connection just established");
        let attempt = (|| -> Result<Reply> {
            t.send(&msg.encode())?;
            Reply::decode(&t.recv()?)
        })();
        match attempt {
            Ok(Reply::Err(e)) => bail!("remote executor: {e}"),
            Ok(reply) => Ok(reply),
            Err(e) => {
                slot.zombie = slot.live.take(); // park; next call re-dials
                Err(e.context("transport failure (connection dropped)"))
            }
        }
    }

    /// Fetch the executor's serving counters (occupancy, buffer-table
    /// size, live sessions).
    pub fn metrics(&self) -> Result<ExecMetrics> {
        match self.roundtrip(&Msg::Metrics)? {
            Reply::Metrics(m) => Ok(m),
            _ => bail!("unexpected reply to metrics"),
        }
    }

    fn drain_frees(&self) -> Vec<u64> {
        std::mem::take(&mut *self.freelist.lock().unwrap())
    }

    /// Re-queue frees whose carrying message never reached the server.
    fn requeue_frees(&self, frees: Vec<u64>) {
        if !frees.is_empty() {
            self.freelist.lock().unwrap().extend(frees);
        }
    }

    fn handle(&self, info: BufInfo) -> Buffer {
        Buffer::Remote(Arc::new(RemoteHandle {
            id: info.id,
            shard: self.shard,
            dtype: info.dtype,
            shape: info.shape,
            freelist: self.freelist.clone(),
        }))
    }

    fn kv_ids(&self, kv: &[Buffer]) -> Result<Vec<u64>> {
        kv.iter()
            .map(|b| match b {
                Buffer::Remote(h) if h.shard == self.shard => Ok(h.id),
                Buffer::Remote(h) => bail!(
                    "kv buffer {h:?} belongs to shard {}, not this \
                     executor (shard {})",
                    h.shard,
                    self.shard
                ),
                other => bail!(
                    "remote backend received a non-remote kv buffer ({other:?}); \
                     stage it with upload() first"
                ),
            })
            .collect()
    }

    /// Shared body of `call` / `call_batched`.
    fn call_lanes(&self, spec: &ArtifactSpec, lanes: Vec<Lane>) -> Result<Vec<CallOut>> {
        let n = lanes.len();
        let frees = self.drain_frees();
        let msg = Msg::Call { artifact: spec.name.clone(), frees, lanes };
        let reply = match self.roundtrip(&msg) {
            Ok(r) => r,
            Err(e) => {
                // The free-list never reached the executor; release the
                // ids with a later message instead of leaking them.
                if let Msg::Call { frees, .. } = msg {
                    self.requeue_frees(frees);
                }
                return Err(e);
            }
        };
        let Reply::Lanes(outs) = reply else {
            bail!("{}: unexpected reply to batched call", spec.name);
        };
        if outs.len() != n {
            bail!("{}: executor returned {} lanes for {n}", spec.name, outs.len());
        }
        Ok(outs
            .into_iter()
            .map(|lane| CallOut {
                outputs: lane.outputs,
                kv: lane.kv.into_iter().map(|b| self.handle(b)).collect(),
            })
            .collect())
    }
}

impl Backend for RemoteBackend {
    fn name(&self) -> &'static str {
        "remote"
    }

    fn call(&self, spec: &ArtifactSpec, kv: &[Buffer], inputs: &[Tensor])
        -> Result<CallOut>
    {
        let lane = Lane { kv: self.kv_ids(kv)?, inputs: inputs.to_vec() };
        let mut outs = self.call_lanes(spec, vec![lane])?;
        Ok(outs.pop().expect("lane count checked"))
    }

    fn call_batched(
        &self,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Result<Vec<CallOut>> {
        let lanes = batch
            .iter()
            .map(|item| {
                Ok(Lane {
                    kv: self.kv_ids(item.kv)?,
                    inputs: item.inputs.to_vec(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        self.call_lanes(spec, lanes)
    }

    fn fresh_kv(&self, spec: &ArtifactSpec) -> Result<Vec<Buffer>> {
        match self.roundtrip(&Msg::FreshKv { artifact: spec.name.clone() })? {
            Reply::Buffers(bs) => {
                Ok(bs.into_iter().map(|b| self.handle(b)).collect())
            }
            _ => bail!("{}: unexpected reply to fresh_kv", spec.name),
        }
    }

    fn upload(&self, t: &Tensor) -> Result<Buffer> {
        match self.roundtrip(&Msg::Upload { tensor: t.clone() })? {
            Reply::Buffers(mut bs) if bs.len() == 1 => {
                Ok(self.handle(bs.pop().expect("length checked")))
            }
            _ => bail!("unexpected reply to upload"),
        }
    }

    fn to_host(&self, b: &Buffer, dtype: DType, shape: &[usize]) -> Result<Tensor> {
        match b {
            Buffer::Remote(h) => {
                let msg = Msg::Download {
                    id: h.id,
                    dtype,
                    shape: shape.to_vec(),
                };
                match self.roundtrip(&msg)? {
                    Reply::Tensor(t) => Ok(t),
                    _ => bail!("unexpected reply to download"),
                }
            }
            other => bail!("to_host on a non-remote buffer {other:?}"),
        }
    }

    fn set_global(&self, name: &str, t: &Tensor) -> Result<()> {
        match self.roundtrip(&Msg::SetGlobal {
            name: name.to_string(),
            tensor: t.clone(),
        })? {
            Reply::Unit => Ok(()),
            _ => bail!("unexpected reply to set_global"),
        }
    }

    fn read_global(&self, name: &str) -> Result<Tensor> {
        match self.roundtrip(&Msg::ReadGlobal { name: name.to_string() })? {
            Reply::Tensor(t) => Ok(t),
            _ => bail!("unexpected reply to read_global"),
        }
    }

    fn reset_global(&self, name: &str) -> Result<()> {
        match self.roundtrip(&Msg::ResetGlobal { name: name.to_string() })? {
            Reply::Unit => Ok(()),
            _ => bail!("unexpected reply to reset_global"),
        }
    }

    fn executor_status(&self) -> Vec<ExecutorStatus> {
        vec![ExecutorStatus {
            shard: self.shard,
            endpoint: self.endpoint(),
            metrics: self.metrics().ok(),
        }]
    }
}
