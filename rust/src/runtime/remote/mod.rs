//! Remote-executor backend: ships batched artifact calls to a separate
//! process/host over the length-prefixed [`proto`] wire format.
//!
//! The client side ([`RemoteBackend`]) implements the full
//! [`crate::runtime::Backend`] trait, so every engine, the scheduler,
//! the router, and the online learner run unmodified against an
//! executor living across a socket. Per-sequence KV state is
//! **server-resident**: the client holds [`RemoteHandle`]s (ids), and a
//! `call_batched` ships only the small per-call inputs — the seam that
//! sharding and multi-host serving build on.
//!
//! ## Pipelining (protocol v3)
//!
//! Each connection is fronted by a [`mux::MuxConn`]: a persistent
//! writer/reader worker pair, a pending-call table keyed by **call id**,
//! and a bounded in-flight **window** ([`mux::DEFAULT_WINDOW`] calls,
//! `DVI_MUX_WINDOW` to override; 1 restores the strict request/response
//! discipline of v2). [`RemoteBackend::submit_lanes`] — surfaced
//! through [`crate::runtime::Backend::call_batched_submit`] — issues a
//! call and returns a completion handle without waiting, so independent
//! chunks overlap on one connection and a sharded tick keeps every
//! shard's pipe full. Replies are matched to callers by id and may
//! arrive out of order.
//!
//! ## Failure semantics (what the scheduler sees)
//!
//! * Execution is **at-most-once**: a call is sent exactly once; if the
//!   transport dies before the reply arrives, the call returns `Err`
//!   and is never replayed (replaying could double-apply a `train_step`
//!   global update). Under pipelining the same rule is per call: a
//!   failed send fails exactly the call it was carrying, a dead
//!   transport fails exactly the calls in flight on it, and a
//!   `Reply::Err` resolves only the call it answers. The scheduler maps
//!   each failed lane onto its existing `fail_lane` path, so one
//!   dropped connection costs its in-flight calls — never a wedged
//!   tick.
//! * Reconnect is **lazy and bounded**: the dead connection is marked
//!   unusable; the *next* call dials again (up to
//!   [`RECONNECT_ATTEMPTS`] times, with a version re-handshake that
//!   also re-checks the executor's weights fingerprint). The executor's
//!   buffer table is shared across a session's connections, so
//!   surviving sequences keep their KV and decode bitwise-identically
//!   after a reconnect (`tests/remote.rs`, `tests/sched.rs`).
//! * Semantic errors (unknown artifact, bad shapes) come back as
//!   `Reply::Err` on a healthy connection and do not tear it down.
//!
//! Dropped client handles are released server-side by piggybacking a
//! free-list on the next `Call` — no per-drop round trip. Buffers are
//! additionally **session-owned**: every backend instance mints one
//! session id, presents it in every handshake, and the executor frees
//! everything the session still owns when its last connection closes —
//! so a client that dies without sending its frees cannot leak executor
//! buffer-table entries. To keep KV alive across a *reconnect* (same
//! session, new connection), the dead connection is retained as a
//! zombie — its mux writer worker **parks** the transport's send half
//! instead of dropping it — until the replacement has completed its
//! handshake: as long as the *server* has not observed the old
//! connection close, the session's live-connection count never touches
//! zero. That is deterministic for client-side failures (the
//! loopback/chaos suite, a send that errored locally); if the server
//! observed the drop first — a real TCP RST/partition — the session
//! ends, its buffers are freed, and the resident sequences fail cleanly
//! on their next call (the scheduler's `fail_lane` absorbs them;
//! serving continues). Bounded state was chosen over best-effort KV
//! survival for server-observed drops.
//!
//! [`shard::ShardedRemoteBackend`] fans the same seam out across N
//! executors; each [`RemoteHandle`] carries the shard that owns it.

pub mod mux;
pub mod proto;
pub mod server;
pub mod shard;
pub mod transport;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::obs::{metrics, trace};
use crate::runtime::backend::{
    Backend, BatchHandle, BatchItem, Buffer, CallOut, ExecutorStatus,
    ReadyBatch,
};
use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::tensor::{DType, Tensor};

use self::mux::{env_window, CallHandle, MuxConn};
use self::proto::{BufInfo, ExecMetrics, HelloInfo, Lane, Msg, Reply, VERSION};
use self::transport::{Connector, Transport};

/// Dial attempts per call before giving up on a dead executor.
pub const RECONNECT_ATTEMPTS: u32 = 3;

/// Ping exchanges per clock-offset estimate (`DVI_CLOCK_PINGS` to
/// override). More pings tighten the bound — the estimate keeps the
/// minimum-RTT sample — at the cost of extra round trips; offsets are
/// only estimated on demand (trace collection), never on the serving
/// path.
pub const DEFAULT_CLOCK_PINGS: usize = 8;

fn env_clock_pings() -> usize {
    std::env::var("DVI_CLOCK_PINGS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(DEFAULT_CLOCK_PINGS)
}

/// Estimated alignment between this process's trace epoch and one
/// executor's, from `ObsPull` ping exchanges: `client_ts ≈ server_ts +
/// offset_ns`. Assuming a symmetric path, the server read its clock
/// somewhere inside the ping's RTT, so the midpoint estimate is wrong
/// by at most half the RTT — `uncertainty_ns`. Keeping the minimum-RTT
/// sample across pings tightens that bound without any clock-rate
/// modelling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClockOffset {
    /// Add to an executor timestamp to land on the client's epoch.
    pub offset_ns: i64,
    /// Half the best ping's RTT: the worst-case error of `offset_ns`.
    pub uncertainty_ns: u64,
}

/// One ping's estimate: the client sampled `t0`/`t1` around a reply
/// carrying the executor clock `server_ns`; the midpoint is the best
/// guess for when the server read its clock.
fn offset_sample(t0_ns: u64, server_ns: u64, t1_ns: u64) -> ClockOffset {
    let rtt = t1_ns.saturating_sub(t0_ns);
    let mid = t0_ns as i64 + (rtt / 2) as i64;
    ClockOffset {
        offset_ns: mid - server_ns as i64,
        uncertainty_ns: rtt / 2,
    }
}

/// One executor's drained observability state
/// ([`RemoteBackend::obs_pull`]): trace events still on the
/// *executor's* clock, its ring-drop counter, a metrics snapshot
/// (JSON), and the clock offset needed to align it all onto the
/// client's epoch.
pub struct ShardObs {
    pub shard: u32,
    pub endpoint: String,
    pub offset: ClockOffset,
    /// Executor-side ring overflow (events lost before the pull).
    pub dropped: u64,
    pub events: Vec<trace::OwnedEvent>,
    /// `Snapshot::to_json()` of the executor's metrics registry.
    pub metrics_json: String,
}

impl ShardObs {
    /// Package as a merged-trace process track: timestamps shifted onto
    /// the client epoch (may go negative for spans predating the
    /// client's start) and a `shard` arg injected on every event so the
    /// client/server/wire decomposition can pair `rpc.call` ↔ `exec`
    /// spans by `(shard, id)`.
    pub fn into_track(mut self) -> crate::obs::chrome::ProcessTrack {
        let shard = self.shard;
        for ev in &mut self.events {
            ev.ts_ns += self.offset.offset_ns;
            // Don't overwrite an existing tag: a loopback executor's
            // dump can carry client-side spans (shared rings) that
            // already know their true shard.
            if !ev.args.iter().any(|(k, _)| k == "shard") {
                ev.args
                    .push(("shard".to_string(), trace::Arg::I(shard as i64)));
            }
        }
        crate::obs::chrome::ProcessTrack {
            pid: crate::obs::chrome::shard_pid(shard),
            label: format!("executor s{shard} ({})", self.endpoint),
            events: self.events,
            dropped: self.dropped,
        }
    }
}

/// Mint a process-unique session id: time entropy (distinct across
/// processes sharing an executor) mixed with a counter (distinct across
/// backends within one process).
fn mint_session_id() -> u64 {
    static COUNTER: AtomicU64 = AtomicU64::new(1);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let seq = COUNTER.fetch_add(1, Ordering::Relaxed);
    let mut z = nanos
        ^ ((std::process::id() as u64) << 32)
        ^ seq.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    // splitmix64 finalizer: spreads the low-entropy inputs.
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Client handle to a server-resident buffer. Dropping the last clone
/// queues the id for release on the next call. `shard` names the
/// executor that owns the buffer (always 0 for a single-executor
/// backend); the sharded client routes by it.
pub struct RemoteHandle {
    pub id: u64,
    pub shard: u32,
    pub dtype: DType,
    pub shape: Vec<usize>,
    freelist: Arc<Mutex<Vec<u64>>>,
}

impl Drop for RemoteHandle {
    fn drop(&mut self) {
        if let Ok(mut frees) = self.freelist.lock() {
            frees.push(self.id);
        }
    }
}

impl std::fmt::Debug for RemoteHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "remote#{}@{}{:?}", self.id, self.shard, self.shape)
    }
}

/// Rehydrate a server-minted buffer descriptor into a client handle.
fn mint_handle(
    freelist: &Arc<Mutex<Vec<u64>>>,
    shard: u32,
    info: BufInfo,
) -> Buffer {
    Buffer::Remote(Arc::new(RemoteHandle {
        id: info.id,
        shard,
        dtype: info.dtype,
        shape: info.shape,
        freelist: freelist.clone(),
    }))
}

/// Map a raw mux completion onto call semantics: `Reply::Err` is a
/// semantic per-call error (connection stays up), a transport `Err`
/// already failed only the calls it belonged to.
fn finish(reply: Result<Reply>) -> Result<Reply> {
    match reply {
        Ok(Reply::Err(e)) => bail!("remote executor: {e}"),
        Ok(reply) => Ok(reply),
        Err(e) => Err(e.context("transport failure (connection dropped)")),
    }
}

/// Connection slot: the live pipelined connection plus, during a
/// reconnect, the previous (dead) one held as a **zombie**. Keeping the
/// zombie until a replacement connection has completed its handshake
/// means the executor never sees this session's connection count reach
/// zero mid-reconnect — so session-owned KV survives (the executor
/// frees a session's buffers only when its *last* connection closes).
/// The zombie's mux writer parks the transport's send half for exactly
/// this reason (see [`mux`]).
#[derive(Default)]
struct ConnSlot {
    live: Option<Arc<MuxConn>>,
    zombie: Option<Arc<MuxConn>>,
    /// Cached clock alignment for this executor (estimated on demand by
    /// [`RemoteBackend::clock_offset`]; cleared only with the slot).
    offset: Option<ClockOffset>,
}

/// Completion handle for one submitted lane call
/// ([`RemoteBackend::submit_lanes`]): owns everything needed to decode
/// the reply into [`CallOut`]s (no borrows), so callers can hold many
/// of these across shards and drain them as executors finish.
pub struct LanesFuture {
    spec_name: String,
    n: usize,
    shard: u32,
    freelist: Arc<Mutex<Vec<u64>>>,
    /// Free-list ids this call is carrying; requeued if the frame may
    /// never have reached the executor (transport failure), *not* on a
    /// semantic `Reply::Err` (the executor processed the frees).
    frees: Vec<u64>,
    sub: Result<CallHandle>,
    /// Submission timestamp (observation-only; feeds the per-shard RPC
    /// latency histogram and the `rpc.call` trace span).
    t0_ns: u64,
    /// Window occupancy at submission time (0 unless tracing is on).
    occ: u64,
}

impl LanesFuture {
    /// Block until the call resolves; per-lane results in lane order.
    pub fn wait_lanes(self) -> Vec<Result<CallOut>> {
        let LanesFuture { spec_name, n, shard, freelist, frees, sub, t0_ns, occ } =
            self;
        let all_err = |msg: String| -> Vec<Result<CallOut>> {
            // Per-shard family: `metrics::rollup_shards` re-derives the
            // fleet total as `rpc.errors.all`, so one flapping executor
            // is attributable without losing the old aggregate view.
            metrics::counter(&format!("rpc.errors.s{shard}"))
                .fetch_add(1, Ordering::Relaxed);
            (0..n).map(|_| Err(anyhow!("{spec_name}: {msg}"))).collect()
        };
        let requeue = |frees: Vec<u64>| {
            if !frees.is_empty() {
                freelist.lock().unwrap().extend(frees);
            }
        };
        let handle = match sub {
            Ok(h) => h,
            Err(e) => {
                // Never submitted: the frees never left this client.
                requeue(frees);
                return all_err(format!("{e:#}"));
            }
        };
        let call_id = handle.id();
        match handle.wait() {
            Err(e) => {
                // Transport failure: the frame may never have arrived,
                // so release the ids with a later message. (If it did
                // arrive, the re-free is an idempotent no-op.)
                requeue(frees);
                all_err(format!(
                    "{:#}",
                    e.context("transport failure (connection dropped)")
                ))
            }
            Ok(Reply::Err(e)) => all_err(format!("remote executor: {e}")),
            Ok(Reply::Lanes(outs)) => {
                // Successful calls only: failures would skew the
                // latency quantiles (they are counted in `rpc.errors`).
                let call_ns = trace::now_ns().saturating_sub(t0_ns);
                metrics::hist(&format!("rpc.{spec_name}.s{shard}_ns"))
                    .observe(call_ns);
                if trace::enabled() {
                    trace::complete_with_dur(
                        "rpc.call",
                        "rpc",
                        call_ns,
                        vec![
                            ("spec", trace::Arg::S(spec_name.clone())),
                            ("shard", trace::Arg::I(shard as i64)),
                            ("id", trace::Arg::I(call_id as i64)),
                            ("inflight", trace::Arg::I(occ as i64)),
                            ("lanes", trace::Arg::I(n as i64)),
                        ],
                    );
                }
                if outs.len() != n {
                    return all_err(format!(
                        "executor returned {} lanes for {n}",
                        outs.len()
                    ));
                }
                outs.into_iter()
                    .map(|lane| {
                        Ok(CallOut {
                            outputs: lane.outputs,
                            kv: lane
                                .kv
                                .into_iter()
                                .map(|b| mint_handle(&freelist, shard, b))
                                .collect(),
                        })
                    })
                    .collect()
            }
            Ok(_) => all_err("unexpected reply to batched call".to_string()),
        }
    }
}

impl BatchHandle for LanesFuture {
    fn wait(self: Box<Self>) -> Vec<Result<CallOut>> {
        (*self).wait_lanes()
    }
}

/// Completion handle for a submitted non-`Call` request (broadcasts,
/// metrics): resolves to the mapped reply.
pub(crate) struct MsgFuture {
    sub: Result<CallHandle>,
}

impl MsgFuture {
    pub(crate) fn wait(self) -> Result<Reply> {
        finish(self.sub?.wait())
    }
}

pub struct RemoteBackend {
    connector: Box<dyn Connector>,
    /// Which shard of a sharded deployment this client is (0 standalone);
    /// stamped on every minted handle so the router can send a lane back
    /// to the executor that holds its KV.
    shard: u32,
    /// Session identity presented in every handshake; stable across
    /// reconnects, so the executor can scope buffer ownership to it.
    session: u64,
    /// In-flight window per connection (>= 1; 1 = serial discipline).
    window: usize,
    conn: Mutex<ConnSlot>,
    freelist: Arc<Mutex<Vec<u64>>>,
    /// Executor weights fingerprint learned at connect time (0 =
    /// unknown); re-checked on every reconnect handshake so a restarted
    /// executor with different weights cannot silently resume the
    /// session.
    expected_hash: AtomicU64,
}

impl RemoteBackend {
    /// Dial the executor and fetch its manifest handshake. Returns the
    /// backend plus everything needed to assemble a
    /// [`crate::runtime::Runtime`] over it.
    pub fn connect(connector: Box<dyn Connector>) -> Result<(RemoteBackend, HelloInfo)> {
        RemoteBackend::connect_shard(connector, 0)
    }

    /// [`RemoteBackend::connect`] tagging every minted handle with
    /// `shard` — used by the sharded client so buffers know which
    /// executor owns them. The in-flight window comes from
    /// `DVI_MUX_WINDOW` (default [`mux::DEFAULT_WINDOW`]).
    pub fn connect_shard(
        connector: Box<dyn Connector>,
        shard: u32,
    ) -> Result<(RemoteBackend, HelloInfo)> {
        RemoteBackend::connect_shard_windowed(connector, shard, env_window()?)
    }

    /// [`RemoteBackend::connect_shard`] with an explicit in-flight
    /// window (benches compare serial `window = 1` against pipelined).
    pub fn connect_shard_windowed(
        connector: Box<dyn Connector>,
        shard: u32,
        window: usize,
    ) -> Result<(RemoteBackend, HelloInfo)> {
        ensure!(window >= 1, "mux window must be >= 1, got {window}");
        let be = RemoteBackend {
            connector,
            shard,
            session: mint_session_id(),
            window,
            conn: Mutex::new(ConnSlot::default()),
            freelist: Arc::new(Mutex::new(Vec::new())),
            expected_hash: AtomicU64::new(0),
        };
        let (conn, backend, manifest_json, weights_hash) =
            be.dial_handshake(true)?;
        be.conn.lock().unwrap().live = Some(Arc::new(conn));
        be.expected_hash.store(weights_hash, Ordering::Relaxed);
        let doc = manifest_json
            .context("executor handshake did not include a manifest")?;
        let mut info =
            proto::parse_hello(&be.connector.endpoint(), backend, &doc)?;
        info.weights_hash = weights_hash;
        Ok((be, info))
    }

    /// Human-readable executor address (for metrics/status lines).
    pub fn endpoint(&self) -> String {
        self.connector.endpoint()
    }

    /// Dial + untagged version handshake (manifest skipped on
    /// reconnects), then split the transport and start the mux worker
    /// pair. Also verifies the executor still fronts the weights this
    /// session first connected to.
    fn dial_handshake(
        &self,
        want_manifest: bool,
    ) -> Result<(MuxConn, String, Option<String>, u64)> {
        let hello = Msg::Hello {
            version: VERSION,
            want_manifest,
            session: self.session,
        };
        let mut last: Option<anyhow::Error> = None;
        for _ in 0..RECONNECT_ATTEMPTS {
            // Only transport-level faults (dial, send, recv, undecodable
            // reply) are retried; once the executor *answers*, its
            // verdict is final — a rejection or fingerprint mismatch
            // would only repeat, and retrying it would mislabel an
            // explicit refusal as "unreachable".
            let attempt = (|| -> Result<(Box<dyn Transport>, Reply)> {
                let mut t = self.connector.connect()?;
                t.send(&hello.encode())?;
                let reply = Reply::decode(&t.recv()?)?;
                Ok((t, reply))
            })();
            let (t, reply) = match attempt {
                Ok(x) => x,
                Err(e) => {
                    last = Some(e);
                    continue;
                }
            };
            return match reply {
                Reply::Hello { backend, manifest_json, weights_hash } => {
                    let expected = self.expected_hash.load(Ordering::Relaxed);
                    ensure!(
                        expected == 0
                            || weights_hash == 0
                            || expected == weights_hash,
                        "executor at {} now serves different weights \
                         (fingerprint {weights_hash:#018x}, session expects \
                         {expected:#018x}) — refusing to resume the session \
                         on it",
                        self.connector.endpoint()
                    );
                    let (tx, rx) = t.split()?;
                    Ok((
                        MuxConn::start(tx, rx, self.window),
                        backend,
                        manifest_json,
                        weights_hash,
                    ))
                }
                Reply::Err(e) => Err(anyhow!("executor rejected handshake: {e}")),
                _ => Err(anyhow!("unexpected handshake reply")),
            };
        }
        Err(last.expect("at least one dial attempt")).with_context(|| {
            format!(
                "remote executor at {} unreachable after {RECONNECT_ATTEMPTS} attempts",
                self.connector.endpoint()
            )
        })
    }

    /// The live pipelined connection, lazily (re)dialed. A dead
    /// connection is parked as a zombie — its parked send half keeps
    /// the server-side session alive — until the replacement has
    /// handshaken; a dial failure keeps the zombie for the next try.
    fn mux(&self) -> Result<Arc<MuxConn>> {
        let mut slot = self.conn.lock().unwrap();
        if let Some(live) = &slot.live {
            if !live.is_dead() {
                return Ok(live.clone());
            }
            slot.zombie = slot.live.take();
        }
        let (conn, _, _, _) = self.dial_handshake(false)?;
        let conn = Arc::new(conn);
        slot.live = Some(conn.clone());
        // The replacement has handshaken (the server counted it), so
        // the old connection can close without ending the session.
        slot.zombie = None;
        Ok(conn)
    }

    /// Submit one request to the pipelined connection; completion
    /// handle returned immediately. At-most-once: a failed call is
    /// never re-sent by this layer.
    fn submit(&self, msg: &Msg) -> Result<CallHandle> {
        self.mux()?.submit(msg)
    }

    /// One request/response (submission + blocking wait).
    fn roundtrip(&self, msg: &Msg) -> Result<Reply> {
        finish(self.submit(msg)?.wait())
    }

    /// Submit a non-`Call` request without waiting (the sharded client
    /// broadcasts globals updates to every shard concurrently).
    pub(crate) fn submit_msg(&self, msg: &Msg) -> MsgFuture {
        MsgFuture { sub: self.submit(msg) }
    }

    /// A [`LanesFuture`] that was never submitted: every lane resolves
    /// to `err`. Keeps submission paths total when lane assembly fails.
    pub(crate) fn submit_lanes_poisoned(
        &self,
        spec: &ArtifactSpec,
        n: usize,
        err: anyhow::Error,
    ) -> LanesFuture {
        LanesFuture {
            spec_name: spec.name.clone(),
            n,
            shard: self.shard,
            freelist: self.freelist.clone(),
            frees: Vec::new(),
            sub: Err(err),
            t0_ns: trace::now_ns(),
            occ: 0,
        }
    }

    /// Submit a lane call without waiting. The returned future owns its
    /// decode context, so many calls can be in flight per connection
    /// (bounded by the window) and across shards.
    pub fn submit_lanes(
        &self,
        spec: &ArtifactSpec,
        lanes: Vec<Lane>,
    ) -> LanesFuture {
        let n = lanes.len();
        let frees = self.drain_frees();
        let msg = Msg::Call {
            artifact: spec.name.clone(),
            frees: frees.clone(),
            lanes,
        };
        let t0_ns = trace::now_ns();
        let sub = self.submit(&msg);
        // Occupancy is a trace annotation only; skip the connection
        // lock entirely when tracing is off.
        let occ = if trace::enabled() {
            self.conn
                .lock()
                .unwrap()
                .live
                .as_ref()
                .map(|c| c.inflight())
                .unwrap_or(0)
        } else {
            0
        };
        LanesFuture {
            spec_name: spec.name.clone(),
            n,
            shard: self.shard,
            freelist: self.freelist.clone(),
            frees,
            sub,
            t0_ns,
            occ,
        }
    }

    /// Fetch the executor's serving counters (occupancy, buffer-table
    /// size, live sessions), plus this connection's realized window
    /// depth (`inflight` / `max_inflight` — client-side gauges the
    /// wire reply cannot know).
    pub fn metrics(&self) -> Result<ExecMetrics> {
        let mut m = match self.roundtrip(&Msg::Metrics)? {
            Reply::Metrics(m) => m,
            _ => bail!("unexpected reply to metrics"),
        };
        let slot = self.conn.lock().unwrap();
        if let Some(live) = &slot.live {
            m.inflight = live.inflight();
            m.max_inflight = live.max_inflight();
        }
        Ok(m)
    }

    /// The cached clock alignment for this executor, estimating it
    /// first if no estimate exists yet. Estimation costs
    /// `DVI_CLOCK_PINGS` round trips, so it runs on demand (trace
    /// collection), never on the serving path.
    pub fn clock_offset(&self) -> Result<ClockOffset> {
        if let Some(off) = self.conn.lock().unwrap().offset {
            return Ok(off);
        }
        self.estimate_clock_offset()
    }

    /// Run the ping exchanges now and cache the result, replacing any
    /// prior estimate (`dvi trace-collect` re-estimates per pull so a
    /// long-lived fleet doesn't serve stale alignments).
    pub fn estimate_clock_offset(&self) -> Result<ClockOffset> {
        let mut best: Option<ClockOffset> = None;
        for _ in 0..env_clock_pings() {
            let t0 = trace::now_ns();
            let reply = self.roundtrip(&Msg::ObsPull { drain: false })?;
            let t1 = trace::now_ns();
            let server_ns = match reply {
                Reply::ObsDump { now_ns, .. } => now_ns,
                _ => bail!("unexpected reply to clock ping"),
            };
            let est = offset_sample(t0, server_ns, t1);
            if best.map_or(true, |b| est.uncertainty_ns < b.uncertainty_ns) {
                best = Some(est);
            }
        }
        let best = best.expect("DVI_CLOCK_PINGS >= 1");
        self.conn.lock().unwrap().offset = Some(best);
        Ok(best)
    }

    /// Drain this executor's trace ring and metrics snapshot
    /// (destructive: each event is returned exactly once across pulls),
    /// re-estimating the clock alignment alongside so the events can be
    /// shifted onto the client epoch via [`ShardObs::into_track`].
    pub fn obs_pull(&self) -> Result<ShardObs> {
        let offset = self.estimate_clock_offset()?;
        match self.roundtrip(&Msg::ObsPull { drain: true })? {
            Reply::ObsDump { dropped, events, metrics_json, .. } => {
                Ok(ShardObs {
                    shard: self.shard,
                    endpoint: self.endpoint(),
                    offset,
                    dropped,
                    events,
                    metrics_json,
                })
            }
            _ => bail!("unexpected reply to obs_pull"),
        }
    }

    fn drain_frees(&self) -> Vec<u64> {
        std::mem::take(&mut *self.freelist.lock().unwrap())
    }

    fn handle(&self, info: BufInfo) -> Buffer {
        mint_handle(&self.freelist, self.shard, info)
    }

    fn kv_ids(&self, kv: &[Buffer]) -> Result<Vec<u64>> {
        kv.iter()
            .map(|b| match b {
                Buffer::Remote(h) if h.shard == self.shard => Ok(h.id),
                Buffer::Remote(h) => bail!(
                    "kv buffer {h:?} belongs to shard {}, not this \
                     executor (shard {})",
                    h.shard,
                    self.shard
                ),
                other => bail!(
                    "remote backend received a non-remote kv buffer ({other:?}); \
                     stage it with upload() first"
                ),
            })
            .collect()
    }

    /// One [`BatchItem`] as a wire lane: KV handles resolved to this
    /// executor's buffer ids plus the per-call host inputs. The single
    /// place the item→lane mapping lives (single-shard and sharded
    /// submission paths both route through it).
    pub(crate) fn assemble_lane(&self, item: &BatchItem<'_>) -> Result<Lane> {
        Ok(Lane {
            kv: self.kv_ids(item.kv)?,
            inputs: item.inputs.to_vec(),
        })
    }

    fn assemble_lanes(&self, batch: &[BatchItem<'_>]) -> Result<Vec<Lane>> {
        batch.iter().map(|item| self.assemble_lane(item)).collect()
    }

    /// Shared body of `call` / `call_batched`: submit + wait, first
    /// lane error wins.
    fn call_lanes(&self, spec: &ArtifactSpec, lanes: Vec<Lane>) -> Result<Vec<CallOut>> {
        self.submit_lanes(spec, lanes).wait_lanes().into_iter().collect()
    }
}

impl Backend for RemoteBackend {
    fn name(&self) -> &'static str {
        "remote"
    }

    fn call(&self, spec: &ArtifactSpec, kv: &[Buffer], inputs: &[Tensor])
        -> Result<CallOut>
    {
        let lane = self.assemble_lane(&BatchItem { kv, inputs })?;
        let mut outs = self.call_lanes(spec, vec![lane])?;
        Ok(outs.pop().expect("lane count checked"))
    }

    fn call_batched(
        &self,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Result<Vec<CallOut>> {
        self.call_lanes(spec, self.assemble_lanes(batch)?)
    }

    fn call_batched_partial(
        &self,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Vec<Result<CallOut>> {
        self.call_batched_submit(spec, batch).wait()
    }

    fn call_batched_submit(
        &self,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Box<dyn BatchHandle> {
        match self.assemble_lanes(batch) {
            Ok(lanes) => Box::new(self.submit_lanes(spec, lanes)),
            Err(e) => {
                let msg = format!("{e:#}");
                Box::new(ReadyBatch(
                    batch.iter().map(|_| Err(anyhow!("{msg}"))).collect(),
                ))
            }
        }
    }

    fn fresh_kv(&self, spec: &ArtifactSpec) -> Result<Vec<Buffer>> {
        match self.roundtrip(&Msg::FreshKv { artifact: spec.name.clone() })? {
            Reply::Buffers(bs) => {
                Ok(bs.into_iter().map(|b| self.handle(b)).collect())
            }
            _ => bail!("{}: unexpected reply to fresh_kv", spec.name),
        }
    }

    fn fork_kv(&self, spec: &ArtifactSpec, parents: &[Buffer]) -> Result<Vec<Buffer>> {
        // Server-side COW alias: the executor re-registers each parent
        // buffer under a fresh id owned by this session, so the child
        // outlives the parent's handle (and vice versa) without copying
        // — buffers are immutable once written. Dtype/shape travel from
        // our own handles; only the ids are server-minted.
        let infos: Vec<BufInfo> = parents
            .iter()
            .map(|b| match b {
                Buffer::Remote(h) if h.shard == self.shard => Ok(BufInfo {
                    id: h.id,
                    dtype: h.dtype,
                    shape: h.shape.clone(),
                }),
                Buffer::Remote(h) => bail!(
                    "fork_kv parent {h:?} belongs to shard {}, not this \
                     executor (shard {})",
                    h.shard,
                    self.shard
                ),
                other => bail!(
                    "fork_kv on a non-remote parent buffer ({other:?})"
                ),
            })
            .collect::<Result<_>>()?;
        match self.roundtrip(&Msg::ForkKv { parents: infos })? {
            Reply::Buffers(bs) => {
                Ok(bs.into_iter().map(|b| self.handle(b)).collect())
            }
            _ => bail!("{}: unexpected reply to fork_kv", spec.name),
        }
    }

    fn upload(&self, t: &Tensor) -> Result<Buffer> {
        match self.roundtrip(&Msg::Upload { tensor: t.clone() })? {
            Reply::Buffers(mut bs) if bs.len() == 1 => {
                Ok(self.handle(bs.pop().expect("length checked")))
            }
            _ => bail!("unexpected reply to upload"),
        }
    }

    fn to_host(&self, b: &Buffer, dtype: DType, shape: &[usize]) -> Result<Tensor> {
        match b {
            Buffer::Remote(h) => {
                let msg = Msg::Download {
                    id: h.id,
                    dtype,
                    shape: shape.to_vec(),
                };
                match self.roundtrip(&msg)? {
                    Reply::Tensor(t) => Ok(t),
                    _ => bail!("unexpected reply to download"),
                }
            }
            other => bail!("to_host on a non-remote buffer {other:?}"),
        }
    }

    fn set_global(&self, name: &str, t: &Tensor) -> Result<()> {
        match self.roundtrip(&Msg::SetGlobal {
            name: name.to_string(),
            tensor: t.clone(),
        })? {
            Reply::Unit => Ok(()),
            _ => bail!("unexpected reply to set_global"),
        }
    }

    fn read_global(&self, name: &str) -> Result<Tensor> {
        match self.roundtrip(&Msg::ReadGlobal { name: name.to_string() })? {
            Reply::Tensor(t) => Ok(t),
            _ => bail!("unexpected reply to read_global"),
        }
    }

    fn reset_global(&self, name: &str) -> Result<()> {
        match self.roundtrip(&Msg::ResetGlobal { name: name.to_string() })? {
            Reply::Unit => Ok(()),
            _ => bail!("unexpected reply to reset_global"),
        }
    }

    fn executor_status(&self) -> Vec<ExecutorStatus> {
        vec![ExecutorStatus {
            shard: self.shard,
            endpoint: self.endpoint(),
            metrics: self.metrics().ok(),
        }]
    }

    fn weights_fingerprint(&self) -> Option<u64> {
        let h = self.expected_hash.load(Ordering::Relaxed);
        (h != 0).then_some(h)
    }

    fn obs_pull(&self) -> Result<Vec<ShardObs>> {
        RemoteBackend::obs_pull(self).map(|obs| vec![obs])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offset_sample_midpoint_and_uncertainty() {
        // Client pings at t0=1000, reply lands at t1=3000 (RTT 2000),
        // server clock read 500_000: best guess is the server read its
        // clock at the midpoint 2000, so client ≈ server − 498_000,
        // wrong by at most half the RTT.
        let est = offset_sample(1000, 500_000, 3000);
        assert_eq!(est.offset_ns, 2000 - 500_000);
        assert_eq!(est.uncertainty_ns, 1000);

        // Server clock behind the client: positive offset.
        let est = offset_sample(10_000, 2_000, 10_400);
        assert_eq!(est.offset_ns, 10_200 - 2_000);
        assert_eq!(est.uncertainty_ns, 200);

        // The true offset always lies within ±uncertainty of the
        // estimate: with true offset D and server read at any point
        // s ∈ [t0, t1] on the client clock, server_ns = s − D, so
        // est = mid − s + D and |est − D| = |mid − s| ≤ RTT/2.
        let (true_offset, t0, t1) = (-7_000i64, 5_000u64, 6_000u64);
        for s in [t0, t0 + 250, t0 + 500, t1] {
            let server_ns = (s as i64 - true_offset) as u64;
            let est = offset_sample(t0, server_ns, t1);
            assert!(
                (est.offset_ns - true_offset).unsigned_abs()
                    <= est.uncertainty_ns,
                "sample at s={s} missed: est {est:?} vs true {true_offset}"
            );
        }
    }

    #[test]
    fn shard_obs_track_aligns_and_tags_events() {
        let obs = ShardObs {
            shard: 3,
            endpoint: "loopback".to_string(),
            offset: ClockOffset { offset_ns: -600, uncertainty_ns: 40 },
            dropped: 9,
            events: vec![trace::OwnedEvent {
                name: "exec".to_string(),
                cat: "exec".to_string(),
                ph: 'X',
                ts_ns: 100,
                dur_ns: 50,
                tid: 1,
                args: vec![("id".to_string(), trace::Arg::I(12))],
            }],
            metrics_json: String::new(),
        };
        let track = obs.into_track();
        assert_eq!(track.pid, crate::obs::chrome::shard_pid(3));
        assert!(track.label.contains("s3"));
        assert_eq!(track.dropped, 9);
        let ev = &track.events[0];
        assert_eq!(ev.ts_ns, -500, "alignment may shift below zero");
        assert!(
            ev.args.contains(&("shard".to_string(), trace::Arg::I(3))),
            "shard arg must be injected for decomposition pairing"
        );
    }
}
